//! Plan explorer: sweep every applicable sProgram over one model and
//! cluster size — the "which plan should I use?" workflow a SuperScaler
//! user actually runs.
//!
//! ```text
//! cargo run --release --example plan_explorer -- --model mbart --gpus 8
//! ```

use superscaler::materialize::CommMode;
use superscaler::models;
use superscaler::plans::*;
use superscaler::util::cli::Args;
use superscaler::util::table::Table;
use superscaler::util::{fmt_bytes, fmt_secs};
use superscaler::{cost::Cluster, sim};

fn main() {
    let args = Args::parse_env();
    let gpus = args.usize("gpus", 8);
    let name = args.str("model", "gpt3").to_string();
    let scale = args.usize("scale", 0);
    let batch = args.usize("batch", 16);
    let k = args.usize("micro", 4);
    let cluster = Cluster::v100(gpus);

    let build = |name: &str| -> models::Model {
        match name {
            "gpt3" => models::gpt3(scale, batch, 2048),
            "swin" => models::swin_transformer(scale, batch, 1536),
            "mbart" => models::mbart(scale, batch, 1024),
            "alphafold2" => models::alphafold2(scale, batch),
            _ => panic!("unknown model"),
        }
    };

    let mut candidates: Vec<(&str, PlanResult)> = vec![
        ("dp", data_parallel(build(&name), gpus)),
        ("tp", megatron(build(&name), 1, 1, gpus, 1, PipeOrder::OneFOneB)),
        ("1f1b", megatron(build(&name), 1, gpus, 1, k, PipeOrder::OneFOneB)),
        ("gpipe", megatron(build(&name), 1, gpus, 1, k, PipeOrder::GPipe)),
        ("zero3", zero3(build(&name), gpus, false)),
        ("zero3-offload", zero3(build(&name), gpus, true)),
        ("coshard", coshard(build(&name), gpus, 4, None)),
    ];
    if name == "mbart" {
        candidates.push(("interlaced", interlaced_pipeline(build(&name), gpus, k, true, false)));
    }
    if name == "alphafold2" {
        candidates.push(("3f1b", pipeline_3f1b(build(&name), gpus, k)));
        candidates.push(("dap+dp", dap_dp(build(&name), gpus, 1)));
    }

    let mut t = Table::new(
        &format!("{name} scale{scale} on {gpus} GPUs (batch {batch}, {k} micro-batches)"),
        &["plan", "iteration", "TFLOPS", "comm", "peak mem", "bubble%", "status"],
    );
    for (label, built) in candidates {
        match built {
            Err(e) => t.row([label.to_string(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), format!("invalid: {e}")]),
            Ok(out) => match sim::run(&out.graph, &out.schedule, &cluster, CommMode::InterRvd) {
                Err(e) => t.row([label.to_string(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into(), format!("deadlock: {e}")]),
                Ok(r) => {
                    let (_, _, bub) = r.breakdown();
                    t.row([
                        label.to_string(),
                        fmt_secs(r.makespan),
                        format!("{:.1}", r.aggregate_tflops),
                        fmt_bytes(r.comm_bytes),
                        fmt_bytes(r.max_peak_mem()),
                        format!("{:.0}%", 100.0 * bub / r.makespan.max(1e-12)),
                        if r.oom { "OOM".into() } else { "ok".to_string() },
                    ]);
                }
            },
        }
    }
    t.print();
    t.write_csv("bench_results/plan_explorer.csv").ok();
}
