//! Plan explorer: the "which plan should I use?" workflow, now powered by
//! the search engine — enumerate every applicable sProgram's feasible
//! `PlanSpec` grid for one model + cluster size, evaluate all candidates in
//! parallel, and print the ranking (best iteration time first).
//!
//! The grid includes heterogeneous per-stage pipelines (`--no-hetero` to
//! exclude them) and is dominance-pruned against the analytic cost lower
//! bound (`--no-prune` to simulate every feasible spec). `--fidelity des`
//! re-scores the top candidates (`--des-top`, default 8) with the
//! discrete-event engine so overlap-friendly pipelines rank by what they
//! actually overlap.
//!
//! ```text
//! cargo run --release --example plan_explorer -- --model mbart --gpus 8
//! cargo run --release --example plan_explorer -- --model gpt3 --gpus 8 --top 5
//! cargo run --release --example plan_explorer -- --model gpt3 --fidelity des
//! cargo run --release --example plan_explorer -- --model gpt3 --no-hetero --no-prune
//! ```

use superscaler::cost::Cluster;
use superscaler::models;
use superscaler::search::{self, SearchConfig};
use superscaler::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let gpus = args.usize("gpus", 8);
    let name = args.str("model", "gpt3").to_string();
    let scale = args.usize("scale", 0);
    let batch = args.usize("batch", 16);
    let top = args.usize("top", 0);
    if args.has("micro") {
        eprintln!("note: --micro is ignored; the search grid sweeps micro-batch counts itself");
    }
    let cluster = Cluster::v100(gpus);

    let build = || -> models::Model {
        match name.as_str() {
            "gpt3" => models::gpt3(scale, batch, 2048),
            "swin" => models::swin_transformer(scale, batch, 1536),
            "mbart" => models::mbart(scale, batch, 1024),
            "alphafold2" => models::alphafold2(scale, batch),
            other => panic!("unknown model '{other}'"),
        }
    };

    let fidelity = {
        let s = args.str("fidelity", "list");
        superscaler::search::Fidelity::parse(s).unwrap_or_else(|| {
            eprintln!("--fidelity expects 'list' or 'des', got '{s}'");
            std::process::exit(2);
        })
    };
    let cfg = SearchConfig::builder()
        .workers(args.usize("workers", 0))
        .hetero(!args.has("no-hetero"))
        .dp_min(args.usize("dp-min", 1))
        .prune(!args.has("no-prune"))
        .fidelity(fidelity)
        .des_top(args.usize("des-top", 8))
        .build();
    // One model build per run — the search borrows it for every candidate.
    let model = build();
    let report = search::search(&model, &cluster, &cfg);
    let t = report.to_table(top);
    t.print();
    t.write_csv("bench_results/plan_explorer.csv").ok();
    if let Some(best) = report.best() {
        println!("best plan: {} ({})", best.plan_name, best.spec);
    } else {
        println!("no feasible plan completed without OOM/deadlock");
    }
}
