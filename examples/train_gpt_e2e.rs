//! End-to-end driver: REAL training through all three layers.
//!
//! ```text
//! make artifacts                       # jax/pallas -> HLO text (once)
//! cargo run --release --example train_gpt_e2e -- --devices 4 --steps 100
//! ```
//!
//! The rust coordinator spawns one thread per simulated device; each loads
//! the AOT-compiled `grad_step` artifact (Pallas kernels inside a jax
//! transformer, lowered to HLO text) on its own PJRT CPU client, computes
//! gradients on its data shard, and the coordinator all-reduces the
//! gradients and applies Adam — the materialized data-parallel plan,
//! executed with real numerics. Python is never in the loop.
//!
//! The loss curve is printed and written to `bench_results/e2e_loss.csv`;
//! EXPERIMENTS.md §E2E records a reference run.

use superscaler::exec::{train_dp, Adam};
use superscaler::util::cli::Args;
use superscaler::util::table::Table;

fn main() {
    let args = Args::parse_env();
    let devices = args.usize("devices", 4);
    let steps = args.usize("steps", 100) as u64;
    let lr = args.f64("lr", 1e-2) as f32;
    let artifacts = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    if !artifacts.join("grad_step.hlo.txt").exists() {
        eprintln!("artifacts not found at {} — run `make artifacts` first", artifacts.display());
        std::process::exit(1);
    }

    println!("== e2e: data-parallel training, {devices} thread-devices, {steps} steps ==");
    let t0 = std::time::Instant::now();
    let curve = train_dp(&artifacts, devices, steps, Adam { lr, ..Default::default() }, 42, 10)
        .expect("training failed");
    let wall = t0.elapsed().as_secs_f64();

    let mut t =
        Table::new("loss curve (leader device)", &["step", "loss", "s/step", "allreduce ms"]);
    for s in curve.iter().filter(|s| s.step % 10 == 0 || s.step == 1) {
        t.row([
            s.step.to_string(),
            format!("{:.4}", s.loss),
            format!("{:.3}", s.step_time),
            format!("{:.2}", s.allreduce_time * 1e3),
        ]);
    }
    t.print();
    t.write_csv("bench_results/e2e_loss.csv").ok();

    let first = curve.first().unwrap().loss;
    let last = curve.last().unwrap().loss;
    println!(
        "\nloss {first:.4} -> {last:.4} ({:.1}% reduction) in {wall:.1}s wall",
        100.0 * (first - last) / first
    );
    if steps >= 20 {
        assert!(last < first, "loss must decrease — e2e stack is broken");
    }
    println!(
        "full three-layer stack verified: Pallas (L1) -> JAX AOT (L2) -> rust PJRT + \
         collectives (L3)"
    );
}
