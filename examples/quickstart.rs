//! Quickstart: the three-phase SuperScaler pipeline on a small GPT-3.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! 1. build a model graph (GPT-3 smallest scale, short sequence);
//! 2. express a parallelization plan with the sProgram primitives
//!    (here: Algorithm 1 data parallelism, then the paper's co-shard);
//! 3. validate the schedule, materialize communication, simulate on the
//!    modeled V100 cluster, and compare.

use superscaler::materialize::CommMode;
use superscaler::models::gpt3;
use superscaler::plans::{coshard, data_parallel};
use superscaler::util::{fmt_bytes, fmt_secs};
use superscaler::{cost::Cluster, sim};

fn main() {
    let ndev = 4;
    let cluster = Cluster::v100(ndev);

    println!("== SuperScaler quickstart: GPT-3 (1.3B config, seq 1024) on {ndev} GPUs ==\n");

    for (label, out) in [
        ("data parallel (Algorithm 1)", data_parallel(&gpt3(0, 8, 1024), ndev).unwrap()),
        ("co-shard x4 + recompute     ", coshard(&gpt3(0, 8, 1024), ndev, 4, None).unwrap()),
    ] {
        let report = sim::run(&out.graph, &out.schedule, &cluster, CommMode::InterRvd)
            .expect("schedule must validate");
        let (comp, comm, bubble) = report.breakdown();
        println!("{label}  [{}]", out.name);
        println!("  iteration {}", fmt_secs(report.makespan));
        println!(
            "  {:.1} aggregate TFLOPS | compute {} comm {} bubble {}",
            report.aggregate_tflops,
            fmt_secs(comp),
            fmt_secs(comm),
            fmt_secs(bubble)
        );
        println!(
            "  peak memory {} | traffic {}{}\n",
            fmt_bytes(report.max_peak_mem()),
            fmt_bytes(report.comm_bytes),
            if report.oom { " ** OOM **" } else { "" }
        );
    }
    println!("co-shard trades a little latency (smaller kernels + recompute) for a");
    println!("large activation-memory cut at identical communication volume -- the");
    println!("paper's Fig. 13 effect in one command.");
}
