//! Routing invariants of the fabric topology layer, and the DES-level
//! guarantees built on it: `flat` reproduces the legacy link sets bitwise,
//! fat-trees reprice cross-rack traffic through shared spine uplinks, and
//! timelines stay bitwise deterministic across worker counts.

use superscaler::cost::{Cluster, LinkId};
use superscaler::des;
use superscaler::materialize::{Plan, Task, TaskKind};
use superscaler::schedule::{DeviceId, CPU_DEVICE};
use superscaler::sim::TaskGraph;
use superscaler::topo::{build_cluster, ClusterShapeError, Topology};
use superscaler::util::prop;
use superscaler::Graph;

/// The pre-topology `group_links` arithmetic, reimplemented verbatim: the
/// oracle the flat fabric must match bitwise.
fn legacy_group_links(c: &Cluster, group: &[DeviceId]) -> Vec<LinkId> {
    let mut devs: Vec<DeviceId> = group.to_vec();
    devs.sort_unstable();
    devs.dedup();
    let mut out: Vec<LinkId> = if devs.contains(&CPU_DEVICE) {
        devs.iter().filter(|&&d| d != CPU_DEVICE).map(|&d| LinkId::Pcie(d)).collect()
    } else if devs.len() <= 1 {
        Vec::new()
    } else {
        let s0 = c.server_of(devs[0]);
        if devs.iter().all(|&d| c.server_of(d) == s0) {
            devs.iter().map(|&d| LinkId::NvLink(d)).collect()
        } else {
            let mut servers: Vec<usize> = devs.iter().map(|&d| c.server_of(d)).collect();
            servers.sort_unstable();
            servers.dedup();
            servers.into_iter().map(LinkId::Nic).collect()
        }
    };
    out.sort_unstable();
    out.dedup();
    out
}

#[test]
fn prop_flat_group_links_reproduce_legacy_bitwise() {
    prop::check("flat-group-links-legacy", 300, |g| {
        let gpus = *g.rng.choose(&[4usize, 8, 16, 32]);
        let c = Cluster::v100(gpus);
        let n = g.int(1, 9);
        let mut group: Vec<DeviceId> = (0..n).map(|_| g.int(0, gpus)).collect();
        if g.bool() {
            group.push(CPU_DEVICE);
        }
        let got = c.group_links(&group);
        let want = legacy_group_links(&c, &group);
        if got != want {
            return Err(format!("group {group:?}: {got:?} != legacy {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_every_pair_routes_and_pairwise_routes_match_flat_group_links() {
    prop::check("route-pairs-vs-group-links", 300, |g| {
        let gpus = *g.rng.choose(&[8usize, 16, 32]);
        let c = Cluster::v100(gpus);
        let a = g.int(0, gpus);
        let b = g.int(0, gpus);
        let mut route = c.topo.route(a, b);
        if a != b && route.is_empty() {
            return Err(format!("{a} -> {b} resolved no route"));
        }
        // Symmetry: the same link set both directions.
        let mut rev = c.topo.route(b, a);
        route.sort_unstable();
        rev.sort_unstable();
        if route != rev {
            return Err(format!("route {a}<->{b} asymmetric: {route:?} vs {rev:?}"));
        }
        // On a flat fabric a pair's route IS its group link set.
        route.dedup();
        let gl = c.group_links(&[a, b]);
        if route != gl {
            return Err(format!("pair ({a},{b}): route {route:?} != group_links {gl:?}"));
        }
        Ok(())
    });
}

fn p2p(id: usize, from: DeviceId, to: DeviceId, dur: f64) -> Task {
    Task {
        id,
        kind: TaskKind::P2P { from, to, bytes: 1 << 20, ptensor: 0 },
        deps: vec![],
        duration: dur,
        label: format!("x{id}").into(),
    }
}

fn des_makespan(c: &Cluster, tasks: Vec<Task>) -> f64 {
    let mut plan = Plan::default();
    plan.tasks = tasks;
    let tg = TaskGraph::of_plan(&plan);
    des::execute(&Graph::new(), &plan, c, &tg).makespan
}

#[test]
fn fat_tree_reprices_cross_rack_transfers_in_the_des_trace() {
    // 4 servers × 4 GPUs, 2 servers per rack: racks {s0,s1} and {s2,s3}.
    let fat = build_cluster(16, Some(4), "fat-tree:2", None).unwrap();
    let flat = build_cluster(16, Some(4), "flat", None).unwrap();

    // Two concurrent cross-rack transfers out of different servers: on the
    // fat-tree both routes cross Up(0) and Up(1), so each fair-shares to
    // half rate and the pair takes 2×. On the flat fabric their NIC sets
    // are disjoint and they run at full rate.
    let cross = |c: &Cluster| des_makespan(c, vec![p2p(0, 0, 8, 1.0), p2p(1, 4, 12, 1.0)]);
    assert!((cross(&flat) - 1.0).abs() < 1e-12, "flat: disjoint NICs, no contention");
    assert!((cross(&fat) - 2.0).abs() < 1e-12, "fat-tree: shared uplinks halve both");

    // The same concurrency kept inside racks touches no uplink: in-rack
    // traffic is repriced exactly like flat. This is the acceptance
    // demonstration: the fabric makes cross-rack strictly slower than
    // in-rack for otherwise identical transfers.
    let in_rack = des_makespan(&fat, vec![p2p(0, 0, 4, 1.0), p2p(1, 8, 12, 1.0)]);
    assert!((in_rack - 1.0).abs() < 1e-12, "in-rack pairs stay uncontended");
    assert!(cross(&fat) > in_rack, "cross-rack must be repriced slower than in-rack");

    // And the link sets say why.
    assert_eq!(
        fat.group_links(&[0, 8]),
        vec![LinkId::Nic(0), LinkId::Nic(2), LinkId::Up(0), LinkId::Up(1)]
    );
    assert_eq!(fat.group_links(&[0, 4]), vec![LinkId::Nic(0), LinkId::Nic(1)]);
}

#[test]
fn flat_des_timeline_is_bitwise_identical_to_legacy_cluster() {
    // A `--topology flat` cluster and the legacy constructor must produce
    // bit-identical DES timelines for the same plan.
    let legacy = Cluster::v100(16);
    let flat = build_cluster(16, None, "flat", None).unwrap();
    let tasks = |c: &Cluster| {
        let d = c.p2p_time(0, 8, 1 << 20);
        vec![p2p(0, 0, 8, d), p2p(1, 1, 9, d), p2p(2, 2, 3, d)]
    };
    let a = des_makespan(&legacy, tasks(&legacy));
    let b = des_makespan(&flat, tasks(&flat));
    assert_eq!(a.to_bits(), b.to_bits(), "flat topology must be bitwise legacy: {a} vs {b}");
}

#[test]
fn des_timelines_deterministic_across_worker_counts_under_fat_tree() {
    use superscaler::prelude::*;
    let model = superscaler::models::gpt3(0, 8, 256);
    let cluster = build_cluster(16, None, "fat-tree:1", None).unwrap();
    let run = |workers: usize| {
        let cfg = SearchConfig::builder()
            .workers(workers)
            .hetero(false)
            .max_candidates(24)
            .fidelity(Fidelity::Des)
            .des_top(4)
            .build();
        search::search(&model, &cluster, &cfg).to_table(0).render()
    };
    let one = run(1);
    let four = run(4);
    assert_eq!(one, four, "fat-tree contention must not break worker-count determinism");
}

#[test]
fn search_report_carries_the_topology_label() {
    use superscaler::prelude::*;
    let model = superscaler::models::gpt3(0, 8, 256);
    let cluster = build_cluster(8, None, "rail:2", None).unwrap();
    let cfg = SearchConfig::builder().workers(1).hetero(false).max_candidates(8).build();
    let report = search::search(&model, &cluster, &cfg);
    assert_eq!(report.topology, "rail:2");
    assert_eq!(report.gpus, 8);
}

#[test]
fn shape_errors_render_actionable_messages() {
    let cases: Vec<(ClusterShapeError, &str)> = vec![
        (build_cluster(12, None, "flat", None).unwrap_err(), "--gpus 12"),
        (build_cluster(12, Some(5), "flat", None).unwrap_err(), "--servers 5"),
        (build_cluster(32, None, "fat-tree:3", None).unwrap_err(), "rack size 3"),
        (build_cluster(16, None, "rail:3", None).unwrap_err(), "rail count 3"),
        (build_cluster(16, None, "mesh", None).unwrap_err(), "'mesh'"),
        (build_cluster(16, None, "flat", Some("a100:8")).unwrap_err(), "sum to 8"),
        (build_cluster(16, None, "flat", Some("q42:16")).unwrap_err(), "'q42:16'"),
    ];
    for (err, needle) in cases {
        let msg = err.to_string();
        assert!(msg.contains(needle), "error '{msg}' should mention '{needle}'");
    }
}

#[test]
fn scale_smoke_routing_at_1024_devices_is_allocation_free_and_total() {
    // 1024 GPUs = 128 servers × 8, 16 racks of 8: every sampled pair
    // resolves through the cached spine table with a reused buffer.
    let topo = Topology::fat_tree(128, 8, 8).unwrap();
    let mut buf = Vec::new();
    topo.route_into(0, 1023, &mut buf);
    let cap = buf.capacity();
    let mut resolved = 0usize;
    for i in 0..1024usize {
        let j = (i * 257 + 31) % 1024; // deterministic scatter across racks
        topo.route_into(i, j, &mut buf);
        if i != j {
            assert!(!buf.is_empty(), "{i} -> {j} unroutable");
            resolved += 1;
        }
    }
    assert!(resolved > 1000);
    assert_eq!(buf.capacity(), cap, "steady-state routing must not reallocate");
}
