//! The declarative plan layer end to end: registry round-trip (every
//! registered planner builds + validates on a zoo model), `PlanSpec`
//! feasibility pruning, and the search engine's determinism + quality
//! (its top plan must not lose to the hand-written megatron baseline).

use superscaler::cost::Cluster;
use superscaler::materialize::CommMode;
use superscaler::models::{self, Model};
use superscaler::plans::{self, registry, PipeOrder, PlanKind, PlanSpec, Planner};
use superscaler::schedule::validate;
use superscaler::search::{self, Infeasible, SearchConfig};
use superscaler::sim;

#[test]
fn registry_covers_every_plan_name() {
    let names: Vec<&str> = registry::all().iter().map(|p| p.name()).collect();
    for want in [
        "dp",
        "tp",
        "megatron",
        "gpipe",
        "zero3",
        "zero3-offload",
        "coshard",
        "interlaced",
        "3f1b",
        "dap",
        "hetero",
    ] {
        assert!(names.contains(&want), "registry missing '{want}' (has {names:?})");
    }
    assert_eq!(names.len(), 11);
}

#[test]
fn find_resolves_names_and_aliases() {
    assert_eq!(registry::find("megatron").unwrap().kind(), PlanKind::Megatron);
    assert_eq!(registry::find("1f1b").unwrap().kind(), PlanKind::Megatron);
    assert_eq!(registry::find("zero3-offload").unwrap().kind(), PlanKind::Zero3Offload);
    assert!(registry::find("not-a-plan").is_none());
}

/// Every registered planner must declare itself applicable to at least one
/// zoo model, build its default spec on 4 GPUs, and pass schedule
/// validation (deadlock-free, fully assigned).
#[test]
fn registry_roundtrip_every_planner_builds_and_validates() {
    let zoo: Vec<fn() -> Model> = vec![
        || models::gpt3(0, 8, 256),
        || models::mbart(0, 8, 128),
        || models::alphafold2(0, 8),
    ];
    for p in registry::all() {
        let mk = zoo
            .iter()
            .find(|mk| p.applicable(&mk()))
            .unwrap_or_else(|| panic!("planner '{}' applicable to no zoo model", p.name()));
        let spec = p.default_spec(4, 4);
        assert_eq!(spec.kind, p.kind(), "{}: default_spec kind mismatch", p.name());
        let out = p
            .build(&mk(), &spec)
            .unwrap_or_else(|e| panic!("{}: build failed: {e}", p.name()));
        assert!(!out.name.is_empty());
        let vs = validate(&out.graph, &out.schedule)
            .unwrap_or_else(|e| panic!("{}: schedule invalid: {e}", p.name()));
        assert!(!vs.topo.is_empty());
    }
}

#[test]
fn enumerate_produces_a_rich_feasible_grid() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(8);
    let (cands, _pruned) = search::enumerate(&model, &cluster);
    assert!(cands.len() >= 20, "only {} feasible candidates", cands.len());
    for (p, s) in &cands {
        assert_eq!(s.devices(), 8, "{}: {s:?} does not tile the cluster", p.name());
        assert!(s.dp <= 8, "{s:?}");
    }
    // The canonical megatron grid point the CLI defaults to must be in the
    // grid (this is what guarantees search never loses to the baseline).
    assert!(
        cands.iter().any(|(p, s)| p.name() == "megatron"
            && s.dp == 1
            && s.pp == 8
            && s.tp == 1
            && s.micro == 4),
        "megatron dp1 pp8 tp1 k4 missing from the grid"
    );
}

#[test]
fn feasibility_prunes_batch_and_memory_bounds() {
    let cluster = Cluster::v100(8);

    // dp wider than the global batch: pruned.
    let small_batch = models::gpt3(0, 2, 256);
    let dp8 = PlanSpec { dp: 8, ..PlanSpec::new(PlanKind::Dp) };
    assert!(matches!(
        search::feasibility(&dp8, &small_batch, &cluster),
        Err(Infeasible::BatchTooSmall { batch: 2, dp: 8 })
    ));
    let (cands, pruned) = search::enumerate(&small_batch, &cluster);
    assert!(pruned > 0, "batch-bound specs must be pruned");
    assert!(cands.iter().all(|(_, s)| s.dp <= 2));

    // Fully replicated 15B model: 4x weights >> 32 GiB, pruned by the cost
    // model's memory bound before anything is built.
    let giant = models::gpt3(3, 32, 1024);
    assert!(matches!(
        search::feasibility(&dp8, &giant, &cluster),
        Err(Infeasible::MemoryBound { .. })
    ));

    // Device-degree mismatch: pruned.
    let mismatch = PlanSpec { dp: 2, pp: 2, tp: 1, ..PlanSpec::new(PlanKind::Megatron) };
    assert!(matches!(
        search::feasibility(&mismatch, &small_batch, &cluster),
        Err(Infeasible::DeviceMismatch { want: 8, got: 4 })
    ));
}

#[test]
fn search_is_deterministic() {
    let cluster = Cluster::v100(4);
    let cfg = SearchConfig::builder().workers(2).build();
    let model = models::gpt3(0, 8, 256);
    let run = || search::search(&model, &cluster, &cfg);
    let a = run();
    let b = run();
    assert_eq!(a.evaluated, b.evaluated);
    assert!(a.evaluated > 0);
    let key = |r: &search::SearchReport| -> Vec<(String, String)> {
        r.ranked
            .iter()
            .map(|c| (c.planner.to_string(), c.plan_name.clone()))
            .collect()
    };
    assert_eq!(key(&a), key(&b), "same inputs must rank identically");
}

#[test]
fn search_top_plan_not_slower_than_megatron_baseline() {
    let gpus = 4;
    let cluster = Cluster::v100(gpus);
    let report =
        search::search(&models::gpt3(0, 8, 512), &cluster, &SearchConfig::default());
    let best = report.best().expect("search found no valid plan");
    let bm = best.metrics().unwrap();

    let base =
        plans::megatron(&models::gpt3(0, 8, 512), 1, gpus, 1, 4, PipeOrder::OneFOneB).unwrap();
    let rb = sim::run(&base.graph, &base.schedule, &cluster, CommMode::InterRvd).unwrap();
    assert!(
        bm.makespan <= rb.makespan * 1.0001,
        "search best {} ({}) slower than megatron baseline {}",
        bm.makespan,
        best.plan_name,
        rb.makespan
    );
}
