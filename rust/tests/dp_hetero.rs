//! The dp outer loop over replicated heterogeneous pipelines: dp >= 2
//! replicas build/validate/simulate end-to-end on small GPT-3, the search
//! enumerates them with exact `dp * sum(stage widths)` device accounting,
//! the extended space can never lose to its dp = 1 restriction, dominance
//! pruning stays sound over the three-level grid, the analytic lower bound
//! stays below every simulated dp-replicated plan, and cross-server
//! replicas synchronize gradients through the RVD decomposition rather
//! than one flat collective.

use superscaler::cost::{Cluster, ModelStats};
use superscaler::graph::CollKind;
use superscaler::materialize::{materialize, CommMode, TaskKind};
use superscaler::models;
use superscaler::plans::{hetero, registry, PlanSpec, StageSpec};
use superscaler::schedule::validate;
use superscaler::search::{self, SearchConfig};
use superscaler::sim;

#[test]
fn dp_replicated_hetero_builds_validates_and_simulates() {
    let out = hetero(
        &models::gpt3(0, 8, 256),
        2,
        2,
        &[StageSpec::tp(2), StageSpec { recompute: true, ..StageSpec::tp(2) }],
    )
    .unwrap();
    assert!(out.name.contains("dp2"), "{}", out.name);
    let vs = validate(&out.graph, &out.schedule).expect("dp hetero schedule validates");
    assert!(!vs.topo.is_empty());
    let c = Cluster::v100(8);
    let r = sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
    assert!(!r.oom);
    assert_eq!(r.per_device.len(), 8, "2 replicas x (2+2)-wide pipeline");
    assert!(r.comm_bytes > 0, "gradient sync must move bytes across replicas");
}

#[test]
fn search_enumerates_dp_replicas_with_exact_device_accounting() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(8);
    let planner = registry::find("hetero").unwrap();
    let cands = planner.candidates(&model, &cluster);
    assert!(cands.iter().any(|s| s.dp >= 2), "dp outer loop emitted no replicas");
    for s in &cands {
        let widths: usize = s.stages.as_ref().unwrap().iter().map(|st| st.width()).sum();
        assert_eq!(s.devices(), s.dp.max(1) * widths, "devices() accounting for {}", s.label());
        assert_eq!(
            search::feasibility(s, &model, &cluster),
            Ok(()),
            "planner emitted an infeasible spec: {}",
            s.label()
        );
    }
    // And the full engine-level enumeration keeps the same invariant.
    let (feasible, _) = search::enumerate(&model, &cluster);
    assert!(feasible
        .iter()
        .any(|(p, s)| p.name() == "hetero" && s.dp >= 2 && s.devices() == 8));
}

/// The dp >= 1 heterogeneous space strictly contains its dp = 1
/// restriction, so the extended search's hetero optimum can never be worse
/// than the dp = 1 hetero optimum under the list simulator.
#[test]
fn dp_space_optimum_no_worse_than_dp1_restriction() {
    let cluster = Cluster::v100(4);
    let report = search::search(
        &models::gpt3(0, 8, 256),
        &cluster,
        &SearchConfig::builder().workers(2).prune(false).build(),
    );
    let best_hetero = |pred: &dyn Fn(&PlanSpec) -> bool| {
        report
            .ranked
            .iter()
            .filter(|c| c.planner == "hetero" && pred(&c.spec))
            .filter_map(|c| c.metrics().filter(|m| !m.oom).map(|m| m.makespan))
            .fold(f64::INFINITY, f64::min)
    };
    let any_dp = best_hetero(&|_| true);
    let dp1 = best_hetero(&|s| s.dp <= 1);
    assert!(any_dp.is_finite(), "no hetero candidate simulated");
    assert!(dp1.is_finite(), "no dp = 1 hetero candidate simulated");
    assert!(any_dp <= dp1, "extended space lost to its restriction: {any_dp} vs {dp1}");
    // The replicated region was actually explored, not vacuously absent.
    assert!(
        report.ranked.iter().any(|c| c.planner == "hetero" && c.spec.dp >= 2),
        "no dp >= 2 hetero candidate reached evaluation"
    );
}

/// Dominance pruning must stay sound over the three-level grid: prune-on
/// and prune-off searches (which now include dp-replicated hetero specs)
/// agree on the optimum, with consistent accounting.
#[test]
fn prune_on_off_agree_over_dp_grid() {
    let cluster = Cluster::v100(4);
    let model = models::gpt3(0, 8, 256);
    let on =
        search::search(&model, &cluster, &SearchConfig::builder().workers(2).prune(true).build());
    let off =
        search::search(&model, &cluster, &SearchConfig::builder().workers(2).prune(false).build());
    assert_eq!(on.evaluated + on.pruned_bound, off.evaluated);
    let (tb, tf) = (on.best().unwrap(), off.best().unwrap());
    let (mb, mf) = (tb.metrics().unwrap().makespan, tf.metrics().unwrap().makespan);
    let rel = (mb - mf).abs() / mf.max(1e-12);
    assert!(
        rel < 1e-4,
        "prune-on best {mb} ({}) vs prune-off {mf} ({})",
        tb.plan_name,
        tf.plan_name
    );
}

/// `--dp-min` restricts the grid to replicated plans and still finds one.
#[test]
fn dp_min_restricts_the_grid_to_replicated_plans() {
    let cluster = Cluster::v100(4);
    let report = search::search(
        &models::gpt3(0, 8, 256),
        &cluster,
        &SearchConfig::builder().workers(2).dp_min(2).build(),
    );
    assert!(!report.ranked.is_empty());
    assert!(report.ranked.iter().all(|c| c.spec.dp >= 2), "dp < 2 spec leaked through --dp-min");
    assert!(report.best().is_some(), "replicated-only search found no plan");
    assert!(report.excluded > 0, "dp-filtered specs must be accounted as excluded");
    // Config exclusions are reported apart from infeasibility, and the
    // rendered accounting carries them.
    assert!(report.to_table(1).title.contains("dp-excluded"));
}

/// The analytic lower bound must stay below the simulated time of every
/// dp-replicated hetero plan it prunes against.
#[test]
fn lower_bound_sound_for_dp_hetero_plans() {
    let cases: [(usize, Vec<StageSpec>, usize, usize); 3] = [
        (2, vec![StageSpec::tp(2), StageSpec::tp(2)], 2, 8),
        (2, vec![StageSpec::tp(1), StageSpec::tp(1)], 4, 4),
        (4, vec![StageSpec::tp(2), StageSpec::tp(2)], 2, 16),
    ];
    let stats = ModelStats::of(&models::gpt3(0, 8, 256).graph);
    for (dp, stages, micro, gpus) in cases {
        let c = Cluster::v100(gpus);
        let spec = PlanSpec::hetero_dp(dp, stages.clone(), micro);
        let out = registry::build("hetero", &models::gpt3(0, 8, 256), &spec).unwrap();
        let r = sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        let lb = c.plan_time_lower_bound(&spec, &stats);
        assert!(lb > 0.0);
        assert!(lb <= r.makespan, "{}: bound {lb} > simulated {}", spec.label(), r.makespan);
    }
}

/// Cross-server dp replicas synchronize gradients through the RVD
/// decomposition: reduce-scatter within servers, all-reduce across,
/// all-gather back — visible as distinct collective tasks. Same-server
/// replicas keep the flat all-reduce.
#[test]
fn dp_grad_sync_rvd_decomposes_across_servers_only() {
    // dp = 4 over 16 GPUs: replicas 0,1 on server 0, replicas 2,3 on
    // server 1, so every gradient's dp group has two members per server.
    let out =
        hetero(&models::gpt3(0, 8, 256), 4, 2, &[StageSpec::tp(2), StageSpec::tp(2)]).unwrap();
    let c = Cluster::v100(16);
    let vs = validate(&out.graph, &out.schedule).unwrap();
    let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
    let sync: Vec<_> = plan.tasks.iter().filter(|t| t.label.starts_with("dp-sync")).collect();
    assert!(!sync.is_empty(), "cross-server gradient sync must decompose");
    let has_kind = |k: CollKind| {
        sync.iter().any(|t| matches!(&t.kind, TaskKind::Collective { kind, .. } if *kind == k))
    };
    assert!(has_kind(CollKind::ReduceScatter), "missing intra-server reduce-scatter");
    assert!(has_kind(CollKind::AllReduce), "missing cross-server all-reduce");
    assert!(has_kind(CollKind::AllGather), "missing intra-server all-gather");
    // Same-server replicas (dp = 2 on one 8-GPU server): flat form.
    let out =
        hetero(&models::gpt3(0, 8, 256), 2, 2, &[StageSpec::tp(2), StageSpec::tp(2)]).unwrap();
    let c8 = Cluster::v100(8);
    let vs = validate(&out.graph, &out.schedule).unwrap();
    let plan = materialize(&out.graph, &vs, &c8, CommMode::InterRvd);
    assert!(plan.tasks.iter().all(|t| !t.label.starts_with("dp-sync")));
    assert!(
        plan.tasks.iter().any(|t| matches!(
            &t.kind,
            TaskKind::Collective { kind: CollKind::AllReduce, .. }
        )),
        "same-server replicas still all-reduce"
    );
}

/// Spec label round-trips cover the dp-replicated hetero family end to end
/// at the integration level: every spec the search enumerates parses back
/// from its own label.
#[test]
fn every_enumerated_spec_label_round_trips() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(8);
    let (feasible, _) = search::enumerate(&model, &cluster);
    assert!(!feasible.is_empty());
    for (_, spec) in feasible {
        let lbl = spec.label();
        let back = PlanSpec::parse(&lbl).unwrap_or_else(|e| panic!("'{lbl}': {e}"));
        assert_eq!(back, spec, "round-trip through '{lbl}'");
    }
}
