//! Integration properties of the delta-replay refinement tier:
//!
//! 1. For every mutation kind the MCMC loop proposes (stage-boundary
//!    move, recompute/offload toggle, widen/narrow, micro resize,
//!    adjacent-op swap), replaying the mutated plan through a captured
//!    [`BaseRun`] is *bitwise* identical — makespan, per-task spans,
//!    per-device busy times and memory peaks — to a from-scratch
//!    [`des::execute`] of the same plan. Delta replay is an optimization,
//!    never an approximation.
//! 2. `--refine` is deterministic across worker counts: the refined
//!    winner (name, DES score bits, gap bits) is a function of the seed
//!    only, so CI results reproduce on any machine shape.

use superscaler::cost::Cluster;
use superscaler::des::delta::{BaseRun, DEFAULT_EPOCHS};
use superscaler::des::{self, DesReport};
use superscaler::graph::Graph;
use superscaler::materialize::{self, CommMode, Plan};
use superscaler::models::{self, Model};
use superscaler::plans::{registry, PlanSpec, StageSpec};
use superscaler::schedule::{self, ValidatedSchedule};
use superscaler::search::{self, Fidelity, RefineConfig, SearchConfig};
use superscaler::sim::TaskGraph;

fn build(
    model: &Model,
    cluster: &Cluster,
    spec: &PlanSpec,
) -> (Graph, ValidatedSchedule, Plan, TaskGraph) {
    let planner = registry::find("hetero").expect("hetero planner registered");
    let out = planner.build(model, spec).expect("plan builds");
    let vs = schedule::validate(&out.graph, &out.schedule).expect("schedule validates");
    let plan = materialize::materialize(&out.graph, &vs, cluster, CommMode::InterRvd);
    let tg = TaskGraph::prepare(&vs, &plan);
    (out.graph, vs, plan, tg)
}

fn assert_bitwise(a: &DesReport, b: &DesReport, what: &str) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{what}: makespan");
    assert_eq!(a.oom, b.oom, "{what}: oom");
    assert_eq!(a.spans.len(), b.spans.len(), "{what}: span count");
    for (x, y) in a.spans.iter().zip(&b.spans) {
        assert_eq!(x.start.to_bits(), y.start.to_bits(), "{what}: task {} start", x.task);
        assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "{what}: task {} finish", x.task);
    }
    assert_eq!(a.per_device.len(), b.per_device.len(), "{what}: device count");
    for (x, y) in a.per_device.iter().zip(&b.per_device) {
        assert_eq!(x.compute.to_bits(), y.compute.to_bits(), "{what}: dev {} compute", x.device);
        assert_eq!(x.comm.to_bits(), y.comm.to_bits(), "{what}: dev {} comm", x.device);
        assert_eq!(x.peak_mem, y.peak_mem, "{what}: dev {} peak mem", x.device);
    }
    assert_eq!(a.mem.len(), b.mem.len(), "{what}: mem timeline count");
    for (x, y) in a.mem.iter().zip(&b.mem) {
        assert_eq!(x.peak, y.peak, "{what}: dev {} mem peak", x.device);
    }
}

/// Replay `to` (built from a mutated spec) through a base captured from
/// `from` and check it against a from-scratch execution.
fn check_pair(model: &Model, cluster: &Cluster, from: &PlanSpec, to: &PlanSpec, what: &str) {
    let (g1, _vs1, plan1, tg1) = build(model, cluster, from);
    let (base, _) = BaseRun::capture(&g1, &plan1, cluster, &tg1, DEFAULT_EPOCHS);
    let (g2, _vs2, plan2, tg2) = build(model, cluster, to);
    let (replayed, stats, _) = base.replay(&g2, &plan2, cluster, &tg2);
    let fresh = des::execute(&g2, &plan2, cluster, &tg2);
    assert!(stats.replayed <= stats.total, "{what}: replay accounting");
    assert_bitwise(&replayed, &fresh, what);
}

fn base_spec() -> PlanSpec {
    PlanSpec::hetero(vec![StageSpec::tp(2), StageSpec::tp(2)], 2)
}

#[test]
fn every_spec_mutation_kind_replays_bitwise_equal() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(4);
    let from = base_spec();
    let nlayers = model.layers.len();

    // Stage-boundary move: explicit partition one layer off the midpoint.
    let boundary = PlanSpec::hetero(
        vec![
            StageSpec { layers: nlayers / 2 - 1, ..StageSpec::tp(2) },
            StageSpec { layers: nlayers - (nlayers / 2 - 1), ..StageSpec::tp(2) },
        ],
        2,
    );
    check_pair(&model, &cluster, &from, &boundary, "boundary move");

    // Recompute toggle on stage 0.
    let recompute = PlanSpec::hetero(
        vec![StageSpec { recompute: true, ..StageSpec::tp(2) }, StageSpec::tp(2)],
        2,
    );
    check_pair(&model, &cluster, &from, &recompute, "recompute toggle");

    // Offload toggle on stage 1.
    let offload = PlanSpec::hetero(
        vec![StageSpec::tp(2), StageSpec { offload: true, ..StageSpec::tp(2) }],
        2,
    );
    check_pair(&model, &cluster, &from, &offload, "offload toggle");

    // Micro-batch resize.
    let micro = PlanSpec::hetero(vec![StageSpec::tp(2), StageSpec::tp(2)], 4);
    check_pair(&model, &cluster, &from, &micro, "micro resize");
}

#[test]
fn width_move_replays_bitwise_equal() {
    // Widen/narrow on a 3-device pipeline: [tp1|tp2] -> [tp2|tp1] moves
    // one device across the boundary (total preserved, widths stay
    // powers of two).
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(3);
    let from = PlanSpec::hetero(vec![StageSpec::tp(1), StageSpec::tp(2)], 2);
    let to = PlanSpec::hetero(vec![StageSpec::tp(2), StageSpec::tp(1)], 2);
    check_pair(&model, &cluster, &from, &to, "width move");
}

#[test]
fn late_op_swap_replays_partial_suffix_bitwise_equal() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(4);
    let spec = base_spec();
    let (g, vs, plan, tg) = build(&model, &cluster, &spec);
    assert!(tg.serial_hints, "hetero plan keeps its serial order hints");
    let (base, _) = BaseRun::capture(&g, &plan, &cluster, &tg, DEFAULT_EPOCHS);

    // Swap the last two ops of the busiest device: the mutation's dirty
    // set starts late on the timeline, so the replay resumes from a late
    // checkpoint instead of re-running the whole iteration.
    let mut vs2 = vs.clone();
    let (&d, _) = vs2
        .device_order
        .iter()
        .max_by_key(|(&d, ops)| (ops.len(), std::cmp::Reverse(d)))
        .expect("plan occupies devices");
    let ops = vs2.device_order.get_mut(&d).unwrap();
    let len = ops.len();
    assert!(len >= 2, "device runs at least two ops");
    ops.swap(len - 2, len - 1);
    let tg2 = TaskGraph::prepare(&vs2, &plan);
    if !tg2.serial_hints {
        // The swapped order conflicts with data deps; the refinement loop
        // would discard exactly this proposal, so there is nothing to
        // replay.
        return;
    }
    let (replayed, stats, _) = base.replay(&g, &plan, &cluster, &tg2);
    let fresh = des::execute(&g, &plan, &cluster, &tg2);
    assert_bitwise(&replayed, &fresh, "late op swap");
    assert!(!stats.full, "a tail-of-timeline mutation must not force full replay");
    assert!(
        stats.replayed < stats.total,
        "late swap replayed {}/{} events — expected a proper suffix",
        stats.replayed,
        stats.total
    );
}

#[test]
fn refined_search_is_deterministic_across_worker_counts() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(4);
    let run = |workers: usize| {
        let cfg = SearchConfig::builder()
            .workers(workers)
            .hetero(true)
            .max_candidates(16)
            .fidelity(Fidelity::Des)
            .des_top(4)
            .refine(Some(RefineConfig { iters: 8, ..RefineConfig::default() }))
            .build();
        search::search(&model, &cluster, &cfg)
    };
    let a = run(1);
    let b = run(3);
    let (wa, wb) = (&a.ranked[0], &b.ranked[0]);
    assert_eq!(wa.plan_name, wb.plan_name, "winner identity");
    let (ma, mb) = (wa.metrics().unwrap(), wb.metrics().unwrap());
    assert_eq!(
        ma.des_makespan.map(f64::to_bits),
        mb.des_makespan.map(f64::to_bits),
        "winner DES score"
    );
    assert_eq!(ma.gap.map(f64::to_bits), mb.gap.map(f64::to_bits), "winner gap certificate");
    let (ra, rb) = (a.refine.as_ref().unwrap(), b.refine.as_ref().unwrap());
    assert_eq!(ra.accepted, rb.accepted, "accepted mutation count");
    assert_eq!(ra.replayed_events, rb.replayed_events, "replayed event count");
    assert!(ra.best_gap.map(|g| g.is_finite()).unwrap_or(false), "winner carries a finite gap");
}
