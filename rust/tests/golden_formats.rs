//! Golden-file tests over the CI artifact formats: the `SearchReport`
//! table (the `bench_results/search.csv` schema external consumers parse)
//! and the Chrome-trace JSON the DES exports. Both are rendered from
//! fixed synthetic inputs — every value hand-checkable — and compared
//! against committed fixtures under `rust/tests/golden/`, so format drift
//! is a visible diff instead of a silently broken artifact consumer.

use superscaler::cost::Cluster;
use superscaler::des;
use superscaler::graph::{Graph, OpKind};
use superscaler::materialize::{Plan, Task, TaskKind};
use superscaler::plans::{PlanKind, PlanSpec, SchedName, SchedSpec, StageSpec};
use superscaler::schedule::ScheduleSpec;
use superscaler::search::{Candidate, Fidelity, Metrics, Outcome, SearchReport};
use superscaler::sim::TaskGraph;
use superscaler::topo::{build_cluster, ClusterShapeError};
use superscaler::util::{json, prop};

/// A fully synthetic report with fixed values: one DES-rescored winner,
/// one OOM grid plan, one build failure — every status path the table
/// renders.
fn synthetic_report() -> SearchReport {
    let ok = Candidate {
        planner: "hetero",
        spec: PlanSpec::hetero_dp(2, vec![StageSpec::tp(2), StageSpec::tp(2)], 4),
        plan_name: "hetero-dp2k4[tp2|tp2]".to_string(),
        outcome: Outcome::Ok(Metrics {
            makespan: 0.0525,
            des_makespan: Some(0.05),
            des_oom: false,
            aggregate_tflops: 120.0,
            comm_bytes: 3 * (1u64 << 30),
            peak_mem: 2 * (1u64 << 30),
            bubble_frac: 0.25,
            oom: false,
            gap: Some(0.04),
            goodput: Some(0.92),
            recovery: Some(1.5),
        }),
    };
    let oom = Candidate {
        planner: "megatron",
        spec: PlanSpec { dp: 2, pp: 2, tp: 2, micro: 4, ..PlanSpec::new(PlanKind::Megatron) },
        plan_name: "megatron-dp2pp2tp2k4-OneFOneB".to_string(),
        outcome: Outcome::Ok(Metrics {
            makespan: 0.075,
            des_makespan: None,
            des_oom: false,
            aggregate_tflops: 80.0,
            comm_bytes: 1u64 << 30,
            peak_mem: 1u64 << 30,
            bubble_frac: 0.5,
            oom: true,
            gap: None,
            goodput: None,
            recovery: None,
        }),
    };
    let failed = Candidate {
        planner: "hetero",
        spec: PlanSpec::hetero(vec![StageSpec::tp(1), StageSpec::tp(1)], 1),
        plan_name: String::new(),
        outcome: Outcome::BuildError("stage 0 conflicts".to_string()),
    };
    SearchReport {
        model: "gpt3-0".to_string(),
        gpus: 8,
        topology: "flat".to_string(),
        ranked: vec![ok, oom, failed],
        pruned: 3,
        excluded: 0,
        capped: 1,
        pruned_bound: 2,
        evaluated: 3,
        fidelity: Fidelity::Des,
        des_rescored: 1,
        refined: 1,
        refine: None,
        resilience_scored: 1,
        resilience: None,
        wall_secs: 1.5,
    }
}

#[test]
fn search_report_table_csv_matches_golden() {
    let report = synthetic_report();
    let table = report.to_table(0);
    // The title carries the full coverage accounting — exact format.
    assert_eq!(
        table.title,
        "plan search: gpt3-0 on 8 GPUs — 3 specs simulated, 3 infeasible, \
         0 dp-excluded, 1 capped, 2 cost-dominated, 1 des-rescored, 1 refined, 1.500 s"
    );
    let path = std::env::temp_dir().join("superscaler_golden_search_table.csv");
    table.write_csv(&path).unwrap();
    let actual = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let golden = include_str!("golden/search_table.csv");
    assert_eq!(
        actual, golden,
        "SearchReport::to_table CSV drifted from rust/tests/golden/search_table.csv\n\
         -- actual --\n{actual}\n-- golden --\n{golden}"
    );
}

#[test]
fn search_report_render_keeps_column_set() {
    // The rendered console table shares rows with the CSV; pin the header
    // set and the per-row status strings without pinning column widths.
    let rendered = synthetic_report().to_table(0).render();
    let cols = [
        "#", "plan", "spec", "iteration", "DES", "TFLOPS", "comm", "peak mem", "bubble%", "gap",
        "goodput", "recover", "status",
    ];
    for col in cols {
        assert!(rendered.contains(col), "missing column '{col}' in:\n{rendered}");
    }
    assert!(rendered.contains("52.500 ms") && rendered.contains("50.000 ms"));
    assert!(rendered.contains("92%"), "winner's goodput column renders");
    assert!(rendered.contains("OOM"));
    assert!(rendered.contains("invalid: stage 0 conflicts"));
}

/// `sched{...}` tokens flow through the report's spec column: a candidate
/// carrying a schedule renders its token into the table row, and the
/// rendered label parses back to the same spec — the fourth search axis is
/// CSV-round-trippable like the other three. (A separate report keeps the
/// pinned `search_table.csv` golden untouched.)
#[test]
fn sched_tokens_round_trip_through_report_labels() {
    let named = PlanSpec {
        pp: 4,
        micro: 8,
        sched: Some(SchedSpec::Named(SchedName::ZeroBubble)),
        ..PlanSpec::new(PlanKind::Megatron)
    };
    // Explicit row sets — the form refine's permutation mutation writes —
    // must survive the same surface.
    let explicit = PlanSpec {
        pp: 2,
        micro: 2,
        sched: Some(SchedSpec::Explicit(ScheduleSpec::one_f_one_b(2, 2))),
        ..PlanSpec::new(PlanKind::Megatron)
    };
    for spec in [named, explicit] {
        let label = spec.label();
        assert!(label.contains("sched{"), "{label}");
        assert_eq!(PlanSpec::parse(&label).unwrap(), spec, "label '{label}' must round-trip");
        let report = SearchReport {
            ranked: vec![Candidate {
                planner: "megatron",
                spec: spec.clone(),
                plan_name: "megatron-sched".to_string(),
                outcome: Outcome::Ok(Metrics {
                    makespan: 0.05,
                    des_makespan: None,
                    des_oom: false,
                    aggregate_tflops: 100.0,
                    comm_bytes: 1u64 << 30,
                    peak_mem: 1u64 << 30,
                    bubble_frac: 0.1,
                    oom: false,
                    gap: None,
                    goodput: None,
                    recovery: None,
                }),
            }],
            ..synthetic_report()
        };
        let row = &report.to_table(0).rows[0];
        let rendered_spec = &row[2];
        assert_eq!(rendered_spec, &label, "spec column must carry the sched token verbatim");
        assert_eq!(PlanSpec::parse(rendered_spec).unwrap().sched, spec.sched);
    }
}

/// Combined-token label fuzz: the per-axis round-trips live next to the
/// parser (`plans::spec`), but CSV consumers see labels that stack a
/// `sched{...}` token on top of the topology-era hetero grammar — explicit
/// per-stage layer counts (`l{n}`) and flag suffixes — in one string. Fuzz
/// exactly those combined labels through `label() -> parse()`.
#[test]
fn prop_combined_sched_and_stage_layer_labels_round_trip() {
    prop::check("combined-label-roundtrip", 300, |g| {
        let pp = g.int(2, 6);
        let micro = g.pow2(8).max(2);
        let names = [
            SchedName::Sync,
            SchedName::OneFOneB,
            SchedName::Interlaced,
            SchedName::ZeroBubble,
            SchedName::VShape,
        ];
        let sched = if g.bool() {
            SchedSpec::Named(*g.rng.choose(&names))
        } else {
            SchedSpec::Explicit(g.rng.choose(&names).rows(pp, micro))
        };
        let spec = if g.bool() {
            // Hetero: every stage carries an explicit `l{n}` layer count so
            // the label exercises the topology-era stage grammar alongside
            // the sched token.
            let stages: Vec<StageSpec> = (0..pp)
                .map(|_| {
                    let mut st = if g.bool() {
                        StageSpec::tp(g.pow2(4))
                    } else {
                        StageSpec::coshard(*g.rng.choose(&[2usize, 4]))
                    };
                    st.recompute = g.bool();
                    st.offload = g.bool();
                    st.layers = g.int(1, 7);
                    st
                })
                .collect();
            let mut s = PlanSpec::hetero_dp(g.pow2(4), stages, micro);
            s.sched = Some(sched);
            s
        } else {
            PlanSpec {
                dp: g.pow2(4),
                pp,
                tp: g.pow2(4),
                micro,
                sched: Some(sched),
                ..PlanSpec::new(PlanKind::Megatron)
            }
        };
        let lbl = spec.label();
        if !lbl.contains("sched{") {
            return Err(format!("label '{lbl}' dropped the sched token"));
        }
        match PlanSpec::parse(&lbl) {
            Ok(back) if back == spec => Ok(()),
            Ok(back) => Err(format!("'{lbl}' parsed to {back:?}, wanted {spec:?}")),
            Err(e) => Err(format!("'{lbl}' failed to parse: {e}")),
        }
    });
}

/// Device-mix cluster fuzz: random (gpus, servers, mix) shapes must either
/// build a cluster whose device count matches, or fail with the typed
/// `ClusterShapeError` the CLI renders — never panic. Aligned mixes always
/// build; misaligned ones always yield the matching typed error.
#[test]
fn prop_device_mix_cluster_shapes_build_or_reject_typed() {
    prop::check("device-mix-shapes", 300, |g| {
        let kinds = ["v100", "a100", "h100"];
        let gpus_per_server = *g.rng.choose(&[2usize, 4, 8]);
        let n_servers = g.int(1, 5);
        let gpus = gpus_per_server * n_servers;
        // Assign each server row a kind; render the mix as kind:count runs.
        let rows: Vec<&str> = (0..n_servers).map(|_| *g.rng.choose(&kinds)).collect();
        let mut runs: Vec<(String, usize)> = Vec::new();
        for k in &rows {
            match runs.last_mut() {
                Some((name, c)) if name == k => *c += gpus_per_server,
                _ => runs.push((k.to_string(), gpus_per_server)),
            }
        }
        let mix: String =
            runs.iter().map(|(k, c)| format!("{k}:{c}")).collect::<Vec<_>>().join(",");
        let c = build_cluster(gpus, Some(n_servers), "flat", Some(&mix))
            .map_err(|e| format!("aligned mix '{mix}' at {gpus} gpus rejected: {e}"))?;
        if c.num_gpus() != gpus {
            return Err(format!("built {} devices, wanted {gpus}", c.num_gpus()));
        }
        // Perturbations hit the typed rejections, never a panic.
        match build_cluster(gpus + gpus_per_server, Some(n_servers + 1), "flat", Some(&mix)) {
            Err(ClusterShapeError::MixSumMismatch { .. }) => {}
            other => return Err(format!("undersized mix: wanted MixSumMismatch, got {other:?}")),
        }
        if gpus_per_server > 1 {
            let odd = format!("{}:{}", rows[0], gpus_per_server - 1);
            match build_cluster(gpus, Some(n_servers), "flat", Some(&odd)) {
                Err(ClusterShapeError::MixNotServerAligned { .. })
                | Err(ClusterShapeError::MixSumMismatch { .. }) => {}
                other => {
                    return Err(format!("misaligned mix: wanted a typed error, got {other:?}"))
                }
            }
        }
        match build_cluster(gpus, Some(n_servers), "flat", Some("tpu:8")) {
            Err(ClusterShapeError::BadDeviceMix(_)) => Ok(()),
            other => Err(format!("unknown kind: wanted BadDeviceMix, got {other:?}")),
        }
    });
}

/// Tiny deterministic DES run: one compute task per server bridged by a
/// cross-server transfer, whole-second durations so every microsecond
/// timestamp is integral and the trace JSON is bit-stable.
fn synthetic_trace() -> (des::DesReport, Plan) {
    let mut g = Graph::new();
    for i in 0..2 {
        g.add_op(&format!("op{i}"), OpKind::Identity, vec![], vec![], 0.0, None, true, 0);
    }
    let mut plan = Plan::default();
    plan.tasks.push(Task {
        id: 0,
        kind: TaskKind::Compute { op: 0, device: 0 },
        deps: vec![],
        duration: 1.0,
        label: "c0".into(),
    });
    plan.tasks.push(Task {
        id: 1,
        kind: TaskKind::P2P { from: 0, to: 8, bytes: 1 << 20, ptensor: 0 },
        deps: vec![0],
        duration: 2.0,
        label: "x1".into(),
    });
    plan.tasks.push(Task {
        id: 2,
        kind: TaskKind::Compute { op: 1, device: 8 },
        deps: vec![1],
        duration: 1.0,
        label: "c2".into(),
    });
    let c = Cluster::v100(16);
    let tg = TaskGraph::of_plan(&plan);
    let r = des::execute(&g, &plan, &c, &tg);
    (r, plan)
}

#[test]
fn chrome_trace_matches_golden() {
    let (r, plan) = synthetic_trace();
    assert_eq!(r.makespan, 4.0, "synthetic chain must be exactly 4 seconds");
    let actual_str = des::trace::chrome_trace(&r, &plan);
    let actual = json::parse(&actual_str).expect("trace is valid JSON");
    let golden = json::parse(include_str!("golden/chrome_trace.json")).expect("fixture parses");
    assert_eq!(
        actual, golden,
        "Chrome-trace schema drifted from rust/tests/golden/chrome_trace.json\n\
         -- actual --\n{actual_str}"
    );
}

#[test]
fn chrome_trace_schema_invariants() {
    let (r, plan) = synthetic_trace();
    let doc = json::parse(&des::trace::chrome_trace(&r, &plan)).unwrap();
    assert_eq!(doc.get("displayTimeUnit").and_then(|v| v.as_str()), Some("ms"));
    let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
    // One X event per task per occupied device; metadata names both
    // streams of both devices; a counter track exists per device.
    let count = |ph: &str| {
        evs.iter().filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some(ph)).count()
    };
    assert_eq!(count("X"), 4, "c0 + x1 on two devices + c2");
    assert_eq!(count("M"), 6, "2 process names + 2x2 thread names");
    assert_eq!(count("C"), 2, "one memory counter point per device");
    // Every X event stays within the makespan and carries pid/tid.
    for e in evs {
        if e.get("ph").and_then(|p| p.as_str()) == Some("X") {
            let ts = e.get("ts").and_then(|v| v.as_f64()).unwrap();
            let dur = e.get("dur").and_then(|v| v.as_f64()).unwrap();
            assert!(ts >= 0.0 && ts + dur <= r.makespan * 1e6 + 1e-6);
            assert!(e.get("pid").is_some() && e.get("tid").is_some());
        }
    }
}
