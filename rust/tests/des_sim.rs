//! The discrete-event engine against the list scheduler: exact agreement
//! where no overlap exists, strict credit where it does (the ISSUE-3
//! acceptance claim on a GPT-3 pipeline), bitwise determinism across runs
//! and worker pools, time-resolved memory consistency, and the
//! `--fidelity des` search path carrying both scores end to end.

use superscaler::cost::{Cluster, ModelStats};
use superscaler::des;
use superscaler::graph::sig::sigs;
use superscaler::graph::{CollKind, DType, Graph, OpKind, TensorKind};
use superscaler::materialize::{materialize, CommMode, Plan, Task, TaskKind};
use superscaler::models;
use superscaler::plans::{hetero, megatron, PipeOrder, PlanSpec, StageSpec};
use superscaler::schedule::{validate, Schedule, CPU_DEVICE};
use superscaler::search::{self, Fidelity, SearchConfig};
use superscaler::sim;

/// A strictly serial linear chain: layer `l` on device `l % ndev`, so
/// every layer boundary is a cross-device transfer but nothing can ever
/// run concurrently — zero overlap opportunity by construction.
fn serial_chain(layers: usize, ndev: usize) -> (Graph, Schedule) {
    let mut g = Graph::new();
    let mut prev = g.add_ptensor("x", &[8, 4, 16], DType::F32, TensorKind::Input);
    let mut s = Schedule::new();
    for l in 0..layers {
        let w = g.add_ptensor(&format!("w{l}"), &[16, 16], DType::F32, TensorKind::Weight);
        let y = g.add_ptensor(&format!("y{l}"), &[8, 4, 16], DType::F32, TensorKind::Activation);
        let (xv, wv, yv) = (g.full_view(prev), g.full_view(w), g.full_view(y));
        let op = g.add_op(
            &format!("lin{l}"),
            OpKind::Matmul,
            vec![xv, wv],
            vec![yv],
            1e10,
            Some(sigs::linear()),
            true,
            l,
        );
        s.assign(op, l % ndev);
        prev = y;
    }
    (g, s)
}

#[test]
fn zero_overlap_schedule_agrees_exactly_with_list_sim() {
    let (g, s) = serial_chain(6, 2);
    let c = Cluster::v100(8);
    let vs = validate(&g, &s).unwrap();
    let plan = materialize(&g, &vs, &c, CommMode::InterRvd);
    let list = sim::simulate(&g, &vs, &plan, &c);
    let d = des::simulate(&g, &vs, &plan, &c);
    assert!(list.makespan > 0.0);
    assert_eq!(
        d.makespan.to_bits(),
        list.makespan.to_bits(),
        "serial chain: DES {} vs list {} must agree exactly",
        d.makespan,
        list.makespan
    );
}

/// The acceptance claim: on a GPT-3 pipeline, transfers between stages run
/// on communication streams while the stages keep computing, so the DES
/// reports a strictly smaller makespan than the device-blocking list model.
#[test]
fn des_credits_overlap_on_gpt3_pipeline() {
    let out = megatron(&models::gpt3(0, 8, 256), 1, 4, 1, 8, PipeOrder::OneFOneB).unwrap();
    let c = Cluster::v100(4);
    let vs = validate(&out.graph, &out.schedule).unwrap();
    let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
    let list = sim::simulate(&out.graph, &vs, &plan, &c);
    let d = des::simulate(&out.graph, &vs, &plan, &c);
    assert!(
        d.makespan < list.makespan,
        "overlap not credited: DES {} vs list {}",
        d.makespan,
        list.makespan
    );
    // Sanity: overlap cannot beat the busiest device's compute-only load.
    let max_compute = d
        .per_device
        .iter()
        .filter(|s| s.device != CPU_DEVICE)
        .map(|s| s.compute)
        .fold(0.0f64, f64::max);
    assert!(d.makespan >= max_compute - 1e-9);
}

#[test]
fn des_is_bitwise_deterministic_across_runs() {
    let run = || {
        let out = megatron(&models::gpt3(0, 8, 256), 2, 2, 1, 4, PipeOrder::OneFOneB).unwrap();
        let c = Cluster::v100(4);
        let vs = validate(&out.graph, &out.schedule).unwrap();
        let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
        des::simulate(&out.graph, &vs, &plan, &c)
    };
    let (a, b) = (run(), run());
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.spans.len(), b.spans.len());
    for (x, y) in a.spans.iter().zip(&b.spans) {
        assert_eq!(x.start.to_bits(), y.start.to_bits(), "task {} start drifted", x.task);
        assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "task {} finish drifted", x.task);
    }
}

/// The DES re-rank must not depend on the search worker pool: tier-3
/// scores are computed per candidate in a single-threaded engine, and the
/// ranking is a pure function of them.
#[test]
fn des_search_deterministic_across_worker_pools() {
    let cluster = Cluster::v100(4);
    let cfg = |workers| {
        SearchConfig::builder()
            .workers(workers)
            .fidelity(Fidelity::Des)
            .des_top(4)
            .hetero(false)
            .build()
    };
    let model = models::gpt3(0, 8, 256);
    let a = search::search(&model, &cluster, &cfg(1));
    let b = search::search(&model, &cluster, &cfg(8));
    let (ba, bb) = (a.best().expect("best a"), b.best().expect("best b"));
    assert_eq!(ba.plan_name, bb.plan_name);
    let (ma, mb) = (ba.metrics().unwrap(), bb.metrics().unwrap());
    assert_eq!(ma.makespan.to_bits(), mb.makespan.to_bits());
    let (da, db) = (ma.des_makespan.expect("des score a"), mb.des_makespan.expect("des score b"));
    assert_eq!(da.to_bits(), db.to_bits());
}

#[test]
fn search_fidelity_des_carries_both_scores() {
    let cluster = Cluster::v100(4);
    let model = models::gpt3(0, 8, 256);
    let report = search::search(
        &model,
        &cluster,
        &SearchConfig::builder().workers(2).fidelity(Fidelity::Des).des_top(4).build(),
    );
    assert!(report.des_rescored > 0, "some candidates must be DES-rescored");
    let best = report.best().expect("search found a plan");
    let m = best.metrics().unwrap();
    let d = m.des_makespan.expect("best plan carries a DES score");
    assert!(m.makespan > 0.0 && d > 0.0);
    assert!(
        d <= m.makespan * 1.05,
        "DES {} should not exceed list {} by more than scheduling noise",
        d,
        m.makespan
    );
    // The re-scored head is ordered by the DES score (DES-OOM candidates
    // deliberately sort last, so they are excluded from the monotonicity
    // check).
    let head: Vec<f64> = report
        .ranked
        .iter()
        .filter_map(|c| c.metrics().filter(|m| !m.des_oom).and_then(|m| m.des_makespan))
        .collect();
    assert!(head.windows(2).all(|w| w[0] <= w[1]), "head not DES-ordered: {head:?}");
    // Both scores reach the rendered report.
    let rendered = report.to_table(5).render();
    assert!(rendered.contains("DES"), "{rendered}");
    assert!(rendered.contains("des-rescored"), "{rendered}");
    // List fidelity leaves tier 3 off.
    let list_report =
        search::search(&model, &cluster, &SearchConfig::builder().workers(2).build());
    assert_eq!(list_report.des_rescored, 0);
    assert!(list_report
        .ranked
        .iter()
        .all(|c| c.metrics().map_or(true, |m| m.des_makespan.is_none())));
    // And the gate's measurement is fidelity-independent.
    let (ga, gb) =
        (report.best_list_makespan().unwrap(), list_report.best_list_makespan().unwrap());
    assert!((ga - gb).abs() / gb < 1e-9, "gate makespan moved: {ga} vs {gb}");
}

/// Cross-engine invariant over the dp > 1 region: every replicated plan's
/// DES makespan sits between the analytic lower bound (what dominance
/// pruning trusts) and the overlap-blind list estimate — overlap can only
/// help, never beat the bound.
#[test]
fn dp_plans_des_makespan_between_bound_and_list() {
    struct Case {
        name: &'static str,
        build: fn() -> superscaler::plans::PlanOutput,
        spec: PlanSpec,
        gpus: usize,
        /// Whether the dp groups stay inside one server. When they span
        /// servers the DES legitimately charges NIC fair-sharing the list
        /// model cannot see, so only the lower-bound side is asserted.
        same_server: bool,
    }
    let cases = [
        Case {
            name: "megatron dp2 tp2",
            build: || megatron(&models::gpt3(0, 8, 256), 2, 1, 2, 2, PipeOrder::OneFOneB).unwrap(),
            spec: PlanSpec {
                dp: 2,
                tp: 2,
                micro: 2,
                ..PlanSpec::new(superscaler::plans::PlanKind::Megatron)
            },
            gpus: 4,
            same_server: true,
        },
        Case {
            name: "hetero dp2 [tp2|tp2]",
            build: || {
                hetero(&models::gpt3(0, 8, 256), 2, 2, &[StageSpec::tp(2), StageSpec::tp(2)])
                    .unwrap()
            },
            spec: PlanSpec::hetero_dp(2, vec![StageSpec::tp(2), StageSpec::tp(2)], 2),
            gpus: 8,
            same_server: true,
        },
        Case {
            name: "hetero dp4 [tp2|tp2] cross-server",
            build: || {
                hetero(&models::gpt3(0, 8, 256), 4, 2, &[StageSpec::tp(2), StageSpec::tp(2)])
                    .unwrap()
            },
            spec: PlanSpec::hetero_dp(4, vec![StageSpec::tp(2), StageSpec::tp(2)], 2),
            gpus: 16,
            same_server: false,
        },
    ];
    let stats = ModelStats::of(&models::gpt3(0, 8, 256).graph);
    for case in cases {
        let out = (case.build)();
        let c = Cluster::v100(case.gpus);
        let vs = validate(&out.graph, &out.schedule).unwrap();
        let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
        let list = sim::simulate(&out.graph, &vs, &plan, &c);
        let d = des::simulate(&out.graph, &vs, &plan, &c);
        let lb = c.plan_time_lower_bound(&case.spec, &stats);
        assert!(lb <= d.makespan, "{}: bound {lb} above DES {}", case.name, d.makespan);
        assert!(lb <= list.makespan, "{}: bound {lb} above list {}", case.name, list.makespan);
        // DES can never beat the busiest device's compute-only load.
        let max_compute = d
            .per_device
            .iter()
            .filter(|s| s.device != CPU_DEVICE)
            .map(|s| s.compute)
            .fold(0.0f64, f64::max);
        assert!(d.makespan >= max_compute - 1e-9, "{}", case.name);
        if case.same_server {
            assert!(
                d.makespan <= list.makespan * 1.05,
                "{}: DES {} above list {} beyond scheduling noise",
                case.name,
                d.makespan,
                list.makespan
            );
        }
    }
}

/// The decomposed gradient-sync collectives of a cross-server dp plan are
/// visible in the exported Chrome trace as communication events.
#[test]
fn grad_sync_collectives_appear_in_chrome_trace() {
    let out =
        hetero(&models::gpt3(0, 8, 256), 4, 2, &[StageSpec::tp(2), StageSpec::tp(2)]).unwrap();
    let c = Cluster::v100(16);
    let vs = validate(&out.graph, &out.schedule).unwrap();
    let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
    assert!(
        plan.tasks.iter().any(|t| t.label.starts_with("dp-sync")),
        "plan carries no decomposed sync collectives"
    );
    let d = des::simulate(&out.graph, &vs, &plan, &c);
    let doc = superscaler::util::json::parse(&des::trace::chrome_trace(&d, &plan)).unwrap();
    let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
    let sync_spans = evs
        .iter()
        .filter(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("X")
                && e.get("cat").and_then(|c| c.as_str()) == Some("comm")
                && e.get("name")
                    .and_then(|n| n.as_str())
                    .map_or(false, |n| n.starts_with("dp-sync"))
        })
        .count();
    assert!(sync_spans > 0, "gradient-sync collectives missing from the trace");
}

/// Two dp replicas per server syncing concurrently fair-share the NICs:
/// each cross-server collective runs at half its solo rate, so the pair
/// takes 2x the solo time (the dslab shared-throughput discipline applied
/// to the new sync collectives).
#[test]
fn concurrent_grad_sync_collectives_fair_share_nics() {
    let c = Cluster::v100(16);
    let mk = |id, group: Vec<usize>, dur| Task {
        id,
        kind: TaskKind::Collective { kind: CollKind::AllReduce, group, bytes: 1 << 20, ptensor: 0 },
        deps: vec![],
        duration: dur,
        label: format!("dp-sync all-reduce:{id}").into(),
    };
    let dur = c.collective_time(CollKind::AllReduce, &[0, 8], 1 << 20);
    // Solo run: exactly the modeled duration.
    let mut solo = Plan::default();
    solo.tasks.push(mk(0, vec![0, 8], dur));
    let tg = sim::TaskGraph::of_plan(&solo);
    let r = des::execute(&Graph::new(), &solo, &c, &tg);
    assert_eq!(r.makespan.to_bits(), dur.to_bits());
    // Two replicas per server syncing at once: both cross Nic(0)+Nic(1),
    // both halve, both finish at 2x.
    let mut pair = Plan::default();
    pair.tasks.push(mk(0, vec![0, 8], dur));
    pair.tasks.push(mk(1, vec![1, 9], dur));
    let tg = sim::TaskGraph::of_plan(&pair);
    let r = des::execute(&Graph::new(), &pair, &c, &tg);
    assert!(
        (r.makespan - 2.0 * dur).abs() < 1e-12,
        "NIC fair-share broken: {} vs {}",
        r.makespan,
        2.0 * dur
    );
}

#[test]
fn memory_timeline_is_consistent_with_peaks_and_returns_to_static() {
    let out = megatron(&models::gpt3(0, 8, 256), 1, 4, 1, 4, PipeOrder::OneFOneB).unwrap();
    let c = Cluster::v100(4);
    let vs = validate(&out.graph, &out.schedule).unwrap();
    let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
    let d = des::simulate(&out.graph, &vs, &plan, &c);
    assert!(!d.mem.is_empty());
    for tl in &d.mem {
        let static_bytes = plan.static_mem.get(&tl.device).copied().unwrap_or(0);
        let grad_bytes = plan.static_grad_mem.get(&tl.device).copied().unwrap_or(0);
        // Gradient buffers are time-resolved in the DES timeline: the
        // baseline is static state *minus* the gradient share, which only
        // becomes resident while a gradient region is actually live.
        let baseline = static_bytes - grad_bytes;
        let (_, first) = tl.points.first().copied().unwrap();
        assert_eq!(
            first, baseline,
            "device {} timeline starts at static-minus-gradients",
            tl.device
        );
        let max_point = tl.points.iter().map(|&(_, b)| b).max().unwrap();
        assert_eq!(max_point, tl.peak, "device {} peak disagrees with points", tl.device);
        let (_, last) = tl.points.last().copied().unwrap();
        assert_eq!(
            last, baseline,
            "device {}: all activations and gradients must be freed by iteration end",
            tl.device
        );
        if let Some(st) = d.per_device.iter().find(|s| s.device == tl.device) {
            assert_eq!(st.peak_mem, tl.peak, "device {} stat/timeline peak", tl.device);
        }
        // Times are non-decreasing.
        assert!(tl.points.windows(2).all(|w| w[0].0 <= w[1].0));
    }
    // Peak memory agrees with the list simulator's watermark for the same
    // plan *when the timelines coincide* — and never exceeds what the
    // device would need under the serialized schedule.
    let list = sim::simulate(&out.graph, &vs, &plan, &c);
    assert_eq!(d.per_device.len(), list.per_device.len());
}
