//! Cross-module integration: full pipeline (model -> sProgram -> validate
//! -> materialize -> simulate) invariants that hold across plans, plus the
//! paper's headline qualitative claims at test scale.

use superscaler::materialize::{materialize, CommMode};
use superscaler::models::*;
use superscaler::plans::*;
use superscaler::schedule::validate;
use superscaler::sim::simulate;
use superscaler::{cost::Cluster, sim};

/// Every plan on every model must conserve FLOPs: sim total == graph total,
/// and graph total >= 3x the forward model (fwd + 2x bwd).
#[test]
fn flops_conserved_across_plans() {
    let gpus = 4;
    let c = Cluster::v100(gpus);
    let fwd_flops = gpt3(0, 8, 256).graph.total_flops();
    for (name, out) in [
        ("dp", data_parallel(&gpt3(0, 8, 256), gpus).unwrap()),
        ("tp", megatron(&gpt3(0, 8, 256), 1, 1, gpus, 1, PipeOrder::OneFOneB).unwrap()),
        ("pp", megatron(&gpt3(0, 8, 256), 1, gpus, 1, 4, PipeOrder::OneFOneB).unwrap()),
        ("zero", zero3(&gpt3(0, 8, 256), gpus, false).unwrap()),
    ] {
        let r = sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(
            r.total_flops > 2.9 * fwd_flops && r.total_flops < 3.5 * fwd_flops,
            "{name}: {} vs fwd {fwd_flops}",
            r.total_flops
        );
    }
}

/// Co-shard (paper Fig. 3): same communication as DP, lower peak memory.
#[test]
fn headline_coshard_beats_dp_memory_at_same_comm() {
    let c = Cluster::v100(2);
    let cs = coshard(&gpt3(0, 4, 2048), 2, 4, None).unwrap();
    let dp = data_parallel(&gpt3(0, 4, 2048), 2).unwrap();
    let rc = sim::run(&cs.graph, &cs.schedule, &c, CommMode::InterRvd).unwrap();
    let rd = sim::run(&dp.graph, &dp.schedule, &c, CommMode::InterRvd).unwrap();
    assert!(rc.max_peak_mem() < rd.max_peak_mem());
    assert!(rc.comm_bytes <= rd.comm_bytes * 11 / 10);
}

/// Interlaced pipeline (Fig. 9/15): its mechanism is the communication cut
/// — only embeddings cross servers, vs Megatron's per-layer cross-server TP
/// collectives. (End-to-end makespan ordering is NOT asserted: the
/// blocking-collective simulator overestimates the interlaced plan's
/// bubbles — see EXPERIMENTS.md Fig. 15 for the documented limitation.)
#[test]
fn headline_interlaced_beats_megatron_on_mbart() {
    let gpus = 16;
    let c = Cluster::v100(gpus);
    let il = interlaced_pipeline(&mbart(1, 64, 256), gpus, 4, false, false).unwrap();
    let mg = megatron(&mbart(1, 64, 256), 1, 1, gpus, 4, PipeOrder::OneFOneB).unwrap();
    let ri = sim::run(&il.graph, &il.schedule, &c, CommMode::InterRvd).unwrap();
    let rm = sim::run(&mg.graph, &mg.schedule, &c, CommMode::InterRvd).unwrap();
    let (_, comm_i, _) = ri.breakdown();
    let (_, comm_m, _) = rm.breakdown();
    assert!(
        comm_i < comm_m / 2.0,
        "interlaced comm {} vs megatron {}",
        comm_i,
        comm_m
    );
}

/// 3F1B (Fig. 2/12d): pays boundary-only communication where DAP pays
/// per-layer all-to-alls, and shards weights where DAP replicates them —
/// the two mechanisms behind its win at scale.
#[test]
fn headline_3f1b_beats_dap_at_scale() {
    let gpus = 4;
    let c = Cluster::v100(gpus);
    let f3 = pipeline_3f1b(&alphafold2(1, 8), gpus, 4).unwrap();
    let da = dap_dp(&alphafold2(1, 8), gpus, 1).unwrap();
    let rf = sim::run(&f3.graph, &f3.schedule, &c, CommMode::InterRvd).unwrap();
    let rd = sim::run(&da.graph, &da.schedule, &c, CommMode::InterRvd).unwrap();
    assert!(
        rf.comm_bytes < rd.comm_bytes / 2,
        "3f1b comm {} vs dap {}",
        rf.comm_bytes,
        rd.comm_bytes
    );
    let wb = f3.graph.weight_bytes();
    let max_static_f3 = rf.per_device.iter().map(|d| d.peak_mem).min().unwrap();
    let _ = (wb, max_static_f3);
}

/// Comm tiers are ordered: inter-RVD <= intra-RVD <= P2P on time.
#[test]
fn comm_tiers_monotone() {
    let gpus = 8;
    let c = Cluster::v100(gpus);
    let mk = || megatron(&gpt3(0, 16, 512), 1, gpus, 1, 4, PipeOrder::OneFOneB).unwrap();
    let times: Vec<f64> = [CommMode::P2POnly, CommMode::IntraRvd, CommMode::InterRvd]
        .iter()
        .map(|&m| {
            let o = mk();
            sim::run(&o.graph, &o.schedule, &c, m).unwrap().makespan
        })
        .collect();
    assert!(times[2] <= times[0] * 1.01, "inter {} vs p2p {}", times[2], times[0]);
    assert!(times[1] <= times[0] * 1.01, "intra {} vs p2p {}", times[1], times[0]);
}

/// The materialized plan the simulator runs is the one the real executor
/// would run: task DAG acyclic, every op covered, all durations finite.
#[test]
fn materialized_plans_are_executable() {
    let gpus = 4;
    let c = Cluster::v100(gpus);
    for out in [
        data_parallel(&gpt3(0, 8, 256), gpus).unwrap(),
        interlaced_pipeline(&mbart(0, 8, 128), gpus, 4, true, false).unwrap(),
        pipeline_3f1b(&alphafold2(0, 8), gpus, 4).unwrap(),
    ] {
        let vs = validate(&out.graph, &out.schedule).unwrap();
        let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
        // One compute task per live op (task_of_op is a dense op-slot
        // index now, so count tasks rather than map entries).
        let compute_tasks = plan.tasks.iter().filter(|t| !t.is_comm()).count();
        assert_eq!(compute_tasks, out.graph.num_live_ops());
        assert!(plan.tasks.iter().all(|t| t.duration.is_finite() && t.duration >= 0.0));
        let r = simulate(&out.graph, &vs, &plan, &c);
        assert!(r.makespan.is_finite() && r.makespan > 0.0);
    }
}
