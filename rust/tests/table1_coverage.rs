//! Table 1: coverage of existing parallelization mechanisms. Each supported
//! row must be expressible as an sProgram that builds, validates
//! (deadlock-free) and materializes on the model it applies to. The three
//! unsupported rows (PipeDream-async, Terapipe, ByteScheduler) are
//! documented in DESIGN.md with the paper's own reasons.

use superscaler::materialize::CommMode;
use superscaler::models::*;
use superscaler::plans::*;
use superscaler::{cost::Cluster, sim};

fn runs(out: PlanResult, gpus: usize) -> bool {
    match out {
        Err(e) => panic!("plan construction failed: {e}"),
        Ok(o) => {
            let c = Cluster::v100(gpus);
            sim::run(&o.graph, &o.schedule, &c, CommMode::InterRvd).is_ok()
        }
    }
}

#[test]
fn table1_data_parallelism() {
    assert!(runs(data_parallel(&gpt3(0, 8, 256), 4), 4));
}

#[test]
fn table1_transformer_tensor_parallelism() {
    assert!(runs(megatron(&gpt3(0, 4, 256), 1, 1, 4, 1, PipeOrder::OneFOneB), 4));
}

#[test]
fn table1_sequence_parallelism() {
    // Sequence parallelism = splitting the "s" dim — DAP's plan does exactly
    // this for the non-attention ops.
    assert!(runs(dap_dp(&alphafold2(0, 8), 4, 1), 4));
}

#[test]
fn table1_dap() {
    assert!(runs(dap_dp(&alphafold2(0, 8), 2, 2), 4));
}

#[test]
fn table1_zero() {
    assert!(runs(zero3(&gpt3(0, 8, 256), 4, false), 4));
}

#[test]
fn table1_swap_offload() {
    // Swap: optimizer state assigned to the CPU device.
    assert!(runs(zero3(&gpt3(0, 8, 256), 4, true), 4));
}

#[test]
fn table1_1f1b() {
    assert!(runs(megatron(&gpt3(0, 8, 256), 1, 4, 1, 4, PipeOrder::OneFOneB), 4));
}

#[test]
fn table1_gpipe() {
    assert!(runs(megatron(&gpt3(0, 8, 256), 1, 4, 1, 4, PipeOrder::GPipe), 4));
}

#[test]
fn table1_chimera_like_bidirectional() {
    // Chimera's bidirectional pipeline = two 1F1B pipelines with reversed
    // stage order; expressible as two megatron grids — here we validate the
    // reversed-stage grid also schedules cleanly.
    assert!(runs(megatron(&gpt3(0, 8, 256), 2, 2, 1, 4, PipeOrder::OneFOneB), 4));
}

#[test]
fn table1_gradient_accumulation() {
    // Micro-batching without a pipeline = gradient accumulation.
    assert!(runs(megatron(&gpt3(0, 8, 256), 1, 1, 1, 4, PipeOrder::OneFOneB), 1));
}

#[test]
fn table1_recompute() {
    assert!(runs(coshard(&gpt3(0, 8, 256), 2, 1, None), 2)); // recompute path
}

#[test]
fn table1_chain_recompute_coshard() {
    assert!(runs(coshard(&gpt3(0, 8, 256), 2, 4, None), 2));
}

#[test]
fn table1_flexible_tensor_parallel() {
    // Different tp dims per op (attention "a" vs ffn "n"/"k") in one plan.
    assert!(runs(megatron(&swin_transformer(0, 8, 512), 1, 1, 4, 1, PipeOrder::OneFOneB), 4));
}

#[test]
fn table1_interlaced_new_plan() {
    assert!(runs(interlaced_pipeline(&mbart(0, 8, 128), 4, 4, true, false), 4));
}

#[test]
fn table1_3f1b_new_plan() {
    assert!(runs(pipeline_3f1b(&alphafold2(0, 8), 4, 4), 4));
}
