//! Fault-injection acceptance tests: the no-fault path is bitwise
//! identical to the plain DES on a real plan, a seeded fault trace scores
//! identically regardless of worker count, and rack-scoped faults on a
//! fat-tree take out exactly the rack's devices (blast radius).

use superscaler::cost::{Cluster, LinkId};
use superscaler::des;
use superscaler::fault::{FaultPlan, FaultSpec, ResilienceConfig};
use superscaler::materialize::{materialize, CommMode};
use superscaler::models;
use superscaler::plans::{megatron, PipeOrder};
use superscaler::schedule::validate;
use superscaler::search::{self, Outcome, SearchConfig};
use superscaler::sim::TaskGraph;
use superscaler::topo::Topology;

/// `n_servers × gps` V100 cluster on a `fat-tree:k` fabric.
fn fat_tree_cluster(n_servers: usize, gps: usize, k: usize) -> Cluster {
    let mut c = Cluster::with_shape(n_servers, gps);
    c.topo = Topology::parse(&format!("fat-tree:{k}"), n_servers, gps).unwrap();
    c
}

#[test]
fn empty_fault_plan_is_bitwise_identical_on_a_real_pipeline() {
    let out = megatron(&models::gpt3(0, 8, 256), 1, 4, 1, 8, PipeOrder::OneFOneB).unwrap();
    let c = Cluster::v100(4);
    let vs = validate(&out.graph, &out.schedule).unwrap();
    let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
    let base = des::simulate(&out.graph, &vs, &plan, &c);
    let tg = TaskGraph::prepare(&vs, &plan);
    let faulted = des::execute_faulted(&out.graph, &plan, &c, &tg, &FaultPlan::default());
    assert_eq!(
        faulted.makespan.to_bits(),
        base.makespan.to_bits(),
        "empty fault plan must not perturb the timeline: {} vs {}",
        faulted.makespan,
        base.makespan
    );
    assert_eq!(faulted.spans.len(), base.spans.len());
    for (a, b) in faulted.spans.iter().zip(&base.spans) {
        assert_eq!(a.task, b.task);
        assert_eq!(a.start.to_bits(), b.start.to_bits(), "task {} start drifted", a.task);
        assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "task {} finish drifted", a.task);
    }
    let f = faulted.faults.expect("faulted entry point carries the outcome");
    assert_eq!(f.n_kills, 0);
    assert_eq!(f.n_faults, 0);
    assert_eq!(f.lost_work, 0.0);
    assert_eq!(f.ckpt_time, 0.0);
}

/// The seeded-trace determinism acceptance: the same search under the same
/// fault trace produces bitwise-identical rankings and resilience scores
/// whether evaluated on 1 worker or 4.
#[test]
fn seeded_fault_trace_scores_identically_across_worker_counts() {
    let model = models::gpt3(0, 16, 256);
    let cluster = Cluster::v100(4);
    let trace = "crash:d1@0.002+0.001,slow:d0x0.5@0.001+0.004";
    let run = |workers: usize| {
        let rc = ResilienceConfig {
            trace: Some(FaultSpec::parse(trace).unwrap()),
            ..Default::default()
        };
        let cfg = SearchConfig::builder()
            .workers(workers)
            .hetero(false)
            .des_top(2)
            .resilience(Some(rc))
            .build();
        search::search(&model, &cluster, &cfg)
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.resilience_scored, b.resilience_scored);
    assert_eq!(a.ranked.len(), b.ranked.len());
    for (ca, cb) in a.ranked.iter().zip(&b.ranked) {
        assert_eq!(ca.spec.label(), cb.spec.label(), "ranking order diverged");
        match (&ca.outcome, &cb.outcome) {
            (Outcome::Ok(ma), Outcome::Ok(mb)) => {
                assert_eq!(ma.makespan.to_bits(), mb.makespan.to_bits());
                assert_eq!(
                    ma.des_makespan.map(f64::to_bits),
                    mb.des_makespan.map(f64::to_bits),
                    "{}: DES makespan diverged",
                    ca.spec.label()
                );
                assert_eq!(
                    ma.goodput.map(f64::to_bits),
                    mb.goodput.map(f64::to_bits),
                    "{}: goodput diverged across worker counts",
                    ca.spec.label()
                );
                assert_eq!(ma.recovery.map(f64::to_bits), mb.recovery.map(f64::to_bits));
            }
            (oa, ob) => assert_eq!(
                std::mem::discriminant(oa),
                std::mem::discriminant(ob),
                "outcome kind diverged for {}",
                ca.spec.label()
            ),
        }
    }
    let (ra, rb) = (a.resilience.expect("winner scored"), b.resilience.expect("winner scored"));
    assert_eq!(ra.goodput.to_bits(), rb.goodput.to_bits());
    assert_eq!(ra.faulted_makespan.to_bits(), rb.faulted_makespan.to_bits());
    assert_eq!(ra.recovery_time.to_bits(), rb.recovery_time.to_bits());
}

/// Rack-loss blast radius: on `fat-tree:2` with 4 servers × 4 GPUs,
/// rack 0 spans servers 0–1, so `rack:0` must kill exactly devices 0..8
/// and an `uplink:0` outage must target that rack's uplink — nothing more.
#[test]
fn rack_loss_blast_radius_covers_exactly_the_rack_on_fat_tree() {
    let c = fat_tree_cluster(4, 4, 2);
    let plan = FaultSpec::parse("rack:0@0.1+0.05").unwrap().resolve(&c).unwrap();
    assert_eq!(plan.kills.len(), 1);
    assert_eq!(plan.kills[0].devices, (0..8).collect::<Vec<_>>());
    assert_eq!(plan.kills[0].repair, 0.05);
    assert!(plan.outages.is_empty() && plan.slowdowns.is_empty());

    let plan = FaultSpec::parse("rack:1@0.1").unwrap().resolve(&c).unwrap();
    assert_eq!(plan.kills[0].devices, (8..16).collect::<Vec<_>>());

    let plan = FaultSpec::parse("uplink:0@0.2+0.1").unwrap().resolve(&c).unwrap();
    assert!(plan.kills.is_empty());
    assert_eq!(plan.outages.len(), 1);
    assert_eq!(plan.outages[0].link, LinkId::Up(0));

    // Flat fabrics have no racks: the same trace is a typed error there.
    let flat = Cluster::v100(16);
    assert!(FaultSpec::parse("rack:0@0.1").unwrap().resolve(&flat).is_err());
}

/// Losing a whole rack is strictly worse than losing one device of it:
/// the DES blast radius scales with the fault domain.
#[test]
fn rack_loss_hurts_more_than_a_single_device_loss() {
    let c = fat_tree_cluster(2, 2, 1);
    let out = megatron(&models::gpt3(0, 4, 256), 2, 1, 1, 2, PipeOrder::OneFOneB).unwrap();
    let vs = validate(&out.graph, &out.schedule).unwrap();
    let plan = materialize(&out.graph, &vs, &c, CommMode::InterRvd);
    let tg = TaskGraph::prepare(&vs, &plan);
    let base = des::simulate(&out.graph, &vs, &plan, &c);
    let mid = base.makespan * 0.5;
    let one = FaultSpec::parse(&format!("crash:d0@{mid}+0.001")).unwrap().resolve(&c).unwrap();
    let rack = FaultSpec::parse(&format!("rack:0@{mid}+0.001")).unwrap().resolve(&c).unwrap();
    let r_one = des::execute_faulted(&out.graph, &plan, &c, &tg, &one);
    let r_rack = des::execute_faulted(&out.graph, &plan, &c, &tg, &rack);
    assert!(r_one.makespan > base.makespan, "a mid-run crash must cost time");
    assert!(
        r_rack.makespan >= r_one.makespan,
        "rack loss ({}) cannot be cheaper than one device ({})",
        r_rack.makespan,
        r_one.makespan
    );
    let (fo, fr) = (r_one.faults.unwrap(), r_rack.faults.unwrap());
    assert_eq!(fo.n_kills, 1);
    assert_eq!(fr.n_kills, 2, "rack 0 holds two devices");
}
