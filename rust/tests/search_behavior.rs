//! Behavior preservation for the zero-rebuild search refactor (ISSUE 5):
//! the borrowed-model pipeline, the dense hot-path indexing and the cached
//! DES re-rank must change *nothing observable* — rankings, rendered
//! tables, DES scores and OOM verdicts all stay what a from-scratch
//! rebuild produces. (The byte-level table/CSV format itself is pinned by
//! the golden fixtures in `rust/tests/golden/` via `golden_formats.rs`.)

use superscaler::cost::Cluster;
use superscaler::materialize::{self, CommMode};
use superscaler::models;
use superscaler::plans::registry;
use superscaler::schedule::validate;
use superscaler::search::{self, Fidelity, SearchConfig};
use superscaler::{des, sim};

/// One borrowed model is the whole search's input: repeated searches over
/// the same `&Model` render byte-identical table rows (the title carries
/// the wall-clock, so rows are the deterministic surface), across runs and
/// worker counts — the probe really is read-only shared state.
#[test]
fn repeated_searches_on_one_borrowed_model_render_identical_rows() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(4);
    let rows = |workers: usize| {
        let cfg = SearchConfig::builder().workers(workers).build();
        search::search(&model, &cluster, &cfg).to_table(0).rows
    };
    let a = rows(1);
    assert!(!a.is_empty());
    assert_eq!(a, rows(1), "same inputs, same rows");
    assert_eq!(a, rows(4), "worker count must not leak into the ranking");
}

/// Prune-on and prune-off searches agree on the winner down to the
/// rendered row — dominance pruning (and the refactor underneath it)
/// cannot move or re-label the optimum.
#[test]
fn prune_on_off_agree_on_the_winning_row() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(4);
    let run = |prune: bool| {
        let cfg = SearchConfig::builder().workers(2).prune(prune).build();
        search::search(&model, &cluster, &cfg)
    };
    let (on, off) = (run(true), run(false));
    assert_eq!(
        on.to_table(1).rows,
        off.to_table(1).rows,
        "prune-on and prune-off winners must render identically"
    );
}

/// A `--fidelity des` search cannot move the list-tier measurement the CI
/// perf gate reads: `best_list_makespan` is bitwise what the plain list
/// search reports.
#[test]
fn des_rerank_does_not_move_the_list_gate_measurement() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(4);
    let run = |fidelity| {
        let cfg = SearchConfig::builder().workers(2).fidelity(fidelity).des_top(4).build();
        search::search(&model, &cluster, &cfg)
    };
    let (list, d) = (run(Fidelity::List), run(Fidelity::Des));
    let (a, b) = (
        list.best_list_makespan().expect("list winner"),
        d.best_list_makespan().expect("des-run list winner"),
    );
    assert_eq!(a.to_bits(), b.to_bits(), "gate measurement moved: {a} vs {b}");
    assert!(d.des_rescored > 0, "the DES tier must actually have re-scored candidates");
}

/// The cached DES re-rank must report exactly what a from-scratch rebuild
/// of the candidate reports: same `des_makespan` bits, same `des_oom` —
/// for every re-scored candidate, not just the winner.
#[test]
fn cached_des_rerank_matches_from_scratch_rebuild() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(4);
    let report = search::search(
        &model,
        &cluster,
        &SearchConfig::builder().workers(2).fidelity(Fidelity::Des).des_top(4).build(),
    );
    let mut checked = 0usize;
    for c in &report.ranked {
        let Some(m) = c.metrics() else { continue };
        let Some(cached_score) = m.des_makespan else { continue };
        // Re-run the full transform -> validate -> materialize -> DES
        // pipeline from scratch against the same borrowed model.
        let planner = registry::find(c.planner).expect("ranked planner is registered");
        let out = planner.build(&model, &c.spec).expect("re-build of a scored candidate");
        let vs = validate(&out.graph, &out.schedule).expect("re-validate");
        let plan = materialize::materialize(&out.graph, &vs, &cluster, CommMode::InterRvd);
        let r = des::simulate(&out.graph, &vs, &plan, &cluster);
        assert_eq!(
            r.makespan.to_bits(),
            cached_score.to_bits(),
            "{}: cached DES score diverged from a from-scratch rebuild",
            c.spec.label()
        );
        assert_eq!(r.oom, m.des_oom, "{}: DES-OOM verdict diverged", c.spec.label());
        checked += 1;
    }
    assert!(checked > 0, "no candidate carried a DES score to verify");
}

/// The list simulator's dense-indexed inner loop produces the same report
/// as running the plan end to end through the one-call wrapper — the
/// prepared-task-graph path and the convenience path cannot drift.
#[test]
fn dense_sim_paths_agree_bitwise() {
    let model = models::gpt3(0, 8, 256);
    let out = registry::find("megatron")
        .unwrap()
        .build(&model, &superscaler::plans::PlanSpec::parse("megatron pp4 k4").unwrap())
        .unwrap();
    let cluster = Cluster::v100(4);
    let vs = validate(&out.graph, &out.schedule).unwrap();
    let plan = materialize::materialize(&out.graph, &vs, &cluster, CommMode::InterRvd);
    let tg = sim::TaskGraph::prepare(&vs, &plan);
    let a = sim::simulate_prepared(&out.graph, &tg, &plan, &cluster);
    let b = sim::simulate(&out.graph, &vs, &plan, &cluster);
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.max_peak_mem(), b.max_peak_mem());
    assert_eq!(a.comm_bytes, b.comm_bytes);
    // And the DES consumes the same prepared graph without divergence.
    let da = des::execute(&out.graph, &plan, &cluster, &tg);
    let db = des::simulate(&out.graph, &vs, &plan, &cluster);
    assert_eq!(da.makespan.to_bits(), db.makespan.to_bits());
}
