//! Differential execution equivalence — the tier-1 slice of the
//! `verify-exec` matrix. The full planner-family × {2,4,8}-device sweep
//! runs in CI's `exec-equivalence` job; here a representative subset keeps
//! the transformation stack's semantics-preservation property under the
//! default `cargo test` run: execute each plan's task graph with real f32
//! tensors on the CPU reference executor and compare elementwise against
//! the single-device serial oracle.

use superscaler::exec::diff;

/// Run a subset of the matrix and assert every cell matches the oracle.
fn assert_matrix_passes(devices: &[usize], families: &[&str]) {
    let fams: Vec<String> = families.iter().map(|f| f.to_string()).collect();
    let out = diff::run_matrix(devices, &fams).expect("matrix runs");
    assert_eq!(out.cases.len(), devices.len() * families.len());
    for c in &out.cases {
        assert!(
            c.passed,
            "{}@{} ({}) diverged from the serial oracle: max_rel {:.3e}, {} elems, {:?}",
            c.family, c.devices, c.label, c.max_rel, c.compared, c.error
        );
        assert!(c.compared > 0, "{}@{} compared nothing — vacuous", c.family, c.devices);
        assert!(c.max_rel <= diff::REL_TOL, "{}@{}: {}", c.family, c.devices, c.max_rel);
    }
    assert!(out.all_passed);
}

#[test]
fn dp_and_tp_match_serial_oracle_on_two_devices() {
    assert_matrix_passes(&[2], &["dp", "tp", "dp-rvd"]);
}

#[test]
fn pipeline_families_match_serial_oracle_on_two_devices() {
    assert_matrix_passes(&[2], &["gpipe", "megatron", "zb"]);
}

#[test]
fn coshard_and_hetero_match_serial_oracle_on_two_devices() {
    assert_matrix_passes(&[2], &["coshard", "hetero"]);
}

#[test]
fn four_device_grid_plans_match_serial_oracle() {
    assert_matrix_passes(&[4], &["dp", "megatron"]);
}

#[test]
fn matrix_reports_calibration_samples() {
    let out = diff::run_matrix(&[2], &["dp".to_string()]).expect("matrix runs");
    let cal = &out.calibration;
    assert!(cal.n_samples > 0, "executed tasks must produce duration samples");
    assert!(cal.rows.iter().any(|r| r.kind.starts_with("compute:")));
    // Every row aggregates positive measured time and carries a ratio.
    for r in &cal.rows {
        assert!(r.n > 0);
        assert!(r.measured_total >= 0.0);
        assert!(r.ratio >= 0.0);
    }
    let j = out.to_json();
    assert_eq!(j.get("all_passed").and_then(|v| v.as_bool()), Some(true));
    assert!(j.get("calibration").and_then(|c| c.get("n_samples")).is_some());
}
