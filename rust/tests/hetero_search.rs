//! The heterogeneous-pipeline plan space and the cost-guided search on top
//! of it: hetero planner round-trip through the registry, per-stage spec
//! feasibility errors, dominance pruning soundness (prune-on and prune-off
//! searches agree on the optimum of a brute-forceable grid, with auditable
//! pruned/simulated accounting), and the acceptance claim that the
//! heterogeneous space never loses to the homogeneous pipeline grid it
//! strictly contains.

use superscaler::cost::Cluster;
use superscaler::models;
use superscaler::plans::{registry, PlanKind, PlanSpec, StageSpec};
use superscaler::schedule::validate;
use superscaler::search::{self, Infeasible, SearchConfig};

#[test]
fn hetero_roundtrip_via_registry() {
    let p = registry::find("hetero").expect("hetero registered");
    assert_eq!(p.kind(), PlanKind::Hetero);
    let model = models::gpt3(0, 8, 256);
    assert!(p.applicable(&model));
    let spec = p.default_spec(4, 4);
    assert_eq!(spec.kind, PlanKind::Hetero);
    assert_eq!(spec.devices(), 4);
    let out = p.build(&model, &spec).expect("hetero default spec builds");
    assert!(out.name.starts_with("hetero"), "{}", out.name);
    let vs = validate(&out.graph, &out.schedule).expect("hetero schedule validates");
    assert!(!vs.topo.is_empty());
}

#[test]
fn stage_spec_feasibility_errors() {
    let model = models::gpt3(0, 8, 256);
    let cluster = Cluster::v100(4);

    // tp and co-shard on the same stage are mutually exclusive.
    let conflict = PlanSpec::hetero(
        vec![StageSpec { tp: 2, shards: 4, ..StageSpec::default() }, StageSpec::tp(2)],
        4,
    );
    assert!(matches!(
        search::feasibility(&conflict, &model, &cluster),
        Err(Infeasible::StageConflict { stage: 0, tp: 2, shards: 4 })
    ));

    // pp must agree with the stage-list arity.
    let mut arity = PlanSpec::hetero(vec![StageSpec::tp(2), StageSpec::tp(2)], 4);
    arity.pp = 3;
    assert!(matches!(
        search::feasibility(&arity, &model, &cluster),
        Err(Infeasible::StageArity { pp: 3, stages: 2 })
    ));

    // Stage widths must tile the cluster.
    let narrow = PlanSpec::hetero(vec![StageSpec::tp(2), StageSpec::tp(1)], 4);
    assert!(matches!(
        search::feasibility(&narrow, &model, &cluster),
        Err(Infeasible::DeviceMismatch { want: 4, got: 3 })
    ));

    // Micro-batches finer than the per-replica batch are rejected.
    let fine = PlanSpec::hetero(vec![StageSpec::tp(2), StageSpec::tp(2)], 16);
    assert!(matches!(
        search::feasibility(&fine, &model, &cluster),
        Err(Infeasible::MicroTooFine { batch: 8, dp: 1, micro: 16 })
    ));

    // And the build itself reports a stage conflict when called directly.
    let p = registry::find("hetero").unwrap();
    let err = p.build(&models::gpt3(0, 8, 256), &conflict).unwrap_err();
    assert!(err.to_string().contains("mutually exclusive"), "{err}");
}

/// Dominance pruning must be sound: on a small brute-forceable grid, the
/// prune-on search finds a best plan exactly as good as the prune-off
/// search that simulates every feasible spec, and the pruned/simulated
/// accounting adds up to the same grid.
#[test]
fn dominance_pruning_never_prunes_the_optimum() {
    let cluster = Cluster::v100(4);
    let model = models::gpt3(0, 8, 256);
    let on =
        search::search(&model, &cluster, &SearchConfig::builder().workers(2).prune(true).build());
    let off =
        search::search(&model, &cluster, &SearchConfig::builder().workers(2).prune(false).build());
    assert_eq!(off.pruned_bound, 0, "prune-off must simulate everything");
    assert_eq!(
        on.evaluated + on.pruned_bound,
        off.evaluated,
        "pruned candidates must be accounted for, not dropped"
    );
    assert_eq!(on.pruned, off.pruned, "feasibility pruning is prune-flag independent");
    let tb = on.best().expect("prune-on search found a plan");
    let tf = off.best().expect("prune-off search found a plan");
    let (mb, mf) = (tb.metrics().unwrap().makespan, tf.metrics().unwrap().makespan);
    let rel = (mb - mf).abs() / mf.max(1e-12);
    assert!(
        rel < 1e-4,
        "prune-on best {mb} ({}) vs prune-off best {mf} ({})",
        tb.plan_name,
        tf.plan_name
    );
}

/// The heterogeneous space strictly contains the homogeneous pipeline grid
/// (uniform stage lists), so its best plan can never lose to the best
/// homogeneous megatron pipeline.
#[test]
fn hetero_best_not_worse_than_homogeneous_pipeline() {
    let cluster = Cluster::v100(4);
    let report = search::search(
        &models::gpt3(0, 8, 256),
        &cluster,
        &SearchConfig::builder().workers(2).prune(false).hetero(true).build(),
    );
    let best_of = |pred: &dyn Fn(&search::Candidate) -> bool| {
        report
            .ranked
            .iter()
            .filter(|c| pred(c))
            .filter_map(|c| c.metrics().filter(|m| !m.oom).map(|m| m.makespan))
            .fold(f64::INFINITY, f64::min)
    };
    let hetero = best_of(&|c| c.planner == "hetero");
    let homog = best_of(&|c| c.planner == "megatron" && c.spec.pp >= 2 && c.spec.dp == 1);
    assert!(hetero.is_finite(), "no hetero candidate simulated");
    assert!(homog.is_finite(), "no homogeneous pipeline candidate simulated");
    // 1% tolerance: the uniform-hetero construction is megatron-equivalent
    // to within the same bound its unit test asserts (hetero's TP split
    // alignment rule is deliberately stricter).
    assert!(
        hetero <= homog * 1.01,
        "best hetero {hetero} worse than best homogeneous pipeline {homog}"
    );
}

/// The report table must make search coverage auditable: simulated,
/// infeasible and cost-dominated counts all appear in the rendered title.
#[test]
fn report_table_carries_prune_accounting() {
    let cluster = Cluster::v100(4);
    let report = search::search(
        &models::gpt3(0, 8, 256),
        &cluster,
        &SearchConfig::builder().workers(2).build(),
    );
    // Every enumerated spec is either simulated, infeasible or
    // cost-dominated — nothing disappears from the accounting.
    let (feasible, infeasible) = search::enumerate(&models::gpt3(0, 8, 256), &cluster);
    assert_eq!(report.evaluated + report.pruned_bound, feasible.len());
    assert_eq!(report.pruned, infeasible);
    assert_eq!(report.total_candidates(), feasible.len() + infeasible);
    let rendered = report.to_table(5).render();
    assert!(rendered.contains("specs simulated"), "{rendered}");
    assert!(rendered.contains("infeasible"), "{rendered}");
    assert!(rendered.contains("cost-dominated"), "{rendered}");
}
