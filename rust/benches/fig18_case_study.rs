//! Fig. 18: the two searched-communication case studies, printed as RVD
//! transition paths (compare with the paper's diagrams).

use superscaler::cost::Cluster;
use superscaler::rvd::{p2p_baseline_time, search_inter, Rvd};
use superscaler::util::fmt_secs;

fn main() {
    let cluster = Cluster::v100(32);
    let bytes = 128u64 << 20;
    let src: Vec<usize> = (0..4).collect(); // server 0
    let dst: Vec<usize> = (8..16).collect(); // server 1

    println!("== Fig 18(a): 4 replicated tensors (server1) -> 8 replicated (server2) ==");
    let from = Rvd::new(4, 1, &[1]);
    let to = Rvd::new(8, 1, &[1]);
    let p = search_inter(&cluster, &src, &dst, bytes, &from, &to).expect("path");
    println!("searched: {}", p.describe(&from));
    println!(
        "time {} vs p2p {} ({:.1}x)",
        fmt_secs(p.time),
        fmt_secs(p2p_baseline_time(&cluster, &src, &dst, bytes, &to)),
        p2p_baseline_time(&cluster, &src, &dst, bytes, &to) / p.time
    );
    println!("(paper's plan: schunk -> RD-scatter -> all-gather)\n");

    println!("== Fig 18(b): 4 value-partials (server1) -> 8 dim-shards (server2) ==");
    let from = Rvd::new(1, 4, &[1]);
    let to = Rvd::new(1, 1, &[8]);
    let p = search_inter(&cluster, &src, &dst, bytes, &from, &to).expect("path");
    println!("searched: {}", p.describe(&from));
    println!(
        "time {} vs p2p {}",
        fmt_secs(p.time),
        fmt_secs(p2p_baseline_time(&cluster, &src, &dst, bytes, &to)),
    );
    println!("(paper's plan: reduce-scatter -> RD-scatter)");
}
