//! Fig. 16: GPT-3 1.3B strong scaling under the three communication tiers
//! (P2P send/recv, intra-RVD, inter-RVD). Left: growing pipeline
//! parallelism; right: growing tensor parallelism.

use superscaler::materialize::CommMode;
use superscaler::models::gpt3;
use superscaler::plans::*;
use superscaler::util::table::Table;
use superscaler::{cost::Cluster, sim};

fn tput(out: &PlanOutput, gpus: usize, mode: CommMode) -> String {
    let c = Cluster::v100(gpus);
    match sim::run(&out.graph, &out.schedule, &c, mode) {
        Ok(r) => format!("{:.2}", 1.0 / r.makespan), // iterations/sec
        Err(_) => "x".into(),
    }
}

fn main() {
    std::fs::create_dir_all("bench_results").ok();
    let batch = 64;
    let seq = 2048;
    let k = 4;

    let mut t = Table::new(
        "Fig 16 (left): GPT-3 1.3B throughput (iter/s) vs pipeline size",
        &["gpus(pp)", "p2p", "intra-rvd", "inter-rvd"],
    );
    for gpus in [2usize, 4, 8, 16] {
        let mk = || megatron(&gpt3(0, batch, seq), 1, gpus, 1, k, PipeOrder::OneFOneB).unwrap();
        t.row([
            gpus.to_string(),
            tput(&mk(), gpus, CommMode::P2POnly),
            tput(&mk(), gpus, CommMode::IntraRvd),
            tput(&mk(), gpus, CommMode::InterRvd),
        ]);
    }
    t.print();
    t.write_csv("bench_results/fig16_pp.csv").ok();

    let mut t = Table::new(
        "Fig 16 (right): GPT-3 1.3B throughput (iter/s) vs tensor-parallel size",
        &["gpus(tp)", "p2p", "intra-rvd", "inter-rvd"],
    );
    for gpus in [2usize, 4, 8, 16] {
        let mk = || megatron(&gpt3(0, batch, seq), 1, 1, gpus, 1, PipeOrder::OneFOneB).unwrap();
        t.row([
            gpus.to_string(),
            tput(&mk(), gpus, CommMode::P2POnly),
            tput(&mk(), gpus, CommMode::IntraRvd),
            tput(&mk(), gpus, CommMode::InterRvd),
        ]);
    }
    t.print();
    t.write_csv("bench_results/fig16_tp.csv").ok();
}
