//! Fig. 12 (a–d): end-to-end weak-scaling evaluation — all four models,
//! SuperScaler's new plans vs the empirical baselines, aggregate TFLOPS.
//!
//! Weak scaling follows Table 2: the model grows with the GPU count
//! {4, 8, 16, 32}. Global batch 512 (128 for AlphaFold2), as in §6.2.
//! OOM configurations print `x` like the paper's figures.
//!
//! Every plan is built through `plans::registry` from a declarative
//! `PlanSpec` — the same path the CLI and the search engine use.
//!
//! ```text
//! cargo bench --bench fig12_e2e                # all four subfigures
//! cargo bench --bench fig12_e2e -- --model swin --quick
//! ```

use superscaler::materialize::CommMode;
use superscaler::models;
use superscaler::plans::*;
use superscaler::util::cli::Args;
use superscaler::util::table::Table;
use superscaler::{cost::Cluster, sim};

fn tflops(out: &PlanOutput, gpus: usize) -> String {
    let cluster = Cluster::v100(gpus);
    match sim::run(&out.graph, &out.schedule, &cluster, CommMode::InterRvd) {
        Ok(r) if r.oom => "x (OOM)".to_string(),
        Ok(r) => format!("{:.0}", r.aggregate_tflops),
        Err(_) => "x (deadlock)".to_string(),
    }
}

fn fail(e: impl std::fmt::Display) -> String {
    format!("x ({e})")
}

/// Megatron-grid spec shorthand.
fn mspec(dp: usize, pp: usize, tp: usize, k: usize) -> PlanSpec {
    PlanSpec { dp, pp, tp, micro: k, ..PlanSpec::new(PlanKind::Megatron) }
}

/// SuperScaler's co-shard configuration for the weak-scaling rows:
/// co-shard heads 8 ways + ZeRO-style optimizer sharding across the DP
/// group (how the large points fit in 32 GB).
fn cspec(gpus: usize) -> PlanSpec {
    PlanSpec { dp: gpus, shards: 8, zero_shard: true, ..PlanSpec::new(PlanKind::Coshard) }
}

/// ZeRO-3 spec + registry name, offload optional.
fn zspec(gpus: usize, offload: bool) -> (&'static str, PlanSpec) {
    if offload {
        (
            "zero3-offload",
            PlanSpec { dp: gpus, offload: true, ..PlanSpec::new(PlanKind::Zero3Offload) },
        )
    } else {
        ("zero3", PlanSpec { dp: gpus, ..PlanSpec::new(PlanKind::Zero3) })
    }
}

fn main() {
    let args = Args::parse_env();
    let only = args.get("model").map(|s| s.to_string());
    // Default sweep stops at 16 GPUs to keep `make bench` wall time
    // bounded; pass --full for the paper's 32-GPU points, --quick for CI.
    let quick = args.bool("quick", false);
    let full = args.bool("full", false);
    let gpus_list: Vec<usize> = if quick {
        vec![4, 8]
    } else if full {
        vec![4, 8, 16, 32]
    } else {
        vec![4, 8, 16]
    };
    let k = args.usize("micro", 4);
    std::fs::create_dir_all("bench_results").ok();

    // ---------- (a) Swin-Transformer ----------
    if only.as_deref().map(|m| m == "swin").unwrap_or(true) {
        let mut t = Table::new(
            "Fig 12(a): Swin-Transformer weak scaling (aggregate TFLOPS, micro-batch 1, 512x512)",
            &["gpus", "params", "superscaler(coshard)", "megatron(tp)", "deepspeed(zero3)"],
        );
        for (i, &gpus) in gpus_list.iter().enumerate() {
            // Per-device micro-batch 1 (the paper's Fig. 13 setting; the
            // global batch is reached by gradient accumulation outside the
            // simulated iteration).
            let batch = gpus;
            // Resolution 512 (not the paper's 1536): our IR replicates
            // layernorm/residual activations under TP (no sequence
            // parallelism), so the full-resolution point OOMs for every
            // system; at 512 the relative ordering emerges. See
            // EXPERIMENTS.md Fig. 12(a).
            let mk = || models::swin_transformer(i, batch, 512);
            let params = format!("{:.1}B", mk().num_params() as f64 / 1e9);
            // SuperScaler: co-shard heads + sharded optimizer state (DP across all).
            let ss = registry::build("coshard", &mk(), &cspec(gpus))
                .map(|o| tflops(&o, gpus))
                .unwrap_or_else(fail);
            // Megatron: tensor parallelism wide enough to fit (paper: 16/32-way at scale).
            let tp = gpus.min(8 * (i + 1));
            let mg = registry::build("megatron", &mk(), &mspec(gpus / tp, 1, tp, k))
                .map(|o| tflops(&o, gpus))
                .unwrap_or_else(fail);
            let (zn, zs) = zspec(gpus, i >= 2);
            let zr = registry::build(zn, &mk(), &zs).map(|o| tflops(&o, gpus)).unwrap_or_else(fail);
            t.row([gpus.to_string(), params, ss, mg, zr]);
        }
        t.print();
        t.write_csv("bench_results/fig12a_swin.csv").ok();
    }

    // ---------- (b) GPT-3 ----------
    if only.as_deref().map(|m| m == "gpt3").unwrap_or(true) {
        let mut t = Table::new(
            "Fig 12(b): GPT-3 weak scaling (aggregate TFLOPS, batch 512, seq 16384)",
            &[
                "gpus",
                "params",
                "superscaler(coshard)",
                "megatron",
                "alpa-like",
                "deepspeed(zero3)",
            ],
        );
        for (i, &gpus) in gpus_list.iter().enumerate() {
            // Micro-batch 1 per device (grad-accumulated to the paper's
            // global 512); at seq 16384 anything larger OOMs every system.
            let batch = gpus;
            let seq = 16384;
            let mk = || models::gpt3(i, batch, seq);
            let params = format!("{:.1}B", mk().num_params() as f64 / 1e9);
            let ss = registry::build("coshard", &mk(), &cspec(gpus))
                .map(|o| tflops(&o, gpus))
                .unwrap_or_else(fail);
            let tp = gpus.min(16);
            let mg = registry::build("megatron", &mk(), &mspec((gpus / tp).max(1), 1, tp, k))
                .map(|o| tflops(&o, gpus))
                .unwrap_or_else(fail);
            // Alpa-like: stage-wise search approximated by the best of a few
            // (dp, pp, tp) grids.
            let alpa = ["a", "b", "c"]
                .iter()
                .enumerate()
                .filter_map(|(j, _)| {
                    let (dp, pp, tp) = match j {
                        0 => (1, gpus.min(4), gpus / gpus.min(4)),
                        1 => ((gpus / 8).max(1), 1, gpus.min(8)),
                        _ => (1, 1, gpus),
                    };
                    if dp * pp * tp != gpus {
                        return None;
                    }
                    registry::build("megatron", &mk(), &mspec(dp, pp, tp, k)).ok().map(|o| {
                        let c = Cluster::v100(gpus);
                        sim::run(&o.graph, &o.schedule, &c, CommMode::InterRvd)
                            .ok()
                            .filter(|r| !r.oom)
                            .map(|r| r.aggregate_tflops)
                            .unwrap_or(0.0)
                    })
                })
                .fold(0.0f64, f64::max);
            let alpa = if alpa > 0.0 { format!("{alpa:.0}") } else { "x (OOM)".into() };
            let (zn, zs) = zspec(gpus, i >= 3);
            let zr = registry::build(zn, &mk(), &zs).map(|o| tflops(&o, gpus)).unwrap_or_else(fail);
            t.row([gpus.to_string(), params, ss, mg, alpa, zr]);
        }
        t.print();
        t.write_csv("bench_results/fig12b_gpt3.csv").ok();
    }

    // ---------- (c) mBART ----------
    if only.as_deref().map(|m| m == "mbart").unwrap_or(true) {
        let mut t = Table::new(
            "Fig 12(c): mBART weak scaling (aggregate TFLOPS, batch 512, seq 1024, 500k vocab)",
            &[
                "gpus",
                "params",
                "superscaler(interlaced)",
                "megatron(tp)",
                "deepspeed(zero3-offload)",
            ],
        );
        for (i, &gpus) in gpus_list.iter().enumerate() {
            let batch = 2 * gpus; // micro-batch 2/device, grad-accumulated
            let mk = || models::mbart(i, batch, 1024);
            let params = format!("{:.1}B", mk().num_params() as f64 / 1e9);
            let il_spec = PlanSpec {
                pp: gpus,
                micro: k,
                recompute: true,
                ..PlanSpec::new(PlanKind::Interlaced)
            };
            let ss = registry::build("interlaced", &mk(), &il_spec)
                .map(|o| tflops(&o, gpus))
                .unwrap_or_else(fail);
            let tp = gpus.min(16);
            let mg = registry::build("megatron", &mk(), &mspec((gpus / tp).max(1), 1, tp, k))
                .map(|o| tflops(&o, gpus))
                .unwrap_or_else(fail);
            let (zn, zs) = zspec(gpus, true);
            let zr = registry::build(zn, &mk(), &zs).map(|o| tflops(&o, gpus)).unwrap_or_else(fail);
            t.row([gpus.to_string(), params, ss, mg, zr]);
        }
        t.print();
        t.write_csv("bench_results/fig12c_mbart.csv").ok();
    }

    // ---------- (d) AlphaFold2 ----------
    if only.as_deref().map(|m| m == "alphafold2").unwrap_or(true) {
        let mut t = Table::new(
            "Fig 12(d): AlphaFold2 weak scaling (aggregate TFLOPS, batch 128, 3F+1B recycling)",
            &["gpus", "params", "superscaler(3f1b)", "dap+dp", "deepspeed(zero3)"],
        );
        for (i, &gpus) in gpus_list.iter().enumerate() {
            // Paper trains batch 128 on 128-GPU-scale clusters; per-GPU
            // load ~1 sample. Keep that ratio here.
            let batch = gpus; // per-device micro-batch 1, grad-accumulated
            let mk = || models::alphafold2(i, batch);
            let params = format!("{:.2}B", mk().num_params() as f64 / 1e9);
            let f3_spec = PlanSpec { pp: gpus, micro: k, ..PlanSpec::new(PlanKind::ThreeFOneB) };
            let ss = registry::build("3f1b", &mk(), &f3_spec)
                .map(|o| tflops(&o, gpus))
                .unwrap_or_else(fail);
            let dap_ways = gpus.min(4 << i.min(3));
            let dp_ways = (gpus / dap_ways).max(1);
            let dap_spec = PlanSpec { dp: dp_ways, tp: dap_ways, ..PlanSpec::new(PlanKind::Dap) };
            let dap = registry::build("dap", &mk(), &dap_spec)
                .map(|o| tflops(&o, gpus))
                .unwrap_or_else(fail);
            let (zn, zs) = zspec(gpus, false);
            let zr = registry::build(zn, &mk(), &zs).map(|o| tflops(&o, gpus)).unwrap_or_else(fail);
            t.row([gpus.to_string(), params, ss, dap, zr]);
        }
        t.print();
        t.write_csv("bench_results/fig12d_alphafold.csv").ok();
    }
}
