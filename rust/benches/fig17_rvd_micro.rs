//! Table 3 + Fig. 17: the 18 inter-RVD micro-benchmark cases — producers on
//! one server, consumers on another, 1-D tensor; searched plan latency vs
//! the P2P send/recv baseline.

use superscaler::cost::Cluster;
use superscaler::rvd::{p2p_baseline_time, search_inter, Rvd};
use superscaler::util::fmt_secs;
use superscaler::util::table::Table;

fn main() {
    std::fs::create_dir_all("bench_results").ok();
    let cluster = Cluster::v100(32);
    let bytes = 256u64 << 20; // 256 MiB tensor
    let mut t = Table::new(
        "Fig 17 / Table 3: inter-RVD search vs P2P (1-D tensor, 256 MiB, cross-server)",
        &["case", "producers", "consumers", "cfg", "rvd time", "p2p time", "speedup", "plan"],
    );
    // Table 3: producer category x consumer category x (8->8, 8->4, 4->8).
    let prod_cat = |i: usize, n: usize| -> Rvd {
        match i {
            0 => Rvd::new(n, 1, &[1]),     // R(i)
            1 => Rvd::new(1, n, &[1]),     // V(i)
            _ => Rvd::new(1, 1, &[n]),     // D(i)
        }
    };
    let cons_cat = |j: usize, n: usize| -> Rvd {
        match j {
            0 => Rvd::new(n, 1, &[1]),     // R(j)
            _ => Rvd::new(1, 1, &[n]),     // D(j)
        }
    };
    let mut case = 0;
    let mut wins = 0;
    let mut best_speedup: f64 = 0.0;
    for pi in 0..3 {
        for cj in 0..2 {
            for &(np, nc) in &[(8usize, 8usize), (8, 4), (4, 8)] {
                case += 1;
                let from = prod_cat(pi, np);
                let to = cons_cat(cj, nc);
                let src: Vec<usize> = (0..np).collect();
                let dst: Vec<usize> = (8..8 + nc).collect();
                let p2p = p2p_baseline_time(&cluster, &src, &dst, bytes, &to);
                match search_inter(&cluster, &src, &dst, bytes, &from, &to) {
                    Some(p) => {
                        let speedup = p2p / p.time.max(1e-12);
                        if speedup > 1.05 {
                            wins += 1;
                        }
                        best_speedup = best_speedup.max(speedup);
                        t.row([
                            case.to_string(),
                            format!("{from}"),
                            format!("{to}"),
                            format!("{np}->{nc}"),
                            fmt_secs(p.time),
                            fmt_secs(p2p),
                            format!("{speedup:.1}x"),
                            p.describe(&from),
                        ]);
                    }
                    None => t.row([
                        case.to_string(),
                        format!("{from}"),
                        format!("{to}"),
                        format!("{np}->{nc}"),
                        "no path".into(),
                        fmt_secs(p2p),
                        "-".into(),
                        "-".into(),
                    ]),
                }
            }
        }
    }
    t.print();
    t.write_csv("bench_results/fig17_rvd_micro.csv").ok();
    println!("inter-RVD beats P2P in {wins}/18 cases; best speedup {best_speedup:.0}x");
    println!("(paper: 12/18 cases, up to 57x)");
}
