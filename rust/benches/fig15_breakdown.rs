//! Fig. 15: mBART end-to-end time breakdown (compute / communication /
//! bubble) — Megatron vs IL-block (interlaced + coarse recompute barrier)
//! vs SuperScaler (interlaced + fine-grained recompute dependencies).
//! The `DES` column replays each plan on the discrete-event engine
//! (comm/compute overlap + link contention); `total − DES` is the overlap
//! headroom the synchronous list model cannot credit.

use superscaler::materialize::CommMode;
use superscaler::models::mbart;
use superscaler::plans::*;
use superscaler::util::fmt_secs;
use superscaler::util::table::Table;
use superscaler::{cost::Cluster, des, sim};

fn main() {
    std::fs::create_dir_all("bench_results").ok();
    let mut t = Table::new(
        "Fig 15: mBART time breakdown per iteration (avg per device)",
        &["gpus", "system", "total", "DES", "compute", "comm", "bubble"],
    );
    for (scale, gpus) in [(2usize, 16usize), (3, 32)] {
        let batch = 128;
        // Micro-batches must be comparable to the stage count for the pipe
        // to fill (bubble fraction ~ (S-1)/(S-1+K)); capped to bound the
        // bench wall time.
        let k = gpus.min(16);
        let cluster = Cluster::v100(gpus);
        let cases: Vec<(&str, PlanResult)> = vec![
            (
                "megatron",
                megatron(
                    &mbart(scale, batch, 1024),
                    (gpus / 16).max(1),
                    1,
                    gpus.min(16),
                    k,
                    PipeOrder::OneFOneB,
                ),
            ),
            ("IL-block", interlaced_pipeline(&mbart(scale, batch, 1024), gpus, k, true, true)),
            ("superscaler", interlaced_pipeline(&mbart(scale, batch, 1024), gpus, k, true, false)),
        ];
        for (name, out) in cases {
            let both = out.map(|o| -> Result<_, superscaler::schedule::ScheduleError> {
                let vs = superscaler::schedule::validate(&o.graph, &o.schedule)?;
                let plan = superscaler::materialize::materialize(
                    &o.graph,
                    &vs,
                    &cluster,
                    CommMode::InterRvd,
                );
                let tg = sim::TaskGraph::prepare(&vs, &plan);
                let list = sim::simulate_prepared(&o.graph, &tg, &plan, &cluster);
                let d = des::execute(&o.graph, &plan, &cluster, &tg);
                Ok((list, d))
            });
            match both {
                Ok(Ok((r, d))) => {
                    let (c, m, b) = r.breakdown();
                    t.row([
                        gpus.to_string(),
                        name.to_string(),
                        fmt_secs(r.makespan),
                        fmt_secs(d.makespan),
                        fmt_secs(c),
                        fmt_secs(m),
                        fmt_secs(b),
                    ]);
                }
                _ => t.row([
                    gpus.to_string(),
                    name.to_string(),
                    "x".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
    }
    t.print();
    t.write_csv("bench_results/fig15_mbart_breakdown.csv").ok();
}
