//! Fig. 13 + Fig. 14: single-GPU memory & latency — co-shard vs recompute
//! vs ZeRO3-Offload. Fig. 13 grows the Swin model size; Fig. 14 grows the
//! GPT-3 1.3B sequence length. Micro-batch fixed to 1 (paper setting).

use superscaler::materialize::CommMode;
use superscaler::models;
use superscaler::plans::*;
use superscaler::util::table::Table;
use superscaler::util::{fmt_bytes, fmt_secs};
use superscaler::{cost::Cluster, sim};

fn probe(out: PlanResult, cluster: &Cluster) -> (String, String) {
    match out {
        Err(e) => (format!("x ({e})"), "-".into()),
        Ok(o) => match sim::run(&o.graph, &o.schedule, cluster, CommMode::InterRvd) {
            Err(_) => ("x (deadlock)".into(), "-".into()),
            Ok(r) => {
                let mem = if r.oom {
                    format!("OOM ({})", fmt_bytes(r.max_peak_mem()))
                } else {
                    fmt_bytes(r.max_peak_mem())
                };
                (mem, fmt_secs(r.makespan))
            }
        },
    }
}

fn main() {
    std::fs::create_dir_all("bench_results").ok();
    let cluster = Cluster::v100(8);

    // ---- Fig. 13: Swin, growing model size, single GPU ----
    let mut t = Table::new(
        "Fig 13: Swin single-GPU peak memory / latency vs model size (micro-batch 1)",
        &[
            "hidden",
            "params",
            "coshard mem",
            "coshard lat",
            "recompute mem",
            "recompute lat",
            "zero3-offload mem",
            "zero3-offload lat",
        ],
    );
    // Paper Fig. 13 sweeps 115M -> 1.3B Swin variants (below Table 2's
    // smallest column); micro-batch 1, resolution 1536.
    let shapes =
        [(16usize, 128usize, 4usize), (24, 192, 6), (24, 256, 8), (32, 320, 10), (32, 384, 12)];
    for (layers, hidden, heads) in shapes {
        let mk = || models::swin_custom(layers, hidden, heads, 1, 1536);
        let params = format!("{:.0}M", mk().num_params() as f64 / 1e6);
        // co-shard: heads split sequentially + recompute.
        let (m1, l1) = probe(coshard(&mk(), 1, 4, None), &cluster);
        // recompute baseline = same plan without co-sharding (shards=1).
        let (m2, l2) = probe(coshard(&mk(), 1, 1, None), &cluster);
        let (m3, l3) = probe(zero3(&mk(), 1, true), &cluster);
        t.row([hidden.to_string(), params, m1, l1, m2, l2, m3, l3]);
    }
    t.print();
    t.write_csv("bench_results/fig13_swin_memory.csv").ok();

    // ---- Fig. 14: GPT-3 1.3B, growing sequence length ----
    let mut t = Table::new(
        "Fig 14: GPT-3 1.3B single-GPU peak memory / latency vs sequence length (micro-batch 1)",
        &[
            "seq",
            "coshard mem",
            "coshard lat",
            "recompute mem",
            "recompute lat",
            "zero3-offload mem",
            "zero3-offload lat",
        ],
    );
    for seq in [2048usize, 4096, 6144, 8192, 10240] {
        let mk = || models::gpt3(0, 1, seq);
        let (m1, l1) = probe(coshard(&mk(), 1, 8, None), &cluster);
        let (m2, l2) = probe(coshard(&mk(), 1, 1, None), &cluster);
        let (m3, l3) = probe(zero3(&mk(), 1, true), &cluster);
        t.row([seq.to_string(), m1, l1, m2, l2, m3, l3]);
    }
    t.print();
    t.write_csv("bench_results/fig14_gpt3_memory.csv").ok();
}
