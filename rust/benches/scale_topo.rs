//! Fabric scaling bench: route resolution, link-set construction, DES
//! contention replay and plan search at 1k–10k devices across the three
//! topology families. The numbers to watch are routes/sec and
//! group_links/sec (both must stay allocation-free and flat across cluster
//! size — the spine table is O(tiers²), not O(devices²)) and the DES/search
//! walls at 1024+ GPUs (what CI's scale-smoke job gates end-to-end).
//!
//! `cargo bench --bench scale_topo [-- --max-gpus 10240] [--search]`

use superscaler::cost::Cluster;
use superscaler::des;
use superscaler::materialize::{Plan, Task, TaskKind};
use superscaler::models::gpt3;
use superscaler::search::{self, Fidelity, SearchConfig};
use superscaler::sim::TaskGraph;
use superscaler::topo::build_cluster;
use superscaler::util::cli::Args;
use superscaler::util::table::{time_it, Table};
use superscaler::Graph;

/// Deterministic device scatter: pairs spread across servers/racks/rails.
fn pair(i: usize, n: usize) -> (usize, usize) {
    (i % n, (i * 257 + 31) % n)
}

fn bench_routing(t: &mut Table, gpus: usize, topology: &str) {
    let c = build_cluster(gpus, None, topology, None).unwrap();
    const ROUTES: usize = 100_000;
    let mut buf = Vec::new();
    let mut touched = 0usize;
    let (mean, _) = time_it(1, 3, || {
        touched = 0;
        for i in 0..ROUTES {
            let (a, b) = pair(i, gpus);
            c.topo.route_into(a, b, &mut buf);
            touched += buf.len();
        }
    });
    let routes_per_sec = ROUTES as f64 / mean;

    // Link sets for dp-style groups (one member per server, 8 groups).
    let n_servers = c.n_servers;
    let groups: Vec<Vec<usize>> = (0..8)
        .map(|g| (0..n_servers).map(|s| s * c.gpus_per_server + g % c.gpus_per_server).collect())
        .collect();
    let mut links = 0usize;
    let (gmean, _) = time_it(1, 3, || {
        links = 0;
        for grp in &groups {
            links += c.group_links(grp).len();
        }
    });
    let groups_per_sec = groups.len() as f64 / gmean;

    t.row([
        gpus.to_string(),
        topology.to_string(),
        format!("{:.2e}", routes_per_sec),
        format!("{touched}"),
        format!("{:.2e}", groups_per_sec),
        format!("{links}"),
    ]);
}

/// A synthetic contention storm: one cross-fabric transfer per server,
/// all concurrent — the DES fair-shares every route hop.
fn bench_des(t: &mut Table, gpus: usize, topology: &str) {
    let c = build_cluster(gpus, None, topology, None).unwrap();
    let n = c.n_servers;
    let mut plan = Plan::default();
    for s in 0..n {
        let from = s * c.gpus_per_server;
        let to = ((s + n / 2) % n) * c.gpus_per_server;
        if c.server_of(from) == c.server_of(to) {
            continue;
        }
        plan.tasks.push(Task {
            id: plan.tasks.len(),
            kind: TaskKind::P2P { from, to, bytes: 1 << 24, ptensor: 0 },
            deps: vec![],
            duration: c.p2p_time(from, to, 1 << 24),
            label: "storm".into(),
        });
    }
    let tasks = plan.tasks.len();
    let tg = TaskGraph::of_plan(&plan);
    let mut makespan = 0.0;
    let (mean, _) = time_it(1, 3, || {
        makespan = des::execute(&Graph::new(), &plan, &c, &tg).makespan;
    });
    t.row([
        gpus.to_string(),
        topology.to_string(),
        tasks.to_string(),
        format!("{:.3}", mean * 1e3),
        format!("{:.2e}", makespan),
    ]);
}

fn bench_search(t: &mut Table, gpus: usize, topology: &str) {
    let c = build_cluster(gpus, None, topology, None).unwrap();
    let model = gpt3(0, 1024, 128);
    let cfg = SearchConfig::builder()
        .workers(0)
        .hetero(false)
        .max_candidates(12)
        .fidelity(Fidelity::List)
        .build();
    let report = search::search(&model, &c, &cfg);
    t.row([
        gpus.to_string(),
        topology.to_string(),
        report.evaluated.to_string(),
        format!("{:.2}", report.wall_secs),
    ]);
}

fn main() {
    let args = Args::parse_env();
    std::fs::create_dir_all("bench_results").ok();
    let max_gpus = args.usize("max-gpus", 10240);
    let sizes: Vec<usize> = [1024usize, 4096, 10240].into_iter().filter(|&g| g <= max_gpus).collect();
    let topologies = ["flat", "fat-tree:8", "rail:4"];

    let mut t = Table::new(
        "fabric scaling: route resolution + link sets (per-call cost flat in cluster size)",
        &["gpus", "topology", "routes/s", "hops", "group_links/s", "links"],
    );
    for &g in &sizes {
        for topo in topologies {
            bench_routing(&mut t, g, topo);
        }
    }
    t.print();
    t.write_csv("bench_results/scale_topo_routing.csv").ok();

    let mut t = Table::new(
        "DES contention storm: one cross-fabric transfer per server, all concurrent",
        &["gpus", "topology", "tasks", "wall_ms", "makespan_s"],
    );
    for &g in &sizes {
        for topo in topologies {
            bench_des(&mut t, g, topo);
        }
    }
    t.print();
    t.write_csv("bench_results/scale_topo_des.csv").ok();

    // Full plan search at 1k devices (the CI scale-smoke shape). Gated
    // behind --search: it dominates the bench wall.
    if args.has("search") {
        let mut t = Table::new(
            "plan search at scale (gpt3, list fidelity, 12-candidate cap)",
            &["gpus", "topology", "evaluated", "wall_s"],
        );
        for topo in topologies {
            bench_search(&mut t, 1024, topo);
        }
        t.print();
        t.write_csv("bench_results/scale_topo_search.csv").ok();
    }
}
