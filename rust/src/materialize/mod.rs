//! Phase 3 — data dependency materialization (paper §3.3, Fig. 8; §4).
//!
//! After transformation and scheduling, producer vTensors may mismatch
//! consumer vTensors (different masks) or live on different devices.
//! [`materialize`] turns the *logical* dependencies tracked through masks
//! into an executable [`Plan`]: compute tasks (one per live op) plus
//! communication tasks connecting them.
//!
//! Communication synthesis has three tiers:
//! 1. **aligned & co-located** — producer covers the consumer's region with
//!    full values on the same device: a plain dependency edge, no traffic;
//! 2. **RVD collectives** (§4) — when producer and consumer views form
//!    *even* partitions, their RVD states are inferred and a Dijkstra
//!    search composes collectives ([`crate::rvd`]); this is the paper's
//!    "aligning with efficient communication collectives";
//! 3. **generic P2P** (Fig. 8) — irregular overlaps fall back to
//!    split → send/recv → concat-or-reduce, exactly the paper's four-step
//!    construction.
//!
//! Weights/optimizer state are produced by the *previous* iteration's
//! optimizer: their redistribution tasks (e.g. ZeRO's weight all-gather)
//! carry cost but no intra-iteration producer dependency.

use crate::cost::Cluster;
use crate::graph::{mask::Mask, CollKind, Graph, OpId, PTensorId, TensorKind};
use crate::rvd::{self, Rvd};
use crate::schedule::{DeviceId, ValidatedSchedule};
use std::collections::HashMap;
use std::sync::Arc;

pub type TaskId = usize;

/// Sentinel in [`Plan::task_of_op`] for op-id slots without a compute task
/// (removed ops, or ops outside the materialized schedule).
pub const NO_TASK: TaskId = usize::MAX;

/// One schedulable unit of the materialized plan.
#[derive(Clone, Debug)]
pub enum TaskKind {
    /// Execute graph op `op` on `device`.
    Compute { op: OpId, device: DeviceId },
    /// Point-to-point transfer.
    P2P { from: DeviceId, to: DeviceId, bytes: u64, ptensor: PTensorId },
    /// Collective over `group`; `bytes` is the per-rank payload.
    Collective {
        kind: CollKind,
        group: Vec<DeviceId>,
        bytes: u64,
        ptensor: PTensorId,
    },
}

#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub kind: TaskKind,
    /// Tasks that must complete before this one starts.
    pub deps: Vec<TaskId>,
    /// Modeled duration, seconds (cost model applied at materialization).
    pub duration: f64,
    /// Human-readable label for traces. Shared (`Arc<str>`): the K
    /// micro-batch transfers of one pTensor (and the per-subgroup tasks of
    /// one sync step) all point at a single interned string, so the
    /// per-candidate materialization pass stops allocating a fresh `String`
    /// per task and task clones are pointer bumps.
    pub label: Arc<str>,
}

impl Task {
    /// Devices this task occupies while running (deduplicated — inferred
    /// collective groups may list a device once per value-partial slot).
    pub fn devices(&self) -> Vec<DeviceId> {
        let mut v = match &self.kind {
            TaskKind::Compute { device, .. } => vec![*device],
            TaskKind::P2P { from, to, .. } => vec![*from, *to],
            TaskKind::Collective { group, .. } => group.clone(),
        };
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn is_comm(&self) -> bool {
        !matches!(self.kind, TaskKind::Compute { .. })
    }

    /// Bytes moved (0 for compute).
    pub fn comm_bytes(&self) -> u64 {
        match &self.kind {
            TaskKind::Compute { .. } => 0,
            TaskKind::P2P { bytes, .. } => *bytes,
            TaskKind::Collective { bytes, group, .. } => *bytes * group.len() as u64,
        }
    }
}

/// The materialized, executable plan.
#[derive(Clone, Debug, Default)]
pub struct Plan {
    pub tasks: Vec<Task>,
    /// op -> its compute task, densely indexed by op-id slot ([`NO_TASK`]
    /// for slots without one). A `Vec` rather than a `HashMap`: the task
    /// graph preparation and materialization's dependency wiring look ops
    /// up on every edge, which dominates the per-candidate evaluation the
    /// search engine runs thousands of times.
    pub task_of_op: Vec<TaskId>,
    /// Static per-device memory (weights + gradients + optimizer state
    /// shards resident for the whole iteration), bytes.
    pub static_mem: HashMap<DeviceId, u64>,
    /// The gradient-region share of [`Plan::static_mem`], bytes. The list
    /// scheduler keeps gradients in the static baseline (high-watermark
    /// semantics); the DES subtracts this share and replays gradient
    /// liveness from the timeline instead ([`crate::sim::gradient_events`]),
    /// so OOM verdicts depend on *when* gradient buffers are live.
    pub static_grad_mem: HashMap<DeviceId, u64>,
    /// Total communication volume, bytes (for §6.5-style reporting).
    pub comm_bytes: u64,
    /// Count of dependency edges materialized through each tier.
    pub n_direct: usize,
    pub n_rvd: usize,
    pub n_p2p: usize,
}

impl Plan {
    fn push(
        &mut self,
        kind: TaskKind,
        deps: Vec<TaskId>,
        duration: f64,
        label: Arc<str>,
    ) -> TaskId {
        let id = self.tasks.len();
        self.comm_bytes += match &kind {
            TaskKind::Compute { .. } => 0,
            _ => 0, // updated below via comm_bytes()
        };
        let t = Task { id, kind, deps, duration, label };
        self.comm_bytes += t.comm_bytes();
        self.tasks.push(t);
        id
    }
}

/// Strategy knob for §6.5's ablation (Fig. 16): force the naive P2P tier,
/// allow intra-group RVD only, or full inter-RVD.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CommMode {
    P2POnly,
    IntraRvd,
    InterRvd,
}

/// A producer or consumer view of a pTensor during materialization.
#[derive(Clone, Debug)]
struct View {
    op: OpId,
    mask: Mask,
    device: DeviceId,
}

/// Materialize `g` + `vs` into an executable [`Plan`] against `cluster`.
pub fn materialize(g: &Graph, vs: &ValidatedSchedule, cluster: &Cluster, mode: CommMode) -> Plan {
    let mut plan = Plan::default();
    plan.task_of_op = vec![NO_TASK; g.num_op_slots()];
    // op -> device lookup table, densely indexed by op-id slot. The
    // unassigned sentinel is deliberately distinct from CPU_DEVICE
    // (usize::MAX): validation guarantees every op it names is assigned,
    // and if that invariant ever breaks, the debug assert below keeps it a
    // loud panic instead of a silently host-priced task.
    const UNSCHEDULED: DeviceId = usize::MAX - 1;
    let mut dev_of: Vec<DeviceId> = vec![UNSCHEDULED; g.num_op_slots()];
    for (&d, ops) in &vs.device_order {
        for &o in ops {
            dev_of[o] = d;
        }
    }
    let dev_of = |op: OpId| -> DeviceId {
        let d = dev_of[op];
        debug_assert_ne!(d, UNSCHEDULED, "op {op} reached materialization unscheduled");
        d
    };

    // ---- compute tasks, in global topo order ----
    for &op in &vs.topo {
        let device = dev_of(op);
        let flops = g.op(op).flops;
        // Per-device spec: mixed fleets price each op by its server row's
        // device kind (CPU ops by the host spec).
        let dur = cluster.device_spec(device).compute_time(flops);
        let id = plan.push(
            TaskKind::Compute { op, device },
            Vec::new(),
            dur,
            Arc::from(g.op(op).name.as_str()),
        );
        plan.task_of_op[op] = id;
    }

    // ---- group dependencies per (ptensor, consumer-mask-pattern) ----
    // deps: (producer, consumer, ptensor) chosen by scheduling validation.
    let mut by_pt: HashMap<PTensorId, (Vec<View>, Vec<View>)> = HashMap::new();
    let mut seen: std::collections::HashSet<(OpId, PTensorId, bool)> = Default::default();
    for &(p, c, pt) in &vs.deps {
        if seen.insert((p, pt, true)) {
            for &ov in &g.op(p).outputs {
                let vt = g.vtensor(ov);
                if vt.ptensor == pt {
                    by_pt.entry(pt).or_default().0.push(View {
                        op: p,
                        mask: vt.mask.clone(),
                        device: dev_of(p),
                    });
                }
            }
        }
        if seen.insert((c, pt, false)) {
            for &iv in &g.op(c).inputs {
                let vt = g.vtensor(iv);
                if vt.ptensor == pt {
                    by_pt.entry(pt).or_default().1.push(View {
                        op: c,
                        mask: vt.mask.clone(),
                        device: dev_of(c),
                    });
                }
            }
        }
    }
    // Weight/OptState pTensors consumed by ops but *produced* by the
    // previous iteration's optimizer: producers = optimizer output views,
    // cross-iteration (no dep edges into this iteration's tasks).
    let access = g.ptensor_access();
    for (&pt, (prods, cons)) in &access {
        let kind = g.ptensor(pt).kind;
        if !matches!(kind, TensorKind::Weight | TensorKind::OptState) {
            continue;
        }
        let entry = by_pt.entry(pt).or_default();
        if entry.1.is_empty() {
            for &c in cons {
                if g.op(c).kind == crate::graph::OpKind::Optimizer {
                    continue; // optimizer reads its own shard in place
                }
                for &iv in &g.op(c).inputs {
                    let vt = g.vtensor(iv);
                    if vt.ptensor == pt {
                        entry.1.push(View {
                            op: c,
                            mask: vt.mask.clone(),
                            device: dev_of(c),
                        });
                    }
                }
            }
        }
        if entry.0.is_empty() {
            for &p in prods {
                if !g.is_cross_iteration(p, pt) {
                    continue;
                }
                for &ov in &g.op(p).outputs {
                    let vt = g.vtensor(ov);
                    if vt.ptensor == pt {
                        entry.0.push(View {
                            op: p,
                            mask: vt.mask.clone(),
                            device: dev_of(p),
                        });
                    }
                }
            }
        }
    }

    // ---- materialize each pTensor's redistribution ----
    let mut pts: Vec<PTensorId> = by_pt.keys().copied().collect();
    pts.sort_unstable();
    for pt in pts {
        let (producers, consumers) = &by_pt[&pt];
        if producers.is_empty() || consumers.is_empty() {
            continue;
        }
        let cross_iter = matches!(
            g.ptensor(pt).kind,
            TensorKind::Weight | TensorKind::OptState
        );
        materialize_ptensor(g, cluster, mode, &mut plan, pt, producers, consumers, cross_iter);
    }
    // ---- per-device serial-order dependencies are the simulator's job ----

    // ---- static memory ----
    let (static_mem, static_grad_mem) = static_memory(g, vs);
    plan.static_mem = static_mem;
    plan.static_grad_mem = static_grad_mem;
    plan
}



#[allow(clippy::too_many_arguments)]
fn materialize_ptensor(
    g: &Graph,
    cluster: &Cluster,
    mode: CommMode,
    plan: &mut Plan,
    pt: PTensorId,
    producers: &[View],
    consumers: &[View],
    cross_iter: bool,
) {
    let total_bytes = g.ptensor(pt).bytes();
    // Fast path per consumer: an aligned co-located producer.
    let mut unresolved: Vec<&View> = Vec::new();
    for c in consumers {
        let aligned = producers.iter().find(|p| {
            p.device == c.device && p.mask.covers(&c.mask) && p.mask.vsplit.is_full()
        });
        match aligned {
            Some(p) => {
                plan.n_direct += 1;
                if !cross_iter {
                    let pt_task = plan.task_of_op[p.op];
                    let ct = plan.task_of_op[c.op];
                    if !plan.tasks[ct].deps.contains(&pt_task) {
                        plan.tasks[ct].deps.push(pt_task);
                    }
                }
            }
            None => unresolved.push(c),
        }
    }
    if unresolved.is_empty() {
        return;
    }

    // Group the remaining traffic into connected components of the
    // producer/consumer overlap graph: e.g. K pipeline micro-batches of one
    // activation are K independent transfers (merging them would create
    // false dependencies — and deadlocks against 1F1B ordering), while the
    // value-partials of a data-parallel gradient all connect into one
    // component (one all-reduce).
    let comps = overlap_components(producers, &unresolved);
    for (comp_prods, comp_cons) in comps {
        synthesize_component(
            g, cluster, mode, plan, pt, total_bytes, &comp_prods, &comp_cons, cross_iter,
        );
    }
}

/// Connected components over the bipartite overlap graph. Returns
/// `(producers, consumers)` per component (producers may repeat across
/// components if they feed several).
fn overlap_components(producers: &[View], consumers: &[&View]) -> Vec<(Vec<View>, Vec<View>)> {
    let np = producers.len();
    let n = np + consumers.len();
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(p: &mut Vec<usize>, i: usize) -> usize {
        if p[i] != i {
            let r = find(p, p[i]);
            p[i] = r;
        }
        p[i]
    }
    for (ci, c) in consumers.iter().enumerate() {
        for (pi, p) in producers.iter().enumerate() {
            if c.mask.depends_on(&p.mask) {
                let (a, b) = (find(&mut parent, pi), find(&mut parent, np + ci));
                parent[a] = b;
            }
        }
    }
    let mut comps: HashMap<usize, (Vec<View>, Vec<View>)> = HashMap::new();
    for (ci, c) in consumers.iter().enumerate() {
        let root = find(&mut parent, np + ci);
        comps.entry(root).or_default().1.push((*c).clone());
    }
    for (pi, p) in producers.iter().enumerate() {
        let root = find(&mut parent, pi);
        if let Some(e) = comps.get_mut(&root) {
            e.0.push(p.clone());
        }
    }
    comps.into_values().filter(|(p, c)| !p.is_empty() && !c.is_empty()).collect()
}

#[allow(clippy::too_many_arguments)]
fn synthesize_component(
    g: &Graph,
    cluster: &Cluster,
    mode: CommMode,
    plan: &mut Plan,
    pt: PTensorId,
    _total_bytes: u64,
    producers: &[View],
    unresolved: &[View],
    cross_iter: bool,
) {
    // Same-device component: a purely local reduce/concat (e.g. the value
    // partials of co-shard's sequential head shards) — dependency edges
    // only, no communication.
    let first_dev = producers[0].device;
    if producers.iter().all(|p| p.device == first_dev)
        && unresolved.iter().all(|c| c.device == first_dev)
    {
        plan.n_direct += unresolved.len();
        if !cross_iter {
            for c in unresolved {
                let ct = plan.task_of_op[c.op];
                for p in producers {
                    if c.mask.depends_on(&p.mask) {
                        let pt_task = plan.task_of_op[p.op];
                        if !plan.tasks[ct].deps.contains(&pt_task) {
                            plan.tasks[ct].deps.push(pt_task);
                        }
                    }
                }
            }
        }
        return;
    }

    // Try RVD synthesis over the component. Inference runs on *deduplicated*
    // views — K micro-batch ops reading the same weight region on one device
    // are a single logical consumer slot — normalized to the component's
    // bounding box (a TP weight shard's gradient lives in a quarter of the
    // pTensor; its all-reduce is over that region, not the whole tensor).
    if mode != CommMode::P2POnly {
        let cons_views: Vec<View> = unresolved.to_vec();
        // Bounding box across all views.
        let rank = producers[0].mask.rank();
        let mut bbox = producers[0].mask.clone();
        bbox.vsplit = crate::graph::mask::VSplit::FULL;
        for v in producers.iter().chain(unresolved.iter()) {
            for a in 0..rank {
                bbox.dims[a] = crate::graph::mask::Interval::new(
                    bbox.dims[a].lo.min(v.mask.dims[a].lo),
                    bbox.dims[a].hi.max(v.mask.dims[a].hi),
                );
            }
        }
        let normalize = |v: &View| -> View {
            let mut m = v.mask.clone();
            for a in 0..rank {
                m.dims[a] = bbox.dims[a].relative(&m.dims[a]);
            }
            View { op: v.op, mask: m, device: v.device }
        };
        let region_bytes = bbox.num_elements(&g.ptensor(pt).shape) as u64
            * g.ptensor(pt).dtype.size_bytes() as u64;
        let mut uniq: Vec<View> = Vec::new();
        for v in &cons_views {
            let v = normalize(v);
            if !uniq.iter().any(|u| u.device == v.device && u.mask == v.mask) {
                uniq.push(v);
            }
        }
        let mut uniq_prods: Vec<View> = Vec::new();
        for v in producers {
            let v = normalize(v);
            if !uniq_prods
                .iter()
                .any(|u| u.device == v.device && u.mask == v.mask)
            {
                uniq_prods.push(v);
            }
        }
        let total_bytes = region_bytes;
        if let (Some((prvd, pgroup)), Some((crvd, cgroup))) =
            (infer_rvd(&uniq_prods), infer_rvd(&uniq))
        {
            // Cross-replica gradient sync: pure value-partials turning into
            // pure replicas over one physical device set — the shape a
            // dp > 1 plan produces for every gradient region. When the dp
            // group spans servers, bypass the flat single-collective
            // synthesis and emit the RVD decomposition (reduce-scatter
            // within servers, all-reduce across, all-gather back) as
            // separate collective tasks, so both execution engines see the
            // per-hop link use ([`Cluster::group_links`]) instead of one
            // opaque group-wide transfer.
            if g.ptensor(pt).kind == TensorKind::Gradient
                && prvd.r == 1
                && prvd.v > 1
                && prvd.d_prod() == 1
                && crvd.v == 1
                && crvd.d_prod() == 1
            {
                let dedup = |g: &[DeviceId]| {
                    let mut d = g.to_vec();
                    d.sort_unstable();
                    d.dedup();
                    d
                };
                let pdevs = dedup(&pgroup);
                let cdevs = dedup(&cgroup);
                let spans_servers = pdevs.len() > 1
                    && !pdevs.contains(&crate::schedule::CPU_DEVICE)
                    && pdevs.iter().any(|&d| !cluster.same_server(d, pdevs[0]));
                if pdevs == cdevs && spans_servers {
                    let sync = rvd::grad_sync_plan(cluster, &pdevs, total_bytes);
                    if sync.is_hierarchical() {
                        plan.n_rvd += 1;
                        emit_sync_plan(g, cluster, plan, pt, producers, &cons_views, &sync);
                        return;
                    }
                }
            }
            let same_group = {
                let mut a = pgroup.clone();
                let mut b = cgroup.clone();
                a.sort_unstable();
                b.sort_unstable();
                a == b
            };
            let path = if same_group {
                rvd::search_intra(cluster, &pgroup, total_bytes, &prvd, &crvd)
            } else if mode == CommMode::InterRvd {
                rvd::search_inter(cluster, &pgroup, &cgroup, total_bytes, &prvd, &crvd)
            } else {
                None
            };
            if let Some(path) = path {
                plan.n_rvd += 1;
                emit_rvd_path(
                    g,
                    plan,
                    pt,
                    total_bytes,
                    producers,
                    &cons_views,
                    &path,
                    cross_iter,
                    &pgroup,
                    &cgroup,
                );
                return;
            }
        }
    }

    // Generic Fig. 8 fallback: per consumer, fetch every overlapping
    // producer piece; reduces/concats are local (free). One interned label
    // serves every transfer of this pTensor. A (producer op, destination
    // device, overlap) triple is materialized ONCE and shared by every
    // consumer on that device: zero-bubble's B and W halves both list the
    // upstream gradient as an input, but the stage receives it over the
    // wire once — without this the cross-stage dy transfer is charged
    // twice (the PR 7 carried debt).
    let p2p_label: Arc<str> = format!("p2p:{}", g.ptensor(pt).name).into();
    let mut shared: Vec<(OpId, DeviceId, Mask, TaskId)> = Vec::new();
    for c in unresolved {
        plan.n_p2p += 1;
        let mut fetched = Vec::new();
        for p in producers {
            if let Some(ov) = c.mask.intersect(&p.mask) {
                let bytes = ov.num_elements(&g.ptensor(pt).shape) as u64
                    * g.ptensor(pt).dtype.size_bytes() as u64;
                if p.device == c.device {
                    // Local slice: free, only a dependency.
                    if !cross_iter {
                        fetched.push(plan.task_of_op[p.op]);
                    }
                    continue;
                }
                if let Some(&(.., t)) = shared
                    .iter()
                    .find(|(po, d, m, _)| *po == p.op && *d == c.device && *m == ov)
                {
                    fetched.push(t);
                    continue;
                }
                let deps = if cross_iter { vec![] } else { vec![plan.task_of_op[p.op]] };
                let dur = cluster.p2p_time(p.device, c.device, bytes);
                let t = plan.push(
                    TaskKind::P2P { from: p.device, to: c.device, bytes, ptensor: pt },
                    deps,
                    dur,
                    p2p_label.clone(),
                );
                shared.push((p.op, c.device, ov, t));
                fetched.push(t);
            }
        }
        let ct = plan.task_of_op[c.op];
        for t in fetched {
            if !plan.tasks[ct].deps.contains(&t) {
                plan.tasks[ct].deps.push(t);
            }
        }
    }
}

/// Emit a [`rvd::SyncPlan`]'s steps as materialized collective tasks: every
/// subgroup of a step becomes its own task (duration = that subgroup's
/// *solo* collective time — contention is the execution engines' job: the
/// list scheduler blocks the subgroup's devices, the DES fair-shares the
/// links the subgroup crosses), steps chain producers → step₁ → … → stepₙ →
/// consumers. Steps over-synchronize slightly (a step waits on the whole
/// previous step, not just the subgroups it reads from); that is safe and
/// keeps the dependency structure acyclic by construction.
fn emit_sync_plan(
    g: &Graph,
    cluster: &Cluster,
    plan: &mut Plan,
    pt: PTensorId,
    producers: &[View],
    consumers: &[View],
    sync: &rvd::SyncPlan,
) {
    let mut frontier: Vec<TaskId> = producers.iter().map(|p| plan.task_of_op[p.op]).collect();
    for step in &sync.steps {
        let name = match step.kind {
            CollKind::AllReduce => "all-reduce",
            CollKind::ReduceScatter => "reduce-scatter",
            CollKind::AllGather => "all-gather",
            CollKind::AllToAll => "all-to-all",
            CollKind::Broadcast => "broadcast",
            CollKind::RdScatter => "rd-scatter",
            CollKind::RdGather => "rd-gather",
        };
        // One interned label per step, shared by all of its subgroups.
        let label: Arc<str> = format!("dp-sync {name}:{}", g.ptensor(pt).name).into();
        let mut next = Vec::with_capacity(step.groups.len());
        for grp in &step.groups {
            let dur = cluster.collective_time(step.kind, grp, step.bytes);
            let t = plan.push(
                TaskKind::Collective {
                    kind: step.kind,
                    group: grp.clone(),
                    bytes: step.bytes,
                    ptensor: pt,
                },
                frontier.clone(),
                dur,
                label.clone(),
            );
            next.push(t);
        }
        frontier = next;
    }
    for c in consumers {
        let ct = plan.task_of_op[c.op];
        for &t in &frontier {
            if !plan.tasks[ct].deps.contains(&t) {
                plan.tasks[ct].deps.push(t);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn emit_rvd_path(
    g: &Graph,
    plan: &mut Plan,
    pt: PTensorId,
    region_bytes: u64,
    producers: &[View],
    consumers: &[View],
    path: &rvd::Path,
    cross_iter: bool,
    pgroup: &[DeviceId],
    cgroup: &[DeviceId],
) {
    // Chain: producers -> step1 -> ... -> stepN -> consumers.
    let mut frontier: Vec<TaskId> = if cross_iter {
        Vec::new()
    } else {
        producers.iter().map(|p| plan.task_of_op[p.op]).collect()
    };
    for (trans, state, dt) in &path.steps {
        let Some(kind) = trans.collective() else { continue }; // local = free
        // Participating devices: the union of the groups this step touches.
        let group: Vec<DeviceId> = match trans {
            rvd::Transition::RdScatter { .. } | rvd::Transition::RdGather { .. } => {
                pgroup.iter().chain(cgroup.iter()).copied().collect()
            }
            _ => {
                // Whichever side the state lives on.
                if state.num_devices() == pgroup.len() && !matches!(kind, CollKind::RdScatter) {
                    pgroup.to_vec()
                } else {
                    cgroup.to_vec()
                }
            }
        };
        let bytes = state.shard_bytes(region_bytes);
        let t = plan.push(
            TaskKind::Collective { kind, group, bytes, ptensor: pt },
            frontier.clone(),
            *dt,
            format!("{}:{}", trans, g.ptensor(pt).name).into(),
        );
        frontier = vec![t];
    }
    for c in consumers {
        let ct = plan.task_of_op[c.op];
        for &t in &frontier {
            if !plan.tasks[ct].deps.contains(&t) {
                plan.tasks[ct].deps.push(t);
            }
        }
    }
}

/// Infer the RVD state of a set of views, if they form an even partition.
/// Returns the state and the device group in RVD layout order
/// (`rank = (ri·v + vi)·∏d + d_linear`).
fn infer_rvd(views: &[View]) -> Option<(Rvd, Vec<DeviceId>)> {
    if views.is_empty() {
        return None;
    }
    let rank = views[0].mask.rank();
    let v = views[0].mask.vsplit.parts as usize;
    if views.iter().any(|w| w.mask.rank() != rank || w.mask.vsplit.parts as usize != v) {
        return None;
    }
    // Per-dim distinct intervals must uniformly tile [0,1).
    let mut d = Vec::with_capacity(rank);
    for axis in 0..rank {
        let mut ivs: Vec<_> = views.iter().map(|w| w.mask.dims[axis]).collect();
        ivs.sort_by(|a, b| a.lo.cmp_frac(b.lo));
        ivs.dedup();
        let k = ivs.len();
        for (i, iv) in ivs.iter().enumerate() {
            let want = crate::graph::mask::Interval::FULL.split(i, k);
            if *iv != want {
                return None;
            }
        }
        d.push(k);
    }
    let dprod: usize = d.iter().product();
    let n = views.len();
    if n % (dprod * v) != 0 {
        return None;
    }
    let r = n / (dprod * v);
    let state = Rvd::new(r, v, &d);
    // Build the group in layout order: bucket views by (d_linear, vsplit).
    let mut buckets: HashMap<(usize, usize), Vec<DeviceId>> = HashMap::new();
    for w in views {
        let mut lin = 0usize;
        for axis in 0..rank {
            let k = d[axis];
            let pos = (0..k)
                .find(|&i| crate::graph::mask::Interval::FULL.split(i, k) == w.mask.dims[axis])?;
            lin = lin * k + pos;
        }
        buckets
            .entry((lin, w.mask.vsplit.index as usize))
            .or_default()
            .push(w.device);
    }
    // Every bucket must have exactly r members.
    let mut group = vec![0; n];
    for ((lin, vi), mut devs) in buckets {
        if devs.len() != r {
            return None;
        }
        devs.sort_unstable();
        for (ri, dev) in devs.into_iter().enumerate() {
            group[(ri * v + vi) * dprod + lin] = dev;
        }
    }
    Some((state, group))
}

/// Static (iteration-long) per-device memory: distinct weight, gradient and
/// optimizer-state regions touched by the ops on each device. Returns
/// `(total, gradient share)` per device — the gradient share is what the
/// DES subtracts from its baseline to replay gradient liveness in time.
fn static_memory(
    g: &Graph,
    vs: &ValidatedSchedule,
) -> (HashMap<DeviceId, u64>, HashMap<DeviceId, u64>) {
    let mut mem: HashMap<DeviceId, HashMap<(PTensorId, u64), (u64, bool)>> = HashMap::new();
    for (&dev, ops) in &vs.device_order {
        let slot = mem.entry(dev).or_default();
        for &op in ops {
            for &vref in g.op(op).inputs.iter().chain(&g.op(op).outputs) {
                let vt = g.vtensor(vref);
                let p = g.ptensor(vt.ptensor);
                if matches!(
                    p.kind,
                    TensorKind::Weight | TensorKind::Gradient | TensorKind::OptState
                ) {
                    // Key by (ptensor, region hash): identical regions on the
                    // same device are one allocation.
                    let key = (vt.ptensor, vt.mask.region_hash());
                    let bytes = vt.mask.num_elements(&p.shape) as u64
                        * p.dtype.size_bytes() as u64;
                    slot.insert(key, (bytes, p.kind == TensorKind::Gradient));
                }
            }
        }
    }
    let mut total = HashMap::new();
    let mut grad = HashMap::new();
    for (d, m) in mem {
        total.insert(d, m.values().map(|&(b, _)| b).sum());
        grad.insert(d, m.values().filter(|&&(_, is_g)| is_g).map(|&(b, _)| b).sum());
    }
    (total, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sig::sigs;
    use crate::graph::{DType, Graph, OpKind, TensorKind};
    use crate::schedule::{validate, Schedule};
    use crate::trans::{autograd, op_trans, TransformAlgo};

    /// One linear layer + loss + optimizer, data-parallel over `n` devices.
    fn dp_model(n: usize) -> (Graph, Schedule) {
        dp_model_on(n, |i| i)
    }

    /// [`dp_model`] with replica `i` placed on device `dev(i)`.
    fn dp_model_on(n: usize, dev: impl Fn(usize) -> usize) -> (Graph, Schedule) {
        let mut g = Graph::new();
        let x = g.add_ptensor("x", &[8, 4, 16], DType::F32, TensorKind::Input);
        let w = g.add_ptensor("w", &[16, 16], DType::F32, TensorKind::Weight);
        let wg = g.add_ptensor("w.grad", &[16, 16], DType::F32, TensorKind::Gradient);
        let m1 = g.add_ptensor("w.m", &[16, 16], DType::F32, TensorKind::OptState);
        let y = g.add_ptensor("y", &[8, 4, 16], DType::F32, TensorKind::Activation);
        let (xv, wv, yv) = (g.full_view(x), g.full_view(w), g.full_view(y));
        let lin = g.add_op(
            "lin",
            OpKind::Matmul,
            vec![xv, wv],
            vec![yv],
            1e9,
            Some(sigs::linear()),
            true,
            0,
        );
        let (gv, wv2, mv, wv3) = (g.full_view(wg), g.full_view(w), g.full_view(m1), g.full_view(w));
        let opt = g.add_op(
            "opt",
            OpKind::Optimizer,
            vec![gv, wv2, mv],
            vec![wv3],
            256.0,
            Some(sigs::optimizer()),
            false,
            0,
        );

        let fwd = op_trans(&mut g, lin, &TransformAlgo::split("b", n)).unwrap();
        let opts = op_trans(&mut g, opt, &TransformAlgo::replicate(n)).unwrap();
        let ag = autograd::complete(&mut g);
        let mut s = Schedule::new();
        for (i, &f) in fwd.iter().enumerate() {
            s.assign(f, dev(i));
            s.assign(ag.bwd_of[&f], dev(i));
            s.assign(opts[i], dev(i));
        }
        (g, s)
    }

    #[test]
    fn dp_materializes_gradient_allreduce() {
        let (g, s) = dp_model(4);
        let vs = validate(&g, &s).unwrap();
        let cluster = Cluster::v100(4);
        let plan = materialize(&g, &vs, &cluster, CommMode::InterRvd);
        // The 4 grad partials -> 4 replicated optimizer reads must become a
        // single all-reduce (possibly + free local steps).
        let colls: Vec<&Task> = plan.tasks.iter().filter(|t| t.is_comm()).collect();
        assert!(
            colls.iter().any(|t| matches!(
                t.kind,
                TaskKind::Collective { kind: CollKind::AllReduce, .. }
            )),
            "expected an all-reduce, got {:?}",
            colls.iter().map(|t| &t.label).collect::<Vec<_>>()
        );
        assert!(plan.n_rvd >= 1);
        // Weight reads are aligned & co-located -> direct.
        assert!(plan.n_direct > 0);
    }

    #[test]
    fn cross_server_dp_grad_sync_is_rvd_decomposed() {
        // 4 replicas, two per server: the gradient sync must decompose into
        // reduce-scatter within servers → all-reduce across → all-gather,
        // each step a separate collective task with its own device group.
        let (g, s) = dp_model_on(4, |i| 4 * i); // devices 0,4 | 8,12
        let vs = validate(&g, &s).unwrap();
        let cluster = Cluster::v100(16);
        let plan = materialize(&g, &vs, &cluster, CommMode::InterRvd);
        let sync: Vec<&Task> =
            plan.tasks.iter().filter(|t| t.label.starts_with("dp-sync")).collect();
        assert!(!sync.is_empty(), "cross-server gradient sync must take the decomposed path");
        let kind_of = |t: &Task| match &t.kind {
            TaskKind::Collective { kind, .. } => *kind,
            other => panic!("dp-sync task is not a collective: {other:?}"),
        };
        assert!(sync.iter().any(|t| kind_of(t) == CollKind::ReduceScatter));
        assert!(sync.iter().any(|t| kind_of(t) == CollKind::AllGather));
        // The cross-server hop: an all-reduce whose group spans servers.
        assert!(sync.iter().any(|t| {
            let devs = t.devices();
            kind_of(t) == CollKind::AllReduce
                && devs.iter().any(|&d| cluster.server_of(d) != cluster.server_of(devs[0]))
        }));
        // Intra-server steps only ever group same-server devices.
        for t in &sync {
            if matches!(kind_of(t), CollKind::ReduceScatter | CollKind::AllGather) {
                let devs = t.devices();
                assert!(devs.iter().all(|&d| cluster.same_server(d, devs[0])), "{:?}", devs);
            }
        }
        // A single-server dp group keeps the flat all-reduce form.
        let (g2, s2) = dp_model(4);
        let vs2 = validate(&g2, &s2).unwrap();
        let plan2 = materialize(&g2, &vs2, &cluster, CommMode::InterRvd);
        assert!(plan2.tasks.iter().all(|t| !t.label.starts_with("dp-sync")));
        assert!(plan2.tasks.iter().any(|t| matches!(
            t.kind,
            TaskKind::Collective { kind: CollKind::AllReduce, .. }
        )));
    }

    #[test]
    fn p2p_mode_uses_no_collectives() {
        let (g, s) = dp_model(4);
        let vs = validate(&g, &s).unwrap();
        let cluster = Cluster::v100(4);
        let plan = materialize(&g, &vs, &cluster, CommMode::P2POnly);
        assert!(plan
            .tasks
            .iter()
            .all(|t| !matches!(t.kind, TaskKind::Collective { .. })));
        assert!(plan.n_p2p > 0);
        // P2P must move at least as many bytes as the collective plan.
        let plan_rvd = materialize(&g, &vs, &cluster, CommMode::InterRvd);
        assert!(plan.comm_bytes >= plan_rvd.comm_bytes);
    }

    #[test]
    fn single_device_plan_has_no_comm() {
        let (g, s) = dp_model(1);
        let vs = validate(&g, &s).unwrap();
        let cluster = Cluster::v100(8);
        let plan = materialize(&g, &vs, &cluster, CommMode::InterRvd);
        let labels: Vec<_> = plan.tasks.iter().map(|t| &t.label).collect();
        assert_eq!(plan.comm_bytes, 0, "{labels:#?}");
        assert!(plan.tasks.iter().all(|t| !t.is_comm()));
    }

    #[test]
    fn static_memory_counts_shards_once() {
        let (g, s) = dp_model(2);
        let vs = validate(&g, &s).unwrap();
        let cluster = Cluster::v100(2);
        let plan = materialize(&g, &vs, &cluster, CommMode::InterRvd);
        // Each device: full w (16*16*4) + full w.grad + full w.m = 3 KiB.
        for d in 0..2 {
            assert_eq!(plan.static_mem[&d], 3 * 16 * 16 * 4, "device {d}");
        }
    }

    #[test]
    fn infer_rvd_recognizes_even_partitions() {
        let full = Mask::full(2);
        let views: Vec<View> = (0..4)
            .map(|i| View { op: i, mask: full.split_dim(1, i, 4), device: i })
            .collect();
        let (state, group) = infer_rvd(&views).unwrap();
        assert_eq!(state, Rvd::new(1, 1, &[1, 4]));
        assert_eq!(group, vec![0, 1, 2, 3]);
        // Value splits.
        let vviews: Vec<View> = (0..3)
            .map(|i| View { op: i, mask: full.split_value(i, 3), device: i })
            .collect();
        let (state, _) = infer_rvd(&vviews).unwrap();
        assert_eq!(state, Rvd::new(1, 3, &[1, 1]));
        // Replicas.
        let rviews: Vec<View> = (0..2)
            .map(|i| View { op: i, mask: full.clone(), device: i })
            .collect();
        let (state, _) = infer_rvd(&rviews).unwrap();
        assert_eq!(state, Rvd::new(2, 1, &[1, 1]));
    }

    #[test]
    fn infer_rvd_rejects_irregular() {
        let full = Mask::full(1);
        // 1/3 + 2/3 split is uneven.
        let views = vec![
            View { op: 0, mask: full.split_dim(0, 0, 3), device: 0 },
            View {
                op: 1,
                mask: Mask {
                    dims: vec![crate::graph::mask::Interval::new(
                        crate::graph::mask::Frac::new(1, 3),
                        crate::graph::mask::Frac::ONE,
                    )],
                    vsplit: crate::graph::mask::VSplit::FULL,
                },
                device: 1,
            },
        ];
        assert!(infer_rvd(&views).is_none());
    }

    #[test]
    fn plan_dependencies_are_acyclic_and_point_backwards_or_forwards_consistently() {
        let (g, s) = dp_model(4);
        let vs = validate(&g, &s).unwrap();
        let cluster = Cluster::v100(4);
        let plan = materialize(&g, &vs, &cluster, CommMode::InterRvd);
        // Kahn over tasks must consume everything (acyclic).
        let n = plan.tasks.len();
        let mut indeg = vec![0usize; n];
        for t in &plan.tasks {
            for &_d in &t.deps {
                indeg[t.id] += 1;
            }
        }
        let mut q: Vec<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut seen = 0;
        let mut consumers: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for t in &plan.tasks {
            for &d in &t.deps {
                consumers[d].push(t.id);
            }
        }
        while let Some(u) = q.pop() {
            seen += 1;
            for &v in &consumers[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    q.push(v);
                }
            }
        }
        assert_eq!(seen, n, "cyclic task plan");
    }

    #[test]
    fn zero_bubble_shares_the_cross_stage_dy_recv() {
        // Zero-bubble splits backward into B/W halves that BOTH list the
        // upstream gradient as an input; the stage must still receive it
        // over the wire once. At micro=1 every legitimate P2P transfer of
        // a pipeline has a distinct (from, to, bytes, ptensor) key, so any
        // duplicate is a double-charged recv.
        use crate::plans::{registry, PlanKind, PlanSpec, SchedName, SchedSpec};
        let model = crate::models::gpt3(0, 8, 256);
        let cluster = Cluster::v100(2);
        let build = |sched: SchedName| {
            let spec = PlanSpec {
                pp: 2,
                micro: 1,
                sched: Some(SchedSpec::Named(sched)),
                ..PlanSpec::new(PlanKind::Megatron)
            };
            let out = registry::build("megatron", &model, &spec).unwrap();
            let vs = validate(&out.graph, &out.schedule).unwrap();
            materialize(&out.graph, &vs, &cluster, CommMode::InterRvd)
        };
        let zb = build(SchedName::ZeroBubble);
        let mut keys: Vec<(DeviceId, DeviceId, u64, PTensorId)> = zb
            .tasks
            .iter()
            .filter_map(|t| match t.kind {
                TaskKind::P2P { from, to, bytes, ptensor } => Some((from, to, bytes, ptensor)),
                _ => None,
            })
            .collect();
        let n = keys.len();
        assert!(n > 0, "a 2-stage pipeline must ship cross-stage tensors");
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), n, "duplicate cross-stage P2P transfer survived dedup");
        // The B/W split may not inflate wire traffic vs plain 1F1B.
        let base = build(SchedName::OneFOneB);
        assert!(
            zb.comm_bytes <= base.comm_bytes,
            "zb wire bytes {} exceed 1f1b's {}",
            zb.comm_bytes,
            base.comm_bytes
        );
    }
}
