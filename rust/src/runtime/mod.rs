//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced by `make artifacts` and execute them on the
//! PJRT CPU client via the `xla` crate. This is the only place the rust
//! side touches XLA; everything above works with plain `Vec<f32>` host
//! buffers.
//!
//! Interchange is HLO *text*: jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use crate::util::json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Parameter ABI entry from the manifest.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub params: Vec<ParamSpec>,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub batch: usize,
    pub n_params: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!("reading {}/manifest.json -- run `make artifacts`", dir.display())
            })?;
        let v = json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let cfg = v.get("config").ok_or_else(|| anyhow!("manifest missing config"))?;
        let geti = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("manifest config missing {k}"))
        };
        let params = v
            .get("params")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .and_then(|n| n.as_str())
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            params,
            vocab: geti("vocab")?,
            hidden: geti("hidden")?,
            layers: geti("layers")?,
            heads: geti("heads")?,
            seq: geti("seq")?,
            batch: geti("batch")?,
            n_params: v.get("n_params").and_then(|x| x.as_usize()).unwrap_or(0),
        })
    }
}

/// A compiled entry point on the PJRT CPU client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// One simulated device's runtime: its own PJRT client + compiled entries
/// (clients are cheap on CPU; per-thread clients sidestep any `Sync`
/// questions in the C API bindings).
pub struct Engine {
    client: xla::PjRtClient,
    pub dir: PathBuf,
}

impl Engine {
    pub fn cpu(artifacts_dir: &Path) -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?,
            dir: artifacts_dir.to_path_buf(),
        })
    }

    /// Load + compile `<name>.hlo.txt`.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?} -- run `make artifacts`", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        Ok(Executable { exe, name: name.to_string() })
    }
}

impl Executable {
    /// Execute with f32 tensor inputs (+ trailing i32 tensors), returning
    /// every output flattened to `Vec<f32>`.
    ///
    /// The jax entry points are lowered with `return_tuple=True`, so the
    /// single result is a tuple we unpack.
    pub fn run(
        &self,
        f32_inputs: &[(&[f32], &[usize])],
        i32_inputs: &[(&[i32], &[usize])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut lits: Vec<xla::Literal> = Vec::with_capacity(f32_inputs.len() + i32_inputs.len());
        for (data, shape) in f32_inputs {
            let l = xla::Literal::vec1(data);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            lits.push(l.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))?);
        }
        for (data, shape) in i32_inputs {
            let l = xla::Literal::vec1(data);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            lits.push(l.reshape(&dims).map_err(|e| anyhow!("reshape i32: {e:?}"))?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert!(m.hidden > 0 && m.layers > 0 && !m.params.is_empty());
        assert_eq!(m.params[0].name, "embed");
        let total: usize = m.params.iter().map(|p| p.numel()).sum();
        assert_eq!(total, m.n_params);
    }

    #[test]
    fn fwd_loss_executes_and_is_near_uniform() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let eng = Engine::cpu(&artifacts_dir()).unwrap();
        let exe = eng.load("fwd_loss").unwrap();
        // Small random params, random tokens: loss ~ ln(vocab).
        let mut rng = crate::util::rng::Rng::new(0);
        let params: Vec<Vec<f32>> = m
            .params
            .iter()
            .map(|p| {
                (0..p.numel())
                    .map(|_| {
                        if p.shape.len() == 1 {
                            1.0
                        } else {
                            0.02 * rng.normal() as f32
                        }
                    })
                    .collect()
            })
            .collect();
        let x: Vec<i32> = (0..m.batch * m.seq)
            .map(|_| rng.below(m.vocab as u64) as i32)
            .collect();
        let y: Vec<i32> = x.iter().map(|&t| (t + 1) % m.vocab as i32).collect();
        let f32_ins: Vec<(&[f32], &[usize])> = m
            .params
            .iter()
            .zip(&params)
            .map(|(spec, data)| (data.as_slice(), spec.shape.as_slice()))
            .collect();
        let shape_xy = [m.batch, m.seq];
        let outs = exe
            .run(&f32_ins, &[(&x, &shape_xy), (&y, &shape_xy)])
            .unwrap();
        assert_eq!(outs.len(), 1);
        let loss = outs[0][0];
        let uniform = (m.vocab as f32).ln();
        assert!(
            (loss - uniform).abs() < 2.0,
            "loss {loss} vs ln(vocab) {uniform}"
        );
    }
}
