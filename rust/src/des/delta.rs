//! Incremental DES replay: checkpoint the engine at periodic event epochs
//! during a base run, and re-execute a mutated plan from the latest
//! checkpoint the mutation provably cannot have perturbed.
//!
//! # Design
//!
//! A [`BaseRun`] captures, for every task, a structural signature
//! ([`TaskSig`]): kind (comm/compute), duration bits, occupied devices,
//! dense link indices, and the sorted predecessor multiset. Two plans
//! whose task `t` carries equal signatures schedule `t` identically *if*
//! the rest of the executed prefix is also identical — so the **dirty
//! set** of a mutation is exactly the tasks whose signatures differ.
//!
//! A checkpoint at `e` executed finish events is valid for replay iff
//! every dirty task, at that checkpoint, (a) has not started, (b) still
//! has at least one unfinished predecessor *under the new edge set*, and
//! (c) is not parked on any stream's waiter queue. Condition (b) is the
//! load-bearing one: `done` sets only grow over a run, so a dirty task
//! with an unfinished new-predecessor at the checkpoint was never ready
//! at any earlier point — the executed prefix is therefore bitwise
//! identical between the old and new plans, and resuming from the clone
//! reproduces the from-scratch run exactly. When no checkpoint after
//! event 0 is valid (the dirty horizon spans the timeline), replay
//! degrades to a full re-execution — correctness never depends on the
//! epoch granularity.
//!
//! Checkpoint geometry (stream slots, link registry width, stat slots)
//! may differ between plans; the restore path resizes those dense arrays
//! to the new geometry. This is safe because any index whose meaning
//! changed can only be referenced by a dirty task, and valid checkpoints
//! contain no trace of dirty tasks (unstarted, no stats, not in flight —
//! signature equality of clean tasks pins their link indices to the same
//! registry mapping).
//!
//! [`BaseRun::replay`] also *promotes* the mutated plan to a new
//! `BaseRun`: checkpoints at or before the resume point are carried over
//! (re-based onto the new geometry), and the replayed suffix records
//! fresh ones — an accepted MCMC move costs no extra full run.

use super::{Engine, EngineState};
use crate::cost::Cluster;
use crate::graph::Graph;
use crate::materialize::{Plan, TaskId};
use crate::schedule::DeviceId;
use crate::sim::TaskGraph;
use std::collections::BTreeSet;

use super::DesReport;

/// Default number of checkpoint epochs per base run. More epochs means a
/// finer dirty-horizon resolution (less replayed work per mutation) at
/// the cost of more clones held in memory.
pub const DEFAULT_EPOCHS: usize = 16;

/// Structural signature of one task; two tasks with equal signatures are
/// scheduled identically given an identical executed prefix.
#[derive(Clone, Debug, PartialEq, Eq)]
struct TaskSig {
    comm: bool,
    dur_bits: u64,
    devices: Vec<DeviceId>,
    /// Dense link indices — numeric equality across two engines implies
    /// the same `LinkId` ↔ index mapping for every link this task uses.
    links: Vec<usize>,
    /// Sorted predecessor multiset (duplicates kept: `indeg` counts edge
    /// multiplicity, so the signature must too).
    preds: Vec<TaskId>,
}

/// Accounting for one replay: how many finish events were re-executed
/// out of the full run's total.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplayStats {
    pub replayed: usize,
    pub total: usize,
    /// True when the replay fell back to a from-scratch execution.
    pub full: bool,
}

/// A completed DES run plus everything needed to incrementally replay a
/// mutated sibling plan: per-task signatures and periodic checkpoints.
pub struct BaseRun {
    sigs: Vec<TaskSig>,
    /// `(events executed, engine state clone)`, ascending; entry 0 is the
    /// pristine pre-seed state (always a valid resume point).
    snaps: Vec<(usize, EngineState)>,
    interval: usize,
    n: usize,
}

/// Invert `consumers` into a sorted predecessor multiset per task.
fn preds_of(tg: &TaskGraph, n: usize) -> Vec<Vec<TaskId>> {
    let mut preds: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for (t, cs) in tg.consumers.iter().enumerate() {
        for &c in cs {
            preds[c].push(t);
        }
    }
    for p in &mut preds {
        p.sort_unstable();
    }
    preds
}

fn sigs_of(eng: &Engine<'_>, plan: &Plan, tg: &TaskGraph) -> Vec<TaskSig> {
    let n = plan.tasks.len();
    let preds = preds_of(tg, n);
    (0..n)
        .map(|t| TaskSig {
            comm: plan.tasks[t].is_comm(),
            dur_bits: plan.tasks[t].duration.to_bits(),
            devices: eng.devices[t].clone(),
            links: eng.links_of[t].clone(),
            preds: preds[t].clone(),
        })
        .collect()
}

/// Drive the event loop to completion, cloning the state every
/// `interval` finish events (skipping the final, fully-drained state —
/// resuming there would replay nothing).
fn run_with_capture(
    eng: &mut Engine<'_>,
    n: usize,
    interval: usize,
    snaps: &mut Vec<(usize, EngineState)>,
) {
    while eng.step() {
        if eng.st.events % interval == 0 && eng.st.completed < n {
            snaps.push((eng.st.events, eng.st.clone()));
        }
    }
}

/// Resize the dense per-slot arrays of a checkpoint to a (possibly
/// different) engine geometry, then re-derive dirty tasks' indegrees
/// under the new edge set and this checkpoint's `done` front.
fn rebase(
    st: &mut EngineState,
    nslots: usize,
    nlinks: usize,
    dirty: &[TaskId],
    sigs: &[TaskSig],
) {
    st.busy.resize(2 * nslots, None);
    st.waiters.resize_with(2 * nslots, BTreeSet::new);
    st.slot_stats.resize(nslots, None);
    st.link_active.resize_with(nlinks, BTreeSet::new);
    for &t in dirty {
        st.indeg[t] = sigs[t].preds.iter().filter(|&&p| !st.done[p]).count();
    }
}

impl BaseRun {
    /// Execute `plan` from scratch, capturing checkpoints at `epochs`
    /// evenly spaced event counts.
    pub fn capture(
        g: &Graph,
        plan: &Plan,
        cluster: &Cluster,
        tg: &TaskGraph,
        epochs: usize,
    ) -> (BaseRun, DesReport) {
        let n = plan.tasks.len();
        let interval = (n / epochs.max(1)).max(1);
        let mut eng = Engine::new(plan, cluster, tg);
        let mut snaps = vec![(0usize, eng.st.clone())];
        eng.seed();
        run_with_capture(&mut eng, n, interval, &mut snaps);
        let report = eng.finalize(g, cluster);
        let sigs = sigs_of(&eng, plan, tg);
        (BaseRun { sigs, snaps, interval, n }, report)
    }

    /// Execute a mutated sibling of this base's plan, resuming from the
    /// latest checkpoint the mutation cannot have perturbed. Returns the
    /// report (bitwise identical to a from-scratch [`super::execute`]),
    /// replay accounting, and the mutated plan promoted to a new base.
    pub fn replay(
        &self,
        g: &Graph,
        plan: &Plan,
        cluster: &Cluster,
        tg: &TaskGraph,
    ) -> (DesReport, ReplayStats, BaseRun) {
        let n = plan.tasks.len();
        let mut eng = Engine::new(plan, cluster, tg);
        let new_sigs = sigs_of(&eng, plan, tg);
        let interval =
            if n == self.n { self.interval } else { (n / DEFAULT_EPOCHS).max(1) };

        let dirty: Vec<TaskId> = if n == self.n {
            (0..n).filter(|&t| new_sigs[t] != self.sigs[t]).collect()
        } else {
            Vec::new() // geometry changed wholesale: force the full path
        };
        let ok_at = |snap: &&(usize, EngineState)| -> bool {
            if snap.0 == 0 {
                return true;
            }
            dirty.iter().all(|&t| {
                !snap.1.started[t]
                    && new_sigs[t].preds.iter().any(|&p| !snap.1.done[p])
                    && !snap.1.waiters.iter().any(|w| {
                        w.contains(&(true, t)) || w.contains(&(false, t))
                    })
            })
        };
        let ev0 = if n == self.n {
            self.snaps.iter().rev().find(ok_at).map(|s| s.0).unwrap_or(0)
        } else {
            0
        };

        let pristine = eng.st.clone();
        let nlinks = pristine.link_active.len();
        let mut snaps = vec![(0usize, pristine)];
        if ev0 == 0 {
            // Dirty horizon spans the whole timeline: full re-execution.
            eng.seed();
        } else {
            let base = &self.snaps.iter().find(|s| s.0 == ev0).unwrap().1;
            let mut st = base.clone();
            rebase(&mut st, eng.nslots, nlinks, &dirty, &new_sigs);
            eng.st = st;
            // Carry earlier checkpoints into the promoted base — they are
            // valid for the new plan by the same prefix argument.
            for (e, s) in &self.snaps {
                if *e > 0 && *e <= ev0 {
                    let mut s2 = s.clone();
                    rebase(&mut s2, eng.nslots, nlinks, &dirty, &new_sigs);
                    snaps.push((*e, s2));
                }
            }
        }
        run_with_capture(&mut eng, n, interval, &mut snaps);
        let report = eng.finalize(g, cluster);
        let stats = ReplayStats { replayed: n - ev0, total: n, full: ev0 == 0 };
        (report, stats, BaseRun { sigs: new_sigs, snaps, interval, n })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::materialize::{Task, TaskKind};
    use crate::util::rng::Rng;

    fn dummy_graph(n: usize) -> Graph {
        let mut g = Graph::new();
        for i in 0..n {
            g.add_op(&format!("op{i}"), OpKind::Identity, vec![], vec![], 0.0, None, true, 0);
        }
        g
    }

    fn compute_task(id: TaskId, device: DeviceId, dur: f64, deps: Vec<TaskId>) -> Task {
        Task {
            id,
            kind: TaskKind::Compute { op: id, device },
            deps,
            duration: dur,
            label: format!("c{id}").into(),
        }
    }

    fn p2p_task(id: TaskId, from: DeviceId, to: DeviceId, dur: f64, deps: Vec<TaskId>) -> Task {
        Task {
            id,
            kind: TaskKind::P2P { from, to, bytes: 1 << 20, ptensor: 0 },
            deps,
            duration: dur,
            label: format!("x{id}").into(),
        }
    }

    /// Random layered plan: compute tasks spread over devices with
    /// forward dependencies, cross-server transfers sprinkled in.
    fn random_plan(rng: &mut Rng, n: usize) -> Plan {
        let mut plan = Plan::default();
        for id in 0..n {
            let mut deps = Vec::new();
            if id > 0 {
                deps.push(id - 1);
                if id > 3 && rng.f64() < 0.4 {
                    deps.push(rng.range(0, id - 1));
                }
            }
            let dur = 0.5 + rng.f64();
            if id > 0 && rng.f64() < 0.25 {
                let from = rng.range(0, 8);
                plan.tasks.push(p2p_task(id, from, from + 8, dur, deps));
            } else {
                plan.tasks.push(compute_task(id, rng.range(0, 16), dur, deps));
            }
        }
        plan
    }

    fn reports_bitwise_equal(a: &DesReport, b: &DesReport) {
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "makespan");
        assert_eq!(a.spans.len(), b.spans.len());
        for (sa, sb) in a.spans.iter().zip(&b.spans) {
            assert_eq!(sa.start.to_bits(), sb.start.to_bits(), "task {} start", sa.task);
            assert_eq!(sa.finish.to_bits(), sb.finish.to_bits(), "task {} finish", sa.task);
        }
        assert_eq!(a.per_device.len(), b.per_device.len());
        for (da, db) in a.per_device.iter().zip(&b.per_device) {
            assert_eq!(da.device, db.device);
            assert_eq!(da.compute.to_bits(), db.compute.to_bits(), "dev {} compute", da.device);
            assert_eq!(da.comm.to_bits(), db.comm.to_bits(), "dev {} comm", da.device);
            assert_eq!(da.peak_mem, db.peak_mem, "dev {} peak", da.device);
        }
        for (ma, mb) in a.mem.iter().zip(&b.mem) {
            assert_eq!(ma.peak, mb.peak, "mem peak dev {}", ma.device);
        }
    }

    #[test]
    fn replay_matches_fresh_execute_for_random_perturbations() {
        let cluster = Cluster::v100(16);
        let mut rng = Rng::new(0xde17a);
        for trial in 0..20 {
            let n = 24 + rng.range(0, 40);
            let plan = random_plan(&mut rng, n);
            let g = dummy_graph(n);
            let tg = TaskGraph::of_plan(&plan);
            let (base, _) = BaseRun::capture(&g, &plan, &cluster, &tg, 4);

            let mut plan2 = plan.clone();
            let victim = rng.range(n / 2, n);
            match rng.range(0, 3) {
                0 => plan2.tasks[victim].duration *= 1.0 + rng.f64(),
                1 => {
                    if let TaskKind::Compute { device, .. } = &mut plan2.tasks[victim].kind {
                        *device = (*device + 1) % 16;
                    } else {
                        plan2.tasks[victim].duration += 0.25;
                    }
                }
                _ => {
                    let extra = rng.range(0, victim);
                    if !plan2.tasks[victim].deps.contains(&extra) {
                        plan2.tasks[victim].deps.push(extra);
                    } else {
                        plan2.tasks[victim].duration += 0.125;
                    }
                }
            }
            let tg2 = TaskGraph::of_plan(&plan2);
            let (rep, stats, _) = base.replay(&g, &plan2, &cluster, &tg2);
            let fresh = super::super::execute(&g, &plan2, &cluster, &tg2);
            reports_bitwise_equal(&rep, &fresh);
            assert!(stats.replayed <= stats.total, "trial {trial}");
        }
    }

    #[test]
    fn late_perturbation_replays_partial_suffix() {
        let cluster = Cluster::v100(16);
        let n = 64;
        // A strict chain so the dirty horizon of a late mutation is late.
        let mut plan = Plan::default();
        for id in 0..n {
            let deps = if id == 0 { vec![] } else { vec![id - 1] };
            plan.tasks.push(compute_task(id, id % 4, 1.0, deps));
        }
        let g = dummy_graph(n);
        let tg = TaskGraph::of_plan(&plan);
        let (base, _) = BaseRun::capture(&g, &plan, &cluster, &tg, 8);
        let mut plan2 = plan.clone();
        plan2.tasks[n - 2].duration = 3.0;
        let tg2 = TaskGraph::of_plan(&plan2);
        let (rep, stats, _) = base.replay(&g, &plan2, &cluster, &tg2);
        let fresh = super::super::execute(&g, &plan2, &cluster, &tg2);
        reports_bitwise_equal(&rep, &fresh);
        assert!(!stats.full, "late single-task mutation must not force full replay");
        assert!(
            stats.replayed * 2 < stats.total,
            "expected <50% replay, got {}/{}",
            stats.replayed,
            stats.total
        );
    }

    #[test]
    fn task_count_change_falls_back_to_full_replay() {
        let cluster = Cluster::v100(16);
        let n = 16;
        let mut plan = Plan::default();
        for id in 0..n {
            let deps = if id == 0 { vec![] } else { vec![id - 1] };
            plan.tasks.push(compute_task(id, id % 2, 1.0, deps));
        }
        let g = dummy_graph(n + 1);
        let tg = TaskGraph::of_plan(&plan);
        let (base, _) = BaseRun::capture(&g, &plan, &cluster, &tg, 4);
        let mut plan2 = plan.clone();
        plan2.tasks.push(compute_task(n, 3, 1.0, vec![n - 1]));
        let tg2 = TaskGraph::of_plan(&plan2);
        let (rep, stats, _) = base.replay(&g, &plan2, &cluster, &tg2);
        let fresh = super::super::execute(&g, &plan2, &cluster, &tg2);
        reports_bitwise_equal(&rep, &fresh);
        assert!(stats.full);
        assert_eq!(stats.replayed, stats.total);
    }

    #[test]
    fn promoted_base_replays_correctly() {
        let cluster = Cluster::v100(16);
        let mut rng = Rng::new(7);
        let n = 48;
        let plan = random_plan(&mut rng, n);
        let g = dummy_graph(n);
        let tg = TaskGraph::of_plan(&plan);
        let (base, _) = BaseRun::capture(&g, &plan, &cluster, &tg, 6);
        // Chain two mutations through promoted bases.
        let mut plan2 = plan.clone();
        plan2.tasks[n - 4].duration *= 2.0;
        let tg2 = TaskGraph::of_plan(&plan2);
        let (_, _, base2) = base.replay(&g, &plan2, &cluster, &tg2);
        let mut plan3 = plan2.clone();
        plan3.tasks[n - 6].duration *= 1.5;
        let tg3 = TaskGraph::of_plan(&plan3);
        let (rep, _, _) = base2.replay(&g, &plan3, &cluster, &tg3);
        let fresh = super::super::execute(&g, &plan3, &cluster, &tg3);
        reports_bitwise_equal(&rep, &fresh);
    }
}
