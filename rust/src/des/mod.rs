//! Discrete-event cluster simulator — the high-fidelity scoring tier.
//!
//! The list scheduler ([`crate::sim`]) charges every communication task to
//! *all* of its devices (synchronous-NCCL) and every transfer its solo
//! bandwidth. That systematically under-credits exactly the schedules the
//! paper's space-time phase (§3.2) exists to find: pipelines that overlap
//! communication with compute, and plans that exploit bandwidth asymmetries
//! between NVLink and the per-server NIC. This module executes the same
//! materialized [`Plan`] + [`TaskGraph`] under a more faithful model:
//!
//! * **two streams per device** — one compute, one communication — so a
//!   collective or point-to-point transfer occupies only the communication
//!   stream of its participants and compute proceeds concurrently whenever
//!   dependencies allow (CUDA-stream semantics);
//! * **fair-sharing link contention** — each transfer crosses the physical
//!   links named by [`Cluster::group_links`]; `k` concurrent transfers
//!   sharing a link each progress at `1/k` of their solo rate,
//!   re-evaluated at every transfer start/finish event (the dslab
//!   shared-throughput discipline). The links that fair-share are the
//!   *shared fabric hops* on a transfer's resolved route
//!   ([`crate::topo::Topology`]): the per-server NIC (a server's 8 GPUs
//!   funnel through one IB port), and on multi-tier fabrics also the rack's
//!   spine uplink (every cross-rack transfer in/out of the rack contends
//!   for it) or the rail switch (rail-optimized pods). A transfer holds
//!   every link on its route, so cross-rack traffic fair-shares at *both*
//!   racks' uplinks — the mechanism by which a fat-tree reprices a
//!   cross-rack collective slower than an in-rack one. NVLink ports and
//!   PCIe lanes belong to a single device, so their exclusivity is already
//!   enforced by that device's communication stream — two transfers
//!   touching the same port serialize rather than degrade, and transfers
//!   on disjoint ports/lanes (including concurrent host offloads from
//!   different GPUs) run at full rate in parallel;
//! * **time-resolved memory** — the full per-device resident-bytes
//!   timeline ([`MemTimeline`]), not just the high-watermark, so
//!   offload/recompute plans are judged on *when* memory peaks. Gradient
//!   buffers are part of the timeline too (allocated at their backward
//!   producer, freed after the optimizer and any sync collective), so a
//!   dp plan OOMs only when gradient liveness actually collides with the
//!   activation peak — not merely because watermark sums exceed capacity;
//! * **trace export** — every task's `(start, finish)` span is kept
//!   ([`TaskSpan`]) and can be serialized to Chrome's `chrome://tracing` /
//!   Perfetto JSON via [`trace::chrome_trace`].
//!
//! The engine is deterministic: the event heap is keyed by
//! `(time bits, issue sequence)`, all contention state lives in ordered
//! maps, and nothing depends on hash iteration or thread scheduling — the
//! same plan always produces bitwise-identical timelines, on any worker
//! pool. On a schedule with no overlap opportunity (a serial dependency
//! chain) the DES and the list scheduler agree exactly, because both add
//! the same task durations along the same critical path; the DES differs
//! only where overlap or contention exists to model.
//!
//! # Snapshotable engine state and delta replay
//!
//! All mutable execution state (event heap, stream cursors, link registry
//! occupancy, transfer fair-sharing state, per-slot stats) lives in one
//! [`EngineState`] struct rather than loop locals, separated from the
//! borrowed plan and the derived static tables. Cloning that struct at an
//! event count is a resumable checkpoint: [`delta`] captures checkpoints
//! at periodic epochs during a base run and, when a plan mutation leaves a
//! prefix of the event timeline untouched, restores the latest checkpoint
//! the mutation cannot have perturbed and re-executes only the suffix —
//! the incremental re-simulation that makes MCMC plan refinement
//! ([`crate::search::refine`]) tractable.

pub mod delta;
pub mod trace;

use crate::cost::{Cluster, LinkId};
use crate::graph::Graph;
use crate::materialize::{Plan, TaskId};
use crate::schedule::{DeviceId, ValidatedSchedule, CPU_DEVICE};
use crate::sim::{activation_events, dev_slot, gradient_events, DeviceStat, TaskGraph};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap};

/// Execution interval of one task on the DES timeline.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpan {
    pub task: TaskId,
    pub start: f64,
    pub finish: f64,
}

/// Time-resolved resident memory of one device: step points
/// `(time, bytes)` — the value holds until the next point. The time-0
/// baseline is the static weights/optimizer bytes; gradient buffers enter
/// and leave the timeline with their actual liveness (they are *not* part
/// of the baseline, unlike the list scheduler's accounting).
#[derive(Clone, Debug)]
pub struct MemTimeline {
    pub device: DeviceId,
    pub points: Vec<(f64, u64)>,
    pub peak: u64,
}

/// Result of one discrete-event execution.
#[derive(Clone, Debug)]
pub struct DesReport {
    pub makespan: f64,
    pub per_device: Vec<DeviceStat>,
    /// Per-task execution spans, indexed by task id.
    pub spans: Vec<TaskSpan>,
    /// Per-device memory timelines (devices sorted; host last).
    pub mem: Vec<MemTimeline>,
    pub total_flops: f64,
    pub aggregate_tflops: f64,
    pub tflops_per_gpu: f64,
    pub comm_bytes: u64,
    pub oom: bool,
}

impl DesReport {
    pub fn max_peak_mem(&self) -> u64 {
        self.per_device.iter().map(|d| d.peak_mem).max().unwrap_or(0)
    }

    /// Mean compute / comm / bubble seconds across devices. `comm` counts
    /// communication-stream busy time, which may overlap compute — the
    /// overlap the list scheduler cannot express.
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let n = self.per_device.len().max(1) as f64;
        let c = self.per_device.iter().map(|d| d.compute).sum::<f64>() / n;
        let m = self.per_device.iter().map(|d| d.comm).sum::<f64>() / n;
        let b = self.per_device.iter().map(|d| d.bubble).sum::<f64>() / n;
        (c, m, b)
    }
}

/// One serial execution lane of a device, as a dense index: device slot
/// `s`'s compute stream is `2s`, its communication stream `2s + 1`. Compute
/// tasks occupy the compute stream of their device; communication tasks the
/// communication stream of every participant — the "one compute + one comm
/// stream per device" model that lets transfers overlap with kernels.
fn compute_stream(d: DeviceId) -> usize {
    2 * dev_slot(d)
}

fn comm_stream(d: DeviceId) -> usize {
    2 * dev_slot(d) + 1
}

/// An in-flight transfer's fair-sharing state. `remaining` is measured in
/// *solo seconds* (the cost model's uncontended duration); contention
/// scales the rate at which it drains, never the total work.
#[derive(Clone, Debug)]
struct Xfer {
    remaining: f64,
    rate: f64,
    last: f64,
}

/// Every mutable value of one engine run — what the event loop reads and
/// writes, with the borrowed plan and the derived static tables kept apart
/// on [`Engine`]. A clone of this struct is a resumable checkpoint of the
/// simulation at `events` executed finish events; [`delta`] snapshots it at
/// periodic epochs so plan mutations replay only the perturbed suffix.
#[derive(Clone, Debug)]
pub(crate) struct EngineState {
    indeg: Vec<usize>,
    start: Vec<f64>,
    finish: Vec<f64>,
    started: Vec<bool>,
    done: Vec<bool>,
    /// Event-version per task: heap entries carrying an older version are
    /// stale re-pricings and are skipped on pop.
    version: Vec<u64>,
    seq: u64,
    /// Min-heap of predicted finish events `(time bits, seq, task, version)`.
    heap: BinaryHeap<Reverse<(u64, u64, TaskId, u64)>>,
    /// Stream slot -> the task currently occupying it.
    busy: Vec<Option<TaskId>>,
    /// Tasks ready but blocked on a busy stream, keyed `(is_compute, id)`
    /// so communication dispatches first (eager send), then lower id.
    waiters: Vec<BTreeSet<(bool, TaskId)>>,
    /// Per-task fair-sharing state (`None` when not an in-flight transfer).
    xfers: Vec<Option<Xfer>>,
    /// Link slot -> transfers currently crossing it (the sets stay ordered
    /// by task id, which is what keeps repricing deterministic).
    link_active: Vec<BTreeSet<TaskId>>,
    completed: usize,
    /// Dense per-slot device stats, accumulated at every finish event;
    /// converted to the device-keyed map once, in [`Engine::finalize`].
    slot_stats: Vec<Option<DeviceStat>>,
    /// Finish events executed so far — the snapshot epoch coordinate.
    events: usize,
}

pub(crate) struct Engine<'a> {
    plan: &'a Plan,
    consumers: &'a [Vec<TaskId>],
    /// Per-task occupied devices, resolved once (`Task::devices` allocates
    /// and sorts a fresh Vec per call — far too hot for the event loop).
    devices: Vec<Vec<DeviceId>>,
    /// Per-task dense stream indices (see [`compute_stream`]/[`comm_stream`]).
    streams_of: Vec<Vec<usize>>,
    /// Per-task dense link indices into `link_active` (the [`LinkId`] →
    /// index registry is built once in [`Engine::new`]).
    links_of: Vec<Vec<usize>>,
    /// Device slots in use (`st.busy.len() / 2`).
    nslots: usize,
    /// The snapshotable mutable state (see [`EngineState`]).
    st: EngineState,
}

impl<'a> Engine<'a> {
    pub(crate) fn new(plan: &'a Plan, cluster: &Cluster, tg: &'a TaskGraph) -> Engine<'a> {
        let n = plan.tasks.len();
        let devices: Vec<Vec<DeviceId>> = plan.tasks.iter().map(|t| t.devices()).collect();
        let max_gpu =
            devices.iter().flatten().copied().filter(|&d| d != CPU_DEVICE).max().unwrap_or(0);
        let nslots = max_gpu + 2;
        let streams_of: Vec<Vec<usize>> = plan
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if t.is_comm() {
                    // The host is not a serializing endpoint: each GPU has
                    // its own PCIe lane + DMA engine, so concurrent
                    // offload transfers from different GPUs proceed in
                    // parallel and only the per-GPU comm stream (and the
                    // Pcie link) constrains them.
                    devices[i]
                        .iter()
                        .copied()
                        .filter(|&d| d != CPU_DEVICE)
                        .map(comm_stream)
                        .collect()
                } else {
                    devices[i].iter().copied().map(compute_stream).collect()
                }
            })
            .collect();
        // Dense link registry: LinkId -> index in first-seen task order
        // (deterministic — the task list is fixed).
        let mut link_index: BTreeMap<LinkId, usize> = BTreeMap::new();
        let links_of: Vec<Vec<usize>> = plan
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if !t.is_comm() {
                    return Vec::new();
                }
                cluster
                    .group_links(&devices[i])
                    .into_iter()
                    .map(|l| {
                        let next = link_index.len();
                        *link_index.entry(l).or_insert(next)
                    })
                    .collect()
            })
            .collect();
        let nlinks = link_index.len();
        Engine {
            plan,
            consumers: &tg.consumers,
            devices,
            streams_of,
            links_of,
            nslots,
            st: EngineState {
                indeg: tg.indeg.clone(),
                start: vec![0.0; n],
                finish: vec![0.0; n],
                started: vec![false; n],
                done: vec![false; n],
                version: vec![0; n],
                seq: 0,
                heap: BinaryHeap::new(),
                busy: vec![None; 2 * nslots],
                waiters: vec![BTreeSet::new(); 2 * nslots],
                xfers: vec![None; n],
                link_active: vec![BTreeSet::new(); nlinks],
                completed: 0,
                slot_stats: vec![None; nslots],
                events: 0,
            },
        }
    }

    /// Dispatch the initial ready set (indegree-0 tasks) at time 0, in
    /// (comm-first, id) order.
    pub(crate) fn seed(&mut self) {
        let mut initial: BTreeSet<(bool, TaskId)> = BTreeSet::new();
        for t in 0..self.plan.tasks.len() {
            if self.st.indeg[t] == 0 {
                initial.insert((!self.plan.tasks[t].is_comm(), t));
            }
        }
        for (_, t) in initial {
            self.try_start(t, 0.0);
        }
    }

    /// Execute the next finish event, skipping stale re-pricings. Returns
    /// false once the heap drains (the run is over).
    pub(crate) fn step(&mut self) -> bool {
        while let Some(Reverse((time_bits, _, t, v))) = self.st.heap.pop() {
            if v != self.st.version[t] || self.st.done[t] {
                continue; // stale re-pricing
            }
            let now = f64::from_bits(time_bits);
            self.finish_task(t, now);
            return true;
        }
        false
    }

    pub(crate) fn run(&mut self) {
        while self.step() {}
    }

    fn push_finish(&mut self, time: f64, t: TaskId) {
        self.st.seq += 1;
        self.st.heap.push(Reverse((time.to_bits(), self.st.seq, t, self.st.version[t])));
    }

    /// Fair-share rate of transfer `t`: 1 / (most crowded link it crosses).
    fn rate_of(&self, t: TaskId) -> f64 {
        let mut widest = 1usize;
        for &l in &self.links_of[t] {
            widest = widest.max(self.st.link_active[l].len());
        }
        1.0 / widest as f64
    }

    /// Re-price every in-flight transfer sharing a link with `t` after the
    /// active set changed at `now`: drain `remaining` at the old rate up to
    /// `now`, adopt the new rate, reissue the finish event. Transfers whose
    /// rate is unchanged are left untouched (no float churn), which is what
    /// makes uncontended runs bit-identical to the list scheduler's sums.
    fn reprice_sharers(&mut self, t: TaskId, now: f64) {
        let mut affected: BTreeSet<TaskId> = BTreeSet::new();
        for &l in &self.links_of[t] {
            affected.extend(self.st.link_active[l].iter().copied());
        }
        affected.remove(&t);
        for u in affected {
            let new_rate = self.rate_of(u);
            let x = self.st.xfers[u].as_mut().expect("active transfer has state");
            if new_rate == x.rate {
                continue;
            }
            x.remaining -= (now - x.last) * x.rate;
            x.remaining = x.remaining.max(0.0);
            x.last = now;
            x.rate = new_rate;
            let fin = now + x.remaining / new_rate;
            self.st.version[u] += 1;
            self.push_finish(fin, u);
        }
    }

    /// Start `t` at `now` if every stream it needs is free; otherwise park
    /// it on its busy streams' waiter queues. Returns whether it started.
    fn try_start(&mut self, t: TaskId, now: f64) -> bool {
        if self.st.started[t] {
            return true;
        }
        let blocked: Vec<usize> = self.streams_of[t]
            .iter()
            .copied()
            .filter(|&s| self.st.busy[s].is_some())
            .collect();
        if !blocked.is_empty() {
            let key = (!self.plan.tasks[t].is_comm(), t);
            for s in blocked {
                self.st.waiters[s].insert(key);
            }
            return false;
        }
        self.st.started[t] = true;
        self.st.start[t] = now;
        for &s in &self.streams_of[t] {
            self.st.busy[s] = Some(t);
        }
        let dur = self.plan.tasks[t].duration;
        self.st.version[t] += 1;
        if self.links_of[t].is_empty() {
            // Compute, or link-free local communication: fixed duration.
            self.push_finish(now + dur, t);
        } else {
            for &l in &self.links_of[t] {
                self.st.link_active[l].insert(t);
            }
            let rate = self.rate_of(t);
            self.st.xfers[t] = Some(Xfer { remaining: dur, rate, last: now });
            self.push_finish(now + dur / rate, t);
            self.reprice_sharers(t, now);
        }
        true
    }

    fn finish_task(&mut self, t: TaskId, now: f64) {
        self.st.done[t] = true;
        self.st.completed += 1;
        self.st.events += 1;
        self.st.finish[t] = now;
        let is_comm = self.plan.tasks[t].is_comm();
        let elapsed = now - self.st.start[t];
        for i in 0..self.devices[t].len() {
            let d = self.devices[t][i];
            if is_comm && d == CPU_DEVICE {
                // The host has no serializing comm stream (per-GPU PCIe
                // lanes carry offload traffic in parallel), so charging it
                // per-transfer elapsed time would exceed wall-clock.
                continue;
            }
            let st = self.st.slot_stats[dev_slot(d)]
                .get_or_insert_with(|| DeviceStat { device: d, ..Default::default() });
            if is_comm {
                st.comm += elapsed;
            } else {
                st.compute += elapsed;
            }
        }
        for &s in &self.streams_of[t] {
            self.st.busy[s] = None;
        }
        if self.st.xfers[t].take().is_some() {
            for &l in &self.links_of[t] {
                self.st.link_active[l].remove(&t);
            }
            self.reprice_sharers(t, now);
        }
        // Successors whose last dependency just resolved, plus parked tasks
        // waiting on the streams this finish freed — dispatched in
        // (comm-first, id) order.
        let mut cands: BTreeSet<(bool, TaskId)> = BTreeSet::new();
        for i in 0..self.consumers[t].len() {
            let c = self.consumers[t][i];
            self.st.indeg[c] -= 1;
            if self.st.indeg[c] == 0 {
                cands.insert((!self.plan.tasks[c].is_comm(), c));
            }
        }
        for i in 0..self.streams_of[t].len() {
            let s = self.streams_of[t][i];
            cands.extend(std::mem::take(&mut self.st.waiters[s]));
        }
        for (_, c) in cands {
            if !self.st.done[c] && !self.st.started[c] {
                self.try_start(c, now);
            }
        }
    }

    /// Convert the drained engine state into a [`DesReport`] — the
    /// once-per-run reporting pass (memory timelines, bubble accounting).
    pub(crate) fn finalize(&self, g: &Graph, cluster: &Cluster) -> DesReport {
        let plan = self.plan;
        let n = plan.tasks.len();
        assert_eq!(
            self.st.completed, n,
            "DES deadlock — TaskGraph::prepare guarantees acyclicity"
        );
        let makespan = self.st.finish.iter().copied().fold(0.0, f64::max);
        let mut stats: HashMap<DeviceId, DeviceStat> =
            self.st.slot_stats.iter().flatten().cloned().map(|s| (s.device, s)).collect();

        // ---- time-resolved memory ----
        // Activations from the shared event stream, *plus* gradient-buffer
        // liveness: the DES baseline is the static bytes minus the gradient
        // share, and each gradient region is allocated when its backward
        // producer starts and freed when its last local toucher (optimizer /
        // sync collective) finishes. A plan therefore OOMs under the DES only
        // if gradient buffers are live *at the same time* as the activation
        // peak — the timeline admission the list scheduler's always-resident
        // watermark cannot express (dp replicas shift when gradients are live).
        let acts = activation_events(g, plan, &self.st.start, &self.st.finish);
        let grads = gradient_events(g, plan, &self.st.start, &self.st.finish);
        let mut devs: BTreeSet<DeviceId> = stats.keys().copied().collect();
        devs.extend(acts.keys().copied());
        devs.extend(grads.keys().copied());
        devs.extend(plan.static_mem.keys().copied());
        let mut mem: Vec<MemTimeline> = Vec::new();
        for d in devs {
            let static_total = plan.static_mem.get(&d).copied().unwrap_or(0);
            let grad_share = plan.static_grad_mem.get(&d).copied().unwrap_or(0);
            let base = static_total.saturating_sub(grad_share) as i64;
            let mut evs: Vec<(f64, i64)> = acts.get(&d).cloned().unwrap_or_default();
            if let Some(ge) = grads.get(&d) {
                evs.extend(ge.iter().copied());
                evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            }
            let mut points: Vec<(f64, u64)> = vec![(0.0, base.max(0) as u64)];
            let mut cur = base;
            let mut peak = base;
            let mut i = 0;
            while i < evs.len() {
                let t0 = evs[i].0;
                while i < evs.len() && evs[i].0 == t0 {
                    cur += evs[i].1;
                    i += 1;
                }
                peak = peak.max(cur);
                points.push((t0, cur.max(0) as u64));
            }
            let peak = peak.max(0) as u64;
            match stats.entry(d) {
                std::collections::hash_map::Entry::Occupied(mut e) => e.get_mut().peak_mem = peak,
                std::collections::hash_map::Entry::Vacant(e) => {
                    // A device with memory traffic but no tasks still reports
                    // (mirrors the list scheduler's accounting).
                    if acts.contains_key(&d) || grads.contains_key(&d) {
                        e.insert(DeviceStat { device: d, peak_mem: peak, ..Default::default() });
                    }
                }
            }
            mem.push(MemTimeline { device: d, points, peak });
        }

        for (dev, st) in stats.iter_mut() {
            st.bubble = (makespan - st.compute - st.comm).max(0.0);
            if *dev != CPU_DEVICE {
                st.oom = st.peak_mem > cluster.mem_capacity(*dev);
            }
        }
        let total_flops = g.total_flops();
        let mut per_device: Vec<DeviceStat> = stats.into_values().collect();
        per_device.sort_by_key(|d| d.device);
        let ngpu = per_device.iter().filter(|d| d.device != CPU_DEVICE).count().max(1);
        let oom = per_device.iter().any(|d| d.oom);
        let spans = (0..n)
            .map(|t| TaskSpan { task: t, start: self.st.start[t], finish: self.st.finish[t] })
            .collect();
        DesReport {
            makespan,
            per_device,
            spans,
            mem,
            total_flops,
            aggregate_tflops: if makespan > 0.0 { total_flops / makespan / 1e12 } else { 0.0 },
            tflops_per_gpu: if makespan > 0.0 {
                total_flops / makespan / 1e12 / ngpu as f64
            } else {
                0.0
            },
            comm_bytes: plan.comm_bytes,
            oom,
        }
    }
}

/// Execute `plan` against an already-prepared [`TaskGraph`]. Low-level
/// entry point shared by [`simulate`] and the synthetic-plan tests.
pub fn execute(g: &Graph, plan: &Plan, cluster: &Cluster, tg: &TaskGraph) -> DesReport {
    let mut eng = Engine::new(plan, cluster, tg);
    eng.seed();
    eng.run();
    eng.finalize(g, cluster)
}

/// Discrete-event execution of one iteration of `plan`, sharing the list
/// scheduler's task-graph preparation (per-device serial hints included).
pub fn simulate(g: &Graph, vs: &ValidatedSchedule, plan: &Plan, cluster: &Cluster) -> DesReport {
    let tg = TaskGraph::prepare(vs, plan);
    execute(g, plan, cluster, &tg)
}

/// Convenience: validate + materialize + DES-simulate in one call (the
/// high-fidelity mirror of [`crate::sim::run`]).
pub fn run(
    g: &Graph,
    sched: &crate::schedule::Schedule,
    cluster: &Cluster,
    mode: crate::materialize::CommMode,
) -> Result<DesReport, crate::schedule::ScheduleError> {
    let vs = crate::schedule::validate(g, sched)?;
    let plan = crate::materialize::materialize(g, &vs, cluster, mode);
    Ok(simulate(g, &vs, &plan, cluster))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::materialize::{Task, TaskKind};

    /// A graph with `n` tensor-less identity ops, so synthetic compute
    /// tasks (whose `op` field indexes the graph) resolve during the
    /// memory-event pass.
    fn dummy_graph(n: usize) -> Graph {
        let mut g = Graph::new();
        for i in 0..n {
            g.add_op(&format!("op{i}"), OpKind::Identity, vec![], vec![], 0.0, None, true, 0);
        }
        g
    }

    fn p2p_task(id: TaskId, from: DeviceId, to: DeviceId, dur: f64, deps: Vec<TaskId>) -> Task {
        Task {
            id,
            kind: TaskKind::P2P { from, to, bytes: 1 << 20, ptensor: 0 },
            deps,
            duration: dur,
            label: format!("x{id}").into(),
        }
    }

    fn compute_task(id: TaskId, device: DeviceId, dur: f64, deps: Vec<TaskId>) -> Task {
        Task {
            id,
            kind: TaskKind::Compute { op: id, device },
            deps,
            duration: dur,
            label: format!("c{id}").into(),
        }
    }

    #[test]
    fn two_transfers_on_one_nic_fair_share() {
        let c = Cluster::v100(16);
        let d = c.p2p_time(0, 8, 1 << 20);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 8, d, vec![]));
        plan.tasks.push(p2p_task(1, 1, 9, d, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&Graph::new(), &plan, &c, &tg);
        // Both cross Nic(0)+Nic(1): each runs at half rate, both finish at 2d.
        assert!((r.makespan - 2.0 * d).abs() < 1e-12, "got {}, want {}", r.makespan, 2.0 * d);
        // Solo run takes exactly d.
        let mut solo = Plan::default();
        solo.tasks.push(p2p_task(0, 0, 8, d, vec![]));
        let tg = TaskGraph::of_plan(&solo);
        let r = execute(&Graph::new(), &solo, &c, &tg);
        assert_eq!(r.makespan.to_bits(), d.to_bits());
    }

    #[test]
    fn disjoint_nvlink_transfers_do_not_contend() {
        let c = Cluster::v100(8);
        let d = c.p2p_time(0, 1, 1 << 20);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 1, d, vec![]));
        plan.tasks.push(p2p_task(1, 2, 3, d, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&Graph::new(), &plan, &c, &tg);
        assert!((r.makespan - d).abs() < 1e-12, "disjoint pairs must run at full rate");
    }

    #[test]
    fn shared_nvlink_port_serializes_on_the_comm_stream() {
        // Two transfers out of device 0 share its NVLink port; the comm
        // stream enforces exclusivity, so they run back-to-back.
        let c = Cluster::v100(8);
        let d = c.p2p_time(0, 1, 1 << 20);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 1, d, vec![]));
        plan.tasks.push(p2p_task(1, 0, 2, d, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&Graph::new(), &plan, &c, &tg);
        assert!((r.makespan - 2.0 * d).abs() < 1e-12, "same-port transfers must serialize");
    }

    #[test]
    fn concurrent_host_offloads_use_independent_pcie_lanes() {
        // Offload traffic from different GPUs does not funnel through a
        // single host stream: each GPU's PCIe lane carries it in parallel.
        let c = Cluster::v100(8);
        let d = c.p2p_time(0, CPU_DEVICE, 1 << 20);
        let mut plan = Plan::default();
        for (i, gpu) in [0usize, 1, 2, 3].into_iter().enumerate() {
            plan.tasks.push(p2p_task(i, gpu, CPU_DEVICE, d, vec![]));
        }
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&Graph::new(), &plan, &c, &tg);
        assert!((r.makespan - d).abs() < 1e-12, "offloads must run in parallel: {}", r.makespan);
    }

    #[test]
    fn comm_overlaps_compute_on_separate_streams() {
        // Device 0: one compute task and one outgoing transfer, independent.
        // List semantics would serialize them (2 units); streams overlap (1).
        let c = Cluster::v100(8);
        let mut plan = Plan::default();
        plan.tasks.push(compute_task(0, 0, 1.0, vec![]));
        plan.tasks.push(p2p_task(1, 0, 1, 1.0, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&dummy_graph(1), &plan, &c, &tg);
        assert!((r.makespan - 1.0).abs() < 1e-12, "overlap not credited: {}", r.makespan);
        let d0 = r.per_device.iter().find(|s| s.device == 0).unwrap();
        assert!((d0.compute - 1.0).abs() < 1e-12 && (d0.comm - 1.0).abs() < 1e-12);
    }

    #[test]
    fn same_stream_tasks_serialize_in_comm_first_id_order() {
        // Two compute tasks on one device with no deps: they must serialize
        // on the compute stream, lower id first.
        let c = Cluster::v100(8);
        let mut plan = Plan::default();
        plan.tasks.push(compute_task(0, 0, 1.0, vec![]));
        plan.tasks.push(compute_task(1, 0, 2.0, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&dummy_graph(2), &plan, &c, &tg);
        assert!((r.makespan - 3.0).abs() < 1e-12);
        assert!(r.spans[0].start < r.spans[1].start);
    }

    #[test]
    fn staggered_contention_stretches_only_the_shared_window() {
        // t0 starts at 0 (solo, duration 2s). t1 (duration 2s) is released
        // at t=1 by an upstream compute on another server. They share the
        // NICs from t=1: both halve. t0: 1s done + 1s left at 1/2 = done at
        // 3; t1 then runs solo its remaining 1s => finish 4.
        let c = Cluster::v100(16);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 8, 2.0, vec![]));
        plan.tasks.push(compute_task(1, 2, 1.0, vec![]));
        plan.tasks.push(p2p_task(2, 1, 9, 2.0, vec![1]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&dummy_graph(2), &plan, &c, &tg);
        assert!((r.spans[0].finish - 3.0).abs() < 1e-9, "t0 finish {}", r.spans[0].finish);
        assert!((r.spans[2].finish - 4.0).abs() < 1e-9, "t2 finish {}", r.spans[2].finish);
    }
}
