//! Discrete-event cluster simulator — the high-fidelity scoring tier.
//!
//! The list scheduler ([`crate::sim`]) charges every communication task to
//! *all* of its devices (synchronous-NCCL) and every transfer its solo
//! bandwidth. That systematically under-credits exactly the schedules the
//! paper's space-time phase (§3.2) exists to find: pipelines that overlap
//! communication with compute, and plans that exploit bandwidth asymmetries
//! between NVLink and the per-server NIC. This module executes the same
//! materialized [`Plan`] + [`TaskGraph`] under a more faithful model:
//!
//! * **two streams per device** — one compute, one communication — so a
//!   collective or point-to-point transfer occupies only the communication
//!   stream of its participants and compute proceeds concurrently whenever
//!   dependencies allow (CUDA-stream semantics);
//! * **fair-sharing link contention** — each transfer crosses the physical
//!   links named by [`Cluster::group_links`]; `k` concurrent transfers
//!   sharing a link each progress at `1/k` of their solo rate,
//!   re-evaluated at every transfer start/finish event (the dslab
//!   shared-throughput discipline). The links that fair-share are the
//!   *shared fabric hops* on a transfer's resolved route
//!   ([`crate::topo::Topology`]): the per-server NIC (a server's 8 GPUs
//!   funnel through one IB port), and on multi-tier fabrics also the rack's
//!   spine uplink (every cross-rack transfer in/out of the rack contends
//!   for it) or the rail switch (rail-optimized pods). A transfer holds
//!   every link on its route, so cross-rack traffic fair-shares at *both*
//!   racks' uplinks — the mechanism by which a fat-tree reprices a
//!   cross-rack collective slower than an in-rack one. NVLink ports and
//!   PCIe lanes belong to a single device, so their exclusivity is already
//!   enforced by that device's communication stream — two transfers
//!   touching the same port serialize rather than degrade, and transfers
//!   on disjoint ports/lanes (including concurrent host offloads from
//!   different GPUs) run at full rate in parallel;
//! * **time-resolved memory** — the full per-device resident-bytes
//!   timeline ([`MemTimeline`]), not just the high-watermark, so
//!   offload/recompute plans are judged on *when* memory peaks. Gradient
//!   buffers are part of the timeline too (allocated at their backward
//!   producer, freed after the optimizer and any sync collective), so a
//!   dp plan OOMs only when gradient liveness actually collides with the
//!   activation peak — not merely because watermark sums exceed capacity;
//! * **trace export** — every task's `(start, finish)` span is kept
//!   ([`TaskSpan`]) and can be serialized to Chrome's `chrome://tracing` /
//!   Perfetto JSON via [`trace::chrome_trace`].
//!
//! The engine is deterministic: the event heap is keyed by
//! `(time bits, issue sequence)`, all contention state lives in ordered
//! maps, and nothing depends on hash iteration or thread scheduling — the
//! same plan always produces bitwise-identical timelines, on any worker
//! pool. On a schedule with no overlap opportunity (a serial dependency
//! chain) the DES and the list scheduler agree exactly, because both add
//! the same task durations along the same critical path; the DES differs
//! only where overlap or contention exists to model.
//!
//! # Snapshotable engine state and delta replay
//!
//! All mutable execution state (event heap, stream cursors, link registry
//! occupancy, transfer fair-sharing state, per-slot stats) lives in one
//! [`EngineState`] struct rather than loop locals, separated from the
//! borrowed plan and the derived static tables. Cloning that struct at an
//! event count is a resumable checkpoint: [`delta`] captures checkpoints
//! at periodic epochs during a base run and, when a plan mutation leaves a
//! prefix of the event timeline untouched, restores the latest checkpoint
//! the mutation cannot have perturbed and re-executes only the suffix —
//! the incremental re-simulation that makes MCMC plan refinement
//! ([`crate::search::refine`]) tractable.
//!
//! # Fault injection and recovery
//!
//! [`execute_faulted`] runs the same event loop under a resolved
//! [`crate::fault::FaultPlan`]. A second, ordered queue of *control
//! events* interleaves with task-finish events in global time order
//! (control wins ties, so a kill at `t` aborts a task that would have
//! finished at `t`). The control-event kinds:
//!
//! * **Kill / DeviceUp** — a device goes down; whatever occupies its
//!   streams is aborted (a collective aborts for *every* participant,
//!   like NCCL) and the elapsed work is counted as lost. The device
//!   returns after `repair + checkpoint reload + replay` (replay covers
//!   the time since the last checkpoint commit — the whole run so far if
//!   checkpointing is off), and aborted tasks re-execute from scratch.
//! * **LinkCut / LinkUp** — a fabric link goes down; every in-flight
//!   transfer crossing it stalls (rate 0, route kept reserved — fat-tree
//!   routes are unique, so there is nothing to reroute onto) and resumes
//!   when all of its links are back. New transfers needing the link wait.
//! * **SlowStart / SlowEnd** — a straggler window reprices the device's
//!   in-flight and future compute by the degradation factor, through the
//!   same remaining/rate mechanism transfers use.
//! * **Ckpt / CkptDone** — a coordinated checkpoint freezes every stream
//!   for the snapshot stall (slowest device's weights+optimizer transfer
//!   to host, [`Cluster::checkpoint_time`]); the commit point becomes the
//!   new replay origin for subsequent kills.
//!
//! The faulted run reports a [`FaultOutcome`] on
//! [`DesReport::faults`]: lost work, checkpoint stall time, down time,
//! longest recovery, and the event log for trace export. *Goodput* — the
//! headline resilience metric ([`crate::fault::evaluate_resilience`]) —
//! is the fault-free makespan divided by the faulted makespan: the
//! fraction of faulted wall-clock spent on useful work. All fault state
//! lives behind an `Option`, and with an empty fault plan the event loop
//! takes the exact fault-free branches — no-fault timelines stay bitwise
//! identical (pinned by the no-fault equivalence test).

pub mod delta;
pub mod trace;

use crate::cost::{Cluster, LinkId};
use crate::graph::Graph;
use crate::materialize::{Plan, TaskId};
use crate::schedule::{DeviceId, ValidatedSchedule, CPU_DEVICE};
use crate::sim::{activation_events, dev_slot, gradient_events, DeviceStat, TaskGraph};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap};

/// Execution interval of one task on the DES timeline.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpan {
    pub task: TaskId,
    pub start: f64,
    pub finish: f64,
}

/// Time-resolved resident memory of one device: step points
/// `(time, bytes)` — the value holds until the next point. The time-0
/// baseline is the static weights/optimizer bytes; gradient buffers enter
/// and leave the timeline with their actual liveness (they are *not* part
/// of the baseline, unlike the list scheduler's accounting).
#[derive(Clone, Debug)]
pub struct MemTimeline {
    pub device: DeviceId,
    pub points: Vec<(f64, u64)>,
    pub peak: u64,
}

/// Result of one discrete-event execution.
#[derive(Clone, Debug)]
pub struct DesReport {
    pub makespan: f64,
    pub per_device: Vec<DeviceStat>,
    /// Per-task execution spans, indexed by task id.
    pub spans: Vec<TaskSpan>,
    /// Per-device memory timelines (devices sorted; host last).
    pub mem: Vec<MemTimeline>,
    pub total_flops: f64,
    pub aggregate_tflops: f64,
    pub tflops_per_gpu: f64,
    pub comm_bytes: u64,
    pub oom: bool,
    /// Fault-injection accounting — `Some` only for [`execute_faulted`]
    /// runs (fault-free reports are unchanged).
    pub faults: Option<FaultOutcome>,
}

/// What a faulted run lost and when: the resilience accounting
/// [`crate::fault::evaluate_resilience`] turns into goodput/recovery
/// metrics, plus the event log the Chrome-trace exporter renders as a
/// fault lane.
#[derive(Clone, Debug, Default)]
pub struct FaultOutcome {
    /// Seconds of in-flight work aborted by device kills.
    pub lost_work: f64,
    /// Seconds every stream spent frozen in checkpoint stalls.
    pub ckpt_time: f64,
    /// Longest single device outage (repair + reload + replay).
    pub recovery_time: f64,
    /// Summed device-seconds of downtime across all kills.
    pub down_time: f64,
    /// Device-kill events that fired (a rack loss counts each device).
    pub n_kills: usize,
    /// All fault events that fired (kills + outages + slowdowns).
    pub n_faults: usize,
    /// Chronological fault/checkpoint windows for trace export.
    pub events: Vec<FaultTraceEvent>,
}

/// One fault or checkpoint window on the timeline.
#[derive(Clone, Copy, Debug)]
pub struct FaultTraceEvent {
    pub at: f64,
    pub until: f64,
    /// The affected device; `None` for cluster-wide windows (link
    /// outages, checkpoint freezes).
    pub device: Option<DeviceId>,
    pub kind: FaultTraceKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultTraceKind {
    /// Device down: kill through recovered.
    Crash,
    /// Link outage window.
    LinkDown,
    /// Straggler degradation window.
    SlowStart,
    /// Coordinated checkpoint freeze window.
    Ckpt,
}

impl DesReport {
    pub fn max_peak_mem(&self) -> u64 {
        self.per_device.iter().map(|d| d.peak_mem).max().unwrap_or(0)
    }

    /// Mean compute / comm / bubble seconds across devices. `comm` counts
    /// communication-stream busy time, which may overlap compute — the
    /// overlap the list scheduler cannot express.
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let n = self.per_device.len().max(1) as f64;
        let c = self.per_device.iter().map(|d| d.compute).sum::<f64>() / n;
        let m = self.per_device.iter().map(|d| d.comm).sum::<f64>() / n;
        let b = self.per_device.iter().map(|d| d.bubble).sum::<f64>() / n;
        (c, m, b)
    }
}

/// One serial execution lane of a device, as a dense index: device slot
/// `s`'s compute stream is `2s`, its communication stream `2s + 1`. Compute
/// tasks occupy the compute stream of their device; communication tasks the
/// communication stream of every participant — the "one compute + one comm
/// stream per device" model that lets transfers overlap with kernels.
fn compute_stream(d: DeviceId) -> usize {
    2 * dev_slot(d)
}

fn comm_stream(d: DeviceId) -> usize {
    2 * dev_slot(d) + 1
}

/// An in-flight transfer's fair-sharing state. `remaining` is measured in
/// *solo seconds* (the cost model's uncontended duration); contention
/// scales the rate at which it drains, never the total work. The fault
/// layer reuses the same mechanism for degraded compute (rate = straggler
/// factor) and for stalled work (rate 0 while a link is cut or a
/// checkpoint freeze is in force).
#[derive(Clone, Debug)]
struct Xfer {
    remaining: f64,
    rate: f64,
    last: f64,
}

/// A fault-injection control event (see the module doc). Ordered by the
/// surrounding `(time bits, seq, Ctrl)` queue key; the payload indexes
/// into [`FaultTables`] or names a slot / dense link directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Ctrl {
    /// Kill event `i` of [`FaultTables::kills`] fires.
    Kill(u32),
    /// Device slot recovers.
    DeviceUp(u32),
    /// Outage `i` of [`FaultTables::outages`] begins.
    LinkCut(u32),
    /// Dense link comes back up.
    LinkUp(u32),
    /// Straggler window `i` of [`FaultTables::slows`] begins.
    SlowStart(u32),
    /// Straggler window on a device slot ends.
    SlowEnd(u32),
    /// Coordinated checkpoint begins (freezes every stream).
    Ckpt,
    /// Checkpoint commits; payload is the commit time's bits (the new
    /// replay origin).
    CkptDone(u64),
}

/// Static fault-injection tables, derived once from the resolved
/// [`crate::fault::FaultPlan`] in [`Engine::with_faults`]: device kills
/// mapped to stream slots, outages to dense link indices, plus the
/// checkpoint cadence and per-slot snapshot costs.
#[derive(Clone, Debug)]
struct FaultTables {
    /// `(at, victim slots, hardware repair secs)` per kill event.
    kills: Vec<(f64, Vec<usize>, f64)>,
    /// `(at, dense link, duration)` per link outage.
    outages: Vec<(f64, usize, f64)>,
    /// `(at, slot, factor, duration)` per straggler window.
    slows: Vec<(f64, usize, f64, f64)>,
    /// Checkpoint interval (0 = off).
    ckpt_interval: f64,
    /// Per-slot weights+optimizer snapshot seconds (the reload cost a
    /// recovering device pays).
    ckpt_secs: Vec<f64>,
    /// The coordinated stall: max of `ckpt_secs`.
    ckpt_stall: f64,
}

/// Mutable fault-injection state, carried inside [`EngineState`] so delta
/// snapshots stay coherent. `None` on fault-free runs — every fault-path
/// branch in the event loop is gated on it.
#[derive(Clone, Debug)]
struct FaultState {
    /// Pending control events, ordered `(time bits, seq, kind)`.
    ctrl: BTreeSet<(u64, u32, Ctrl)>,
    ctrl_seq: u32,
    /// Per-slot compute-rate multiplier (1.0 nominal).
    degrade: Vec<f64>,
    /// Per-slot recovery time; `NEG_INFINITY` = up.
    down_until: Vec<f64>,
    /// Per-dense-link outage end; `NEG_INFINITY` = up.
    link_down: Vec<f64>,
    /// Ready tasks blocked by a down device, cut link or freeze, keyed
    /// `(is_compute, id)` like the stream waiter queues.
    held: BTreeSet<(bool, TaskId)>,
    /// Started tasks currently stalled at rate 0 (link cut / freeze).
    paused: BTreeSet<TaskId>,
    /// Checkpoint freeze in force until this time (`NEG_INFINITY` = none).
    frozen_until: f64,
    /// Last checkpoint commit — the replay origin for kills.
    ckpt_last: f64,
    outcome: FaultOutcome,
}

/// Inverse of [`dev_slot`]: slot 0 is the host, slot `s` is GPU `s - 1`.
fn device_of_slot(s: usize) -> DeviceId {
    if s == 0 {
        CPU_DEVICE
    } else {
        s - 1
    }
}

/// Every mutable value of one engine run — what the event loop reads and
/// writes, with the borrowed plan and the derived static tables kept apart
/// on [`Engine`]. A clone of this struct is a resumable checkpoint of the
/// simulation at `events` executed finish events; [`delta`] snapshots it at
/// periodic epochs so plan mutations replay only the perturbed suffix.
#[derive(Clone, Debug)]
pub(crate) struct EngineState {
    indeg: Vec<usize>,
    start: Vec<f64>,
    finish: Vec<f64>,
    started: Vec<bool>,
    done: Vec<bool>,
    /// Event-version per task: heap entries carrying an older version are
    /// stale re-pricings and are skipped on pop.
    version: Vec<u64>,
    seq: u64,
    /// Min-heap of predicted finish events `(time bits, seq, task, version)`.
    heap: BinaryHeap<Reverse<(u64, u64, TaskId, u64)>>,
    /// Stream slot -> the task currently occupying it.
    busy: Vec<Option<TaskId>>,
    /// Tasks ready but blocked on a busy stream, keyed `(is_compute, id)`
    /// so communication dispatches first (eager send), then lower id.
    waiters: Vec<BTreeSet<(bool, TaskId)>>,
    /// Per-task fair-sharing state (`None` when not an in-flight transfer).
    xfers: Vec<Option<Xfer>>,
    /// Link slot -> transfers currently crossing it (the sets stay ordered
    /// by task id, which is what keeps repricing deterministic).
    link_active: Vec<BTreeSet<TaskId>>,
    completed: usize,
    /// Dense per-slot device stats, accumulated at every finish event;
    /// converted to the device-keyed map once, in [`Engine::finalize`].
    slot_stats: Vec<Option<DeviceStat>>,
    /// Finish events executed so far — the snapshot epoch coordinate.
    events: usize,
    /// Fault-injection state; `None` on fault-free runs (every fault
    /// branch in the loop is gated on it, keeping those runs bitwise
    /// identical to the pre-fault engine).
    faults: Option<FaultState>,
}

pub(crate) struct Engine<'a> {
    plan: &'a Plan,
    consumers: &'a [Vec<TaskId>],
    /// Per-task occupied devices, resolved once (`Task::devices` allocates
    /// and sorts a fresh Vec per call — far too hot for the event loop).
    devices: Vec<Vec<DeviceId>>,
    /// Per-task dense stream indices (see [`compute_stream`]/[`comm_stream`]).
    streams_of: Vec<Vec<usize>>,
    /// Per-task dense link indices into `link_active` (the [`LinkId`] →
    /// index registry is built once in [`Engine::new`]).
    links_of: Vec<Vec<usize>>,
    /// Device slots in use (`st.busy.len() / 2`).
    nslots: usize,
    /// The [`LinkId`] → dense index registry behind `links_of`, kept so
    /// fault-plan link outages can resolve to `link_active` slots.
    link_index: BTreeMap<LinkId, usize>,
    /// Static fault-injection tables; `None` on fault-free runs.
    ftab: Option<FaultTables>,
    /// The snapshotable mutable state (see [`EngineState`]).
    st: EngineState,
}

impl<'a> Engine<'a> {
    pub(crate) fn new(plan: &'a Plan, cluster: &Cluster, tg: &'a TaskGraph) -> Engine<'a> {
        let n = plan.tasks.len();
        let devices: Vec<Vec<DeviceId>> = plan.tasks.iter().map(|t| t.devices()).collect();
        let max_gpu =
            devices.iter().flatten().copied().filter(|&d| d != CPU_DEVICE).max().unwrap_or(0);
        let nslots = max_gpu + 2;
        let streams_of: Vec<Vec<usize>> = plan
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if t.is_comm() {
                    // The host is not a serializing endpoint: each GPU has
                    // its own PCIe lane + DMA engine, so concurrent
                    // offload transfers from different GPUs proceed in
                    // parallel and only the per-GPU comm stream (and the
                    // Pcie link) constrains them.
                    devices[i]
                        .iter()
                        .copied()
                        .filter(|&d| d != CPU_DEVICE)
                        .map(comm_stream)
                        .collect()
                } else {
                    devices[i].iter().copied().map(compute_stream).collect()
                }
            })
            .collect();
        // Dense link registry: LinkId -> index in first-seen task order
        // (deterministic — the task list is fixed).
        let mut link_index: BTreeMap<LinkId, usize> = BTreeMap::new();
        let links_of: Vec<Vec<usize>> = plan
            .tasks
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if !t.is_comm() {
                    return Vec::new();
                }
                cluster
                    .group_links(&devices[i])
                    .into_iter()
                    .map(|l| {
                        let next = link_index.len();
                        *link_index.entry(l).or_insert(next)
                    })
                    .collect()
            })
            .collect();
        let nlinks = link_index.len();
        Engine {
            plan,
            consumers: &tg.consumers,
            devices,
            streams_of,
            links_of,
            nslots,
            link_index,
            ftab: None,
            st: EngineState {
                indeg: tg.indeg.clone(),
                start: vec![0.0; n],
                finish: vec![0.0; n],
                started: vec![false; n],
                done: vec![false; n],
                version: vec![0; n],
                seq: 0,
                heap: BinaryHeap::new(),
                busy: vec![None; 2 * nslots],
                waiters: vec![BTreeSet::new(); 2 * nslots],
                xfers: vec![None; n],
                link_active: vec![BTreeSet::new(); nlinks],
                completed: 0,
                slot_stats: vec![None; nslots],
                events: 0,
                faults: None,
            },
        }
    }

    /// [`Engine::new`] plus fault injection: lower the resolved
    /// [`crate::fault::FaultPlan`] to dense tables (kills → stream slots,
    /// outages → dense links; outages on links no task crosses are
    /// dropped — there is nothing to stall) and seed the control-event
    /// queue. Checkpoint reload costs come from the host-link tier
    /// ([`Cluster::checkpoint_time`]) over each device's static
    /// weights+optimizer bytes.
    pub(crate) fn with_faults(
        plan: &'a Plan,
        cluster: &Cluster,
        tg: &'a TaskGraph,
        fp: &crate::fault::FaultPlan,
    ) -> Engine<'a> {
        let mut eng = Self::new(plan, cluster, tg);
        let nslots = eng.nslots;
        let mut ckpt_secs = vec![0.0f64; nslots];
        for (&d, &bytes) in &plan.static_mem {
            if d == CPU_DEVICE || dev_slot(d) >= nslots {
                continue;
            }
            let grad = plan.static_grad_mem.get(&d).copied().unwrap_or(0);
            ckpt_secs[dev_slot(d)] = cluster.checkpoint_time(d, bytes.saturating_sub(grad));
        }
        let ckpt_stall = ckpt_secs.iter().copied().fold(0.0, f64::max);
        let kills: Vec<(f64, Vec<usize>, f64)> = fp
            .kills
            .iter()
            .map(|k| {
                let slots: Vec<usize> = k
                    .devices
                    .iter()
                    .map(|&d| dev_slot(d))
                    .filter(|&s| s > 0 && s < nslots)
                    .collect();
                (k.at, slots, k.repair)
            })
            .collect();
        let outages: Vec<(f64, usize, f64)> = fp
            .outages
            .iter()
            .filter_map(|o| eng.link_index.get(&o.link).map(|&l| (o.at, l, o.duration)))
            .collect();
        let slows: Vec<(f64, usize, f64, f64)> = fp
            .slowdowns
            .iter()
            .map(|s| (s.at, dev_slot(s.device), s.factor, s.duration))
            .filter(|&(_, slot, _, _)| slot > 0 && slot < nslots)
            .collect();
        let mut fs = FaultState {
            ctrl: BTreeSet::new(),
            ctrl_seq: 0,
            degrade: vec![1.0; nslots],
            down_until: vec![f64::NEG_INFINITY; nslots],
            link_down: vec![f64::NEG_INFINITY; eng.st.link_active.len()],
            held: BTreeSet::new(),
            paused: BTreeSet::new(),
            frozen_until: f64::NEG_INFINITY,
            ckpt_last: 0.0,
            outcome: FaultOutcome::default(),
        };
        let mut seeds: Vec<(f64, Ctrl)> = Vec::new();
        for (i, k) in kills.iter().enumerate() {
            seeds.push((k.0, Ctrl::Kill(i as u32)));
        }
        for (i, o) in outages.iter().enumerate() {
            seeds.push((o.0, Ctrl::LinkCut(i as u32)));
        }
        for (i, s) in slows.iter().enumerate() {
            seeds.push((s.0, Ctrl::SlowStart(i as u32)));
        }
        if fp.ckpt_interval > 0.0 {
            seeds.push((fp.ckpt_interval, Ctrl::Ckpt));
        }
        for (at, c) in seeds {
            fs.ctrl_seq += 1;
            fs.ctrl.insert((at.to_bits(), fs.ctrl_seq, c));
        }
        eng.ftab = Some(FaultTables {
            kills,
            outages,
            slows,
            ckpt_interval: fp.ckpt_interval,
            ckpt_secs,
            ckpt_stall,
        });
        eng.st.faults = Some(fs);
        eng
    }

    /// Dispatch the initial ready set (indegree-0 tasks) at time 0, in
    /// (comm-first, id) order.
    pub(crate) fn seed(&mut self) {
        let mut initial: BTreeSet<(bool, TaskId)> = BTreeSet::new();
        for t in 0..self.plan.tasks.len() {
            if self.st.indeg[t] == 0 {
                initial.insert((!self.plan.tasks[t].is_comm(), t));
            }
        }
        for (_, t) in initial {
            self.try_start(t, 0.0);
        }
    }

    /// Execute the next event, skipping stale re-pricings. Returns false
    /// once the run is over. On fault-free runs this is a single heap pop;
    /// with faults active the control queue is merged in, control events
    /// winning time ties (a crash at `t` kills a task that would have
    /// finished at exactly `t`).
    pub(crate) fn step(&mut self) -> bool {
        if self.ftab.is_none() {
            while let Some(Reverse((time_bits, _, t, v))) = self.st.heap.pop() {
                if v != self.st.version[t] || self.st.done[t] {
                    continue; // stale re-pricing
                }
                let now = f64::from_bits(time_bits);
                self.finish_task(t, now);
                return true;
            }
            return false;
        }
        loop {
            // Faulted runs stop at task completion, not queue exhaustion:
            // the periodic checkpoint event re-arms itself forever.
            if self.st.completed == self.plan.tasks.len() {
                return false;
            }
            let next_fin = self.peek_valid_finish();
            let next_ctrl = self.st.faults.as_ref().and_then(|f| f.ctrl.first().copied());
            match (next_ctrl, next_fin) {
                (None, None) => return false,
                (None, Some(_)) => {
                    self.pop_finish();
                    return true;
                }
                (Some((cb, _, _)), Some(fb)) if cb > fb => {
                    self.pop_finish();
                    return true;
                }
                (Some(c), _) => {
                    self.st.faults.as_mut().expect("faults set").ctrl.remove(&c);
                    self.run_ctrl(f64::from_bits(c.0), c.2);
                }
            }
        }
    }

    /// Pop stale finish events off the heap top; return the time bits of
    /// the first live one (left on the heap), if any.
    fn peek_valid_finish(&mut self) -> Option<u64> {
        while let Some(&Reverse((time_bits, _, t, v))) = self.st.heap.peek() {
            if v != self.st.version[t] || self.st.done[t] {
                self.st.heap.pop();
                continue;
            }
            return Some(time_bits);
        }
        None
    }

    /// Execute the (already-validated) finish event at the heap top.
    fn pop_finish(&mut self) {
        let Reverse((time_bits, _, t, _)) =
            self.st.heap.pop().expect("peek_valid_finish found an event");
        let now = f64::from_bits(time_bits);
        self.finish_task(t, now);
    }

    fn run_ctrl(&mut self, now: f64, c: Ctrl) {
        match c {
            Ctrl::Kill(i) => self.ctrl_kill(i as usize, now),
            Ctrl::DeviceUp(s) => self.ctrl_device_up(s as usize, now),
            Ctrl::LinkCut(i) => self.ctrl_link_cut(i as usize, now),
            Ctrl::LinkUp(l) => self.ctrl_link_up(l as usize, now),
            Ctrl::SlowStart(i) => self.ctrl_slow_start(i as usize, now),
            Ctrl::SlowEnd(s) => self.ctrl_slow_end(s as usize, now),
            Ctrl::Ckpt => self.ctrl_ckpt(now),
            Ctrl::CkptDone(t0) => self.ctrl_ckpt_done(f64::from_bits(t0), now),
        }
    }

    /// Fail the devices of kill event `i`: in-flight tasks on their
    /// streams are aborted (their progress is lost work), the devices stay
    /// down through repair + checkpoint reload + replay of everything
    /// since the last checkpoint, and a [`Ctrl::DeviceUp`] marks the end.
    fn ctrl_kill(&mut self, i: usize, now: f64) {
        let (slots, repair) = {
            let k = &self.ftab.as_ref().expect("ftab set").kills[i];
            (k.1.clone(), k.2)
        };
        let ckpt_secs: Vec<f64> =
            slots.iter().map(|&s| self.ftab.as_ref().expect("ftab set").ckpt_secs[s]).collect();
        let mut downed: Vec<usize> = Vec::new();
        {
            let fs = self.st.faults.as_ref().expect("faults set");
            for &s in &slots {
                if !(now < fs.down_until[s]) {
                    downed.push(s);
                }
            }
        }
        if downed.is_empty() {
            return;
        }
        let mut victims: BTreeSet<TaskId> = BTreeSet::new();
        let mut freed: BTreeSet<usize> = BTreeSet::new();
        for (j, &s) in slots.iter().enumerate() {
            if !downed.contains(&s) {
                continue;
            }
            let up_at = {
                let fs = self.st.faults.as_mut().expect("faults set");
                let replay = (now - fs.ckpt_last).max(0.0);
                let up_at = now + repair + ckpt_secs[j] + replay;
                fs.down_until[s] = up_at;
                fs.outcome.n_kills += 1;
                fs.outcome.n_faults += 1;
                fs.outcome.down_time += up_at - now;
                fs.outcome.recovery_time = fs.outcome.recovery_time.max(up_at - now);
                fs.outcome.events.push(FaultTraceEvent {
                    at: now,
                    until: up_at,
                    device: Some(device_of_slot(s)),
                    kind: FaultTraceKind::Crash,
                });
                up_at
            };
            self.push_ctrl(up_at, Ctrl::DeviceUp(s as u32));
            for &stream in &[2 * s, 2 * s + 1] {
                if let Some(u) = self.st.busy[stream] {
                    victims.insert(u);
                }
                freed.insert(stream);
            }
        }
        for u in victims {
            let lost = (now - self.st.start[u]).max(0.0);
            self.st.started[u] = false;
            self.st.version[u] += 1;
            for &stream in &self.streams_of[u] {
                self.st.busy[stream] = None;
                freed.insert(stream);
            }
            if self.st.xfers[u].take().is_some() {
                for &l in &self.links_of[u] {
                    self.st.link_active[l].remove(&u);
                }
                self.reprice_sharers(u, now);
            }
            let key = (!self.plan.tasks[u].is_comm(), u);
            let fs = self.st.faults.as_mut().expect("faults set");
            fs.paused.remove(&u);
            fs.held.insert(key);
            fs.outcome.lost_work += lost;
        }
        // Waiters parked on the freed streams would sleep forever without a
        // finish event to wake them — re-dispatch (they will be re-held if
        // their own devices are the ones down).
        let mut cands: BTreeSet<(bool, TaskId)> = BTreeSet::new();
        for s in freed {
            cands.extend(std::mem::take(&mut self.st.waiters[s]));
        }
        for (_, c) in cands {
            if !self.st.done[c] && !self.st.started[c] {
                self.try_start(c, now);
            }
        }
    }

    fn ctrl_device_up(&mut self, slot: usize, now: f64) {
        self.st.faults.as_mut().expect("faults set").down_until[slot] = f64::NEG_INFINITY;
        self.drain_held(now);
    }

    /// Cut dense link `i`'s [`LinkId`]: in-flight transfers crossing it
    /// freeze (rate 0) until the matching [`Ctrl::LinkUp`].
    fn ctrl_link_cut(&mut self, i: usize, now: f64) {
        let (l, dur) = {
            let o = &self.ftab.as_ref().expect("ftab set").outages[i];
            (o.1, o.2)
        };
        let until = now + dur;
        {
            let fs = self.st.faults.as_mut().expect("faults set");
            fs.link_down[l] = fs.link_down[l].max(until);
            fs.outcome.n_faults += 1;
            fs.outcome.events.push(FaultTraceEvent {
                at: now,
                until,
                device: None,
                kind: FaultTraceKind::LinkDown,
            });
        }
        self.push_ctrl(until, Ctrl::LinkUp(l as u32));
        let active: Vec<TaskId> = self.st.link_active[l].iter().copied().collect();
        for u in active {
            let already = self.st.faults.as_ref().expect("faults set").paused.contains(&u);
            if !already {
                self.pause_task(u, now);
            }
        }
    }

    fn ctrl_link_up(&mut self, l: usize, now: f64) {
        self.st.faults.as_mut().expect("faults set").link_down[l] = f64::NEG_INFINITY;
        let active: Vec<TaskId> = self.st.link_active[l].iter().copied().collect();
        for u in active {
            let resumable = {
                let fs = self.st.faults.as_ref().expect("faults set");
                fs.paused.contains(&u)
                    && now >= fs.frozen_until
                    && self.links_of[u].iter().all(|&l2| now >= fs.link_down[l2])
            };
            if resumable {
                self.resume_task(u, now);
            }
        }
        self.drain_held(now);
    }

    /// Start straggler window `i`: the device's compute runs at `factor`
    /// speed until the matching [`Ctrl::SlowEnd`]. Overlapping windows on
    /// one device are last-writer-wins (the end event restores 1.0).
    fn ctrl_slow_start(&mut self, i: usize, now: f64) {
        let (slot, factor, dur) = {
            let s = &self.ftab.as_ref().expect("ftab set").slows[i];
            (s.1, s.2, s.3)
        };
        let until = now + dur;
        {
            let fs = self.st.faults.as_mut().expect("faults set");
            fs.degrade[slot] = factor;
            fs.outcome.n_faults += 1;
            fs.outcome.events.push(FaultTraceEvent {
                at: now,
                until,
                device: Some(device_of_slot(slot)),
                kind: FaultTraceKind::SlowStart,
            });
        }
        self.push_ctrl(until, Ctrl::SlowEnd(slot as u32));
        self.reprice_compute(slot, now);
    }

    fn ctrl_slow_end(&mut self, slot: usize, now: f64) {
        self.st.faults.as_mut().expect("faults set").degrade[slot] = 1.0;
        self.reprice_compute(slot, now);
    }

    /// Take a global checkpoint: every in-flight task pauses for the
    /// stall (the widest device's host-link writeback), after which
    /// `ckpt_last` commits to the checkpoint *start* time and the next
    /// periodic checkpoint is armed.
    fn ctrl_ckpt(&mut self, now: f64) {
        let (stall, interval) = {
            let ft = self.ftab.as_ref().expect("ftab set");
            (ft.ckpt_stall, ft.ckpt_interval)
        };
        if interval <= 0.0 {
            return;
        }
        if stall <= 0.0 {
            // Nothing resident to write back — a free checkpoint.
            self.st.faults.as_mut().expect("faults set").ckpt_last = now;
            self.push_ctrl(now + interval, Ctrl::Ckpt);
            return;
        }
        let until = now + stall;
        {
            let fs = self.st.faults.as_mut().expect("faults set");
            fs.frozen_until = until;
            fs.outcome.ckpt_time += stall;
            fs.outcome.events.push(FaultTraceEvent {
                at: now,
                until,
                device: None,
                kind: FaultTraceKind::Ckpt,
            });
        }
        self.push_ctrl(until, Ctrl::CkptDone(now.to_bits()));
        self.push_ctrl(until + interval, Ctrl::Ckpt);
        let mut inflight: BTreeSet<TaskId> = BTreeSet::new();
        for s in 0..self.st.busy.len() {
            if let Some(u) = self.st.busy[s] {
                inflight.insert(u);
            }
        }
        for u in inflight {
            let already = self.st.faults.as_ref().expect("faults set").paused.contains(&u);
            if !already {
                self.pause_task(u, now);
            }
        }
    }

    fn ctrl_ckpt_done(&mut self, t0: f64, now: f64) {
        {
            let fs = self.st.faults.as_mut().expect("faults set");
            fs.frozen_until = f64::NEG_INFINITY;
            fs.ckpt_last = t0;
        }
        let paused: Vec<TaskId> = {
            let fs = self.st.faults.as_ref().expect("faults set");
            fs.paused.iter().copied().collect()
        };
        for u in paused {
            let links_up = {
                let fs = self.st.faults.as_ref().expect("faults set");
                self.links_of[u].iter().all(|&l| now >= fs.link_down[l])
            };
            if links_up {
                self.resume_task(u, now);
            }
        }
        self.drain_held(now);
    }

    fn push_ctrl(&mut self, time: f64, c: Ctrl) {
        let fs = self.st.faults.as_mut().expect("faults set");
        fs.ctrl_seq += 1;
        fs.ctrl.insert((time.to_bits(), fs.ctrl_seq, c));
    }

    /// Re-dispatch every task held back by a down device / cut link /
    /// checkpoint freeze; still-blocked ones re-insert themselves.
    fn drain_held(&mut self, now: f64) {
        let held = std::mem::take(&mut self.st.faults.as_mut().expect("faults set").held);
        for (_, t) in held {
            if !self.st.done[t] && !self.st.started[t] {
                self.try_start(t, now);
            }
        }
    }

    /// Freeze in-flight task `u` at `now`: drain its progress into an
    /// [`Xfer`] (creating one for compute tasks) and set rate 0 so no
    /// finish event fires until [`Engine::resume_task`].
    fn pause_task(&mut self, u: TaskId, now: f64) {
        {
            let fs = self.st.faults.as_mut().expect("faults set");
            if !fs.paused.insert(u) {
                return;
            }
        }
        match self.st.xfers[u].as_mut() {
            Some(x) => {
                x.remaining -= (now - x.last) * x.rate;
                x.remaining = x.remaining.max(0.0);
                x.last = now;
                x.rate = 0.0;
            }
            None => {
                let dur = self.plan.tasks[u].duration;
                let elapsed = (now - self.st.start[u]).max(0.0);
                self.st.xfers[u] =
                    Some(Xfer { remaining: (dur - elapsed).max(0.0), rate: 0.0, last: now });
            }
        }
        self.st.version[u] += 1; // invalidate the pending finish event
    }

    fn resume_task(&mut self, u: TaskId, now: f64) {
        let rate = if self.links_of[u].is_empty() { self.degrade_rate(u) } else { self.rate_of(u) };
        let remaining = {
            let x = self.st.xfers[u].as_mut().expect("paused task has drained state");
            x.last = now;
            x.rate = rate;
            x.remaining
        };
        self.st.version[u] += 1;
        self.push_finish(now + remaining / rate, u);
        self.st.faults.as_mut().expect("faults set").paused.remove(&u);
    }

    /// Re-price the compute task running on `slot` (if any) after its
    /// device's degradation factor changed.
    fn reprice_compute(&mut self, slot: usize, now: f64) {
        let Some(u) = self.st.busy[2 * slot] else { return };
        if !self.links_of[u].is_empty() {
            return; // link-crossing transfer: degradation targets compute
        }
        if self.st.faults.as_ref().expect("faults set").paused.contains(&u) {
            return; // resume path re-reads the degradation factor
        }
        let rate = self.degrade_rate(u);
        match self.st.xfers[u].as_mut() {
            Some(x) => {
                x.remaining -= (now - x.last) * x.rate;
                x.remaining = x.remaining.max(0.0);
                x.last = now;
                if rate == x.rate {
                    return;
                }
                x.rate = rate;
            }
            None => {
                if rate == 1.0 {
                    return;
                }
                let dur = self.plan.tasks[u].duration;
                let elapsed = (now - self.st.start[u]).max(0.0);
                self.st.xfers[u] = Some(Xfer { remaining: (dur - elapsed).max(0.0), rate, last: now });
            }
        }
        let remaining = self.st.xfers[u].as_ref().expect("just set").remaining;
        self.st.version[u] += 1;
        self.push_finish(now + remaining / rate, u);
    }

    /// Compute-speed multiplier for task `u`: the slowest degradation
    /// factor among its devices (1.0 when faults are off).
    fn degrade_rate(&self, u: TaskId) -> f64 {
        let Some(fs) = self.st.faults.as_ref() else { return 1.0 };
        let mut rate: f64 = 1.0;
        for &d in &self.devices[u] {
            if d != CPU_DEVICE {
                rate = rate.min(fs.degrade[dev_slot(d)]);
            }
        }
        rate
    }

    pub(crate) fn run(&mut self) {
        while self.step() {}
    }

    fn push_finish(&mut self, time: f64, t: TaskId) {
        self.st.seq += 1;
        self.st.heap.push(Reverse((time.to_bits(), self.st.seq, t, self.st.version[t])));
    }

    /// Fair-share rate of transfer `t`: 1 / (most crowded link it crosses).
    fn rate_of(&self, t: TaskId) -> f64 {
        let mut widest = 1usize;
        for &l in &self.links_of[t] {
            widest = widest.max(self.st.link_active[l].len());
        }
        1.0 / widest as f64
    }

    /// Re-price every in-flight transfer sharing a link with `t` after the
    /// active set changed at `now`: drain `remaining` at the old rate up to
    /// `now`, adopt the new rate, reissue the finish event. Transfers whose
    /// rate is unchanged are left untouched (no float churn), which is what
    /// makes uncontended runs bit-identical to the list scheduler's sums.
    fn reprice_sharers(&mut self, t: TaskId, now: f64) {
        let mut affected: BTreeSet<TaskId> = BTreeSet::new();
        for &l in &self.links_of[t] {
            affected.extend(self.st.link_active[l].iter().copied());
        }
        affected.remove(&t);
        for u in affected {
            let new_rate = self.rate_of(u);
            let x = self.st.xfers[u].as_mut().expect("active transfer has state");
            if x.rate == 0.0 {
                continue; // paused by a fault — resume_task re-prices it
            }
            if new_rate == x.rate {
                continue;
            }
            x.remaining -= (now - x.last) * x.rate;
            x.remaining = x.remaining.max(0.0);
            x.last = now;
            x.rate = new_rate;
            let fin = now + x.remaining / new_rate;
            self.st.version[u] += 1;
            self.push_finish(fin, u);
        }
    }

    /// Start `t` at `now` if every stream it needs is free; otherwise park
    /// it on its busy streams' waiter queues. Returns whether it started.
    fn try_start(&mut self, t: TaskId, now: f64) -> bool {
        if self.st.started[t] {
            return true;
        }
        if self.st.faults.is_some() {
            let barred = {
                let fs = self.st.faults.as_ref().expect("faults set");
                now < fs.frozen_until
                    || self.devices[t]
                        .iter()
                        .any(|&d| d != CPU_DEVICE && now < fs.down_until[dev_slot(d)])
                    || self.links_of[t].iter().any(|&l| now < fs.link_down[l])
            };
            if barred {
                let key = (!self.plan.tasks[t].is_comm(), t);
                self.st.faults.as_mut().expect("faults set").held.insert(key);
                return false;
            }
        }
        let blocked: Vec<usize> = self.streams_of[t]
            .iter()
            .copied()
            .filter(|&s| self.st.busy[s].is_some())
            .collect();
        if !blocked.is_empty() {
            let key = (!self.plan.tasks[t].is_comm(), t);
            for s in blocked {
                self.st.waiters[s].insert(key);
            }
            return false;
        }
        self.st.started[t] = true;
        self.st.start[t] = now;
        for &s in &self.streams_of[t] {
            self.st.busy[s] = Some(t);
        }
        let dur = self.plan.tasks[t].duration;
        self.st.version[t] += 1;
        if self.links_of[t].is_empty() {
            // Compute, or link-free local communication: fixed duration
            // (stretched on straggler devices via an [`Xfer`] so later
            // degradation changes can re-price mid-flight).
            let rate = self.degrade_rate(t);
            if rate < 1.0 {
                self.st.xfers[t] = Some(Xfer { remaining: dur, rate, last: now });
                self.push_finish(now + dur / rate, t);
            } else {
                self.push_finish(now + dur, t);
            }
        } else {
            for &l in &self.links_of[t] {
                self.st.link_active[l].insert(t);
            }
            let rate = self.rate_of(t);
            self.st.xfers[t] = Some(Xfer { remaining: dur, rate, last: now });
            self.push_finish(now + dur / rate, t);
            self.reprice_sharers(t, now);
        }
        true
    }

    fn finish_task(&mut self, t: TaskId, now: f64) {
        self.st.done[t] = true;
        self.st.completed += 1;
        self.st.events += 1;
        self.st.finish[t] = now;
        let is_comm = self.plan.tasks[t].is_comm();
        let elapsed = now - self.st.start[t];
        for i in 0..self.devices[t].len() {
            let d = self.devices[t][i];
            if is_comm && d == CPU_DEVICE {
                // The host has no serializing comm stream (per-GPU PCIe
                // lanes carry offload traffic in parallel), so charging it
                // per-transfer elapsed time would exceed wall-clock.
                continue;
            }
            let st = self.st.slot_stats[dev_slot(d)]
                .get_or_insert_with(|| DeviceStat { device: d, ..Default::default() });
            if is_comm {
                st.comm += elapsed;
            } else {
                st.compute += elapsed;
            }
        }
        for &s in &self.streams_of[t] {
            self.st.busy[s] = None;
        }
        if self.st.xfers[t].take().is_some() {
            for &l in &self.links_of[t] {
                self.st.link_active[l].remove(&t);
            }
            self.reprice_sharers(t, now);
        }
        // Successors whose last dependency just resolved, plus parked tasks
        // waiting on the streams this finish freed — dispatched in
        // (comm-first, id) order.
        let mut cands: BTreeSet<(bool, TaskId)> = BTreeSet::new();
        for i in 0..self.consumers[t].len() {
            let c = self.consumers[t][i];
            self.st.indeg[c] -= 1;
            if self.st.indeg[c] == 0 {
                cands.insert((!self.plan.tasks[c].is_comm(), c));
            }
        }
        for i in 0..self.streams_of[t].len() {
            let s = self.streams_of[t][i];
            cands.extend(std::mem::take(&mut self.st.waiters[s]));
        }
        for (_, c) in cands {
            if !self.st.done[c] && !self.st.started[c] {
                self.try_start(c, now);
            }
        }
    }

    /// Convert the drained engine state into a [`DesReport`] — the
    /// once-per-run reporting pass (memory timelines, bubble accounting).
    pub(crate) fn finalize(&self, g: &Graph, cluster: &Cluster) -> DesReport {
        let plan = self.plan;
        let n = plan.tasks.len();
        assert_eq!(
            self.st.completed, n,
            "DES deadlock — TaskGraph::prepare guarantees acyclicity"
        );
        let makespan = self.st.finish.iter().copied().fold(0.0, f64::max);
        let mut stats: HashMap<DeviceId, DeviceStat> =
            self.st.slot_stats.iter().flatten().cloned().map(|s| (s.device, s)).collect();

        // ---- time-resolved memory ----
        // Activations from the shared event stream, *plus* gradient-buffer
        // liveness: the DES baseline is the static bytes minus the gradient
        // share, and each gradient region is allocated when its backward
        // producer starts and freed when its last local toucher (optimizer /
        // sync collective) finishes. A plan therefore OOMs under the DES only
        // if gradient buffers are live *at the same time* as the activation
        // peak — the timeline admission the list scheduler's always-resident
        // watermark cannot express (dp replicas shift when gradients are live).
        let acts = activation_events(g, plan, &self.st.start, &self.st.finish);
        let grads = gradient_events(g, plan, &self.st.start, &self.st.finish);
        let mut devs: BTreeSet<DeviceId> = stats.keys().copied().collect();
        devs.extend(acts.keys().copied());
        devs.extend(grads.keys().copied());
        devs.extend(plan.static_mem.keys().copied());
        let mut mem: Vec<MemTimeline> = Vec::new();
        for d in devs {
            let static_total = plan.static_mem.get(&d).copied().unwrap_or(0);
            let grad_share = plan.static_grad_mem.get(&d).copied().unwrap_or(0);
            let base = static_total.saturating_sub(grad_share) as i64;
            let mut evs: Vec<(f64, i64)> = acts.get(&d).cloned().unwrap_or_default();
            if let Some(ge) = grads.get(&d) {
                evs.extend(ge.iter().copied());
                evs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            }
            let mut points: Vec<(f64, u64)> = vec![(0.0, base.max(0) as u64)];
            let mut cur = base;
            let mut peak = base;
            let mut i = 0;
            while i < evs.len() {
                let t0 = evs[i].0;
                while i < evs.len() && evs[i].0 == t0 {
                    cur += evs[i].1;
                    i += 1;
                }
                peak = peak.max(cur);
                points.push((t0, cur.max(0) as u64));
            }
            let peak = peak.max(0) as u64;
            match stats.entry(d) {
                std::collections::hash_map::Entry::Occupied(mut e) => e.get_mut().peak_mem = peak,
                std::collections::hash_map::Entry::Vacant(e) => {
                    // A device with memory traffic but no tasks still reports
                    // (mirrors the list scheduler's accounting).
                    if acts.contains_key(&d) || grads.contains_key(&d) {
                        e.insert(DeviceStat { device: d, peak_mem: peak, ..Default::default() });
                    }
                }
            }
            mem.push(MemTimeline { device: d, points, peak });
        }

        for (dev, st) in stats.iter_mut() {
            st.bubble = (makespan - st.compute - st.comm).max(0.0);
            if *dev != CPU_DEVICE {
                st.oom = st.peak_mem > cluster.mem_capacity(*dev);
            }
        }
        let total_flops = g.total_flops();
        let mut per_device: Vec<DeviceStat> = stats.into_values().collect();
        per_device.sort_by_key(|d| d.device);
        let ngpu = per_device.iter().filter(|d| d.device != CPU_DEVICE).count().max(1);
        let oom = per_device.iter().any(|d| d.oom);
        let spans = (0..n)
            .map(|t| TaskSpan { task: t, start: self.st.start[t], finish: self.st.finish[t] })
            .collect();
        DesReport {
            makespan,
            per_device,
            spans,
            mem,
            total_flops,
            aggregate_tflops: if makespan > 0.0 { total_flops / makespan / 1e12 } else { 0.0 },
            tflops_per_gpu: if makespan > 0.0 {
                total_flops / makespan / 1e12 / ngpu as f64
            } else {
                0.0
            },
            comm_bytes: plan.comm_bytes,
            oom,
            faults: None,
        }
    }
}

/// Execute `plan` against an already-prepared [`TaskGraph`]. Low-level
/// entry point shared by [`simulate`] and the synthetic-plan tests.
pub fn execute(g: &Graph, plan: &Plan, cluster: &Cluster, tg: &TaskGraph) -> DesReport {
    let mut eng = Engine::new(plan, cluster, tg);
    eng.seed();
    eng.run();
    eng.finalize(g, cluster)
}

/// [`execute`] under a resolved fault plan: crashes, outages, stragglers
/// and periodic checkpoints are interleaved with the plan's own events,
/// and the report carries the [`FaultOutcome`] accounting. With an empty
/// plan ([`crate::fault::FaultPlan::default`]) the timeline is bitwise
/// identical to [`execute`]'s.
pub fn execute_faulted(
    g: &Graph,
    plan: &Plan,
    cluster: &Cluster,
    tg: &TaskGraph,
    fp: &crate::fault::FaultPlan,
) -> DesReport {
    let mut eng = Engine::with_faults(plan, cluster, tg, fp);
    eng.seed();
    eng.run();
    let mut rep = eng.finalize(g, cluster);
    rep.faults = eng.st.faults.take().map(|f| f.outcome);
    rep
}

/// Discrete-event execution of one iteration of `plan`, sharing the list
/// scheduler's task-graph preparation (per-device serial hints included).
pub fn simulate(g: &Graph, vs: &ValidatedSchedule, plan: &Plan, cluster: &Cluster) -> DesReport {
    let tg = TaskGraph::prepare(vs, plan);
    execute(g, plan, cluster, &tg)
}

/// Convenience: validate + materialize + DES-simulate in one call (the
/// high-fidelity mirror of [`crate::sim::run`]).
pub fn run(
    g: &Graph,
    sched: &crate::schedule::Schedule,
    cluster: &Cluster,
    mode: crate::materialize::CommMode,
) -> Result<DesReport, crate::schedule::ScheduleError> {
    let vs = crate::schedule::validate(g, sched)?;
    let plan = crate::materialize::materialize(g, &vs, cluster, mode);
    Ok(simulate(g, &vs, &plan, cluster))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::materialize::{Task, TaskKind};

    /// A graph with `n` tensor-less identity ops, so synthetic compute
    /// tasks (whose `op` field indexes the graph) resolve during the
    /// memory-event pass.
    fn dummy_graph(n: usize) -> Graph {
        let mut g = Graph::new();
        for i in 0..n {
            g.add_op(&format!("op{i}"), OpKind::Identity, vec![], vec![], 0.0, None, true, 0);
        }
        g
    }

    fn p2p_task(id: TaskId, from: DeviceId, to: DeviceId, dur: f64, deps: Vec<TaskId>) -> Task {
        Task {
            id,
            kind: TaskKind::P2P { from, to, bytes: 1 << 20, ptensor: 0 },
            deps,
            duration: dur,
            label: format!("x{id}").into(),
        }
    }

    fn compute_task(id: TaskId, device: DeviceId, dur: f64, deps: Vec<TaskId>) -> Task {
        Task {
            id,
            kind: TaskKind::Compute { op: id, device },
            deps,
            duration: dur,
            label: format!("c{id}").into(),
        }
    }

    #[test]
    fn two_transfers_on_one_nic_fair_share() {
        let c = Cluster::v100(16);
        let d = c.p2p_time(0, 8, 1 << 20);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 8, d, vec![]));
        plan.tasks.push(p2p_task(1, 1, 9, d, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&Graph::new(), &plan, &c, &tg);
        // Both cross Nic(0)+Nic(1): each runs at half rate, both finish at 2d.
        assert!((r.makespan - 2.0 * d).abs() < 1e-12, "got {}, want {}", r.makespan, 2.0 * d);
        // Solo run takes exactly d.
        let mut solo = Plan::default();
        solo.tasks.push(p2p_task(0, 0, 8, d, vec![]));
        let tg = TaskGraph::of_plan(&solo);
        let r = execute(&Graph::new(), &solo, &c, &tg);
        assert_eq!(r.makespan.to_bits(), d.to_bits());
    }

    #[test]
    fn disjoint_nvlink_transfers_do_not_contend() {
        let c = Cluster::v100(8);
        let d = c.p2p_time(0, 1, 1 << 20);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 1, d, vec![]));
        plan.tasks.push(p2p_task(1, 2, 3, d, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&Graph::new(), &plan, &c, &tg);
        assert!((r.makespan - d).abs() < 1e-12, "disjoint pairs must run at full rate");
    }

    #[test]
    fn shared_nvlink_port_serializes_on_the_comm_stream() {
        // Two transfers out of device 0 share its NVLink port; the comm
        // stream enforces exclusivity, so they run back-to-back.
        let c = Cluster::v100(8);
        let d = c.p2p_time(0, 1, 1 << 20);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 1, d, vec![]));
        plan.tasks.push(p2p_task(1, 0, 2, d, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&Graph::new(), &plan, &c, &tg);
        assert!((r.makespan - 2.0 * d).abs() < 1e-12, "same-port transfers must serialize");
    }

    #[test]
    fn concurrent_host_offloads_use_independent_pcie_lanes() {
        // Offload traffic from different GPUs does not funnel through a
        // single host stream: each GPU's PCIe lane carries it in parallel.
        let c = Cluster::v100(8);
        let d = c.p2p_time(0, CPU_DEVICE, 1 << 20);
        let mut plan = Plan::default();
        for (i, gpu) in [0usize, 1, 2, 3].into_iter().enumerate() {
            plan.tasks.push(p2p_task(i, gpu, CPU_DEVICE, d, vec![]));
        }
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&Graph::new(), &plan, &c, &tg);
        assert!((r.makespan - d).abs() < 1e-12, "offloads must run in parallel: {}", r.makespan);
    }

    #[test]
    fn comm_overlaps_compute_on_separate_streams() {
        // Device 0: one compute task and one outgoing transfer, independent.
        // List semantics would serialize them (2 units); streams overlap (1).
        let c = Cluster::v100(8);
        let mut plan = Plan::default();
        plan.tasks.push(compute_task(0, 0, 1.0, vec![]));
        plan.tasks.push(p2p_task(1, 0, 1, 1.0, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&dummy_graph(1), &plan, &c, &tg);
        assert!((r.makespan - 1.0).abs() < 1e-12, "overlap not credited: {}", r.makespan);
        let d0 = r.per_device.iter().find(|s| s.device == 0).unwrap();
        assert!((d0.compute - 1.0).abs() < 1e-12 && (d0.comm - 1.0).abs() < 1e-12);
    }

    #[test]
    fn same_stream_tasks_serialize_in_comm_first_id_order() {
        // Two compute tasks on one device with no deps: they must serialize
        // on the compute stream, lower id first.
        let c = Cluster::v100(8);
        let mut plan = Plan::default();
        plan.tasks.push(compute_task(0, 0, 1.0, vec![]));
        plan.tasks.push(compute_task(1, 0, 2.0, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&dummy_graph(2), &plan, &c, &tg);
        assert!((r.makespan - 3.0).abs() < 1e-12);
        assert!(r.spans[0].start < r.spans[1].start);
    }

    #[test]
    fn staggered_contention_stretches_only_the_shared_window() {
        // t0 starts at 0 (solo, duration 2s). t1 (duration 2s) is released
        // at t=1 by an upstream compute on another server. They share the
        // NICs from t=1: both halve. t0: 1s done + 1s left at 1/2 = done at
        // 3; t1 then runs solo its remaining 1s => finish 4.
        let c = Cluster::v100(16);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 8, 2.0, vec![]));
        plan.tasks.push(compute_task(1, 2, 1.0, vec![]));
        plan.tasks.push(p2p_task(2, 1, 9, 2.0, vec![1]));
        let tg = TaskGraph::of_plan(&plan);
        let r = execute(&dummy_graph(2), &plan, &c, &tg);
        assert!((r.spans[0].finish - 3.0).abs() < 1e-9, "t0 finish {}", r.spans[0].finish);
        assert!((r.spans[2].finish - 4.0).abs() < 1e-9, "t2 finish {}", r.spans[2].finish);
    }

    // ---- fault injection ----

    use crate::fault::{FaultPlan, KillEvent, OutageEvent, SlowEvent};

    #[test]
    fn crash_restarts_the_task_after_repair_and_replay() {
        // Compute of 1s on device 0; crash at 0.5 with 0.1s repair, no
        // checkpoints. Replay = time since t=0 (the implicit last
        // checkpoint) = 0.5, so the device is back at 0.5+0.1+0.5 = 1.1
        // and the task restarts from scratch: makespan 2.1.
        let c = Cluster::v100(8);
        let mut plan = Plan::default();
        plan.tasks.push(compute_task(0, 0, 1.0, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let fp = FaultPlan {
            kills: vec![KillEvent { at: 0.5, devices: vec![0], repair: 0.1 }],
            ..Default::default()
        };
        let r = execute_faulted(&dummy_graph(1), &plan, &c, &tg, &fp);
        assert!((r.makespan - 2.1).abs() < 1e-9, "makespan {}", r.makespan);
        let f = r.faults.expect("faulted run reports an outcome");
        assert_eq!(f.n_kills, 1);
        assert!((f.lost_work - 0.5).abs() < 1e-9, "lost_work {}", f.lost_work);
        assert!((f.recovery_time - 0.6).abs() < 1e-9, "recovery {}", f.recovery_time);
        assert_eq!(f.events.len(), 1);
        assert_eq!(f.events[0].kind, FaultTraceKind::Crash);
    }

    #[test]
    fn straggler_stretches_compute_by_the_slow_factor() {
        // 1s compute; device 0 runs at 0.5x from t=0.2. 0.2s done at full
        // speed, the remaining 0.8 at half rate: finish at 0.2+1.6 = 1.8.
        let c = Cluster::v100(8);
        let mut plan = Plan::default();
        plan.tasks.push(compute_task(0, 0, 1.0, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let fp = FaultPlan {
            slowdowns: vec![SlowEvent { at: 0.2, device: 0, factor: 0.5, duration: 2.0 }],
            ..Default::default()
        };
        let r = execute_faulted(&dummy_graph(1), &plan, &c, &tg, &fp);
        assert!((r.makespan - 1.8).abs() < 1e-9, "makespan {}", r.makespan);
        let f = r.faults.expect("outcome");
        assert_eq!((f.n_kills, f.n_faults), (0, 1));
    }

    #[test]
    fn link_outage_stalls_the_transfer_for_its_duration() {
        // Cross-server transfer of duration d; its source NIC goes dark
        // over [0.25d, 0.75d]. Progress freezes for 0.5d: finish at 1.5d.
        let c = Cluster::v100(16);
        let d = c.p2p_time(0, 8, 1 << 20);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 8, d, vec![]));
        let tg = TaskGraph::of_plan(&plan);
        let fp = FaultPlan {
            outages: vec![OutageEvent { at: 0.25 * d, link: LinkId::Nic(0), duration: 0.5 * d }],
            ..Default::default()
        };
        let r = execute_faulted(&Graph::new(), &plan, &c, &tg, &fp);
        assert!((r.makespan - 1.5 * d).abs() < 1e-9 * d, "makespan {} want {}", r.makespan, 1.5 * d);
    }

    #[test]
    fn periodic_checkpoint_freezes_and_charges_the_stall() {
        // 1s compute on device 0 holding 1 MiB of static state; one
        // checkpoint fires at 0.6 and stalls everything for the host
        // writeback time s: makespan 1.0 + s, ckpt_time == s.
        let c = Cluster::v100(8);
        let mut plan = Plan::default();
        plan.tasks.push(compute_task(0, 0, 1.0, vec![]));
        plan.static_mem.insert(0, 1 << 20);
        let s = c.checkpoint_time(0, 1 << 20);
        assert!(s > 0.0);
        let tg = TaskGraph::of_plan(&plan);
        let fp = FaultPlan { ckpt_interval: 0.6, ..Default::default() };
        let r = execute_faulted(&dummy_graph(1), &plan, &c, &tg, &fp);
        assert!((r.makespan - (1.0 + s)).abs() < 1e-9, "makespan {} want {}", r.makespan, 1.0 + s);
        let f = r.faults.expect("outcome");
        assert!((f.ckpt_time - s).abs() < 1e-12, "ckpt_time {} want {}", f.ckpt_time, s);
        assert_eq!(f.events.len(), 1);
        assert_eq!(f.events[0].kind, FaultTraceKind::Ckpt);
    }

    #[test]
    fn empty_fault_plan_is_bitwise_identical_to_the_plain_engine() {
        // The staggered-contention plan exercises fair-share repricing;
        // an empty fault plan must reproduce its timeline bit for bit.
        let c = Cluster::v100(16);
        let mut plan = Plan::default();
        plan.tasks.push(p2p_task(0, 0, 8, 2.0, vec![]));
        plan.tasks.push(compute_task(1, 2, 1.0, vec![]));
        plan.tasks.push(p2p_task(2, 1, 9, 2.0, vec![1]));
        let tg = TaskGraph::of_plan(&plan);
        let base = execute(&dummy_graph(2), &plan, &c, &tg);
        let faulted = execute_faulted(&dummy_graph(2), &plan, &c, &tg, &FaultPlan::default());
        assert_eq!(base.makespan.to_bits(), faulted.makespan.to_bits());
        for (a, b) in base.spans.iter().zip(faulted.spans.iter()) {
            assert_eq!(a.start.to_bits(), b.start.to_bits());
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
        }
        let f = faulted.faults.expect("outcome present even when empty");
        assert_eq!((f.n_kills, f.n_faults, f.events.len()), (0, 0, 0));
    }
}
