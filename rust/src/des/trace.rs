//! Chrome-trace (catapult / Perfetto) export of a DES timeline.
//!
//! `superscaler simulate --fidelity des --trace out.json` (and the CI
//! search-smoke job) write this format; load it at `chrome://tracing` or
//! <https://ui.perfetto.dev> to inspect a plan visually: one process per
//! device, thread 0 = compute stream, thread 1 = communication stream,
//! complete (`ph: "X"`) events per task span, and a `resident bytes`
//! counter track per device carrying the time-resolved memory profile.
//!
//! Faulted runs ([`crate::des::execute_faulted`]) add a synthetic
//! `faults` process (pid [`FAULT_PID`]) with one span per injected
//! event — crash/repair windows, link outages, straggler windows,
//! checkpoint stalls — plus an instant marker at each recovery point.
//! Fault-free reports emit byte-identical traces to the pre-fault format.

use super::{DesReport, FaultTraceKind};
use crate::materialize::Plan;
use crate::schedule::{DeviceId, CPU_DEVICE};
use crate::util::json::{self, Value};

/// Trace pid for a device: the host gets pid 0, GPU `d` gets `d + 1`
/// (`usize::MAX` does not survive the JSON number round-trip).
fn pid_of(d: DeviceId) -> usize {
    if d == CPU_DEVICE {
        0
    } else {
        d + 1
    }
}

/// Trace pid of the synthetic fault lane — far above any device pid.
pub const FAULT_PID: usize = 9999;

fn device_name(d: DeviceId) -> String {
    if d == CPU_DEVICE {
        "host".to_string()
    } else {
        format!("GPU {d}")
    }
}

/// Serialize `report`'s timeline as a Chrome trace JSON document.
/// Timestamps are microseconds, matching the viewer's native unit.
pub fn chrome_trace(report: &DesReport, plan: &Plan) -> String {
    let us = 1e6;
    let mut events: Vec<Value> = Vec::new();
    // Process/thread naming metadata, one process per device.
    for st in &report.per_device {
        let pid = pid_of(st.device);
        events.push(Value::obj([
            ("name", "process_name".into()),
            ("ph", "M".into()),
            ("pid", pid.into()),
            ("args", Value::obj([("name", device_name(st.device).into())])),
        ]));
        for (tid, name) in [(0usize, "compute"), (1, "comm")] {
            events.push(Value::obj([
                ("name", "thread_name".into()),
                ("ph", "M".into()),
                ("pid", pid.into()),
                ("tid", tid.into()),
                ("args", Value::obj([("name", name.into())])),
            ]));
        }
    }
    // One complete event per task per occupied device.
    for span in &report.spans {
        let task = &plan.tasks[span.task];
        let (cat, tid) = if task.is_comm() { ("comm", 1usize) } else { ("compute", 0) };
        for d in task.devices() {
            events.push(Value::obj([
                ("name", Value::Str(task.label.to_string())),
                ("cat", cat.into()),
                ("ph", "X".into()),
                ("ts", (span.start * us).into()),
                ("dur", ((span.finish - span.start) * us).into()),
                ("pid", pid_of(d).into()),
                ("tid", tid.into()),
            ]));
        }
    }
    // Fault lane: one span per injected event, an instant at each
    // recovery point. Absent entirely on fault-free reports, keeping
    // their traces byte-identical to the pre-fault format.
    if let Some(f) = &report.faults {
        if !f.events.is_empty() {
            events.push(Value::obj([
                ("name", "process_name".into()),
                ("ph", "M".into()),
                ("pid", FAULT_PID.into()),
                ("args", Value::obj([("name", "faults".into())])),
            ]));
            for ev in &f.events {
                let (name, cat) = match ev.kind {
                    FaultTraceKind::Crash => ("crash", "fault"),
                    FaultTraceKind::LinkDown => ("link down", "fault"),
                    FaultTraceKind::SlowStart => ("straggler", "fault"),
                    FaultTraceKind::Ckpt => ("checkpoint", "ckpt"),
                };
                let label = match ev.device {
                    Some(d) => format!("{name}: {}", device_name(d)),
                    None => name.to_string(),
                };
                events.push(Value::obj([
                    ("name", Value::Str(label)),
                    ("cat", cat.into()),
                    ("ph", "X".into()),
                    ("ts", (ev.at * us).into()),
                    ("dur", ((ev.until - ev.at) * us).into()),
                    ("pid", FAULT_PID.into()),
                    ("tid", 0usize.into()),
                ]));
                events.push(Value::obj([
                    ("name", "recovered".into()),
                    ("cat", cat.into()),
                    ("ph", "i".into()),
                    ("ts", (ev.until * us).into()),
                    ("pid", FAULT_PID.into()),
                    ("tid", 0usize.into()),
                    ("s", "p".into()),
                ]));
            }
        }
    }
    // Per-device resident-memory counter track.
    for tl in &report.mem {
        for &(t, bytes) in &tl.points {
            events.push(Value::obj([
                ("name", "resident bytes".into()),
                ("ph", "C".into()),
                ("ts", (t * us).into()),
                ("pid", pid_of(tl.device).into()),
                ("args", Value::obj([("bytes", bytes.into())])),
            ]));
        }
    }
    json::to_string(&Value::obj([
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", "ms".into()),
    ]))
}

/// [`chrome_trace`] written to `path` (parent directories created).
pub fn write_chrome_trace(path: &str, report: &DesReport, plan: &Plan) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, chrome_trace(report, plan) + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Cluster;
    use crate::materialize::CommMode;
    use crate::models::gpt3;
    use crate::plans::{megatron, PipeOrder};

    #[test]
    fn trace_is_valid_json_with_one_span_per_task_device() {
        let out = megatron(&gpt3(0, 4, 256), 1, 2, 1, 2, PipeOrder::OneFOneB).unwrap();
        let c = Cluster::v100(2);
        let vs = crate::schedule::validate(&out.graph, &out.schedule).unwrap();
        let plan = crate::materialize::materialize(&out.graph, &vs, &c, CommMode::InterRvd);
        let r = crate::des::simulate(&out.graph, &vs, &plan, &c);
        let doc = json::parse(&chrome_trace(&r, &plan)).expect("trace parses");
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
        let spans = evs
            .iter()
            .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .count();
        let want: usize = plan.tasks.iter().map(|t| t.devices().len()).sum();
        assert_eq!(spans, want, "one X event per task per device");
        assert!(
            evs.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("C")),
            "memory counter events present"
        );
        // Spans stay within the makespan.
        for e in evs {
            if e.get("ph").and_then(|p| p.as_str()) == Some("X") {
                let ts = e.get("ts").and_then(|v| v.as_f64()).unwrap();
                let dur = e.get("dur").and_then(|v| v.as_f64()).unwrap();
                assert!(ts >= 0.0 && ts + dur <= r.makespan * 1e6 + 1e-6);
            }
        }
    }
}
