//! SuperScaler CLI — the leader entrypoint.
//!
//! ```text
//! superscaler simulate --model gpt3 --plan coshard --gpus 16 [--scale 2 ...]
//!                      [--fidelity list|des] [--trace out.json]
//! superscaler search   --model gpt3 --gpus 8 [--top 10] [--workers N]
//!                      [--fidelity list|des] [--trace out.json]
//! superscaler rvd --from "R(1)V(2)D(1,2)" --to "R(2)V(1)D(2,1)" --gpus 4
//! superscaler train --devices 4 --steps 100 [--artifacts artifacts]
//! superscaler verify-exec [--devices 2,4,8] [--families dp,tp,...] [--json FILE]
//! superscaler plans                      # list registered sPrograms
//! ```
//!
//! Plan names resolve through `plans::registry`; `simulate` builds exactly
//! one spec, `search` enumerates and ranks the whole feasible spec grid.
//! `--fidelity des` scores with the discrete-event engine (comm/compute
//! overlap + link contention) on top of the list simulation; `--trace`
//! writes the DES timeline as a Chrome trace for `chrome://tracing`.

use superscaler::materialize::CommMode;
use superscaler::models;
use superscaler::plans::{self, PlanKind, PlanSpec, Planner, StageSpec};
use superscaler::rvd::Rvd;
use superscaler::search;
use superscaler::util::cli::Args;
use superscaler::util::{fmt_bytes, fmt_secs};
use superscaler::{cost::Cluster, sim};

fn main() {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "simulate" => simulate(&args),
        "search" => search_cmd(&args),
        "rvd" => rvd_query(&args),
        "train" => train(&args),
        "verify-exec" => verify_exec(&args),
        "plans" => list_plans(),
        _ => usage(),
    }
}

fn usage() {
    println!(
        "superscaler — flexible DNN parallelization via a unified abstraction\n\
         \n\
         USAGE:\n\
           superscaler simulate --model <gpt3|swin|mbart|alphafold2> --plan <name>\n\
                                [--gpus N] [--scale 0..3] [--batch B] [--seq S]\n\
                                [--tp T] [--pp P] [--dp D] [--micro K] [--shards C]\n\
                                [--comm p2p|intra|inter] [--fidelity list|des]\n\
                                [--trace FILE] [--servers N]\n\
                                [--topology flat|fat-tree:K|rail:R]\n\
                                [--device-mix kind:count,...]\n\
                                  --fidelity des additionally executes the plan\n\
                                  on the discrete-event engine (per-device\n\
                                  compute+comm streams, fair-shared link\n\
                                  contention) and reports the overlap headroom\n\
                                  the list simulation cannot credit; --trace\n\
                                  writes the DES timeline as Chrome-trace JSON\n\
           superscaler search   --model <gpt3|swin|mbart|alphafold2> [--gpus N]\n\
                                [--scale 0..3] [--batch B] [--seq S] [--top N]\n\
                                [--workers N] [--max-candidates N]\n\
                                [--comm p2p|intra|inter] [--hetero] [--no-prune]\n\
                                [--dp-min D]\n\
                                [--fidelity list|des] [--des-top K] [--trace FILE]\n\
                                [--baseline FILE] [--write-baseline] [--tol F]\n\
                                [--bench-json FILE] [--schedule NAME|sched{{...}}]\n\
                                [--servers N] [--topology flat|fat-tree:K|rail:R]\n\
                                [--device-mix kind:count,...]\n\
                                [--faults TRACE] [--mtbf SECS] [--fault-seed S]\n\
                                [--ckpt-interval off|auto|SECS] [--no-rack-spread]\n\
                                [--fault-baseline FILE]\n\
                                [refine flags — see REFINE below]\n\
                                  --topology models the cluster fabric: flat\n\
                                  (one NIC/server, legacy), fat-tree:K (K\n\
                                  servers per rack, cross-rack traffic shares\n\
                                  per-rack spine uplinks) or rail:R (R rail\n\
                                  switches, per-GPU NICs). --servers overrides\n\
                                  the 8-GPU server shape; --device-mix (e.g.\n\
                                  a100:8,h100:8) assigns device kinds to\n\
                                  server rows for heterogeneous fleets. All\n\
                                  shape combinations are validated up front\n\
                                  (typed error + exit 2 when they don't\n\
                                  divide evenly).\n\
                                  enumerate the feasible PlanSpec grid (--hetero\n\
                                  adds heterogeneous per-stage pipelines),\n\
                                  dominance-prune against the analytic cost\n\
                                  lower bound (--no-prune simulates everything),\n\
                                  --dp-min restricts the grid to specs with at\n\
                                  least that data-parallel degree (replicated\n\
                                  pipelines only),\n\
                                  evaluate survivors in parallel (transform ->\n\
                                  validate -> materialize -> simulate), print the\n\
                                  ranking (best iteration time first).\n\
                                  --fidelity des re-scores the top K (--des-top,\n\
                                  default 8) candidates with the discrete-event\n\
                                  engine and re-ranks them by it; the report\n\
                                  carries both scores. --trace writes the\n\
                                  winning plan's DES Chrome trace.\n\
                                  --baseline gates the best list-simulated time\n\
                                  against a committed JSON (exit 3 on regression\n\
                                  > --tol, default 0.001) AND the search's own\n\
                                  wall-clock against the baseline's\n\
                                  max_wall_secs ceiling (exit 3 when the search\n\
                                  itself gets slower); --write-baseline\n\
                                  refreshes both.\n\
                                  --bench-json writes the search-throughput\n\
                                  trajectory artifact (wall_secs, evaluated,\n\
                                  pruned counts, des_rescored, best list and\n\
                                  DES makespans, refine_iters, refine_accepted,\n\
                                  delta_replay_frac, best_gap) — CI uploads it\n\
                                  as BENCH_search.json.\n\
                                  --schedule pins every candidate to one\n\
                                  pipeline schedule — the fourth search axis:\n\
                                  a name (sync|1f1b|interlaced|zb|vshape) or\n\
                                  an explicit sched{{...}} row token. Without\n\
                                  it planners contribute their own schedule\n\
                                  points (megatron emits each pipelined grid\n\
                                  under 1F1B and zero-bubble).\n\
                                  --faults injects a deterministic seeded fault\n\
                                  trace (comma tokens kind:target@time[+dur]:\n\
                                  crash:dN, server:N, rack:N, uplink:N,\n\
                                  slow:dNxF) into a DES re-run of the top\n\
                                  candidates; --mtbf samples a trace instead\n\
                                  (exponential per device, --fault-seed).\n\
                                  Checkpoint/restart is modeled over the host\n\
                                  links: --ckpt-interval auto picks Young's\n\
                                  interval from the stall and MTBF. The head\n\
                                  re-ranks by goodput-adjusted time and the\n\
                                  table gains goodput/recover columns. Racks\n\
                                  are failure domains: dp replicas are spread\n\
                                  rack-by-rack first (--no-rack-spread keeps\n\
                                  the contiguous placement). --fault-baseline\n\
                                  gates the winner's goodput against a\n\
                                  committed floor (exit 3 on breach,\n\
                                  bootstrap/refresh like --baseline).\n\
           REFINE (superscaler search flag group):\n\
             --refine            run the seeded MCMC/hill-climbing tier over\n\
                                 the top grid candidates (stage-boundary\n\
                                 moves, recompute/offload toggles,\n\
                                 widen/narrow, micro resize, schedule-row\n\
                                 permutations, op swaps), re-scoring each\n\
                                 mutation by incremental DES delta replay\n\
             --refine-iters N    mutation budget per chain (implies --refine)\n\
             --refine-seed S     fix the chains' RNG seed\n\
             --refine-top K      how many top candidates seed chains\n\
             --gap-target F      stop a chain once its optimality-gap\n\
                                 certificate (vs the analytic lower bound) is\n\
                                 at or under F\n\
             --gap-ceiling F     exit 3 when the refined winner's gap exceeds\n\
                                 F (the CI gate)\n\
           superscaler rvd      --from 'R(r)V(v)D(k1,k2)' --to '...' [--gpus N]\n\
                                [--src-gpus N] [--dst-gpus N] [--mb MB]\n\
           superscaler train    [--devices N] [--steps N] [--lr F] [--artifacts DIR]\n\
           superscaler verify-exec [--devices 2,4,8] [--families dp,tp,...]\n\
                                [--json FILE]\n\
                                  differential execution harness: run every\n\
                                  planner family's plan on the CPU reference\n\
                                  executor (one thread per simulated device,\n\
                                  real f32 tensors) and assert elementwise\n\
                                  equivalence against a single-device serial\n\
                                  oracle; prints the pass matrix plus the\n\
                                  measured-vs-analytic cost calibration\n\
                                  table; --json writes BENCH_exec.json;\n\
                                  exit 1 when any cell fails\n\
           superscaler plans"
    );
}

fn list_plans() {
    println!("registered sPrograms (plans::registry):");
    for p in plans::registry::all() {
        println!("  {:<15} {}", p.name(), p.description());
    }
}

fn build_model(args: &Args) -> models::Model {
    let name = args.str("model", "gpt3");
    let scale = args.usize("scale", 0);
    let batch = args.usize("batch", 8);
    match name {
        "gpt3" => models::gpt3(scale, batch, args.usize("seq", 2048)),
        "swin" => models::swin_transformer(scale, batch, args.usize("resolution", 1536)),
        "mbart" => models::mbart(scale, batch, args.usize("seq", 1024)),
        "alphafold2" => models::alphafold2(scale, batch),
        other => {
            eprintln!("unknown model '{other}'");
            std::process::exit(2);
        }
    }
}

fn comm_mode(args: &Args) -> CommMode {
    match args.str("comm", "inter") {
        "p2p" => CommMode::P2POnly,
        "intra" => CommMode::IntraRvd,
        _ => CommMode::InterRvd,
    }
}

fn fidelity(args: &Args) -> search::Fidelity {
    let s = args.str("fidelity", "list");
    search::Fidelity::parse(s).unwrap_or_else(|| {
        eprintln!("--fidelity expects 'list' or 'des', got '{s}'");
        std::process::exit(2);
    })
}

/// `--schedule`: a named pipeline schedule (`sync`, `1f1b`, `interlaced`,
/// `zb`, `vshape` or an alias) or a full `sched{...}` row token — pins the
/// search's fourth axis. `None` when the flag is absent.
fn schedule(args: &Args) -> Option<plans::SchedSpec> {
    let s = args.get("schedule")?;
    let parsed = plans::SchedSpec::parse_token(&format!("sched{{{s}}}"))
        .or_else(|| plans::SchedSpec::parse_token(s));
    match parsed {
        Some(sp) => Some(sp),
        None => {
            eprintln!(
                "--schedule expects a name (sync|1f1b|interlaced|zb|vshape) or a \
                 sched{{...}} token, got '{s}'"
            );
            std::process::exit(2);
        }
    }
}

/// The refine CLI flag group (`--refine`, `--refine-iters`,
/// `--refine-seed`, `--refine-top`, `--gap-target`, `--gap-ceiling` —
/// documented under REFINE in the usage text), parsed once and routed as
/// one value instead of six ad-hoc lookups spread over `search_cmd`.
struct RefineOpts {
    /// `--refine` (or any budget flag that implies it).
    enabled: bool,
    iters: usize,
    seed: u64,
    top: usize,
    gap_target: f64,
    /// `--gap-ceiling`: the CI gate on the refined winner's certificate —
    /// checked by `search_cmd` after the run, not part of [`RefineConfig`].
    gap_ceiling: Option<f64>,
}

impl RefineOpts {
    fn from_args(args: &Args) -> RefineOpts {
        let d = search::RefineConfig::default();
        RefineOpts {
            enabled: args.has("refine") || args.has("refine-iters"),
            iters: args.usize("refine-iters", d.iters),
            seed: args.usize("refine-seed", d.seed as usize) as u64,
            top: args.usize("refine-top", d.top),
            gap_target: args.f64("gap-target", d.gap_target),
            gap_ceiling: args.get("gap-ceiling").map(|s| {
                s.parse::<f64>().unwrap_or_else(|_| {
                    eprintln!("--gap-ceiling expects a number, got '{s}'");
                    std::process::exit(2);
                })
            }),
        }
    }

    /// The engine-facing tier config; `None` when the tier is off.
    fn config(&self) -> Option<search::RefineConfig> {
        self.enabled.then(|| search::RefineConfig {
            iters: self.iters,
            seed: self.seed,
            top: self.top,
            gap_target: self.gap_target,
        })
    }
}

/// The resilience CLI flag group (`--faults`, `--mtbf`, `--fault-seed`,
/// `--ckpt-interval`, `--no-rack-spread`): `None` unless a fault source
/// (explicit trace or MTBF) was given, so fault-free searches stay
/// byte-identical to earlier releases. An explicit trace is validated
/// against the cluster up front — a rack fault on a flat topology (or an
/// out-of-range device) exits 2 with the typed error instead of failing
/// silently per candidate.
fn resilience_opts(args: &Args, cluster: &Cluster) -> Option<superscaler::fault::ResilienceConfig> {
    use superscaler::fault::{CkptPolicy, FaultSpec, ResilienceConfig};
    let trace = args.get("faults").map(|s| {
        let spec = FaultSpec::parse(s).unwrap_or_else(|e| {
            eprintln!("invalid --faults trace: {e}");
            std::process::exit(2);
        });
        if let Err(e) = spec.resolve(cluster) {
            eprintln!("--faults trace does not fit this cluster: {e}");
            std::process::exit(2);
        }
        spec
    });
    let mtbf = args.get("mtbf").map(|s| {
        let v = s.parse::<f64>().ok().filter(|&v| v.is_finite() && v > 0.0).unwrap_or_else(|| {
            eprintln!("--mtbf expects positive seconds, got '{s}'");
            std::process::exit(2);
        });
        v
    });
    if trace.is_none() && mtbf.is_none() {
        return None;
    }
    let ckpt = match args.get("ckpt-interval") {
        None => CkptPolicy::Auto,
        Some(s) => CkptPolicy::parse(s).unwrap_or_else(|| {
            eprintln!("--ckpt-interval expects off, auto or positive seconds, got '{s}'");
            std::process::exit(2);
        }),
    };
    Some(ResilienceConfig {
        trace,
        mtbf,
        seed: args.usize("fault-seed", 1) as u64,
        ckpt,
        spread: !args.has("no-rack-spread"),
    })
}

/// The planner's canonical spec for this GPU count, overridden by whatever
/// degree flags the user passed.
fn spec_from_args(planner: &dyn Planner, args: &Args, gpus: usize) -> PlanSpec {
    let mut spec = planner.default_spec(gpus, args.usize("micro", 4));
    spec.dp = args.usize("dp", spec.dp);
    spec.pp = args.usize("pp", spec.pp);
    spec.tp = args.usize("tp", spec.tp);
    spec.micro = args.usize("micro", spec.micro);
    spec.shards = args.usize("shards", spec.shards);
    if args.has("offload") {
        spec.offload = args.bool("offload", spec.offload);
    }
    // DAP's axial width fills whatever the DP degree leaves — unless the
    // user pinned it explicitly with --tp.
    if spec.kind == PlanKind::Dap && !args.has("tp") {
        spec.tp = (gpus / spec.dp.max(1)).max(1);
    }
    // Hetero builds from its stage list, so degree flags rebuild it as a
    // uniform pipeline (--pp stages of --tp width, default gpus/pp) instead
    // of silently drifting from the stages the planner chose.
    if spec.kind == PlanKind::Hetero {
        if args.has("pp") || args.has("tp") {
            let pp = spec.pp.max(1);
            let width =
                if args.has("tp") { spec.tp.max(1) } else { (gpus / spec.dp.max(1) / pp).max(1) };
            spec.stages = Some(vec![StageSpec::tp(width); pp]);
        }
        if let Some(stages) = &spec.stages {
            spec.pp = stages.len();
            spec.tp = 1;
        }
    }
    spec
}

/// Build the modeled cluster from the CLI shape flags (`--gpus`,
/// `--servers`, `--topology`, `--device-mix`). Every divisibility
/// constraint is validated up front; a combination that doesn't divide
/// evenly exits 2 with the typed [`superscaler::topo::ClusterShapeError`]
/// instead of panicking or silently truncating the fleet.
fn cluster_from_args(args: &Args, gpus: usize) -> Cluster {
    let servers = args.get("servers").map(|s| {
        s.parse::<usize>().unwrap_or_else(|_| {
            eprintln!("--servers expects an integer, got '{s}'");
            std::process::exit(2);
        })
    });
    let topology = args.str("topology", "flat");
    match superscaler::topo::build_cluster(gpus, servers, topology, args.get("device-mix")) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("invalid cluster shape: {e}");
            std::process::exit(2);
        }
    }
}

fn simulate(args: &Args) {
    let gpus = args.usize("gpus", 4);
    let model = build_model(args);
    let plan_name = args.str("plan", "dp");
    let Some(planner) = plans::registry::find(plan_name) else {
        eprintln!("unknown plan '{plan_name}' (see `superscaler plans`)");
        std::process::exit(2);
    };
    let cluster = cluster_from_args(args, gpus);
    let spec = spec_from_args(planner, args, gpus);
    let out = planner.build(&model, &spec).unwrap_or_else(|e| {
        eprintln!("plan construction failed: {e}");
        std::process::exit(1);
    });
    let vs = match superscaler::schedule::validate(&out.graph, &out.schedule) {
        Ok(vs) => vs,
        Err(e) => {
            eprintln!("schedule invalid: {e}");
            std::process::exit(1);
        }
    };
    let plan = superscaler::materialize::materialize(&out.graph, &vs, &cluster, comm_mode(args));
    let tg = sim::TaskGraph::prepare(&vs, &plan);
    let r = sim::simulate_prepared(&out.graph, &tg, &plan, &cluster);
    let (comp, comm, bub) = r.breakdown();
    println!("plan       {}", out.name);
    println!("iteration  {}", fmt_secs(r.makespan));
    println!("aggregate  {:.1} TFLOPS ({:.1}/GPU)", r.aggregate_tflops, r.tflops_per_gpu);
    println!(
        "breakdown  compute {} | comm {} | bubble {}",
        fmt_secs(comp),
        fmt_secs(comm),
        fmt_secs(bub)
    );
    println!("comm       {}", fmt_bytes(r.comm_bytes));
    let oom = if r.oom { "  ** OOM **" } else { "" };
    println!("peak mem   {}{}", fmt_bytes(r.max_peak_mem()), oom);
    // The high-fidelity tier: overlap + contention replay, and the trace.
    if fidelity(args) == search::Fidelity::Des || args.has("trace") {
        let d = superscaler::des::execute(&out.graph, &plan, &cluster, &tg);
        let headroom = (r.makespan - d.makespan) / r.makespan.max(1e-12);
        println!(
            "DES        {} ({:+.1}% vs list — comm/compute overlap credited)",
            fmt_secs(d.makespan),
            -100.0 * headroom
        );
        let oom = if d.oom { "  ** OOM **" } else { "" };
        println!("DES peak   {}{}", fmt_bytes(d.max_peak_mem()), oom);
        if let Some(path) = args.get("trace") {
            match superscaler::des::trace::write_chrome_trace(path, &d, &plan) {
                Ok(()) => println!("trace      wrote {path} (open in chrome://tracing)"),
                Err(e) => {
                    eprintln!("cannot write trace {path}: {e}");
                    std::process::exit(2);
                }
            }
        }
    }
}

fn search_cmd(args: &Args) {
    let gpus = args.usize("gpus", 8);
    let top = args.usize("top", 10);
    let cluster = cluster_from_args(args, gpus);
    let refine_opts = RefineOpts::from_args(args);
    let cfg = search::SearchConfig::builder()
        .workers(args.usize("workers", 0))
        .comm(comm_mode(args))
        .max_candidates(args.usize("max-candidates", 256))
        .hetero(args.has("hetero"))
        .dp_min(args.usize("dp-min", 1))
        .prune(!args.has("no-prune"))
        .fidelity(fidelity(args))
        .des_top(args.usize("des-top", 8))
        .refine(refine_opts.config())
        .schedule(schedule(args))
        .resilience(resilience_opts(args, &cluster))
        .build();
    // One model build per search run: the engine borrows it for every
    // candidate evaluation, the DES re-rank and the winner's trace replay.
    let model = build_model(args);
    let report = search::search(&model, &cluster, &cfg);
    let t = report.to_table(top);
    t.print();
    t.write_csv("bench_results/search.csv").ok();
    if let Some(path) = args.get("bench-json") {
        write_bench_json(path, &report);
    }
    if let Some(rs) = &report.refine {
        println!(
            "refine: {} chains, {} mutations ({} accepted), delta replay {}, best gap {}",
            rs.chains,
            rs.iters,
            rs.accepted,
            rs.delta_replay_frac()
                .map(|f| format!("{:.1}%", 100.0 * f))
                .unwrap_or_else(|| "-".to_string()),
            rs.best_gap.map(|g| format!("{:.2}%", 100.0 * g)).unwrap_or_else(|| "-".to_string()),
        );
        // The refinement invariant: every chain's best starts at its seed
        // score, so the refined winner can never be worse than the grid
        // winner it started from. A violation is an engine bug, not a
        // perf regression — fail loudly (same exit-3 convention as the
        // perf gates).
        if let (Some(start), Some(best)) = (rs.start_best, rs.best) {
            if best > start * (1.0 + 1e-9) {
                eprintln!(
                    "REFINE GATE FAILED: refined best {} worse than grid-search best {}",
                    fmt_secs(best),
                    fmt_secs(start)
                );
                std::process::exit(3);
            }
        }
        // --gap-ceiling: CI asserts the refined winner's optimality-gap
        // certificate stays under a conservative ceiling.
        if let Some(ceil) = refine_opts.gap_ceiling {
            match rs.best_gap {
                Some(g) if g <= ceil => {
                    println!("gap gate ok: {:.2}% <= ceiling {:.2}%", 100.0 * g, 100.0 * ceil)
                }
                Some(g) => {
                    eprintln!(
                        "GAP GATE FAILED: best gap {:.2}% exceeds ceiling {:.2}%",
                        100.0 * g,
                        100.0 * ceil
                    );
                    std::process::exit(3);
                }
                None => {
                    eprintln!("GAP GATE FAILED: refinement produced no gap certificate");
                    std::process::exit(3);
                }
            }
        }
    }
    match report.best() {
        Some(best) => {
            let m = best.metrics().expect("best candidate has metrics");
            match m.des_makespan {
                Some(d) => println!(
                    "best: {} — {} / iteration (DES; list {}), {:.1} TFLOPS, peak mem {}{}",
                    best.plan_name,
                    fmt_secs(d),
                    fmt_secs(m.makespan),
                    m.aggregate_tflops,
                    fmt_bytes(m.peak_mem),
                    if m.des_oom { "  ** DES-OOM **" } else { "" }
                ),
                None => println!(
                    "best: {} — {} / iteration, {:.1} TFLOPS, peak mem {}",
                    best.plan_name,
                    fmt_secs(m.makespan),
                    m.aggregate_tflops,
                    fmt_bytes(m.peak_mem)
                ),
            }
            if let Some(res) = &report.resilience {
                println!(
                    "resilience: goodput {:.1}% (fault-free {} -> faulted {}), recovery {}, \
                     lost work {}, ckpt stall {} @ interval {}, {} kills / {} faults",
                    100.0 * res.goodput,
                    fmt_secs(res.base_makespan),
                    fmt_secs(res.faulted_makespan),
                    fmt_secs(res.recovery_time),
                    fmt_secs(res.lost_work),
                    fmt_secs(res.ckpt_time),
                    if res.ckpt_interval > 0.0 {
                        fmt_secs(res.ckpt_interval)
                    } else {
                        "off".to_string()
                    },
                    res.n_kills,
                    res.n_faults
                );
            }
            if let Some(path) = args.get("trace") {
                trace_best(path, best, &model, args, &cluster);
            }
            if let Some(path) = args.get("baseline") {
                baseline_gate(path, &report, args);
            }
            if let Some(path) = args.get("fault-baseline") {
                fault_gate(path, &report, args);
            }
        }
        None => {
            eprintln!("no feasible plan completed without OOM/deadlock");
            std::process::exit(1);
        }
    }
}

/// Rebuild the search's winning plan, replay it on the DES and write its
/// Chrome trace — the search-smoke CI artifact that makes a regression's
/// pipeline shape inspectable without re-running anything locally.
///
/// This re-runs the build → validate → materialize → DES pipeline against
/// the search's borrowed probe model (the model itself is never
/// reconstructed): the search's O(des_top) artifact cache is consumed by
/// the re-rank and lives inside the engine, and the trace path also works
/// for list-fidelity searches that never DES-scored anything.
fn trace_best(
    path: &str,
    best: &search::Candidate,
    model: &models::Model,
    args: &Args,
    cluster: &Cluster,
) {
    let Some(planner) = plans::registry::find(best.planner) else {
        eprintln!("winning planner '{}' not in registry", best.planner);
        std::process::exit(2);
    };
    let out = planner.build(model, &best.spec).unwrap_or_else(|e| {
        eprintln!("winning plan failed to rebuild for tracing: {e}");
        std::process::exit(2);
    });
    let vs = superscaler::schedule::validate(&out.graph, &out.schedule).unwrap_or_else(|e| {
        eprintln!("winning plan failed to re-validate for tracing: {e}");
        std::process::exit(2);
    });
    let plan = superscaler::materialize::materialize(&out.graph, &vs, cluster, comm_mode(args));
    let r = superscaler::des::simulate(&out.graph, &vs, &plan, cluster);
    match superscaler::des::trace::write_chrome_trace(path, &r, &plan) {
        Ok(()) => println!("trace: wrote {path} ({} DES)", fmt_secs(r.makespan)),
        Err(e) => {
            eprintln!("cannot write trace {path}: {e}");
            std::process::exit(2);
        }
    }
}

/// `--bench-json`: write the search-throughput trajectory artifact
/// (`BENCH_search.json`). Each CI search-smoke run uploads one, so the
/// repo accumulates a wall-clock + coverage trajectory of the search
/// itself (what the `max_wall_secs` gate protects).
fn write_bench_json(path: &str, report: &search::SearchReport) {
    use superscaler::util::json::{self, Value};
    let v = Value::obj([
        ("model", report.model.clone().into()),
        ("gpus", report.gpus.into()),
        // `devices`/`topology`: the scaling axes — they distinguish a
        // 16-GPU smoke run from a 1k-device fat-tree scaling run in the
        // accumulated trajectory.
        ("devices", report.gpus.into()),
        ("topology", report.topology.clone().into()),
        ("wall_secs", report.wall_secs.into()),
        ("evaluated", report.evaluated.into()),
        ("pruned_infeasible", report.pruned.into()),
        ("pruned_bound", report.pruned_bound.into()),
        ("excluded", report.excluded.into()),
        ("capped", report.capped.into()),
        ("des_rescored", report.des_rescored.into()),
        (
            "best_list_makespan",
            report.best_list_makespan().map(Value::from).unwrap_or(Value::Null),
        ),
        (
            "best_des_makespan",
            report
                .best()
                .and_then(|c| c.metrics())
                .and_then(|m| m.des_makespan)
                .map(Value::from)
                .unwrap_or(Value::Null),
        ),
        (
            "refine_iters",
            report.refine.as_ref().map(|r| Value::from(r.iters)).unwrap_or(Value::Null),
        ),
        (
            "refine_accepted",
            report.refine.as_ref().map(|r| Value::from(r.accepted)).unwrap_or(Value::Null),
        ),
        (
            "delta_replay_frac",
            report
                .refine
                .as_ref()
                .and_then(|r| r.delta_replay_frac())
                .map(Value::from)
                .unwrap_or(Value::Null),
        ),
        (
            "best_gap",
            report
                .refine
                .as_ref()
                .and_then(|r| r.best_gap)
                .map(Value::from)
                .unwrap_or(Value::Null),
        ),
        // Resilience trajectory (null on fault-free runs): the fault-smoke
        // job accumulates goodput / recovery alongside the perf numbers.
        ("resilience_scored", report.resilience_scored.into()),
        (
            "goodput",
            report.resilience.as_ref().map(|r| Value::from(r.goodput)).unwrap_or(Value::Null),
        ),
        (
            "faulted_makespan",
            report
                .resilience
                .as_ref()
                .map(|r| Value::from(r.faulted_makespan))
                .unwrap_or(Value::Null),
        ),
        (
            "recovery_secs",
            report.resilience.as_ref().map(|r| Value::from(r.recovery_time)).unwrap_or(Value::Null),
        ),
        (
            "lost_work_secs",
            report.resilience.as_ref().map(|r| Value::from(r.lost_work)).unwrap_or(Value::Null),
        ),
        (
            "ckpt_overhead_secs",
            report.resilience.as_ref().map(|r| Value::from(r.ckpt_time)).unwrap_or(Value::Null),
        ),
        (
            "n_kills",
            report.resilience.as_ref().map(|r| Value::from(r.n_kills)).unwrap_or(Value::Null),
        ),
    ]);
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir).ok();
    }
    match std::fs::write(path, json::to_string_pretty(&v) + "\n") {
        Ok(()) => println!(
            "bench: wrote {path} (wall {}, {} evaluated)",
            fmt_secs(report.wall_secs),
            report.evaluated
        ),
        Err(e) => {
            eprintln!("cannot write bench json {path}: {e}");
            std::process::exit(2);
        }
    }
}

/// `verify-exec`: the differential plan-execution harness. Runs every
/// requested planner family × device count on the CPU reference executor,
/// compares elementwise against the serial oracle, prints the pass matrix
/// and the measured-vs-analytic calibration table, optionally writes
/// `BENCH_exec.json`, and exits 1 when any cell fails.
fn verify_exec(args: &Args) {
    use superscaler::exec::diff;
    use superscaler::util::json;

    let devices: Vec<usize> = args
        .str("devices", "2,4,8")
        .split(',')
        .filter(|t| !t.is_empty())
        .map(|t| {
            t.trim().parse::<usize>().unwrap_or_else(|_| {
                eprintln!("--devices expects a comma list of integers, got '{t}'");
                std::process::exit(2);
            })
        })
        .collect();
    let families: Vec<String> = match args.get("families") {
        None => diff::default_families(),
        Some(list) => list
            .split(',')
            .filter(|t| !t.is_empty())
            .map(|t| t.trim().to_string())
            .collect(),
    };
    if devices.is_empty() || families.is_empty() {
        eprintln!("verify-exec needs at least one device count and one family");
        std::process::exit(2);
    }

    println!(
        "verify-exec: {} families x {:?} devices against the serial oracle (tol {:.0e} rel)",
        families.len(),
        devices,
        diff::REL_TOL
    );
    let out = diff::run_matrix(&devices, &families).unwrap_or_else(|e| {
        eprintln!("verify-exec: {e}");
        std::process::exit(1);
    });
    println!("{}", diff::render_matrix(&out));
    println!("cost calibration (measured CPU vs analytic V100 profile):");
    println!("{}", out.calibration.render());

    if let Some(path) = args.get("json") {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        match std::fs::write(path, json::to_string_pretty(&out.to_json()) + "\n") {
            Ok(()) => println!("bench: wrote {path}"),
            Err(e) => {
                eprintln!("cannot write bench json {path}: {e}");
                std::process::exit(2);
            }
        }
    }

    let failed = out.cases.iter().filter(|c| !c.passed).count();
    if failed > 0 {
        eprintln!("verify-exec: {failed}/{} cells FAILED equivalence", out.cases.len());
        std::process::exit(1);
    }
    println!("verify-exec: all {} cells match the serial oracle", out.cases.len());
}

/// The CI perf-trajectory gate: compare the search's best iteration time
/// against a committed baseline JSON, and the search's own wall-clock
/// against the baseline's `max_wall_secs` ceiling (the search-throughput
/// gate — both regressions exit 3). A missing/unset baseline (or
/// `--write-baseline`) writes the current numbers instead of gating, so the
/// first CI run bootstraps the file it uploads as an artifact.
fn baseline_gate(path: &str, report: &search::SearchReport, args: &Args) {
    use superscaler::util::json::{self, Value};
    let des_best = report.best().expect("gate runs only with a best plan");
    let des_score = des_best.metrics().and_then(|m| m.des_makespan);
    // Gate on the best *list-simulated* time: it is measured for every
    // candidate under every fidelity, so a `--fidelity des` run cannot
    // shift what the baseline compares against. `best_plan`/`best_spec`/
    // `best_makespan` therefore describe the list winner (a consistent
    // tuple); the DES winner and its score are recorded alongside for the
    // overlap-headroom audit.
    let best = report.best_by_list().expect("a best plan implies a list winner");
    let gate_makespan = best.metrics().expect("list winner has metrics").makespan;
    let tol = args.f64("tol", 0.001);
    // The throughput ceiling the written baseline records: 3x the measured
    // wall-clock (floored at 1 s), generous enough for CI-runner noise yet
    // tight enough that committing a green run's artifact arms a real gate.
    let next_ceiling = (report.wall_secs * 3.0).max(1.0);
    let current = Value::obj([
        ("model", report.model.clone().into()),
        ("gpus", report.gpus.into()),
        ("best_plan", best.plan_name.clone().into()),
        ("best_spec", best.spec.label().into()),
        ("best_makespan", gate_makespan.into()),
        (
            "des_best_plan",
            if des_score.is_some() { des_best.plan_name.clone().into() } else { Value::Null },
        ),
        ("des_best_makespan", des_score.map(Value::from).unwrap_or(Value::Null)),
        ("simulated", report.evaluated.into()),
        ("pruned_infeasible", report.pruned.into()),
        ("capped", report.capped.into()),
        ("pruned_cost_bound", report.pruned_bound.into()),
        ("wall_secs", report.wall_secs.into()),
        ("max_wall_secs", next_ceiling.into()),
    ]);
    let write = |reason: &str| {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        match std::fs::write(path, json::to_string_pretty(&current) + "\n") {
            Ok(()) => {
                println!("baseline {reason}: wrote {path} (best {})", fmt_secs(gate_makespan))
            }
            Err(e) => {
                eprintln!("cannot write baseline {path}: {e}");
                std::process::exit(2);
            }
        }
    };
    let doc = std::fs::read_to_string(path).ok().and_then(|s| json::parse(&s).ok());
    let prior = doc
        .as_ref()
        .and_then(|v| v.get("best_makespan").and_then(|b| b.as_f64()))
        .filter(|&b| b > 0.0);
    // Search-throughput ceiling: armed only when the makespan baseline is
    // (a positive `max_wall_secs` alone does not arm it — a bootstrap run
    // rewrites the whole file, so gating against the stale ceiling it just
    // replaced would fail the run meant to arm both gates).
    let wall_ceiling = prior.and(
        doc.as_ref()
            .and_then(|v| v.get("max_wall_secs").and_then(|b| b.as_f64()))
            .filter(|&b| b > 0.0),
    );
    match prior {
        None => write("bootstrap"),
        Some(base) => {
            let ratio = gate_makespan / base;
            let delta = (ratio - 1.0) * 100.0;
            if ratio > 1.0 + tol {
                if !args.has("write-baseline") {
                    eprintln!(
                        "PERF GATE FAILED: best plan {} at {} regressed {delta:+.2}% vs \
                         baseline {}",
                        best.plan_name,
                        fmt_secs(gate_makespan),
                        fmt_secs(base)
                    );
                    std::process::exit(3);
                }
                println!(
                    "perf gate: REGRESSION {delta:+.2}% vs {} accepted by --write-baseline",
                    fmt_secs(base)
                );
            } else {
                println!(
                    "perf gate ok: {} vs baseline {} ({delta:+.2}%)",
                    fmt_secs(gate_makespan),
                    fmt_secs(base)
                );
            }
            if args.has("write-baseline") {
                write("refresh");
            } else if ratio < 1.0 - tol {
                println!("note: best improved; refresh with --write-baseline to lock it in");
            }
        }
    }
    // ---- the search-throughput gate (ISSUE 5): the search itself must
    // not get slower. Same exit-3 convention as the makespan gate; a
    // --write-baseline run accepts the slower wall and records a fresh
    // ceiling instead.
    if let Some(ceil) = wall_ceiling {
        if report.wall_secs > ceil {
            if args.has("write-baseline") {
                println!(
                    "throughput gate: wall {} above ceiling {} accepted by --write-baseline",
                    fmt_secs(report.wall_secs),
                    fmt_secs(ceil)
                );
            } else {
                eprintln!(
                    "SEARCH THROUGHPUT GATE FAILED: search wall-clock {} exceeds \
                     max_wall_secs {} from the committed baseline",
                    fmt_secs(report.wall_secs),
                    fmt_secs(ceil)
                );
                std::process::exit(3);
            }
        } else {
            println!(
                "throughput gate ok: search wall {} <= ceiling {}",
                fmt_secs(report.wall_secs),
                fmt_secs(ceil)
            );
        }
    }
}

/// The CI resilience gate (`--fault-baseline`): the winner's goodput under
/// the seeded fault trace must stay at or above the committed
/// `min_goodput` floor — exit 3 on breach, same convention as the perf
/// gates. A missing baseline bootstraps the file with a floor at 90% of
/// the measured goodput (headroom for simulator noise across plan churn);
/// `--write-baseline` refreshes it.
fn fault_gate(path: &str, report: &search::SearchReport, args: &Args) {
    use superscaler::util::json::{self, Value};
    let Some(res) = &report.resilience else {
        eprintln!(
            "FAULT GATE FAILED: --fault-baseline needs a fault-scored winner \
             (pass --faults or --mtbf)"
        );
        std::process::exit(3);
    };
    let current = Value::obj([
        ("model", report.model.clone().into()),
        ("gpus", report.gpus.into()),
        ("topology", report.topology.clone().into()),
        ("goodput", res.goodput.into()),
        ("min_goodput", (res.goodput * 0.9).into()),
        ("recovery_secs", res.recovery_time.into()),
        ("ckpt_interval", res.ckpt_interval.into()),
        ("n_kills", res.n_kills.into()),
        ("n_faults", res.n_faults.into()),
    ]);
    let write = |reason: &str| {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        match std::fs::write(path, json::to_string_pretty(&current) + "\n") {
            Ok(()) => println!(
                "fault baseline {reason}: wrote {path} (goodput {:.1}%, floor {:.1}%)",
                100.0 * res.goodput,
                90.0 * res.goodput
            ),
            Err(e) => {
                eprintln!("cannot write fault baseline {path}: {e}");
                std::process::exit(2);
            }
        }
    };
    let floor = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| json::parse(&s).ok())
        .and_then(|v| v.get("min_goodput").and_then(|b| b.as_f64()))
        .filter(|&b| b > 0.0);
    match floor {
        None => write("bootstrap"),
        Some(min) => {
            if res.goodput < min {
                if !args.has("write-baseline") {
                    eprintln!(
                        "FAULT GATE FAILED: goodput {:.1}% under the committed floor {:.1}%",
                        100.0 * res.goodput,
                        100.0 * min
                    );
                    std::process::exit(3);
                }
                println!(
                    "fault gate: goodput {:.1}% below floor {:.1}% accepted by --write-baseline",
                    100.0 * res.goodput,
                    100.0 * min
                );
            } else {
                println!(
                    "fault gate ok: goodput {:.1}% >= floor {:.1}%",
                    100.0 * res.goodput,
                    100.0 * min
                );
            }
            if args.has("write-baseline") {
                write("refresh");
            }
        }
    }
}

fn parse_rvd(s: &str) -> Rvd {
    // "R(2)V(1)D(2,1)"
    let nums: Vec<usize> = s
        .split(|c: char| !c.is_ascii_digit())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse().unwrap())
        .collect();
    assert!(nums.len() >= 3, "bad RVD '{s}'");
    Rvd::new(nums[0], nums[1], &nums[2..])
}

fn rvd_query(args: &Args) {
    let from = parse_rvd(args.str("from", "R(4)V(1)D(1)"));
    let to = parse_rvd(args.str("to", "R(8)V(1)D(1)"));
    let mb = args.usize("mb", 64) as u64 * (1 << 20);
    let src_n = args.usize("src-gpus", from.num_devices());
    let dst_n = args.usize("dst-gpus", to.num_devices());
    let cluster = Cluster::v100(32);
    let src: Vec<usize> = (0..src_n).collect();
    let dst: Vec<usize> = (8..8 + dst_n).collect();
    println!(
        "searching {from} ({src_n} gpus, server 0) -> {to} ({dst_n} gpus, server 1), {}",
        fmt_bytes(mb)
    );
    match superscaler::rvd::search_inter(&cluster, &src, &dst, mb, &from, &to) {
        Some(p) => {
            println!("plan: {}", p.describe(&from));
            println!("time: {}", fmt_secs(p.time));
            let p2p = superscaler::rvd::p2p_baseline_time(&cluster, &src, &dst, mb, &to);
            println!("p2p baseline: {} ({:.1}x slower)", fmt_secs(p2p), p2p / p.time.max(1e-12));
        }
        None => println!("no path found"),
    }
}

fn train(args: &Args) {
    let devices = args.usize("devices", 2);
    let steps = args.usize("steps", 50) as u64;
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let adam = superscaler::exec::Adam {
        lr: args.f64("lr", 1e-2) as f32,
        ..Default::default()
    };
    println!("training data-parallel over {devices} thread-devices, {steps} steps");
    match superscaler::exec::train_dp(&dir, devices, steps, adam, 42, 10) {
        Ok(curve) => {
            let first = curve.first().unwrap();
            let last = curve.last().unwrap();
            println!(
                "loss {:.4} -> {:.4} over {} steps ({:.2} s/step)",
                first.loss,
                last.loss,
                curve.len(),
                curve.iter().map(|s| s.step_time).sum::<f64>() / curve.len() as f64
            );
        }
        Err(e) => {
            eprintln!("training failed: {e}");
            std::process::exit(1);
        }
    }
}
