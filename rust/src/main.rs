//! SuperScaler CLI — the leader entrypoint.
//!
//! ```text
//! superscaler simulate --model gpt3 --plan coshard --gpus 16 [--scale 2 ...]
//! superscaler search   --model gpt3 --gpus 8 [--top 10] [--workers N]
//! superscaler rvd --from "R(1)V(2)D(1,2)" --to "R(2)V(1)D(2,1)" --gpus 4
//! superscaler train --devices 4 --steps 100 [--artifacts artifacts]
//! superscaler plans                      # list registered sPrograms
//! ```
//!
//! Plan names resolve through `plans::registry`; `simulate` builds exactly
//! one spec, `search` enumerates and ranks the whole feasible spec grid.

use superscaler::materialize::CommMode;
use superscaler::models;
use superscaler::plans::{self, PlanKind, PlanSpec, Planner, StageSpec};
use superscaler::rvd::Rvd;
use superscaler::search;
use superscaler::util::cli::Args;
use superscaler::util::{fmt_bytes, fmt_secs};
use superscaler::{cost::Cluster, sim};

fn main() {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "simulate" => simulate(&args),
        "search" => search_cmd(&args),
        "rvd" => rvd_query(&args),
        "train" => train(&args),
        "plans" => list_plans(),
        _ => usage(),
    }
}

fn usage() {
    println!(
        "superscaler — flexible DNN parallelization via a unified abstraction\n\
         \n\
         USAGE:\n\
           superscaler simulate --model <gpt3|swin|mbart|alphafold2> --plan <name>\n\
                                [--gpus N] [--scale 0..3] [--batch B] [--seq S]\n\
                                [--tp T] [--pp P] [--dp D] [--micro K] [--shards C]\n\
                                [--comm p2p|intra|inter]\n\
           superscaler search   --model <gpt3|swin|mbart|alphafold2> [--gpus N]\n\
                                [--scale 0..3] [--batch B] [--seq S] [--top N]\n\
                                [--workers N] [--max-candidates N]\n\
                                [--comm p2p|intra|inter] [--hetero] [--no-prune]\n\
                                [--baseline FILE] [--write-baseline] [--tol F]\n\
                                  enumerate the feasible PlanSpec grid (--hetero\n\
                                  adds heterogeneous per-stage pipelines),\n\
                                  dominance-prune against the analytic cost\n\
                                  lower bound (--no-prune simulates everything),\n\
                                  evaluate survivors in parallel (transform ->\n\
                                  validate -> materialize -> simulate), print the\n\
                                  ranking (best iteration time first).\n\
                                  --baseline gates the best time against a\n\
                                  committed JSON (exit 3 on regression > --tol,\n\
                                  default 0.001); --write-baseline refreshes it\n\
           superscaler rvd      --from 'R(r)V(v)D(k1,k2)' --to '...' [--gpus N]\n\
                                [--src-gpus N] [--dst-gpus N] [--mb MB]\n\
           superscaler train    [--devices N] [--steps N] [--lr F] [--artifacts DIR]\n\
           superscaler plans"
    );
}

fn list_plans() {
    println!("registered sPrograms (plans::registry):");
    for p in plans::registry::all() {
        println!("  {:<15} {}", p.name(), p.description());
    }
}

fn build_model(args: &Args) -> models::Model {
    let name = args.str("model", "gpt3");
    let scale = args.usize("scale", 0);
    let batch = args.usize("batch", 8);
    match name {
        "gpt3" => models::gpt3(scale, batch, args.usize("seq", 2048)),
        "swin" => models::swin_transformer(scale, batch, args.usize("resolution", 1536)),
        "mbart" => models::mbart(scale, batch, args.usize("seq", 1024)),
        "alphafold2" => models::alphafold2(scale, batch),
        other => {
            eprintln!("unknown model '{other}'");
            std::process::exit(2);
        }
    }
}

fn comm_mode(args: &Args) -> CommMode {
    match args.str("comm", "inter") {
        "p2p" => CommMode::P2POnly,
        "intra" => CommMode::IntraRvd,
        _ => CommMode::InterRvd,
    }
}

/// The planner's canonical spec for this GPU count, overridden by whatever
/// degree flags the user passed.
fn spec_from_args(planner: &dyn Planner, args: &Args, gpus: usize) -> PlanSpec {
    let mut spec = planner.default_spec(gpus, args.usize("micro", 4));
    spec.dp = args.usize("dp", spec.dp);
    spec.pp = args.usize("pp", spec.pp);
    spec.tp = args.usize("tp", spec.tp);
    spec.micro = args.usize("micro", spec.micro);
    spec.shards = args.usize("shards", spec.shards);
    if args.has("offload") {
        spec.offload = args.bool("offload", spec.offload);
    }
    // DAP's axial width fills whatever the DP degree leaves — unless the
    // user pinned it explicitly with --tp.
    if spec.kind == PlanKind::Dap && !args.has("tp") {
        spec.tp = (gpus / spec.dp.max(1)).max(1);
    }
    // Hetero builds from its stage list, so degree flags rebuild it as a
    // uniform pipeline (--pp stages of --tp width, default gpus/pp) instead
    // of silently drifting from the stages the planner chose.
    if spec.kind == PlanKind::Hetero {
        if args.has("pp") || args.has("tp") {
            let pp = spec.pp.max(1);
            let width =
                if args.has("tp") { spec.tp.max(1) } else { (gpus / spec.dp.max(1) / pp).max(1) };
            spec.stages = Some(vec![StageSpec::tp(width); pp]);
        }
        if let Some(stages) = &spec.stages {
            spec.pp = stages.len();
            spec.tp = 1;
        }
    }
    spec
}

fn simulate(args: &Args) {
    let gpus = args.usize("gpus", 4);
    let model = build_model(args);
    let plan_name = args.str("plan", "dp");
    let Some(planner) = plans::registry::find(plan_name) else {
        eprintln!("unknown plan '{plan_name}' (see `superscaler plans`)");
        std::process::exit(2);
    };
    let spec = spec_from_args(planner, args, gpus);
    let out = planner.build(model, &spec).unwrap_or_else(|e| {
        eprintln!("plan construction failed: {e}");
        std::process::exit(1);
    });
    let cluster = Cluster::v100(gpus);
    match sim::run(&out.graph, &out.schedule, &cluster, comm_mode(args)) {
        Ok(r) => {
            let (comp, comm, bub) = r.breakdown();
            println!("plan       {}", out.name);
            println!("iteration  {}", fmt_secs(r.makespan));
            println!("aggregate  {:.1} TFLOPS ({:.1}/GPU)", r.aggregate_tflops, r.tflops_per_gpu);
            println!(
                "breakdown  compute {} | comm {} | bubble {}",
                fmt_secs(comp),
                fmt_secs(comm),
                fmt_secs(bub)
            );
            println!("comm       {}", fmt_bytes(r.comm_bytes));
            let oom = if r.oom { "  ** OOM **" } else { "" };
            println!("peak mem   {}{}", fmt_bytes(r.max_peak_mem()), oom);
        }
        Err(e) => {
            eprintln!("schedule invalid: {e}");
            std::process::exit(1);
        }
    }
}

fn search_cmd(args: &Args) {
    let gpus = args.usize("gpus", 8);
    if gpus == 0 || (gpus > 8 && gpus % 8 != 0) {
        eprintln!("--gpus must be 1..=8 or a multiple of 8 (servers hold 8 GPUs)");
        std::process::exit(2);
    }
    let top = args.usize("top", 10);
    let cluster = Cluster::v100(gpus);
    let cfg = search::SearchConfig {
        workers: args.usize("workers", 0),
        comm: comm_mode(args),
        max_candidates: args.usize("max-candidates", 256),
        hetero: args.has("hetero"),
        prune: !args.has("no-prune"),
    };
    let report = search::search(|| build_model(args), &cluster, &cfg);
    let t = report.to_table(top);
    t.print();
    t.write_csv("bench_results/search.csv").ok();
    match report.best() {
        Some(best) => {
            let m = best.metrics().expect("best candidate has metrics");
            println!(
                "best: {} — {} / iteration, {:.1} TFLOPS, peak mem {}",
                best.plan_name,
                fmt_secs(m.makespan),
                m.aggregate_tflops,
                fmt_bytes(m.peak_mem)
            );
            if let Some(path) = args.get("baseline") {
                baseline_gate(path, &report, args);
            }
        }
        None => {
            eprintln!("no feasible plan completed without OOM/deadlock");
            std::process::exit(1);
        }
    }
}

/// The CI perf-trajectory gate: compare the search's best iteration time
/// against a committed baseline JSON. A missing/unset baseline (or
/// `--write-baseline`) writes the current numbers instead of gating, so the
/// first CI run bootstraps the file it uploads as an artifact.
fn baseline_gate(path: &str, report: &search::SearchReport, args: &Args) {
    use superscaler::util::json::{self, Value};
    let best = report.best().expect("gate runs only with a best plan");
    let m = best.metrics().expect("best candidate has metrics");
    let tol = args.f64("tol", 0.001);
    let current = Value::obj([
        ("model", report.model.clone().into()),
        ("gpus", report.gpus.into()),
        ("best_plan", best.plan_name.clone().into()),
        ("best_spec", best.spec.label().into()),
        ("best_makespan", m.makespan.into()),
        ("simulated", report.evaluated.into()),
        ("pruned_infeasible", report.pruned.into()),
        ("capped", report.capped.into()),
        ("pruned_cost_bound", report.pruned_bound.into()),
    ]);
    let write = |reason: &str| {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir).ok();
        }
        match std::fs::write(path, json::to_string_pretty(&current) + "\n") {
            Ok(()) => println!("baseline {reason}: wrote {path} (best {})", fmt_secs(m.makespan)),
            Err(e) => {
                eprintln!("cannot write baseline {path}: {e}");
                std::process::exit(2);
            }
        }
    };
    let prior = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| json::parse(&s).ok())
        .and_then(|v| v.get("best_makespan").and_then(|b| b.as_f64()))
        .filter(|&b| b > 0.0);
    match prior {
        None => write("bootstrap"),
        Some(base) => {
            let ratio = m.makespan / base;
            let delta = (ratio - 1.0) * 100.0;
            if ratio > 1.0 + tol {
                if !args.has("write-baseline") {
                    eprintln!(
                        "PERF GATE FAILED: best plan {} at {} regressed {delta:+.2}% vs \
                         baseline {}",
                        best.plan_name,
                        fmt_secs(m.makespan),
                        fmt_secs(base)
                    );
                    std::process::exit(3);
                }
                println!(
                    "perf gate: REGRESSION {delta:+.2}% vs {} accepted by --write-baseline",
                    fmt_secs(base)
                );
            } else {
                println!(
                    "perf gate ok: {} vs baseline {} ({delta:+.2}%)",
                    fmt_secs(m.makespan),
                    fmt_secs(base)
                );
            }
            if args.has("write-baseline") {
                write("refresh");
            } else if ratio < 1.0 - tol {
                println!("note: best improved; refresh with --write-baseline to lock it in");
            }
        }
    }
}

fn parse_rvd(s: &str) -> Rvd {
    // "R(2)V(1)D(2,1)"
    let nums: Vec<usize> = s
        .split(|c: char| !c.is_ascii_digit())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse().unwrap())
        .collect();
    assert!(nums.len() >= 3, "bad RVD '{s}'");
    Rvd::new(nums[0], nums[1], &nums[2..])
}

fn rvd_query(args: &Args) {
    let from = parse_rvd(args.str("from", "R(4)V(1)D(1)"));
    let to = parse_rvd(args.str("to", "R(8)V(1)D(1)"));
    let mb = args.usize("mb", 64) as u64 * (1 << 20);
    let src_n = args.usize("src-gpus", from.num_devices());
    let dst_n = args.usize("dst-gpus", to.num_devices());
    let cluster = Cluster::v100(32);
    let src: Vec<usize> = (0..src_n).collect();
    let dst: Vec<usize> = (8..8 + dst_n).collect();
    println!(
        "searching {from} ({src_n} gpus, server 0) -> {to} ({dst_n} gpus, server 1), {}",
        fmt_bytes(mb)
    );
    match superscaler::rvd::search_inter(&cluster, &src, &dst, mb, &from, &to) {
        Some(p) => {
            println!("plan: {}", p.describe(&from));
            println!("time: {}", fmt_secs(p.time));
            let p2p = superscaler::rvd::p2p_baseline_time(&cluster, &src, &dst, mb, &to);
            println!("p2p baseline: {} ({:.1}x slower)", fmt_secs(p2p), p2p / p.time.max(1e-12));
        }
        None => println!("no path found"),
    }
}

fn train(args: &Args) {
    let devices = args.usize("devices", 2);
    let steps = args.usize("steps", 50) as u64;
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let adam = superscaler::exec::Adam {
        lr: args.f64("lr", 1e-2) as f32,
        ..Default::default()
    };
    println!("training data-parallel over {devices} thread-devices, {steps} steps");
    match superscaler::exec::train_dp(&dir, devices, steps, adam, 42, 10) {
        Ok(curve) => {
            let first = curve.first().unwrap();
            let last = curve.last().unwrap();
            println!(
                "loss {:.4} -> {:.4} over {} steps ({:.2} s/step)",
                first.loss,
                last.loss,
                curve.len(),
                curve.iter().map(|s| s.step_time).sum::<f64>() / curve.len() as f64
            );
        }
        Err(e) => {
            eprintln!("training failed: {e}");
            std::process::exit(1);
        }
    }
}
