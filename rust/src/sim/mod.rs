//! Cluster simulator: deterministic list-scheduling execution of a
//! materialized [`Plan`](crate::materialize::Plan) on the modeled cluster.
//!
//! This substitutes for the paper's 32×V100 testbed (§6.1). The simulator
//! executes one training iteration:
//!
//! * every task starts when all its dependencies have finished **and** all
//!   devices it occupies are free (compute and communication both block
//!   their devices — the synchronous-NCCL model the paper's bubble analysis
//!   assumes);
//! * per-device serial order follows the validated schedule (phase-2
//!   completion), so `op-order` pipelining decisions directly shape the
//!   timeline;
//! * activation memory is tracked as a high-watermark: output buffers are
//!   live from producer start until their last consumer finishes; static
//!   memory (weight/grad/optimizer shards) comes from materialization.
//!
//! Outputs per run: makespan, per-device compute/comm/bubble breakdown
//! (Fig. 15), aggregate TFLOPS (Fig. 12), peak memory + OOM flags
//! (Figs. 13/14).
//!
//! # Fidelity tiers
//!
//! This list scheduler is the *middle* of three plan-scoring tiers that
//! trade cost for accuracy:
//!
//! 1. **analytic lower bound** ([`Cluster::plan_time_lower_bound`]) —
//!    microseconds per spec, sound but optimistic; used by the search for
//!    dominance pruning;
//! 2. **list simulation** (this module) — milliseconds per plan; models
//!    device occupancy and schedule order exactly but charges every
//!    collective to *all* of its devices (no comm/compute overlap) and
//!    every transfer its solo bandwidth (no link contention) — a
//!    synchronous-NCCL pessimist;
//! 3. **discrete-event simulation** ([`crate::des`]) — tens of
//!    milliseconds per plan; separate per-device compute/communication
//!    streams credit overlap-friendly schedules, and concurrent transfers
//!    fair-share the links they cross ([`Cluster::group_links`]).
//!
//! The search screens with tier 2 and re-ranks its top candidates with
//! tier 3 (`--fidelity des`). Both engines consume the same
//! [`TaskGraph`] preparation (dependency DAG + per-device serial hints),
//! so they disagree only where the execution *model* differs — never on
//! which order the schedule asked for.
//!
//! # The zero-rebuild evaluation pipeline
//!
//! This simulator is the inner loop of the plan search, which evaluates
//! thousands of candidates off **one** borrowed probe model (built once
//! per [`crate::search::search`] run; planners clone only the graph).
//! Correspondingly the hot paths here are allocation-lean: the scheduling
//! loop resolves every task's device list once up front and indexes
//! per-device state (availability, stats) by dense slot rather than hash
//! map, task labels are interned `Arc<str>`s from materialization, and
//! the `(Graph, TaskGraph, Plan)` triple of a top candidate is cached by
//! the search (O(`des_top`) of them) so the DES re-rank replays it via
//! [`crate::des::execute`] instead of re-running
//! transform → validate → materialize.

use crate::cost::Cluster;
use crate::graph::{Graph, TensorKind};
use crate::materialize::{Plan, Task, TaskId, TaskKind};
use crate::schedule::{DeviceId, ValidatedSchedule, CPU_DEVICE};
use std::collections::HashMap;

/// Dense per-device state slot shared by BOTH execution engines (host = 0,
/// GPU `d` = `d + 1`). One definition on purpose: the list scheduler and
/// the DES must agree bitwise on identical plans, so their device indexing
/// must be literally the same code.
pub(crate) fn dev_slot(d: DeviceId) -> usize {
    if d == CPU_DEVICE {
        0
    } else {
        d + 1
    }
}

/// Per-device simulation statistics.
#[derive(Clone, Debug, Default)]
pub struct DeviceStat {
    pub device: DeviceId,
    /// Seconds busy in compute tasks.
    pub compute: f64,
    /// Seconds busy in communication tasks.
    pub comm: f64,
    /// Seconds idle while the iteration is in flight (bubble time).
    pub bubble: f64,
    /// Peak memory, bytes (static + activation watermark).
    pub peak_mem: u64,
    pub oom: bool,
}

/// Result of simulating one training iteration.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub makespan: f64,
    pub per_device: Vec<DeviceStat>,
    pub total_flops: f64,
    /// Aggregate achieved TFLOPS across the cluster (the paper's Fig. 12
    /// metric).
    pub aggregate_tflops: f64,
    /// Per-GPU achieved TFLOPS.
    pub tflops_per_gpu: f64,
    pub comm_bytes: u64,
    pub oom: bool,
}

impl SimReport {
    pub fn max_peak_mem(&self) -> u64 {
        self.per_device.iter().map(|d| d.peak_mem).max().unwrap_or(0)
    }

    /// Mean compute / comm / bubble fractions across devices (Fig. 15).
    pub fn breakdown(&self) -> (f64, f64, f64) {
        let n = self.per_device.len().max(1) as f64;
        let c = self.per_device.iter().map(|d| d.compute).sum::<f64>() / n;
        let m = self.per_device.iter().map(|d| d.comm).sum::<f64>() / n;
        let b = self.per_device.iter().map(|d| d.bubble).sum::<f64>() / n;
        (c, m, b)
    }
}

/// The dependency structure both execution engines (this list scheduler and
/// the discrete-event simulator, [`crate::des`]) schedule against: the task
/// DAG of the materialized plan plus — when they do not create a cycle —
/// per-device serial edges from the validated schedule's compute order.
///
/// The serial *hints* can conflict with merged communication chains (a
/// collective waits on ALL producers of a component while validation
/// ordered against one replica). Dropping them is safe — data/comm
/// dependencies still hold and devices still serialize through their
/// availability — so [`TaskGraph::prepare`] falls back to the bare DAG
/// when the hinted graph is cyclic. Extracting this once keeps the two
/// engines agreeing on *what* may run when; they differ only in how
/// devices and links are occupied.
#[derive(Clone, Debug)]
pub struct TaskGraph {
    /// `consumers[t]` = tasks with an edge from `t` (deps + serial hints).
    pub consumers: Vec<Vec<TaskId>>,
    /// In-degree of each task under the same edge set.
    pub indeg: Vec<usize>,
    /// Whether the per-device serial hints were kept (false = fallback).
    pub serial_hints: bool,
}

impl TaskGraph {
    /// Build the task graph for `plan` with `vs`'s serial hints, falling
    /// back to plain data dependencies if the hints introduce a cycle.
    /// Panics if the plan's own dependencies are cyclic — that is a
    /// materialization bug, not a schedule property.
    pub fn prepare(vs: &ValidatedSchedule, plan: &Plan) -> TaskGraph {
        let hinted = TaskGraph::build(plan, Some(vs));
        if hinted.is_acyclic() {
            return hinted;
        }
        let bare = TaskGraph::build(plan, None);
        assert!(
            bare.is_acyclic(),
            "task plan has a true dependency cycle — materialization bug"
        );
        bare
    }

    /// Task graph of the plan's data dependencies alone (no schedule).
    pub fn of_plan(plan: &Plan) -> TaskGraph {
        TaskGraph::build(plan, None)
    }

    fn build(plan: &Plan, vs: Option<&ValidatedSchedule>) -> TaskGraph {
        let n = plan.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut consumers: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for t in &plan.tasks {
            for &d in &t.deps {
                consumers[d].push(t.id);
                indeg[t.id] += 1;
            }
        }
        if let Some(vs) = vs {
            for ops in vs.device_order.values() {
                for w in ops.windows(2) {
                    let (a, b) = (plan.task_of_op[w[0]], plan.task_of_op[w[1]]);
                    consumers[a].push(b);
                    indeg[b] += 1;
                }
            }
        }
        TaskGraph { consumers, indeg, serial_hints: vs.is_some() }
    }

    /// Kahn check: does the edge set admit a complete topological order?
    pub fn is_acyclic(&self) -> bool {
        let n = self.indeg.len();
        let mut indeg = self.indeg.clone();
        let mut q: Vec<TaskId> = (0..n).filter(|&t| indeg[t] == 0).collect();
        let mut seen = 0usize;
        while let Some(u) = q.pop() {
            seen += 1;
            for &v in &self.consumers[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    q.push(v);
                }
            }
        }
        seen == n
    }
}

/// Per-device activation memory *events* of an executed plan:
/// `+bytes` at the producing task's start, `-bytes` when the region's last
/// consumer finishes (frees sort before allocations at equal time). Both
/// engines derive their memory accounting from this one function — the
/// list scheduler reduces the events to a high-watermark, the DES keeps
/// the full timeline — so a plan's memory profile never depends on which
/// engine scored it, only on the start/finish times it produced.
pub fn activation_events(
    g: &Graph,
    plan: &Plan,
    start: &[f64],
    finish: &[f64],
) -> HashMap<DeviceId, Vec<(f64, i64)>> {
    let mut events: HashMap<DeviceId, Vec<(f64, i64)>> = HashMap::new();
    let mut last_read: HashMap<(usize, u64), f64> = HashMap::new(); // (ptensor, region) -> time
    for t in &plan.tasks {
        if let TaskKind::Compute { op, .. } = t.kind {
            for &iv in &g.op(op).inputs {
                let vt = g.vtensor(iv);
                let kind = g.ptensor(vt.ptensor).kind;
                if matches!(kind, TensorKind::Activation | TensorKind::Input) {
                    let key = (vt.ptensor, vt.mask.region_hash());
                    let e = last_read.entry(key).or_insert(0.0);
                    *e = e.max(finish[t.id]);
                }
            }
        }
    }
    for t in &plan.tasks {
        if let TaskKind::Compute { op, device } = t.kind {
            for &ov in &g.op(op).outputs {
                let vt = g.vtensor(ov);
                let p = g.ptensor(vt.ptensor);
                if !matches!(p.kind, TensorKind::Activation | TensorKind::Input) {
                    continue;
                }
                let bytes = (vt.mask.num_elements(&p.shape) * p.dtype.size_bytes()) as i64;
                let key = (vt.ptensor, vt.mask.region_hash());
                let freed = last_read.get(&key).copied().unwrap_or(finish[t.id]);
                let evs = events.entry(device).or_default();
                evs.push((start[t.id], bytes));
                evs.push((freed.max(finish[t.id]), -bytes));
            }
        }
    }
    for evs in events.values_mut() {
        evs.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap()
                // Frees before allocs at equal time.
                .then(a.1.cmp(&b.1))
        });
    }
    events
}

/// Per-device *gradient-buffer* liveness events, mirroring
/// [`activation_events`]: a gradient region on a device is resident from
/// the start of the first task touching it (its producing backward op)
/// until the finish of the last local toucher — the optimizer region op,
/// kept alive through any collective or P2P transfer that ships the
/// region's pTensor. The list scheduler does not consume these (it keeps
/// gradients in the static baseline, the conservative high-watermark
/// semantics); the discrete-event engine subtracts the gradient share from
/// its static baseline and replays these events instead, so an OOM verdict
/// depends on *when* gradient buffers are live and whether they collide
/// with the activation peak — dp replicas shift exactly that.
pub fn gradient_events(
    g: &Graph,
    plan: &Plan,
    start: &[f64],
    finish: &[f64],
) -> HashMap<DeviceId, Vec<(f64, i64)>> {
    // (device, ptensor, region) -> (alloc time, free time, bytes).
    let mut regions: HashMap<(DeviceId, usize, u64), (f64, f64, i64)> = HashMap::new();
    for t in &plan.tasks {
        if let TaskKind::Compute { op, device } = t.kind {
            for &vref in g.op(op).inputs.iter().chain(g.op(op).outputs.iter()) {
                let vt = g.vtensor(vref);
                let p = g.ptensor(vt.ptensor);
                if p.kind != TensorKind::Gradient {
                    continue;
                }
                let bytes = (vt.mask.num_elements(&p.shape) * p.dtype.size_bytes()) as i64;
                let e = regions
                    .entry((device, vt.ptensor, vt.mask.region_hash()))
                    .or_insert((start[t.id], finish[t.id], bytes));
                e.0 = e.0.min(start[t.id]);
                e.1 = e.1.max(finish[t.id]);
                e.2 = e.2.max(bytes);
            }
        }
    }
    // Communication shipping a gradient pTensor pins its regions on every
    // participating device until the transfer completes (the buffer is the
    // collective's working storage).
    let mut comm_pin: HashMap<(DeviceId, usize), f64> = HashMap::new();
    for t in &plan.tasks {
        let pt = match &t.kind {
            TaskKind::P2P { ptensor, .. } | TaskKind::Collective { ptensor, .. } => *ptensor,
            TaskKind::Compute { .. } => continue,
        };
        // Synthetic plans (DES unit tests) carry placeholder pTensor ids
        // that may not resolve against their graph.
        if pt >= g.ptensors.len() || g.ptensor(pt).kind != TensorKind::Gradient {
            continue;
        }
        for d in t.devices() {
            let e = comm_pin.entry((d, pt)).or_insert(0.0);
            *e = e.max(finish[t.id]);
        }
    }
    let mut events: HashMap<DeviceId, Vec<(f64, i64)>> = HashMap::new();
    let mut keys: Vec<(DeviceId, usize, u64)> = regions.keys().copied().collect();
    keys.sort_unstable();
    for key in keys {
        let (alloc, free, bytes) = regions[&key];
        let free = free.max(comm_pin.get(&(key.0, key.1)).copied().unwrap_or(0.0));
        let evs = events.entry(key.0).or_default();
        evs.push((alloc, bytes));
        evs.push((free.max(alloc), -bytes));
    }
    for evs in events.values_mut() {
        evs.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap()
                // Frees before allocs at equal time.
                .then(a.1.cmp(&b.1))
        });
    }
    events
}

/// Simulate one iteration of `plan`. `vs` supplies the per-device serial
/// order for compute tasks; communication tasks are interleaved at the
/// position their dependencies allow.
pub fn simulate(g: &Graph, vs: &ValidatedSchedule, plan: &Plan, cluster: &Cluster) -> SimReport {
    let tg = TaskGraph::prepare(vs, plan);
    simulate_prepared(g, &tg, plan, cluster)
}

/// [`simulate`] against an already-prepared [`TaskGraph`] (shared with the
/// DES when both engines score the same plan).
pub fn simulate_prepared(g: &Graph, tg: &TaskGraph, plan: &Plan, cluster: &Cluster) -> SimReport {
    let n = plan.tasks.len();
    let mut indeg = tg.indeg.clone();
    let consumers = &tg.consumers;
    // ---- event-driven greedy scheduling (lazy min-heap) ----
    // Among ready tasks (all deps finished), repeatedly dispatch the one
    // with the earliest feasible start time (deps ⊔ device availability);
    // ties prefer communication tasks (they unblock downstream devices —
    // the "eager send" behaviour of real pipeline runtimes), then lower id.
    //
    // Device availability only ever moves forward, so a task's feasible
    // start is monotone: the heap stores the start time at push time, and a
    // popped entry whose start has since slipped is simply re-pushed with
    // the fresh value (a "lazy" heap). O(n log n) instead of the naive
    // O(n · |ready|) scan — the difference between minutes and milliseconds
    // on the 100k-task Fig. 12 plans (see EXPERIMENTS.md §Perf).
    let mut finish = vec![0.0f64; n];
    let mut start = vec![0.0f64; n];
    // Per-task device lists resolved ONCE: `Task::devices` allocates (and
    // sorts) a fresh Vec per call, and the lazy heap below would otherwise
    // re-ask it on every push, pop and re-push. Device state is densely
    // indexed by slot (host = 0, GPU d = d + 1) instead of hashed.
    let devs: Vec<Vec<DeviceId>> = plan.tasks.iter().map(|t| t.devices()).collect();
    let max_gpu =
        devs.iter().flatten().copied().filter(|&d| d != CPU_DEVICE).max().unwrap_or(0);
    let slot = dev_slot;
    let nslots = max_gpu + 2;
    let mut dev_free = vec![0.0f64; nslots];
    let mut stats: Vec<Option<DeviceStat>> = vec![None; nslots];
    // Min-heap keys: (est_bits, !is_comm, id). f64 >= 0 compares correctly
    // through its raw bit pattern.
    let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(u64, bool, TaskId)>> =
        std::collections::BinaryHeap::new();
    let est_of = |t: TaskId, finish: &[f64], dev_free: &[f64]| {
        let task = &plan.tasks[t];
        let mut est = task.deps.iter().map(|&d| finish[d]).fold(0.0f64, f64::max);
        for &d in &devs[t] {
            est = est.max(dev_free[slot(d)]);
        }
        est
    };
    for t in 0..n {
        if indeg[t] == 0 {
            let est = est_of(t, &finish, &dev_free);
            heap.push(std::cmp::Reverse((est.to_bits(), !plan.tasks[t].is_comm(), t)));
        }
    }
    let mut scheduled = 0usize;
    while let Some(std::cmp::Reverse((est_bits, _, t))) = heap.pop() {
        let est_now = est_of(t, &finish, &dev_free);
        if est_now.to_bits() > est_bits {
            // Stale: devices got busier since this entry was pushed.
            heap.push(std::cmp::Reverse((est_now.to_bits(), !plan.tasks[t].is_comm(), t)));
            continue;
        }
        let task = &plan.tasks[t];
        start[t] = est_now;
        finish[t] = est_now + task.duration;
        for &d in &devs[t] {
            dev_free[slot(d)] = finish[t];
            let st = stats[slot(d)]
                .get_or_insert_with(|| DeviceStat { device: d, ..Default::default() });
            if task.is_comm() {
                st.comm += task.duration;
            } else {
                st.compute += task.duration;
            }
        }
        scheduled += 1;
        for &v in &consumers[t] {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                let est = est_of(v, &finish, &dev_free);
                heap.push(std::cmp::Reverse((est.to_bits(), !plan.tasks[v].is_comm(), v)));
            }
        }
    }
    assert_eq!(scheduled, n, "TaskGraph::prepare guarantees an acyclic task graph");
    let makespan = finish.iter().copied().fold(0.0, f64::max);

    // ---- memory watermark ----
    // Activation regions: live from producer start to last-consumer finish;
    // the shared event stream reduced to a per-device high-watermark.
    // (Every event device produced a compute task above, so its slot fits.)
    for (dev, evs) in activation_events(g, plan, &start, &finish) {
        let mut cur: i64 = 0;
        let mut peak: i64 = 0;
        for (_, delta) in evs {
            cur += delta;
            peak = peak.max(cur);
        }
        let st = stats[slot(dev)]
            .get_or_insert_with(|| DeviceStat { device: dev, ..Default::default() });
        st.peak_mem = peak as u64;
    }
    // Add static memory + OOM check (per-device capacity: mixed fleets
    // give each server row its own limit).
    for st in stats.iter_mut().flatten() {
        st.peak_mem += plan.static_mem.get(&st.device).copied().unwrap_or(0);
        st.bubble = (makespan - st.compute - st.comm).max(0.0);
        if st.device != CPU_DEVICE {
            st.oom = st.peak_mem > cluster.mem_capacity(st.device);
        }
    }

    let total_flops = g.total_flops();
    let mut per_device: Vec<DeviceStat> = stats.into_iter().flatten().collect();
    per_device.sort_by_key(|d| d.device);
    let ngpu = per_device.iter().filter(|d| d.device != CPU_DEVICE).count().max(1);
    let oom = per_device.iter().any(|d| d.oom);
    SimReport {
        makespan,
        total_flops,
        aggregate_tflops: if makespan > 0.0 { total_flops / makespan / 1e12 } else { 0.0 },
        tflops_per_gpu: if makespan > 0.0 {
            total_flops / makespan / 1e12 / ngpu as f64
        } else {
            0.0
        },
        comm_bytes: plan.comm_bytes,
        per_device,
        oom,
    }
}

/// Convenience: validate + materialize + simulate in one call.
pub fn run(
    g: &Graph,
    sched: &crate::schedule::Schedule,
    cluster: &Cluster,
    mode: crate::materialize::CommMode,
) -> Result<SimReport, crate::schedule::ScheduleError> {
    let vs = crate::schedule::validate(g, sched)?;
    let plan = crate::materialize::materialize(g, &vs, cluster, mode);
    Ok(simulate(g, &vs, &plan, cluster))
}

// Re-export for bench ergonomics.
pub use crate::materialize::CommMode;
pub type SimTask = Task;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sig::sigs;
    use crate::graph::{DType, Graph, OpKind};
    use crate::materialize::{materialize, CommMode};
    use crate::schedule::{validate, Schedule};
    use crate::trans::{autograd, op_trans, TransformAlgo};

    fn linear_chain(layers: usize, flops: f64) -> (Graph, Vec<crate::graph::OpId>) {
        let mut g = Graph::new();
        let mut prev = g.add_ptensor("x", &[8, 4, 16], DType::F32, TensorKind::Input);
        let mut ops = Vec::new();
        for l in 0..layers {
            let w = g.add_ptensor(&format!("w{l}"), &[16, 16], DType::F32, TensorKind::Weight);
            let _wg =
                g.add_ptensor(&format!("w{l}.grad"), &[16, 16], DType::F32, TensorKind::Gradient);
            let y =
                g.add_ptensor(&format!("y{l}"), &[8, 4, 16], DType::F32, TensorKind::Activation);
            let (xv, wv, yv) = (g.full_view(prev), g.full_view(w), g.full_view(y));
            ops.push(g.add_op(
                &format!("lin{l}"),
                OpKind::Matmul,
                vec![xv, wv],
                vec![yv],
                flops,
                Some(sigs::linear()),
                true,
                l,
            ));
            prev = y;
        }
        (g, ops)
    }

    #[test]
    fn serial_chain_time_adds_up() {
        let (g, ops) = linear_chain(4, 1e10);
        let mut s = Schedule::new();
        s.assign_all(&ops, 0);
        let c = Cluster::v100(8);
        let r = run(&g, &s, &c, CommMode::InterRvd).unwrap();
        let per_op = c.spec.compute_time(1e10);
        assert!((r.makespan - 4.0 * per_op).abs() < 1e-9);
        assert_eq!(r.comm_bytes, 0);
        assert!(!r.oom);
        // One device: zero bubble.
        assert!(r.per_device[0].bubble < 1e-12);
    }

    #[test]
    fn cross_device_chain_pays_comm_and_bubbles() {
        let (g, ops) = linear_chain(2, 1e10);
        let mut s = Schedule::new();
        s.assign(ops[0], 0);
        s.assign(ops[1], 1);
        let c = Cluster::v100(8);
        let r = run(&g, &s, &c, CommMode::InterRvd).unwrap();
        assert!(r.comm_bytes > 0, "activation must cross devices");
        // Device 1 idles while device 0 computes -> bubble.
        let d1 = r.per_device.iter().find(|d| d.device == 1).unwrap();
        assert!(d1.bubble > 0.0);
        assert!(r.makespan > c.spec.compute_time(1e10) * 2.0);
    }

    #[test]
    fn dp_scales_compute_but_adds_allreduce() {
        // 1 layer + optimizer, DP over 4: per-device compute should be
        // 1/4 of serial, plus an all-reduce.
        let mut g = Graph::new();
        let x = g.add_ptensor("x", &[8, 4, 256], DType::F32, TensorKind::Input);
        let w = g.add_ptensor("w", &[256, 256], DType::F32, TensorKind::Weight);
        let _wg = g.add_ptensor("w.grad", &[256, 256], DType::F32, TensorKind::Gradient);
        let y = g.add_ptensor("y", &[8, 4, 256], DType::F32, TensorKind::Activation);
        let (xv, wv, yv) = (g.full_view(x), g.full_view(w), g.full_view(y));
        let lin = g.add_op(
            "lin",
            OpKind::Matmul,
            vec![xv, wv],
            vec![yv],
            4e10,
            Some(sigs::linear()),
            true,
            0,
        );
        let wgv = g.full_view(_wg);
        let wv2 = g.full_view(w);
        let wv3 = g.full_view(w);
        let opt = g.add_op(
            "opt",
            OpKind::Optimizer,
            vec![wgv, wv2],
            vec![wv3],
            1e5,
            Some(sigs::optimizer()),
            false,
            0,
        );
        let fwd = op_trans(&mut g, lin, &TransformAlgo::split("b", 4)).unwrap();
        let opts = op_trans(&mut g, opt, &TransformAlgo::replicate(4)).unwrap();
        let ag = autograd::complete(&mut g);
        let mut s = Schedule::new();
        for (i, &f) in fwd.iter().enumerate() {
            s.assign(f, i);
            s.assign(ag.bwd_of[&f], i);
            s.assign(opts[i], i);
        }
        let c = Cluster::v100(4);
        let r = run(&g, &s, &c, CommMode::InterRvd).unwrap();
        assert!(r.comm_bytes > 0);
        let d0 = &r.per_device[0];
        // fwd quarter + bwd quarter (2x) + opt
        let expect = c.spec.compute_time(1e10) + c.spec.compute_time(2e10);
        assert!(d0.compute > expect * 0.9 && d0.compute < expect * 1.3, "{}", d0.compute);
        assert!(d0.comm > 0.0);
    }

    #[test]
    fn memory_watermark_frees_after_last_reader() {
        // Two layers on one device: y0 frees after lin1 reads it; both
        // activations never overlap with... actually they do (y0 live while
        // y1 is produced). Peak = y0 + y1 + static.
        let (g, ops) = linear_chain(2, 1e9);
        let mut s = Schedule::new();
        s.assign_all(&ops, 0);
        let c = Cluster::v100(8);
        let vs = validate(&g, &s).unwrap();
        let plan = materialize(&g, &vs, &c, CommMode::InterRvd);
        let r = simulate(&g, &vs, &plan, &c);
        let act_bytes = 8 * 4 * 16 * 4; // one activation
        let static_bytes: u64 = plan.static_mem[&0];
        let d0 = &r.per_device[0];
        // y0 + y1 live at peak (x is a model input, materialized outside
        // the graph; it has no producing task).
        assert_eq!(d0.peak_mem, static_bytes + 2 * act_bytes, "peak {}", d0.peak_mem);
    }

    #[test]
    fn oom_detected_when_activations_exceed_capacity() {
        let mut g = Graph::new();
        // One enormous activation: 64 GiB > 32 GiB card.
        let x = g.add_ptensor("x", &[1 << 30, 16], DType::F32, TensorKind::Input);
        let y = g.add_ptensor("y", &[1 << 30, 16], DType::F32, TensorKind::Activation);
        let (xv, yv) = (g.full_view(x), g.full_view(y));
        g.add_op("big", OpKind::Identity, vec![xv], vec![yv], 1e9, None, true, 0);
        let mut s = Schedule::new();
        s.assign(0, 0);
        let c = Cluster::v100(8);
        let r = run(&g, &s, &c, CommMode::InterRvd).unwrap();
        assert!(r.oom);
    }

    #[test]
    fn pipeline_order_edges_reduce_to_1f1b_shape() {
        // Two stages, two micro-batches: stage0(mb0) -> stage1(mb0),
        // stage0(mb1) -> stage1(mb1); stage1 on device 1.
        // With op-order forcing mb0 fully first, device1 bubbles at start.
        let mut g = Graph::new();
        let mut mk = |g: &mut Graph, name: &str, inp: Option<usize>| {
            let i = match inp {
                Some(p) => p,
                None => g.add_ptensor(&format!("{name}.in"), &[4], DType::F32, TensorKind::Input),
            };
            let o = g.add_ptensor(&format!("{name}.out"), &[4], DType::F32, TensorKind::Activation);
            let iv = g.full_view(i);
            let ov = g.full_view(o);
            let op = g.add_op(name, OpKind::Identity, vec![iv], vec![ov], 1e10, None, true, 0);
            (op, o)
        };
        let (s0m0, t00) = mk(&mut g, "s0m0", None);
        let (s0m1, t01) = mk(&mut g, "s0m1", None);
        let (s1m0, _) = mk(&mut g, "s1m0", Some(t00));
        let (s1m1, _) = mk(&mut g, "s1m1", Some(t01));
        let mut s = Schedule::new();
        s.assign_all(&[s0m0, s0m1], 0);
        s.assign_all(&[s1m0, s1m1], 1);
        s.order(s0m0, s0m1);
        s.order(s1m0, s1m1);
        let c = Cluster::v100(8);
        let r = run(&g, &s, &c, CommMode::InterRvd).unwrap();
        let per_op = c.spec.compute_time(1e10);
        // Pipelined: 3 slots + comm, not 4.
        assert!(r.makespan < 4.0 * per_op, "no pipelining happened: {}", r.makespan);
        assert!(r.makespan > 2.9 * per_op);
        let d1 = r.per_device.iter().find(|d| d.device == 1).unwrap();
        assert!(d1.bubble > per_op * 0.8, "startup bubble expected");
    }

    #[test]
    fn prop_makespan_bounds() {
        // Makespan >= critical path of any single device's work; makespan
        // <= sum of all task durations (serial execution bound).
        crate::util::prop::check("sim-bounds", 30, |gen| {
            let layers = gen.int(1, 5);
            let (g, ops) = linear_chain(layers, 1e9);
            let mut s = Schedule::new();
            let ndev = gen.int(1, 4);
            for &o in &ops {
                s.assign(o, gen.int(0, ndev));
            }
            let c = Cluster::v100(8);
            let r = run(&g, &s, &c, CommMode::InterRvd).unwrap();
            let total: f64 = r.per_device.iter().map(|d| d.compute + d.comm).sum();
            if r.makespan > total + 1e-9 {
                return Err(format!("makespan {} > serial bound {total}", r.makespan));
            }
            let max_dev: f64 = r
                .per_device
                .iter()
                .map(|d| d.compute + d.comm)
                .fold(0.0, f64::max);
            if r.makespan < max_dev - 1e-9 {
                return Err(format!("makespan {} < busiest device {max_dev}", r.makespan));
            }
            Ok(())
        });
    }
}
