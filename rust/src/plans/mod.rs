//! The sProgram library (paper §3.4): parallelization plans written against
//! the three primitives `op-trans` / `op-assign` / `op-order`.
//!
//! Every plan is a function `Model -> PlanOutput { graph, schedule }`; the
//! caller then runs `sim::run` (or the real executor) on the result. Plans
//! include the empirical baselines the paper compares against — data
//! parallelism (Algorithm 1), Megatron-style TP/PP with 1F1B, GPipe,
//! ZeRO-3 (±offload), DAP — and the paper's new plans: **co-shard**,
//! **interlaced pipeline** (Algorithm 2) and **3F1B**.
//!
//! # The declarative layer: `PlanSpec` / `Planner` / `registry`
//!
//! On top of the free functions sits a uniform plan abstraction:
//!
//! * [`PlanSpec`] — a declarative description of one plan instance (kind +
//!   dp/pp/tp degrees, micro-batch count, shard count, offload/recompute
//!   flags). Pure data: it can be enumerated, pruned and compared without
//!   building anything. A spec may additionally carry a [`StageSpec`] list:
//!   one intra-stage transformation choice (tp width / co-shard count /
//!   recompute / optimizer offload) per pipeline stage, which the `hetero`
//!   planner materializes as a *heterogeneous* pipeline — the §5 / Fig. 18
//!   plan family in which different stages parallelize differently. At
//!   `dp > 1` the whole per-stage pipeline replicates and the replicas'
//!   gradients synchronize through RVD-decomposed collectives
//!   ([`crate::rvd::grad_sync_plan`]) — the search over this space is
//!   three-level: dp × stage-width composition × per-stage choice.
//!   A spec may also carry a [`SchedSpec`] — the pipeline schedule as
//!   data (`sched{zb}`, `sched{f0b0;f0b0}` label tokens): named
//!   disciplines or explicit per-stage slot rows from
//!   [`crate::schedule::dsl`], making the temporal ordering the fourth
//!   searchable axis instead of a per-planner hard-coding.
//!   Labels round-trip: [`PlanSpec::label`] is complete and
//!   [`PlanSpec::parse`] inverts it with typed [`SpecParseError`]s.
//! * [`Planner`] — the trait every sProgram implements: `name()`,
//!   `applicable(&Model)`, `default_spec(...)`, `candidates(...)` (its
//!   slice of the search grid) and `build(&Model, &PlanSpec) -> PlanResult`.
//!   `build` **borrows** the model: the search engine builds one probe
//!   model per run and shares it read-only across all worker threads;
//!   every plan function clones only the graph (the structure the
//!   transformation rewrites) and reads layer/tp-dim/embedding metadata
//!   through the borrow — nothing in the per-candidate path reconstructs
//!   a model from its builder.
//! * [`registry`] — the central table of all planners. The CLI, the
//!   benches, the examples and the search engine ([`crate::search`]) all
//!   resolve plan names here, so a new sProgram becomes visible everywhere
//!   by adding one registry entry.
//!
//! The free functions (`data_parallel`, `megatron`, ...) remain the
//! implementation vocabulary; planners are thin declarative adapters over
//! them.

mod coshard;
mod dap;
mod dp;
mod hetero;
mod interlaced;
mod megatron;
mod pipe3f1b;
pub mod registry;
mod spec;
mod zero;

pub use coshard::{coshard, coshard_opt, CoshardPlanner};
pub use dap::{dap_dp, DapPlanner};
pub use dp::{data_parallel, DpPlanner};
pub use hetero::{hetero, hetero_candidates, HeteroPlanner};
pub use interlaced::{interlaced_pipeline, InterlacedPlanner};
pub use megatron::{megatron, GPipePlanner, MegatronPlanner, PipeOrder, TpPlanner};
pub use pipe3f1b::{pipeline_3f1b, ThreeFOneBPlanner};
pub use spec::{factorizations, PlanKind, PlanSpec, Planner, SpecParseError, StageSpec};
pub use zero::{zero3, Zero3OffloadPlanner, Zero3Planner};

// The schedule vocabulary is part of the spec grammar (`sched{...}`
// tokens), so the plan layer re-exports it alongside `PlanSpec`.
pub use crate::schedule::{SchedName, SchedSpec, ScheduleSpec};

use crate::graph::{Graph, OpId, OpKind, PTensorId, TensorKind};
use crate::models::Model;
use crate::schedule::{dsl, DeviceId, Schedule};
use crate::trans::{op_trans, TransformAlgo};
use std::collections::HashMap;

/// Result of running an sProgram.
pub struct PlanOutput {
    pub graph: Graph,
    pub schedule: Schedule,
    pub name: String,
}

/// Plan-construction errors (transformation + scheduling phases).
pub type PlanResult = Result<PlanOutput, crate::trans::TransError>;

/// Split every op in `ops` along its batch dim into `k` pieces, returning
/// `pieces[orig_index][microbatch]`.
pub fn split_batch(g: &mut Graph, ops: &[OpId], k: usize) -> Vec<Vec<OpId>> {
    ops.iter()
        .map(|&op| {
            let dim = g
                .op(op)
                .signature
                .as_ref()
                .and_then(|s| s.batch.clone())
                .expect("op has no batch dim");
            op_trans(g, op, &TransformAlgo::split(&dim, k)).expect("batch split")
        })
        .collect()
}

/// The shared dp → micro → tp transform of one forward layer op — the
/// common prefix of the megatron and hetero planners. The op is split
/// `dp` ways along its batch dim, each replica into `k` micro-batches,
/// and each micro-batch into `tp` tensor-parallel shards along `tp_dim`
/// (replicated when the op declares no TP dim). Returns the shard lists
/// indexed `[dpg * k + mb]`.
///
/// `eff_split(dim_size, tp)` chooses the *effective* tensor-split factor,
/// which is where the two callers legitimately differ: megatron caps the
/// split by the dim's actual size and fills the group with replicas (early
/// Swin stages have fewer heads than tp), while hetero additionally
/// requires the factor to divide the stage width so the `idx % width`
/// device layout keeps corresponding producer/consumer shards aligned.
pub fn transform_layer_op(
    g: &mut Graph,
    op: OpId,
    dp: usize,
    k: usize,
    tp: usize,
    tp_dim: Option<&str>,
    eff_split: &dyn Fn(Option<usize>, usize) -> usize,
) -> Result<Vec<Vec<OpId>>, crate::trans::TransError> {
    let batch_dim = g
        .op(op)
        .signature
        .as_ref()
        .and_then(|s| s.batch.clone())
        .expect("fwd op without batch");
    let mut out = Vec::with_capacity(dp * k);
    for p in op_trans(g, op, &TransformAlgo::split(&batch_dim, dp))? {
        for m in op_trans(g, p, &TransformAlgo::split(&batch_dim, k))? {
            let shards = match tp_dim {
                Some(dim) if tp > 1 => {
                    let eff = eff_split(dim_size(g, m, dim), tp);
                    let mut sh = Vec::with_capacity(tp);
                    for piece in op_trans(g, m, &TransformAlgo::split(dim, eff))? {
                        if tp / eff > 1 {
                            sh.extend(op_trans(g, piece, &TransformAlgo::replicate(tp / eff))?);
                        } else {
                            sh.push(piece);
                        }
                    }
                    sh
                }
                _ if tp > 1 => op_trans(g, m, &TransformAlgo::replicate(tp))?,
                _ => vec![m],
            };
            out.push(shards);
        }
    }
    Ok(out)
}

/// Apply tensor-parallel splitting: each op splits `t` ways along its
/// model-declared TP dim, or replicates if it has none (layernorm etc).
/// Returns `shards[orig_index][t]`.
pub fn split_tp(
    g: &mut Graph,
    ops: &[OpId],
    tp_dim: &HashMap<OpId, &'static str>,
    origin_of: impl Fn(OpId) -> OpId,
    t: usize,
) -> Vec<Vec<OpId>> {
    ops.iter()
        .map(|&op| {
            let orig = origin_of(op);
            match tp_dim.get(&orig) {
                Some(dim) if t > 1 => op_trans(g, op, &TransformAlgo::split(dim, t))
                    .or_else(|_| op_trans(g, op, &TransformAlgo::replicate(t)))
                    .unwrap(),
                _ if t > 1 => op_trans(g, op, &TransformAlgo::replicate(t)).unwrap(),
                _ => vec![op],
            }
        })
        .collect()
}

/// Resolve an op's original (pre-transformation) id for map lookups.
pub fn origin(g: &Graph, op: OpId) -> OpId {
    g.op(op).origin.unwrap_or(op)
}

/// Re-shape optimizer ops to match the gradient shards autograd produced
/// (paper §5: optimizer ops adapt to the forward transformation). For each
/// weight, the original full-weight Adam op is replaced by one op per
/// distinct gradient *region*; value-split partials of the same region map
/// to a single op (the all-reduce happens at materialization).
///
/// Returns `weight pTensor -> (region ops, producer devices hint)`.
pub fn align_optimizers(g: &mut Graph) -> HashMap<PTensorId, Vec<OpId>> {
    let opt_ops: Vec<OpId> = g
        .live_ops()
        .filter(|o| o.kind == OpKind::Optimizer)
        .map(|o| o.id)
        .collect();
    // Distinct grad regions per gradient pTensor.
    let mut regions: HashMap<PTensorId, Vec<crate::graph::mask::Mask>> = HashMap::new();
    for o in g.live_ops() {
        for &ov in &o.outputs {
            let vt = g.vtensor(ov);
            if g.ptensor(vt.ptensor).kind == TensorKind::Gradient {
                let mut spatial = vt.mask.clone();
                spatial.vsplit = crate::graph::mask::VSplit::FULL;
                let rs = regions.entry(vt.ptensor).or_default();
                if !rs.iter().any(|m| m.same_region(&spatial)) {
                    rs.push(spatial);
                }
            }
        }
    }
    let mut out: HashMap<PTensorId, Vec<OpId>> = HashMap::new();
    for op_id in opt_ops {
        let old = g.op(op_id).clone();
        let grad_pt = g.vtensor(old.inputs[0]).ptensor;
        let w_pt = g.vtensor(old.outputs[0]).ptensor;
        let Some(regs) = regions.get(&grad_pt).cloned() else {
            // Weight received no gradient (e.g. no_grad passes only) —
            // keep the op as-is.
            out.entry(w_pt).or_default().push(op_id);
            continue;
        };
        if regs.len() == 1 && regs[0] == crate::graph::mask::Mask::full(regs[0].rank()) {
            out.entry(w_pt).or_default().push(op_id);
            continue; // already aligned
        }
        let old = g.remove_op(op_id);
        for (ri, reg) in regs.iter().enumerate() {
            let vol = reg.volume().to_f64();
            let mk = |g: &mut Graph, v: crate::graph::VTensorId| {
                let vt = g.vtensor(v).clone();
                g.add_vtensor(vt.ptensor, reg.clone())
            };
            let inputs: Vec<_> = old.inputs.iter().map(|&v| mk(g, v)).collect();
            let outputs: Vec<_> = old.outputs.iter().map(|&v| mk(g, v)).collect();
            let mut op = old.clone();
            op.id = 0;
            op.name = format!("{}#{ri}", old.name);
            op.inputs = inputs;
            op.outputs = outputs;
            op.flops = old.flops * vol;
            op.origin = Some(old.origin.unwrap_or(op_id));
            let id = g.insert_op(op);
            out.entry(w_pt).or_default().push(id);
        }
    }
    out
}

/// Assign every optimizer op to the device where (one of) its gradient
/// region's producers lives; if the grad partials come from several devices
/// (data-parallel replicas), the op is replicated across those devices so
/// each replica updates its local copy after the all-reduce — the standard
/// DP/Megatron optimizer placement.
pub fn assign_optimizers(g: &mut Graph, sched: &mut Schedule) {
    let opt_ops: Vec<OpId> = g
        .live_ops()
        .filter(|o| o.kind == OpKind::Optimizer && sched.device_of(o.id).is_none())
        .map(|o| o.id)
        .collect();
    // grad region -> producer devices.
    let mut producers: HashMap<(PTensorId, u64), Vec<DeviceId>> = HashMap::new();
    for o in g.live_ops() {
        if let Some(dev) = sched.device_of(o.id) {
            for &ov in &o.outputs {
                let vt = g.vtensor(ov);
                if g.ptensor(vt.ptensor).kind == TensorKind::Gradient {
                    producers
                        .entry((vt.ptensor, spatial_key(&vt.mask)))
                        .or_default()
                        .push(dev);
                }
            }
        }
    }
    for op_id in opt_ops {
        let gv = g.op(op_id).inputs[0];
        let vt = g.vtensor(gv).clone();
        let devs = producers
            .get(&(vt.ptensor, spatial_key(&vt.mask)))
            .cloned()
            .unwrap_or_default();
        let mut devs: Vec<DeviceId> =
            devs.into_iter().collect::<std::collections::HashSet<_>>().into_iter().collect();
        devs.sort_unstable();
        match devs.len() {
            0 => sched.assign(op_id, 0),
            1 => sched.assign(op_id, devs[0]),
            n => {
                let copies = op_trans(g, op_id, &TransformAlgo::replicate(n)).unwrap();
                for (c, d) in copies.into_iter().zip(devs) {
                    sched.assign(c, d);
                }
            }
        }
    }
}

fn spatial_key(m: &crate::graph::mask::Mask) -> u64 {
    m.region_hash()
}

/// Partition `layers` into `s` contiguous stages balanced by FLOPs.
pub fn balance_stages(g: &Graph, layers: &[Vec<OpId>], s: usize) -> Vec<Vec<usize>> {
    let costs: Vec<f64> = layers
        .iter()
        .map(|ops| ops.iter().map(|&o| g.op(o).flops).sum())
        .collect();
    let total: f64 = costs.iter().sum();
    let target = total / s as f64;
    let mut stages: Vec<Vec<usize>> = vec![Vec::new(); s];
    let mut acc = 0.0;
    let mut cur = 0usize;
    for (li, &c) in costs.iter().enumerate() {
        if acc + c / 2.0 > target * (cur + 1) as f64 && cur + 1 < s {
            cur += 1;
        }
        stages[cur].push(li);
        acc += c;
    }
    // No empty stages: steal from the left neighbour.
    for i in 1..s {
        if stages[i].is_empty() {
            let steal = stages[i - 1].pop().expect("layer starvation");
            stages[i].push(steal);
        }
    }
    stages
}

/// Chain tasks in 1F1B order for one stage (paper Fig. 1 bottom): with `s`
/// the stage index (0-based), `n_stages` total and `k` micro-batches, the
/// stage runs `warmup = n_stages - s` forwards, then alternates 1B1F, then
/// drains. Emits `op-order` edges between consecutive tasks via their
/// representative ops. `fwd[m]` / `bwd[m]` are the (first, last) ops of
/// micro-batch `m`'s forward / backward work on this stage.
///
/// Since the schedule DSL landed this is a thin wrapper over
/// [`dsl::row_1f1b`] + [`dsl::lower_row`]: the row builder emits the same
/// slot sequence this function used to hand-roll, so the generated edges
/// are bitwise-identical (pinned by tests in `schedule::dsl`).
pub fn order_1f1b(
    sched: &mut Schedule,
    s: usize,
    n_stages: usize,
    k: usize,
    fwd: &[(OpId, OpId)],
    bwd: &[(OpId, OpId)],
) {
    let row = dsl::row_1f1b(s, n_stages, k);
    dsl::lower_row(sched, s, &row, fwd, bwd, &[]).expect("1f1b row spans k micro-batches");
}

/// GPipe order (paper Fig. 1 middle): all forwards, then all backwards.
/// Thin wrapper over [`dsl::row_sync`] + [`dsl::lower_row`] (same edges as
/// the legacy hand-rolled loop).
pub fn order_gpipe(sched: &mut Schedule, fwd: &[(OpId, OpId)], bwd: &[(OpId, OpId)]) {
    let row = dsl::row_sync(fwd.len().max(bwd.len()));
    dsl::lower_row(sched, 0, &row, fwd, bwd, &[]).expect("sync row spans all micro-batches");
}

/// Concrete size of a signature dim on an op (looked up through its
/// input/output vTensor shapes). `None` if the dim is absent.
pub fn dim_size(g: &Graph, op: OpId, dim: &str) -> Option<usize> {
    let o = g.op(op);
    let sig = o.signature.as_ref()?;
    for (i, &v) in o.inputs.iter().enumerate() {
        if let Some(axis) = sig.input_axis(i, dim) {
            return Some(g.vtensor_shape(v)[axis]);
        }
    }
    for (i, &v) in o.outputs.iter().enumerate() {
        if let Some(axis) = sig.output_axis(i, dim) {
            return Some(g.vtensor_shape(v)[axis]);
        }
    }
    None
}

/// Largest divisor of `size` that is <= `want` (the feasible split factor).
pub fn feasible_split(size: usize, want: usize) -> usize {
    (1..=want.min(size)).rev().find(|&c| size % c == 0).unwrap_or(1)
}

/// First/last ops of a set in graph-id order (the data-flow order within a
/// micro-batch's stage work).
pub fn span(ops: &[OpId]) -> (OpId, OpId) {
    let mut v = ops.to_vec();
    v.sort_unstable();
    (*v.first().unwrap(), *v.last().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::gpt3;

    #[test]
    fn transform_layer_op_yields_dp_x_micro_lists_of_tp_shards() {
        let mut model = gpt3(0, 8, 256);
        let op = model.layers[1][0]; // first transformer-layer op
        let tp_dim = model.tp_dim.get(&op).copied();
        let g = &mut model.graph;
        let cap = |sz: Option<usize>, tp: usize| sz.map(|s| feasible_split(s, tp)).unwrap_or(1);
        let lists = transform_layer_op(g, op, 2, 2, 2, tp_dim, &cap).unwrap();
        assert_eq!(lists.len(), 4, "dp=2 x micro=2 shard lists");
        for l in &lists {
            assert_eq!(l.len(), 2, "tp=2 shards per micro-batch");
        }
        // All pieces are distinct live ops.
        let mut all: Vec<OpId> = lists.iter().flatten().copied().collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 8);
    }

    #[test]
    fn transform_layer_op_without_tp_is_plain_dp_micro() {
        let mut model = gpt3(0, 4, 256);
        let op = model.layers[1][0];
        let g = &mut model.graph;
        let cap = |sz: Option<usize>, tp: usize| sz.map(|s| feasible_split(s, tp)).unwrap_or(1);
        let lists = transform_layer_op(g, op, 1, 4, 1, None, &cap).unwrap();
        assert_eq!(lists.len(), 4);
        assert!(lists.iter().all(|l| l.len() == 1));
    }
}
