//! The declarative plan layer: a [`PlanSpec`] says *what* parallelization to
//! apply (plan kind + dp/pp/tp degrees + micro-batch / shard counts +
//! offload/recompute flags) without running anything; the [`Planner`] trait
//! turns a spec into a concrete transformed graph + schedule. Every sProgram
//! implements `Planner` and registers itself in [`super::registry`], giving
//! the CLI, the benches and the search engine ([`crate::search`]) one
//! uniform way to name, enumerate and build plans — the string-matched
//! constructor calls that used to live in three separate binaries all route
//! through here now.

use super::PlanResult;
use crate::cost::Cluster;
use crate::models::Model;
use crate::schedule::{SchedName, SchedSpec};

/// Which sProgram family a [`PlanSpec`] selects.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum PlanKind {
    /// Algorithm 1 data parallelism.
    Dp,
    /// Pure (Shoeybi-style) tensor parallelism: the megatron grid, pp = 1.
    Tp,
    /// The Megatron dp × pp × tp grid with 1F1B ordering.
    Megatron,
    /// The megatron grid under GPipe ordering.
    GPipe,
    /// DeepSpeed ZeRO-3 optimizer/gradient/weight sharding.
    Zero3,
    /// ZeRO-3 with the optimizer offloaded to the host.
    Zero3Offload,
    /// The paper's co-located shards + recompute plan (Fig. 3).
    Coshard,
    /// The paper's interlaced pipeline for mBART (Algorithm 2).
    Interlaced,
    /// The paper's 3F1B recycling pipeline for AlphaFold2 (Fig. 2).
    ThreeFOneB,
    /// Dynamic Axial Parallelism + DP (the FastFold baseline).
    Dap,
    /// Heterogeneous pipeline: each stage applies its own intra-stage
    /// transformation ([`StageSpec`]) — the §5 / Fig. 18 plan family that
    /// homogeneous grids cannot express.
    Hetero,
}

impl PlanKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            PlanKind::Dp => "dp",
            PlanKind::Tp => "tp",
            PlanKind::Megatron => "megatron",
            PlanKind::GPipe => "gpipe",
            PlanKind::Zero3 => "zero3",
            PlanKind::Zero3Offload => "zero3-offload",
            PlanKind::Coshard => "coshard",
            PlanKind::Interlaced => "interlaced",
            PlanKind::ThreeFOneB => "3f1b",
            PlanKind::Dap => "dap",
            PlanKind::Hetero => "hetero",
        }
    }

    /// Parse a CLI/bench plan name (aliases included).
    pub fn parse(name: &str) -> Option<PlanKind> {
        Some(match name {
            "dp" => PlanKind::Dp,
            "tp" => PlanKind::Tp,
            "megatron" | "1f1b" => PlanKind::Megatron,
            "gpipe" => PlanKind::GPipe,
            "zero3" => PlanKind::Zero3,
            "zero3-offload" | "zero3_offload" => PlanKind::Zero3Offload,
            "coshard" => PlanKind::Coshard,
            "interlaced" => PlanKind::Interlaced,
            "3f1b" => PlanKind::ThreeFOneB,
            "dap" | "dap+dp" => PlanKind::Dap,
            "hetero" => PlanKind::Hetero,
            _ => return None,
        })
    }
}

impl std::fmt::Display for PlanKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Intra-stage transformation choice for ONE pipeline stage of a
/// heterogeneous plan ([`PlanKind::Hetero`]). A stage occupies `tp`
/// consecutive devices; `shards > 1` selects co-located sequential
/// co-sharding (with recompute, as in [`PlanKind::Coshard`]) and requires
/// `tp == 1`; `recompute` re-executes the stage's forward ops during
/// backward; `offload` moves the stage's optimizer ops to the host.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct StageSpec {
    /// Tensor-parallel width of the stage (devices it occupies).
    pub tp: usize,
    /// Co-located sequential shard count (coshard-style; needs `tp == 1`).
    pub shards: usize,
    /// Per-layer activation recompute within the stage.
    pub recompute: bool,
    /// Offload this stage's optimizer state to the host over PCIe.
    pub offload: bool,
    /// Explicit layer count for this stage (`0` = auto FLOP-balanced
    /// split). When every stage of a hetero spec sets it, the partition
    /// replaces [`crate::plans::balance_stages`] — this is how the MCMC
    /// refinement's stage-boundary moves re-materialize.
    pub layers: usize,
}

impl Default for StageSpec {
    fn default() -> Self {
        StageSpec { tp: 1, shards: 1, recompute: false, offload: false, layers: 0 }
    }
}

impl StageSpec {
    /// A plain tensor-parallel stage of the given width.
    pub fn tp(width: usize) -> StageSpec {
        StageSpec { tp: width.max(1), ..StageSpec::default() }
    }

    /// A single-device co-shard stage of the given shard count.
    pub fn coshard(shards: usize) -> StageSpec {
        StageSpec { shards: shards.max(1), ..StageSpec::default() }
    }

    /// Devices this stage occupies (its tensor-parallel width).
    pub fn width(&self) -> usize {
        self.tp.max(1)
    }

    /// Compact label: width + layer/shard/flag suffixes, e.g. `tp4`,
    /// `x8`, `tp2l3r` (`l{n}` = explicit layer count).
    pub fn label(&self) -> String {
        let mut s = format!("tp{}", self.tp.max(1));
        if self.shards.max(1) > 1 {
            s = format!("x{}", self.shards);
        }
        if self.layers > 0 {
            s.push_str(&format!("l{}", self.layers));
        }
        if self.recompute {
            s.push('r');
        }
        if self.offload {
            s.push('o');
        }
        s
    }

    /// Parse one stage label back into a [`StageSpec`] — the exact inverse
    /// of [`StageSpec::label`] for every valid stage (`tp > 1` implies
    /// `shards == 1`, so the `x{n}` form losing the width is lossless).
    pub fn parse(tok: &str) -> Result<StageSpec, SpecParseError> {
        let bad = || SpecParseError::BadStage(tok.to_string());
        let mut st = StageSpec::default();
        let mut rest = tok;
        // Flag suffixes (`r` recompute, `o` offload) — digits can't collide.
        loop {
            if let Some(r) = rest.strip_suffix('o') {
                if st.offload {
                    return Err(bad());
                }
                st.offload = true;
                rest = r;
            } else if let Some(r) = rest.strip_suffix('r') {
                if st.recompute {
                    return Err(bad());
                }
                st.recompute = true;
                rest = r;
            } else {
                break;
            }
        }
        let num = |s: &str| match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(bad()),
        };
        // Explicit layer-count suffix `l{n}` (the base `tp{n}`/`x{n}` forms
        // contain no 'l', so the rightmost 'l' is unambiguous).
        if let Some(i) = rest.rfind('l') {
            st.layers = num(&rest[i + 1..])?;
            rest = &rest[..i];
        }
        if let Some(n) = rest.strip_prefix("tp") {
            st.tp = num(n)?;
        } else if let Some(n) = rest.strip_prefix('x') {
            st.shards = num(n)?;
        } else {
            return Err(bad());
        }
        Ok(st)
    }
}

/// Typed error of [`PlanSpec::parse`] / [`StageSpec::parse`]. Malformed
/// input is always a value of this enum, never a panic — the parser is fed
/// CLI arguments and round-trip fuzz input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpecParseError {
    /// The input was empty (no plan-kind token).
    Empty,
    /// The first token is not a registered plan-kind name.
    UnknownKind(String),
    /// A degree/flag token is not part of the label grammar.
    BadToken(String),
    /// A stage token inside `[...]` is malformed.
    BadStage(String),
    /// A `sched{...}` token names no known schedule and is not a
    /// well-formed explicit row encoding.
    BadSched(String),
}

impl std::fmt::Display for SpecParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecParseError::Empty => write!(f, "empty plan spec"),
            SpecParseError::UnknownKind(k) => write!(f, "unknown plan kind '{k}'"),
            SpecParseError::BadToken(t) => write!(f, "bad spec token '{t}'"),
            SpecParseError::BadStage(t) => write!(f, "bad stage spec '{t}'"),
            SpecParseError::BadSched(t) => write!(f, "bad schedule token '{t}'"),
        }
    }
}

impl std::error::Error for SpecParseError {}

/// Declarative description of one parallelization plan instance. Degrees
/// default to 1 and flags to off; each planner reads the fields it uses.
#[derive(Clone, PartialEq, Debug)]
pub struct PlanSpec {
    pub kind: PlanKind,
    /// Data-parallel replicas.
    pub dp: usize,
    /// Pipeline stages (interlaced/3f1b: stages == devices).
    pub pp: usize,
    /// Tensor-parallel width (for [`PlanKind::Dap`]: the axial width).
    pub tp: usize,
    /// Micro-batches per data-parallel replica.
    pub micro: usize,
    /// Co-located shard count (coshard only).
    pub shards: usize,
    /// ZeRO: offload optimizer state to the host over PCIe.
    pub offload: bool,
    /// Coshard: ZeRO-style optimizer sharding across the DP group.
    pub zero_shard: bool,
    /// Interlaced: per-layer recompute.
    pub recompute: bool,
    /// Interlaced: coarse IL-block recompute barrier (Fig. 15 baseline).
    pub block_recompute: bool,
    /// Coshard: restrict co-sharding to the first N layers (`None` = all).
    pub coshard_layers: Option<usize>,
    /// Pipeline schedule — the fourth search axis. `None` keeps the
    /// planner's historical default (1F1B for megatron/hetero, sync for
    /// GPipe); `Some` selects a named discipline or explicit slot rows
    /// (see [`crate::schedule::dsl`]). Labeled as a `sched{...}` token.
    pub sched: Option<SchedSpec>,
    /// Hetero: per-stage intra-stage transformations. `Some` implies
    /// `kind == Hetero` and `pp == stages.len()`; the stage widths replace
    /// `tp` in the device count.
    pub stages: Option<Vec<StageSpec>>,
}

impl Default for PlanSpec {
    fn default() -> Self {
        PlanSpec {
            kind: PlanKind::Dp,
            dp: 1,
            pp: 1,
            tp: 1,
            micro: 1,
            shards: 1,
            offload: false,
            zero_shard: false,
            recompute: false,
            block_recompute: false,
            coshard_layers: None,
            sched: None,
            stages: None,
        }
    }
}

impl PlanSpec {
    /// All-defaults spec of the given kind (fill fields with struct update).
    pub fn new(kind: PlanKind) -> PlanSpec {
        PlanSpec { kind, ..PlanSpec::default() }
    }

    /// A heterogeneous-pipeline spec from per-stage choices. `pp` is pinned
    /// to `stages.len()` so arity can never drift from the stage list.
    pub fn hetero(stages: Vec<StageSpec>, micro: usize) -> PlanSpec {
        PlanSpec::hetero_dp(1, stages, micro)
    }

    /// [`PlanSpec::hetero`] replicated `dp` ways: `dp` identical copies of
    /// the per-stage pipeline, gradients synchronized across replicas every
    /// iteration (RVD-decomposed when the dp groups span servers). The
    /// spec occupies `dp * sum(stage widths)` devices.
    pub fn hetero_dp(dp: usize, stages: Vec<StageSpec>, micro: usize) -> PlanSpec {
        PlanSpec {
            kind: PlanKind::Hetero,
            dp: dp.max(1),
            pp: stages.len().max(1),
            micro: micro.max(1),
            stages: Some(stages),
            ..PlanSpec::default()
        }
    }

    /// Devices the spec occupies: `dp * pp * tp` for homogeneous plans,
    /// `dp * sum(stage widths)` for heterogeneous ones.
    pub fn devices(&self) -> usize {
        if let Some(stages) = &self.stages {
            let width: usize = stages.iter().map(|s| s.width()).sum();
            return self.dp.max(1) * width.max(1);
        }
        self.dp.max(1) * self.pp.max(1) * self.tp.max(1)
    }

    /// Optimistic lower bound on per-device *static* bytes. Full static
    /// state is 4× the weight bytes (weights + grads + two Adam moments),
    /// divided by whatever sharding the spec guarantees. Used by the
    /// search's memory-capacity pruning: a spec whose lower bound already
    /// exceeds device memory cannot run, so it is never built.
    pub fn static_bytes_lower_bound(&self, weight_bytes: u64) -> u64 {
        let w = weight_bytes;
        let full = 4 * w;
        let d = self.devices().max(1) as u64;
        match self.kind {
            PlanKind::Dp | PlanKind::Dap => full,
            PlanKind::Tp | PlanKind::Megatron | PlanKind::GPipe => {
                full / (self.pp.max(1) * self.tp.max(1)) as u64
            }
            PlanKind::Zero3 => w + 3 * w / d,
            // Offload moves optimizer state to host memory; only the
            // weights are guaranteed resident on the device.
            PlanKind::Zero3Offload => w,
            PlanKind::Coshard => {
                if self.zero_shard {
                    w + 3 * w / d
                } else {
                    full
                }
            }
            PlanKind::Interlaced | PlanKind::ThreeFOneB => full / self.pp.max(1) as u64,
            // Per stage: ~1/pp of the weights (FLOP-balanced stages of a
            // uniform-layer model), split across the stage's tp width; an
            // offloaded stage is only guaranteed to keep the weights
            // resident. The bound is the busiest stage's device.
            PlanKind::Hetero => {
                let Some(stages) = &self.stages else { return full };
                let pp = stages.len().max(1) as u64;
                stages
                    .iter()
                    .map(|s| {
                        let share = if s.offload { w / pp } else { full / pp };
                        share / s.width() as u64
                    })
                    .max()
                    .unwrap_or(full)
            }
        }
    }

    /// Compact human label: kind + the non-unit degrees and set flags.
    /// Complete — every non-default field appears — so
    /// [`PlanSpec::parse`] round-trips it exactly (covered by the spec
    /// property tests).
    pub fn label(&self) -> String {
        let mut s = self.kind.as_str().to_string();
        if self.dp > 1 {
            s.push_str(&format!(" dp{}", self.dp));
        }
        if self.pp > 1 {
            s.push_str(&format!(" pp{}", self.pp));
        }
        if self.tp > 1 {
            s.push_str(&format!(" tp{}", self.tp));
        }
        if self.micro > 1 {
            s.push_str(&format!(" k{}", self.micro));
        }
        if self.shards > 1 {
            s.push_str(&format!(" x{}", self.shards));
        }
        if self.offload {
            s.push_str(" offload");
        }
        if self.zero_shard {
            s.push_str(" zero");
        }
        if self.recompute {
            s.push_str(" rc");
        }
        if self.block_recompute {
            s.push_str(" block");
        }
        if let Some(n) = self.coshard_layers {
            s.push_str(&format!(" L{n}"));
        }
        if let Some(sched) = &self.sched {
            s.push(' ');
            s.push_str(&sched.token());
        }
        if let Some(stages) = &self.stages {
            let inner: Vec<String> = stages.iter().map(|st| st.label()).collect();
            s.push_str(&format!(" [{}]", inner.join("|")));
        }
        s
    }

    /// Parse a [`PlanSpec::label`]-formatted string back into a spec — the
    /// format → parse round-trip that lets labels in reports, baselines and
    /// CLI flags name exact grid points. Grammar (whitespace-separated):
    ///
    /// ```text
    /// <kind> [dpN] [ppN] [tpN] [kN] [xN] [offload] [zero] [rc] [block]
    ///        [LN] [sched{name|rows}] [[stage|stage|...]]
    /// ```
    ///
    /// Absent tokens keep their defaults (degree 1 / flag off). A stage
    /// list implies `pp = stages.len()` unless an explicit `ppN` token
    /// disagrees — that inconsistency is preserved so
    /// [`crate::search::feasibility`] can reject it with the typed
    /// `StageArity` error rather than the parser silently repairing it.
    /// Malformed input returns a typed [`SpecParseError`]; this function
    /// never panics.
    pub fn parse(s: &str) -> Result<PlanSpec, SpecParseError> {
        let mut toks = s.split_whitespace();
        let kind_tok = toks.next().ok_or(SpecParseError::Empty)?;
        let kind = PlanKind::parse(kind_tok)
            .ok_or_else(|| SpecParseError::UnknownKind(kind_tok.to_string()))?;
        let mut spec = PlanSpec::new(kind);
        let mut explicit_pp = false;
        for tok in toks {
            if let Some(inner) = tok.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
                let stages: Result<Vec<StageSpec>, SpecParseError> =
                    inner.split('|').map(StageSpec::parse).collect();
                spec.stages = Some(stages?);
                continue;
            }
            let num = |rest: &str| match rest.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(n),
                _ => Err(SpecParseError::BadToken(tok.to_string())),
            };
            match tok {
                "offload" => spec.offload = true,
                "zero" => spec.zero_shard = true,
                "rc" => spec.recompute = true,
                "block" => spec.block_recompute = true,
                _ => {
                    if let Some(r) = tok.strip_prefix("dp") {
                        spec.dp = num(r)?;
                    } else if let Some(r) = tok.strip_prefix("pp") {
                        spec.pp = num(r)?;
                        explicit_pp = true;
                    } else if let Some(r) = tok.strip_prefix("tp") {
                        spec.tp = num(r)?;
                    } else if let Some(r) = tok.strip_prefix('k') {
                        spec.micro = num(r)?;
                    } else if let Some(r) = tok.strip_prefix('x') {
                        spec.shards = num(r)?;
                    } else if let Some(r) = tok.strip_prefix('L') {
                        spec.coshard_layers = Some(num(r)?);
                    } else if tok.starts_with("sched{") {
                        spec.sched = Some(
                            SchedSpec::parse_token(tok)
                                .ok_or_else(|| SpecParseError::BadSched(tok.to_string()))?,
                        );
                    } else {
                        return Err(SpecParseError::BadToken(tok.to_string()));
                    }
                }
            }
        }
        if let Some(stages) = &spec.stages {
            if !explicit_pp {
                spec.pp = stages.len().max(1);
            }
        }
        Ok(spec)
    }
}

impl std::fmt::Display for PlanSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// All ordered `(dp, pp, tp)` triples with `dp * pp * tp == n` — the
/// megatron-family search grid.
pub fn factorizations(n: usize) -> Vec<(usize, usize, usize)> {
    let n = n.max(1);
    let mut out = Vec::new();
    for dp in 1..=n {
        if n % dp != 0 {
            continue;
        }
        let rest = n / dp;
        for pp in 1..=rest {
            if rest % pp != 0 {
                continue;
            }
            out.push((dp, pp, rest / pp));
        }
    }
    out
}

/// A named, registered sProgram: applicability test + spec-driven builder.
/// `Sync` so trait objects can live in the static registry and be shared by
/// the search's worker threads.
pub trait Planner: Sync {
    /// The spec kind this planner builds.
    fn kind(&self) -> PlanKind;

    /// Registry / CLI name.
    fn name(&self) -> &'static str {
        self.kind().as_str()
    }

    /// One-line description for `superscaler plans`.
    fn description(&self) -> &'static str;

    /// Whether the plan is expressible on `model` at all (structural
    /// requirements such as recycled passes or tagged embedding layers).
    fn applicable(&self, model: &Model) -> bool;

    /// The canonical spec for `gpus` devices (the CLI's defaults).
    fn default_spec(&self, gpus: usize, micro: usize) -> PlanSpec;

    /// Candidate specs for the search grid on this model + cluster. May
    /// include infeasible points; [`crate::search::feasibility`] prunes
    /// them before anything is built.
    fn candidates(&self, model: &Model, cluster: &Cluster) -> Vec<PlanSpec>;

    /// Transform + schedule the model according to `spec`.
    ///
    /// The model is **borrowed**: one probe model built per search is
    /// shared read-only across every candidate build (and across the
    /// worker threads — the trait is `Sync` and so is [`Model`]). A
    /// planner clones only the sub-structures it actually mutates — in
    /// practice the graph, which every transformation rewrites — and reads
    /// the layer/tp-dim/embedding metadata straight through the borrow.
    /// This is what makes per-candidate evaluation zero-rebuild: nothing
    /// ever reconstructs the model from its builder inside a search.
    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in [
            PlanKind::Dp,
            PlanKind::Tp,
            PlanKind::Megatron,
            PlanKind::GPipe,
            PlanKind::Zero3,
            PlanKind::Zero3Offload,
            PlanKind::Coshard,
            PlanKind::Interlaced,
            PlanKind::ThreeFOneB,
            PlanKind::Dap,
            PlanKind::Hetero,
        ] {
            assert_eq!(PlanKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(PlanKind::parse("1f1b"), Some(PlanKind::Megatron));
        assert_eq!(PlanKind::parse("nope"), None);
    }

    #[test]
    fn devices_is_degree_product() {
        let s = PlanSpec { dp: 2, pp: 2, tp: 2, ..PlanSpec::new(PlanKind::Megatron) };
        assert_eq!(s.devices(), 8);
        assert_eq!(PlanSpec::new(PlanKind::Dp).devices(), 1);
    }

    #[test]
    fn factorizations_cover_and_multiply_out() {
        let f = factorizations(8);
        assert_eq!(f.len(), 10);
        assert!(f.iter().all(|&(a, b, c)| a * b * c == 8));
        assert!(f.contains(&(1, 8, 1)));
        assert!(f.contains(&(2, 2, 2)));
        assert_eq!(factorizations(1), vec![(1, 1, 1)]);
    }

    #[test]
    fn hetero_devices_sum_stage_widths() {
        let s = PlanSpec::hetero(vec![StageSpec::tp(4), StageSpec::tp(2), StageSpec::tp(2)], 4);
        assert_eq!(s.devices(), 8);
        assert_eq!(s.pp, 3);
        let lbl = s.label();
        assert!(lbl.contains("hetero") && lbl.contains("[tp4|tp2|tp2]"), "{lbl}");
    }

    #[test]
    fn hetero_memory_bound_tracks_busiest_stage() {
        let w = 1 << 30;
        // Two stages: tp4 holds 4W/2/4 = W/2; tp1 holds 4W/2 = 2W -> bound 2W.
        let s = PlanSpec::hetero(vec![StageSpec::tp(4), StageSpec::tp(1)], 4);
        assert_eq!(s.static_bytes_lower_bound(w), 2 * w);
        // Offloading the narrow stage drops it to weights-only: W/2.
        let off = StageSpec { offload: true, ..StageSpec::tp(1) };
        let s = PlanSpec::hetero(vec![StageSpec::tp(4), off], 4);
        assert_eq!(s.static_bytes_lower_bound(w), w / 2);
    }

    #[test]
    fn hetero_dp_multiplies_device_count() {
        let s = PlanSpec::hetero_dp(2, vec![StageSpec::tp(2), StageSpec::tp(2)], 4);
        assert_eq!(s.devices(), 8);
        assert_eq!(s.dp, 2);
        let lbl = s.label();
        assert!(lbl.contains("dp2") && lbl.contains("[tp2|tp2]"), "{lbl}");
    }

    #[test]
    fn spec_label_parse_roundtrip_examples() {
        let cases = [
            PlanSpec::new(PlanKind::Dp),
            PlanSpec { dp: 4, ..PlanSpec::new(PlanKind::Dp) },
            PlanSpec { dp: 2, pp: 2, tp: 2, micro: 8, ..PlanSpec::new(PlanKind::Megatron) },
            PlanSpec { dp: 8, offload: true, ..PlanSpec::new(PlanKind::Zero3Offload) },
            PlanSpec {
                shards: 4,
                zero_shard: true,
                coshard_layers: Some(3),
                ..PlanSpec::new(PlanKind::Coshard)
            },
            PlanSpec {
                pp: 4,
                recompute: true,
                block_recompute: true,
                micro: 4,
                ..PlanSpec::new(PlanKind::Interlaced)
            },
            PlanSpec {
                dp: 2,
                pp: 4,
                micro: 8,
                sched: Some(SchedSpec::Named(SchedName::ZeroBubble)),
                ..PlanSpec::new(PlanKind::Megatron)
            },
            PlanSpec {
                pp: 2,
                micro: 2,
                sched: Some(SchedSpec::Explicit(crate::schedule::ScheduleSpec::one_f_one_b(2, 2))),
                ..PlanSpec::new(PlanKind::Megatron)
            },
            PlanSpec::hetero(vec![StageSpec::tp(4), StageSpec::coshard(8)], 4),
            PlanSpec::hetero_dp(
                2,
                vec![
                    StageSpec { recompute: true, ..StageSpec::tp(2) },
                    StageSpec { offload: true, ..StageSpec::tp(1) },
                    StageSpec { recompute: true, ..StageSpec::coshard(4) },
                ],
                2,
            ),
        ];
        for spec in cases {
            let lbl = spec.label();
            let back = PlanSpec::parse(&lbl).unwrap_or_else(|e| panic!("parse '{lbl}': {e}"));
            assert_eq!(back, spec, "round-trip through '{lbl}'");
        }
    }

    #[test]
    fn spec_parse_rejects_malformed_with_typed_errors() {
        assert_eq!(PlanSpec::parse(""), Err(SpecParseError::Empty));
        assert_eq!(PlanSpec::parse("   "), Err(SpecParseError::Empty));
        assert_eq!(
            PlanSpec::parse("warp dp2"),
            Err(SpecParseError::UnknownKind("warp".into()))
        );
        assert_eq!(
            PlanSpec::parse("megatron qq7"),
            Err(SpecParseError::BadToken("qq7".into()))
        );
        assert_eq!(
            PlanSpec::parse("megatron dp"),
            Err(SpecParseError::BadToken("dp".into()))
        );
        assert_eq!(
            PlanSpec::parse("megatron dp0"),
            Err(SpecParseError::BadToken("dp0".into()))
        );
        assert_eq!(
            PlanSpec::parse("hetero [tp2|zz]"),
            Err(SpecParseError::BadStage("zz".into()))
        );
        assert_eq!(
            PlanSpec::parse("megatron pp2 k2 sched{nope}"),
            Err(SpecParseError::BadSched("sched{nope}".into()))
        );
        assert_eq!(
            PlanSpec::parse("megatron sched{f0b0;}"),
            Err(SpecParseError::BadSched("sched{f0b0;}".into()))
        );
        assert_eq!(
            PlanSpec::parse("megatron sched{f0b0"),
            Err(SpecParseError::BadSched("sched{f0b0".into()))
        );
        // Canonical named tokens round-trip; aliases normalize.
        let s = PlanSpec::parse("megatron pp2 k4 sched{zb}").unwrap();
        assert_eq!(s.sched, Some(SchedSpec::Named(SchedName::ZeroBubble)));
        assert_eq!(PlanSpec::parse(&s.label()).unwrap(), s);
        let alias = PlanSpec::parse("megatron pp2 k4 sched{gpipe}").unwrap();
        assert_eq!(alias.sched, Some(SchedSpec::Named(SchedName::Sync)));
        // An explicit pp disagreeing with the stage arity parses — the
        // typed StageArity rejection is feasibility's job, not the parser's.
        let s = PlanSpec::parse("hetero pp3 [tp2|tp2]").unwrap();
        assert_eq!(s.pp, 3);
        assert_eq!(s.stages.as_ref().unwrap().len(), 2);
    }

    #[test]
    fn prop_spec_label_parse_roundtrip() {
        crate::util::prop::check("spec-roundtrip", 300, |g| {
            let kinds = [
                PlanKind::Dp,
                PlanKind::Tp,
                PlanKind::Megatron,
                PlanKind::GPipe,
                PlanKind::Zero3,
                PlanKind::Zero3Offload,
                PlanKind::Coshard,
                PlanKind::Interlaced,
                PlanKind::ThreeFOneB,
                PlanKind::Dap,
                PlanKind::Hetero,
            ];
            let kind = *g.rng.choose(&kinds);
            let mut spec = PlanSpec::new(kind);
            spec.dp = g.pow2(8);
            spec.micro = g.pow2(16);
            spec.offload = g.bool();
            spec.zero_shard = g.bool();
            spec.recompute = g.bool();
            spec.block_recompute = g.bool();
            if g.bool() {
                spec.coshard_layers = Some(g.int(1, 9));
            }
            if kind == PlanKind::Hetero {
                let n = g.int(1, 5);
                let stages: Vec<StageSpec> = (0..n)
                    .map(|_| {
                        let mut st = if g.bool() {
                            StageSpec::tp(g.pow2(8))
                        } else {
                            StageSpec::coshard(*g.rng.choose(&[2usize, 4, 8]))
                        };
                        st.recompute = g.bool();
                        st.offload = g.bool();
                        st.layers = if g.bool() { g.int(1, 6) } else { 0 };
                        st
                    })
                    .collect();
                spec.pp = stages.len();
                spec.stages = Some(stages);
            } else {
                spec.pp = g.pow2(8);
                spec.tp = g.pow2(8);
                spec.shards = g.pow2(8);
            }
            if g.bool() {
                let names = [
                    SchedName::Sync,
                    SchedName::OneFOneB,
                    SchedName::Interlaced,
                    SchedName::ZeroBubble,
                    SchedName::VShape,
                ];
                spec.sched = Some(if g.bool() {
                    SchedSpec::Named(*g.rng.choose(&names))
                } else {
                    let rows = g.rng.choose(&names).rows(g.int(1, 5), g.int(1, 6));
                    SchedSpec::Explicit(rows)
                });
            }
            let lbl = spec.label();
            match PlanSpec::parse(&lbl) {
                Ok(back) if back == spec => Ok(()),
                Ok(back) => Err(format!("'{lbl}' parsed to {back:?}, wanted {spec:?}")),
                Err(e) => Err(format!("'{lbl}' failed to parse: {e}")),
            }
        });
    }

    #[test]
    fn prop_spec_parse_never_panics_on_garbage() {
        crate::util::prop::check("spec-parse-fuzz", 500, |g| {
            const ALPHABET: &[u8] = b"dpthexkol 0123456789[]|rLzc-sfbw{};";
            let len = g.int(0, 24);
            let s: String = (0..len)
                .map(|_| ALPHABET[g.int(0, ALPHABET.len())] as char)
                .collect();
            // Any outcome is fine — the property is "returns, never panics",
            // and Ok results must round-trip their own label.
            if let Ok(spec) = PlanSpec::parse(&s) {
                let lbl = spec.label();
                if PlanSpec::parse(&lbl) != Ok(spec) {
                    return Err(format!("accepted '{s}' but label '{lbl}' diverges"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn memory_lower_bound_reflects_sharding() {
        let w = 1 << 30;
        let dp = PlanSpec { dp: 8, ..PlanSpec::new(PlanKind::Dp) };
        let mg = PlanSpec { pp: 4, tp: 2, ..PlanSpec::new(PlanKind::Megatron) };
        let z = PlanSpec { dp: 8, ..PlanSpec::new(PlanKind::Zero3) };
        assert_eq!(dp.static_bytes_lower_bound(w), 4 * w);
        assert_eq!(mg.static_bytes_lower_bound(w), 4 * w / 8);
        assert!(z.static_bytes_lower_bound(w) < 2 * w);
    }
}
