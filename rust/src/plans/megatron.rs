//! Megatron-LM-style hierarchical parallelism: `dp × pp × tp` with GPipe or
//! 1F1B micro-batch ordering — the paper's main empirical baseline (§6.1).
//! Layers are grouped into FLOP-balanced pipeline stages; within a stage,
//! every op splits along its model-declared tensor-parallel dim; the whole
//! grid replicates `dp` ways with gradient all-reduce.
//!
//! With `pp == 1, tp == 1` this degenerates to Algorithm 1's data
//! parallelism; with `pp == 1` it is pure (Shoeybi-style) tensor
//! parallelism — the same sProgram covers the whole empirical family, which
//! is the point of the unified abstraction.

use super::*;
use crate::trans::autograd;

/// Micro-batch ordering discipline for the pipeline.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PipeOrder {
    GPipe,
    OneFOneB,
}

/// Build the Megatron plan. Requires `dp * pp * tp` devices; `k` is the
/// micro-batch count per dp replica. The model is borrowed: the graph is
/// cloned (it is what the transformation rewrites); layer lists and TP-dim
/// metadata are read through the borrow.
pub fn megatron(
    model: &Model,
    dp: usize,
    pp: usize,
    tp: usize,
    k: usize,
    order: PipeOrder,
) -> PlanResult {
    let tp_dim = &model.tp_dim;
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();
    let stages = balance_stages(g, &model.layers, pp);
    let stage_of_layer: HashMap<usize, usize> = stages
        .iter()
        .enumerate()
        .flat_map(|(s, ls)| ls.iter().map(move |&l| (l, s)))
        .collect();
    let device = |dpg: usize, s: usize, t: usize| (dpg * pp + s) * tp + t;

    // ---- transformation: dp split -> K micro-batches -> tp shards ----
    // pieces[(layer_idx, dpg, mb)] = Vec<OpId> (tp shards of every op).
    // The split factor is capped by the dim's actual size (early Swin
    // stages have fewer heads than tp), replicas filling the rest.
    let cap_by_size = |sz: Option<usize>, tp: usize| sz.map(|s| feasible_split(s, tp)).unwrap_or(1);
    let mut pieces: HashMap<(usize, usize, usize), Vec<OpId>> = HashMap::new();
    for (li, ops) in model.layers.iter().enumerate() {
        for &op in ops {
            let shard_lists =
                transform_layer_op(g, op, dp, k, tp, tp_dim.get(&op).copied(), &cap_by_size)?;
            for (idx, shards) in shard_lists.into_iter().enumerate() {
                let (dpg, mi) = (idx / k, idx % k);
                pieces.entry((li, dpg, mi)).or_default().extend(shards);
            }
        }
    }

    let ag = autograd::complete(g);

    // ---- spatial assignment ----
    for (&(li, dpg, _mi), ops) in &pieces {
        let s = stage_of_layer[&li];
        for (idx, &op) in ops.iter().enumerate() {
            // Shards of one op are laid out across the tp group; successive
            // ops reuse the same group.
            let t = idx % tp;
            sched.assign(op, device(dpg, s, t));
            if let Some(&b) = ag.bwd_of.get(&op) {
                sched.assign(b, device(dpg, s, t));
            }
        }
    }
    align_optimizers(g);
    assign_optimizers(g, &mut sched);

    // ---- temporal ordering ----
    for dpg in 0..dp {
        for (s, ls) in stages.iter().enumerate() {
            let mut fwd_spans = Vec::with_capacity(k);
            let mut bwd_spans = Vec::with_capacity(k);
            for m in 0..k {
                let fops: Vec<OpId> = ls
                    .iter()
                    .flat_map(|&li| pieces[&(li, dpg, m)].iter().copied())
                    .collect();
                let bops: Vec<OpId> = fops
                    .iter()
                    .filter_map(|op| ag.bwd_of.get(op).copied())
                    .collect();
                if fops.is_empty() || bops.is_empty() {
                    continue;
                }
                fwd_spans.push(span(&fops));
                bwd_spans.push(span(&bops));
            }
            if fwd_spans.len() == k {
                match order {
                    PipeOrder::OneFOneB => order_1f1b(&mut sched, s, pp, k, &fwd_spans, &bwd_spans),
                    PipeOrder::GPipe => order_gpipe(&mut sched, &fwd_spans, &bwd_spans),
                }
            }
        }
    }

    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!("megatron-dp{dp}pp{pp}tp{tp}k{k}-{order:?}"),
    })
}

/// [`Planner`] for the Megatron dp × pp × tp grid with 1F1B ordering.
pub struct MegatronPlanner;

/// [`Planner`] for pure tensor parallelism (the grid with pp = 1, tp = n).
pub struct TpPlanner;

/// [`Planner`] for the megatron grid under GPipe ordering.
pub struct GPipePlanner;

impl Planner for MegatronPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::Megatron
    }

    fn description(&self) -> &'static str {
        "dp x pp x tp grid, 1F1B ordering"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, micro: usize) -> PlanSpec {
        PlanSpec { pp: gpus.max(1), micro: micro.max(1), ..PlanSpec::new(PlanKind::Megatron) }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        let mut out = Vec::new();
        for (dp, pp, tp) in factorizations(cluster.num_gpus()) {
            // The fine micro-batch grid (dominance pruning keeps it
            // affordable); the degenerate pp = 1 grids are plain dp×tp and
            // need only one micro-batch. Specs whose dp × micro overruns
            // the global batch are feasibility-pruned by the search.
            let micros: &[usize] = if pp > 1 { &[1, 2, 4, 8, 16] } else { &[1] };
            for &k in micros {
                out.push(PlanSpec { dp, pp, tp, micro: k, ..PlanSpec::new(PlanKind::Megatron) });
            }
        }
        out
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        megatron(
            model,
            spec.dp.max(1),
            spec.pp.max(1),
            spec.tp.max(1),
            spec.micro.max(1),
            PipeOrder::OneFOneB,
        )
    }
}

impl Planner for TpPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::Tp
    }

    fn description(&self) -> &'static str {
        "Megatron tensor parallelism (megatron with pp=1)"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, _micro: usize) -> PlanSpec {
        PlanSpec { tp: gpus.max(1), ..PlanSpec::new(PlanKind::Tp) }
    }

    fn candidates(&self, _model: &Model, _cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        // The megatron grid already owns the (1, 1, n) point; contributing
        // it again here would make every search evaluate it twice.
        Vec::new()
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        megatron(
            model,
            spec.dp.max(1),
            spec.pp.max(1),
            spec.tp.max(1),
            spec.micro.max(1),
            PipeOrder::OneFOneB,
        )
    }
}

impl Planner for GPipePlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::GPipe
    }

    fn description(&self) -> &'static str {
        "megatron grid with GPipe ordering"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, micro: usize) -> PlanSpec {
        PlanSpec { pp: gpus.max(1), micro: micro.max(1), ..PlanSpec::new(PlanKind::GPipe) }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        factorizations(cluster.num_gpus())
            .into_iter()
            .filter(|&(_, pp, _)| pp > 1)
            .map(|(dp, pp, tp)| PlanSpec { dp, pp, tp, micro: 4, ..PlanSpec::new(PlanKind::GPipe) })
            .collect()
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        megatron(
            model,
            spec.dp.max(1),
            spec.pp.max(1),
            spec.tp.max(1),
            spec.micro.max(1),
            PipeOrder::GPipe,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::gpt3;

    #[test]
    fn tensor_parallel_only_runs_and_communicates() {
        let model = gpt3(0, 4, 256);
        let out = megatron(&model, 1, 1, 4, 1, PipeOrder::OneFOneB).unwrap();
        let c = crate::cost::Cluster::v100(4);
        let r = crate::sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(r.comm_bytes > 0, "TP must communicate activations");
        assert!(!r.oom);
        assert_eq!(r.per_device.len(), 4);
    }

    #[test]
    fn pipeline_1f1b_beats_gpipe_memory() {
        // 1F1B's early backwards free activations sooner; with several
        // micro-batches its peak must be <= GPipe's.
        let c = crate::cost::Cluster::v100(4);
        let a = megatron(&gpt3(0, 8, 256), 1, 4, 1, 8, PipeOrder::OneFOneB).unwrap();
        let b = megatron(&gpt3(0, 8, 256), 1, 4, 1, 8, PipeOrder::GPipe).unwrap();
        let ra = crate::sim::run(&a.graph, &a.schedule, &c, CommMode::InterRvd).unwrap();
        let rb = crate::sim::run(&b.graph, &b.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(
            ra.max_peak_mem() <= rb.max_peak_mem(),
            "1f1b {} vs gpipe {}",
            ra.max_peak_mem(),
            rb.max_peak_mem()
        );
    }

    #[test]
    fn pipeline_has_bubbles_dp_does_not() {
        let c = crate::cost::Cluster::v100(4);
        let pp = megatron(&gpt3(0, 8, 256), 1, 4, 1, 4, PipeOrder::OneFOneB).unwrap();
        let dp = megatron(&gpt3(0, 8, 256), 4, 1, 1, 1, PipeOrder::OneFOneB).unwrap();
        let rp = crate::sim::run(&pp.graph, &pp.schedule, &c, CommMode::InterRvd).unwrap();
        let rd = crate::sim::run(&dp.graph, &dp.schedule, &c, CommMode::InterRvd).unwrap();
        let (_, _, bub_p) = rp.breakdown();
        let (_, _, bub_d) = rd.breakdown();
        assert!(bub_p > bub_d, "pipeline bubble {bub_p} vs dp {bub_d}");
    }
}
