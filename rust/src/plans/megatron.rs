//! Megatron-LM-style hierarchical parallelism: `dp × pp × tp` with GPipe or
//! 1F1B micro-batch ordering — the paper's main empirical baseline (§6.1).
//! Layers are grouped into FLOP-balanced pipeline stages; within a stage,
//! every op splits along its model-declared tensor-parallel dim; the whole
//! grid replicates `dp` ways with gradient all-reduce.
//!
//! With `pp == 1, tp == 1` this degenerates to Algorithm 1's data
//! parallelism; with `pp == 1` it is pure (Shoeybi-style) tensor
//! parallelism — the same sProgram covers the whole empirical family, which
//! is the point of the unified abstraction.

use super::*;
use crate::trans::{autograd, TransError};

/// Micro-batch ordering discipline for the pipeline. Kept for API
/// compatibility; each variant is now just a name for a [`SchedSpec`]
/// ([`megatron`] delegates to [`megatron_sched`]).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PipeOrder {
    GPipe,
    OneFOneB,
}

/// Build the Megatron plan under a legacy [`PipeOrder`] — a thin wrapper
/// selecting the equivalent named [`SchedSpec`] (1F1B or sync). The
/// generated schedules are bitwise-identical to the pre-DSL hand-rolled
/// ordering loops.
pub fn megatron(
    model: &Model,
    dp: usize,
    pp: usize,
    tp: usize,
    k: usize,
    order: PipeOrder,
) -> PlanResult {
    let sched_spec = match order {
        PipeOrder::OneFOneB => SchedSpec::Named(SchedName::OneFOneB),
        PipeOrder::GPipe => SchedSpec::Named(SchedName::Sync),
    };
    megatron_sched(model, dp, pp, tp, k, &sched_spec)
}

/// Human tag a schedule contributes to the plan name (legacy names — used
/// in golden CSVs and baselines — are preserved for the two disciplines
/// that predate the DSL).
fn sched_tag(sched_spec: &SchedSpec) -> &'static str {
    match sched_spec {
        SchedSpec::Named(SchedName::OneFOneB) => "OneFOneB",
        SchedSpec::Named(SchedName::Sync) => "GPipe",
        SchedSpec::Named(n) => n.as_str(),
        SchedSpec::Explicit(_) => "custom",
    }
}

/// Build the Megatron plan under an arbitrary schedule. Requires
/// `dp * pp * tp` devices; `k` is the micro-batch count per dp replica.
/// The model is borrowed: the graph is cloned (it is what the
/// transformation rewrites); layer lists and TP-dim metadata are read
/// through the borrow.
///
/// The schedule resolves to per-stage slot rows ([`SchedSpec::resolve`])
/// which are structurally checked up front — an infeasible schedule is a
/// typed [`TransError::Invalid`], not a downstream deadlock. Schedules
/// that use W slots (zero-bubble) split every two-class backward op into
/// B/W halves ([`autograd::split_bw`]) so weight-grad work can fill
/// pipeline bubbles.
pub fn megatron_sched(
    model: &Model,
    dp: usize,
    pp: usize,
    tp: usize,
    k: usize,
    sched_spec: &SchedSpec,
) -> PlanResult {
    let rows = sched_spec.resolve(pp, k);
    if rows.rows.len() != pp {
        return Err(TransError::Invalid(format!(
            "schedule has {} stage rows, pipeline has {pp}",
            rows.rows.len()
        )));
    }
    rows.check(k).map_err(|e| TransError::Invalid(format!("schedule: {e}")))?;
    let tp_dim = &model.tp_dim;
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();
    let stages = balance_stages(g, &model.layers, pp);
    let stage_of_layer: HashMap<usize, usize> = stages
        .iter()
        .enumerate()
        .flat_map(|(s, ls)| ls.iter().map(move |&l| (l, s)))
        .collect();
    let device = |dpg: usize, s: usize, t: usize| (dpg * pp + s) * tp + t;

    // ---- transformation: dp split -> K micro-batches -> tp shards ----
    // pieces[(layer_idx, dpg, mb)] = Vec<OpId> (tp shards of every op).
    // The split factor is capped by the dim's actual size (early Swin
    // stages have fewer heads than tp), replicas filling the rest.
    let cap_by_size = |sz: Option<usize>, tp: usize| sz.map(|s| feasible_split(s, tp)).unwrap_or(1);
    let mut pieces: HashMap<(usize, usize, usize), Vec<OpId>> = HashMap::new();
    for (li, ops) in model.layers.iter().enumerate() {
        for &op in ops {
            let shard_lists =
                transform_layer_op(g, op, dp, k, tp, tp_dim.get(&op).copied(), &cap_by_size)?;
            for (idx, shards) in shard_lists.into_iter().enumerate() {
                let (dpg, mi) = (idx / k, idx % k);
                pieces.entry((li, dpg, mi)).or_default().extend(shards);
            }
        }
    }

    let mut ag = autograd::complete(g);
    // W-slot schedules need the backward split into B (activation-grad,
    // critical path) and W (weight-grad, bubble filler) halves.
    let wmap = if rows.uses_wgrad() {
        autograd::split_bw(g, &mut ag)
    } else {
        HashMap::new()
    };

    // ---- spatial assignment ----
    for (&(li, dpg, _mi), ops) in &pieces {
        let s = stage_of_layer[&li];
        for (idx, &op) in ops.iter().enumerate() {
            // Shards of one op are laid out across the tp group; successive
            // ops reuse the same group.
            let t = idx % tp;
            sched.assign(op, device(dpg, s, t));
            if let Some(&b) = ag.bwd_of.get(&op) {
                sched.assign(b, device(dpg, s, t));
            }
            if let Some(&w) = wmap.get(&op) {
                sched.assign(w, device(dpg, s, t));
            }
        }
    }
    align_optimizers(g);
    assign_optimizers(g, &mut sched);

    // ---- temporal ordering ----
    for dpg in 0..dp {
        for (s, ls) in stages.iter().enumerate() {
            let mut fwd_spans = Vec::with_capacity(k);
            let mut bwd_spans = Vec::with_capacity(k);
            let mut w_spans: Vec<Option<(OpId, OpId)>> = Vec::with_capacity(k);
            for m in 0..k {
                let fops: Vec<OpId> = ls
                    .iter()
                    .flat_map(|&li| pieces[&(li, dpg, m)].iter().copied())
                    .collect();
                let bops: Vec<OpId> = fops
                    .iter()
                    .filter_map(|op| ag.bwd_of.get(op).copied())
                    .collect();
                if fops.is_empty() || bops.is_empty() {
                    continue;
                }
                let wops: Vec<OpId> = fops.iter().filter_map(|op| wmap.get(op).copied()).collect();
                fwd_spans.push(span(&fops));
                bwd_spans.push(span(&bops));
                w_spans.push((!wops.is_empty()).then(|| span(&wops)));
            }
            if fwd_spans.len() == k {
                dsl::lower_row(&mut sched, s, &rows.rows[s], &fwd_spans, &bwd_spans, &w_spans)
                    .map_err(|e| TransError::Invalid(format!("schedule lowering: {e}")))?;
            }
        }
    }

    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!("megatron-dp{dp}pp{pp}tp{tp}k{k}-{}", sched_tag(sched_spec)),
    })
}

/// [`Planner`] for the Megatron dp × pp × tp grid with 1F1B ordering.
pub struct MegatronPlanner;

/// [`Planner`] for pure tensor parallelism (the grid with pp = 1, tp = n).
pub struct TpPlanner;

/// [`Planner`] for the megatron grid under GPipe ordering.
pub struct GPipePlanner;

impl Planner for MegatronPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::Megatron
    }

    fn description(&self) -> &'static str {
        "dp x pp x tp grid, 1F1B ordering"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, micro: usize) -> PlanSpec {
        PlanSpec { pp: gpus.max(1), micro: micro.max(1), ..PlanSpec::new(PlanKind::Megatron) }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        let mut out = Vec::new();
        for (dp, pp, tp) in factorizations(cluster.num_gpus()) {
            // The fine micro-batch grid (dominance pruning keeps it
            // affordable); the degenerate pp = 1 grids are plain dp×tp and
            // need only one micro-batch. Specs whose dp × micro overruns
            // the global batch are feasibility-pruned by the search.
            let micros: &[usize] = if pp > 1 { &[1, 2, 4, 8, 16] } else { &[1] };
            for &k in micros {
                out.push(PlanSpec { dp, pp, tp, micro: k, ..PlanSpec::new(PlanKind::Megatron) });
                // Fourth axis: the same spatial grid under a zero-bubble
                // schedule (only meaningful with a pipeline and >1 micro).
                if pp > 1 && k > 1 {
                    out.push(PlanSpec {
                        dp,
                        pp,
                        tp,
                        micro: k,
                        sched: Some(SchedSpec::Named(SchedName::ZeroBubble)),
                        ..PlanSpec::new(PlanKind::Megatron)
                    });
                }
            }
        }
        out
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        let sched = spec.sched.clone().unwrap_or(SchedSpec::Named(SchedName::OneFOneB));
        megatron_sched(
            model,
            spec.dp.max(1),
            spec.pp.max(1),
            spec.tp.max(1),
            spec.micro.max(1),
            &sched,
        )
    }
}

impl Planner for TpPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::Tp
    }

    fn description(&self) -> &'static str {
        "Megatron tensor parallelism (megatron with pp=1)"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, _micro: usize) -> PlanSpec {
        PlanSpec { tp: gpus.max(1), ..PlanSpec::new(PlanKind::Tp) }
    }

    fn candidates(&self, _model: &Model, _cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        // The megatron grid already owns the (1, 1, n) point; contributing
        // it again here would make every search evaluate it twice.
        Vec::new()
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        let sched = spec.sched.clone().unwrap_or(SchedSpec::Named(SchedName::OneFOneB));
        megatron_sched(
            model,
            spec.dp.max(1),
            spec.pp.max(1),
            spec.tp.max(1),
            spec.micro.max(1),
            &sched,
        )
    }
}

impl Planner for GPipePlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::GPipe
    }

    fn description(&self) -> &'static str {
        "megatron grid with GPipe ordering"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, micro: usize) -> PlanSpec {
        PlanSpec { pp: gpus.max(1), micro: micro.max(1), ..PlanSpec::new(PlanKind::GPipe) }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        factorizations(cluster.num_gpus())
            .into_iter()
            .filter(|&(_, pp, _)| pp > 1)
            .map(|(dp, pp, tp)| PlanSpec { dp, pp, tp, micro: 4, ..PlanSpec::new(PlanKind::GPipe) })
            .collect()
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        let sched = spec.sched.clone().unwrap_or(SchedSpec::Named(SchedName::Sync));
        megatron_sched(
            model,
            spec.dp.max(1),
            spec.pp.max(1),
            spec.tp.max(1),
            spec.micro.max(1),
            &sched,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::gpt3;

    #[test]
    fn tensor_parallel_only_runs_and_communicates() {
        let model = gpt3(0, 4, 256);
        let out = megatron(&model, 1, 1, 4, 1, PipeOrder::OneFOneB).unwrap();
        let c = crate::cost::Cluster::v100(4);
        let r = crate::sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(r.comm_bytes > 0, "TP must communicate activations");
        assert!(!r.oom);
        assert_eq!(r.per_device.len(), 4);
    }

    #[test]
    fn pipeline_1f1b_beats_gpipe_memory() {
        // 1F1B's early backwards free activations sooner; with several
        // micro-batches its peak must be <= GPipe's.
        let c = crate::cost::Cluster::v100(4);
        let a = megatron(&gpt3(0, 8, 256), 1, 4, 1, 8, PipeOrder::OneFOneB).unwrap();
        let b = megatron(&gpt3(0, 8, 256), 1, 4, 1, 8, PipeOrder::GPipe).unwrap();
        let ra = crate::sim::run(&a.graph, &a.schedule, &c, CommMode::InterRvd).unwrap();
        let rb = crate::sim::run(&b.graph, &b.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(
            ra.max_peak_mem() <= rb.max_peak_mem(),
            "1f1b {} vs gpipe {}",
            ra.max_peak_mem(),
            rb.max_peak_mem()
        );
    }

    #[test]
    fn zero_bubble_validates_and_beats_1f1b_on_des() {
        // ZB-H1: halving the critical-path backward and filling bubbles
        // with W work must not lose to 1F1B under the high-fidelity DES.
        let c = crate::cost::Cluster::v100(4);
        let model = gpt3(0, 8, 256);
        let zb = megatron_sched(&model, 1, 4, 1, 8, &SchedSpec::Named(SchedName::ZeroBubble))
            .unwrap();
        let fb = megatron_sched(&model, 1, 4, 1, 8, &SchedSpec::Named(SchedName::OneFOneB))
            .unwrap();
        assert!(zb.name.ends_with("-zb"), "name: {}", zb.name);
        assert!(fb.name.ends_with("-OneFOneB"), "legacy name preserved: {}", fb.name);
        let vz = crate::schedule::validate(&zb.graph, &zb.schedule).unwrap();
        let vf = crate::schedule::validate(&fb.graph, &fb.schedule).unwrap();
        let pz = crate::materialize::materialize(&zb.graph, &vz, &c, CommMode::InterRvd);
        let pf = crate::materialize::materialize(&fb.graph, &vf, &c, CommMode::InterRvd);
        let rz = crate::des::simulate(&zb.graph, &vz, &pz, &c);
        let rf = crate::des::simulate(&fb.graph, &vf, &pf, &c);
        assert!(!rz.oom && !rf.oom);
        assert!(
            rz.makespan <= rf.makespan * 1.0001,
            "zb makespan {} vs 1f1b {}",
            rz.makespan,
            rf.makespan
        );
    }

    #[test]
    fn megatron_sched_rejects_malformed_schedules_with_typed_errors() {
        let model = gpt3(0, 4, 256);
        // Wrong row arity: 2 stage rows against a pp=4 pipeline.
        let two_rows = SchedSpec::Explicit(crate::schedule::ScheduleSpec::one_f_one_b(2, 4));
        let err = megatron_sched(&model, 1, 4, 1, 4, &two_rows).unwrap_err();
        assert!(format!("{err}").contains("stage rows"), "got: {err}");
        // Structurally broken row set: B before its F deadlocks stage 0.
        use crate::schedule::Slot;
        let stuck = SchedSpec::Explicit(crate::schedule::ScheduleSpec {
            rows: vec![vec![Slot::b(0), Slot::f(0)], vec![Slot::f(0), Slot::b(0)]],
        });
        let err = megatron_sched(&model, 1, 2, 1, 1, &stuck).unwrap_err();
        assert!(format!("{err}").contains("schedule"), "got: {err}");
    }

    #[test]
    fn pipeline_has_bubbles_dp_does_not() {
        let c = crate::cost::Cluster::v100(4);
        let pp = megatron(&gpt3(0, 8, 256), 1, 4, 1, 4, PipeOrder::OneFOneB).unwrap();
        let dp = megatron(&gpt3(0, 8, 256), 4, 1, 1, 1, PipeOrder::OneFOneB).unwrap();
        let rp = crate::sim::run(&pp.graph, &pp.schedule, &c, CommMode::InterRvd).unwrap();
        let rd = crate::sim::run(&dp.graph, &dp.schedule, &c, CommMode::InterRvd).unwrap();
        let (_, _, bub_p) = rp.breakdown();
        let (_, _, bub_d) = rd.breakdown();
        assert!(bub_p > bub_d, "pipeline bubble {bub_p} vs dp {bub_d}");
    }
}
