//! Interlaced pipeline — the paper's new plan for mBART (§3.4.2,
//! Algorithm 2, Fig. 9). mBART's embedding layers hold gigabytes of weight
//! with almost no compute; conventional pipelines must give them a stage of
//! their own (wasting a device) or share a stage (forcing cross-server
//! tensor parallelism on *all* layers — Megatron's failure mode in
//! Fig. 12c/15).
//!
//! The interlaced plan breaks the disjoint-stage assumption: transformer
//! layers form a normal 1F1B pipeline over the S devices, while the
//! embedding + tied LM head are *vocab-sharded across all S devices*
//! (`ShardEmbedAlgo`), interleaving with pipeline steps on the same GPUs.

use super::*;
use crate::trans::{autograd, recompute, TransError};

/// `interlaced_pipeline(model, s, k, block_recompute)`: `s` stages =
/// devices, `k` micro-batches. `layer_recompute` enables per-layer
/// recompute; `block_recompute` additionally serializes each micro-batch's
/// recompute behind the previous backward (the coarse "IL-block" baseline
/// of Fig. 15 — SuperScaler's fine-grained dependencies leave it false).
///
/// Uses the default 1F1B schedule; [`interlaced_sched`] accepts any W-free
/// [`SchedSpec`] for the transformer-pipeline part.
pub fn interlaced_pipeline(
    model: &Model,
    s: usize,
    k: usize,
    layer_recompute: bool,
    block_recompute: bool,
) -> PlanResult {
    interlaced_sched(model, s, k, layer_recompute, block_recompute, None)
}

/// [`interlaced_pipeline`] under an explicit schedule. The schedule drives
/// only the transformer pipeline (embedding shards interleave through data
/// dependencies, as before). W slots are rejected with a typed error: the
/// vocab-sharded embedding backward is not split here, so there is no
/// weight-grad work to place.
pub fn interlaced_sched(
    model: &Model,
    s: usize,
    k: usize,
    layer_recompute: bool,
    block_recompute: bool,
    sched_spec: Option<&SchedSpec>,
) -> PlanResult {
    let rows = match sched_spec {
        Some(sp) => {
            let rows = sp.resolve(s, k);
            if rows.rows.len() != s {
                return Err(TransError::Invalid(format!(
                    "schedule has {} stage rows, pipeline has {s}",
                    rows.rows.len()
                )));
            }
            if rows.uses_wgrad() {
                return Err(TransError::Invalid(
                    "interlaced pipeline does not support W-slot schedules".into(),
                ));
            }
            rows.check(k).map_err(|e| TransError::Invalid(format!("schedule: {e}")))?;
            rows
        }
        None => ScheduleSpec::one_f_one_b(s, k),
    };
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();
    let emb_set: std::collections::HashSet<OpId> = model.emb_ops.iter().copied().collect();

    // Transformer layers only (embedding layers handled separately).
    let stage_layers: Vec<(usize, Vec<OpId>)> = model
        .layers
        .iter()
        .enumerate()
        .map(|(li, ops)| {
            (
                li,
                ops.iter().copied().filter(|o| !emb_set.contains(o)).collect::<Vec<_>>(),
            )
        })
        .filter(|(_, ops)| !ops.is_empty())
        .collect();
    let only_layers: Vec<Vec<OpId>> = stage_layers.iter().map(|(_, o)| o.clone()).collect();
    let stages = balance_stages(g, &only_layers, s);

    // ---- 1F1B transformation: K micro-batches (Algorithm 2 line 2-4) ----
    let mut mb_pieces: HashMap<(usize, usize), Vec<OpId>> = HashMap::new(); // (stage_layer_idx, mb)
    for (idx, (_, ops)) in stage_layers.iter().enumerate() {
        for &op in ops {
            let dim = g
                .op(op)
                .signature
                .as_ref()
                .and_then(|sg| sg.batch.clone())
                .expect("fwd op without batch");
            for (m, p) in op_trans(g, op, &TransformAlgo::split(&dim, k))?.into_iter().enumerate() {
                mb_pieces.entry((idx, m)).or_default().push(p);
            }
        }
    }
    // ---- embedding: micro-batch + vocab shard across ALL devices ----
    let mut emb_pieces: HashMap<(usize, usize), Vec<OpId>> = HashMap::new(); // (mb, dev)
    for &op in &model.emb_ops {
        let dim = g
            .op(op)
            .signature
            .as_ref()
            .and_then(|sg| sg.batch.clone())
            .unwrap();
        for (m, p) in op_trans(g, op, &TransformAlgo::split(&dim, k))?.into_iter().enumerate() {
            // Algorithm 2 line 9-12: ShardEmbedAlgo(S) + assign across devs.
            for (d, shard) in op_trans(g, p, &TransformAlgo::split("v", s))?.into_iter().enumerate()
            {
                emb_pieces.entry((m, d)).or_default().push(shard);
            }
        }
    }

    let ag = autograd::complete(g);

    // ---- recompute (Fig. 15 setting: recompute every layer) ----
    let bwd_all: Vec<OpId> = ag.bwd_of.values().copied().collect();
    // One recompute() call per layer (all micro-batches together) so the
    // twins share recomputed-activation pTensors and each micro-batch's
    // backward reads its own twin region.
    let mut rc_pieces: HashMap<(usize, usize), Vec<OpId>> = HashMap::new();
    if layer_recompute {
        for idx in 0..stage_layers.len() {
            let flat: Vec<OpId> = (0..k)
                .flat_map(|m| mb_pieces[&(idx, m)].iter().copied())
                .collect();
            let rc = recompute(g, &flat, &bwd_all);
            let mut cursor = 0;
            for m in 0..k {
                let n = mb_pieces[&(idx, m)].len();
                rc_pieces.insert((idx, m), rc[cursor..cursor + n].to_vec());
                cursor += n;
            }
        }
    }

    // ---- assignment ----
    let stage_of: HashMap<usize, usize> = stages
        .iter()
        .enumerate()
        .flat_map(|(si, ls)| ls.iter().map(move |&l| (l, si)))
        .collect();
    for (&(idx, m), ops) in &mb_pieces {
        let dev = stage_of[&idx];
        for &op in ops {
            sched.assign(op, dev);
            if let Some(&b) = ag.bwd_of.get(&op) {
                sched.assign(b, dev);
            }
        }
        if let Some(rc) = rc_pieces.get(&(idx, m)) {
            sched.assign_all(rc, dev);
        }
    }
    for (&(_m, d), ops) in &emb_pieces {
        for &op in ops {
            sched.assign(op, d);
            if let Some(&b) = ag.bwd_of.get(&op) {
                sched.assign(b, d);
            }
        }
    }
    align_optimizers(g);
    assign_optimizers(g, &mut sched);

    // ---- interlaced 1F1B ordering (Algorithm 2 line 13-22) ----
    for (si, ls) in stages.iter().enumerate() {
        let mut fwd_spans = Vec::new();
        let mut bwd_spans = Vec::new();
        for m in 0..k {
            let fops: Vec<OpId> = ls
                .iter()
                .flat_map(|&l| mb_pieces[&(l, m)].iter().copied())
                .collect();
            let bops: Vec<OpId> = fops
                .iter()
                .filter_map(|o| ag.bwd_of.get(o).copied())
                .collect();
            fwd_spans.push(span(&fops));
            bwd_spans.push(span(&bops));
        }
        dsl::lower_row(&mut sched, si, &rows.rows[si], &fwd_spans, &bwd_spans, &[])
            .map_err(|e| TransError::Invalid(format!("schedule lowering: {e}")))?;
        // IL-block: recompute of micro-batch m may only start after the
        // previous backward fully drains (coarse scheduling).
        if block_recompute {
            for m in 1..k {
                let rcs: Vec<OpId> = ls
                    .iter()
                    .filter_map(|&l| rc_pieces.get(&(l, m)).cloned())
                    .flatten()
                    .collect();
                if !rcs.is_empty() {
                    sched.order(bwd_spans[m - 1].1, span(&rcs).0);
                }
            }
        }
    }

    // Named schedules keep the legacy name (1F1B is interlaced's native
    // discipline); explicit (e.g. refine-mutated) row sets are flagged.
    let sched_suffix = match sched_spec {
        Some(SchedSpec::Explicit(_)) => "-custom",
        _ => "",
    };
    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!(
            "interlaced-s{s}k{k}{}{sched_suffix}",
            if block_recompute { "-block" } else { "" }
        ),
    })
}

/// [`Planner`] for the interlaced pipeline (Algorithm 2).
pub struct InterlacedPlanner;

impl Planner for InterlacedPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::Interlaced
    }

    fn description(&self) -> &'static str {
        "NEW: interlaced pipeline for mBART (Algorithm 2)"
    }

    fn applicable(&self, model: &Model) -> bool {
        // Needs tagged embedding layers to vocab-shard across all devices.
        !model.emb_ops.is_empty()
    }

    fn default_spec(&self, gpus: usize, micro: usize) -> PlanSpec {
        PlanSpec {
            pp: gpus.max(1),
            micro: micro.max(1),
            recompute: true,
            ..PlanSpec::new(PlanKind::Interlaced)
        }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        [4usize, 8]
            .iter()
            .map(|&k| PlanSpec {
                pp: cluster.num_gpus(),
                micro: k,
                recompute: true,
                ..PlanSpec::new(PlanKind::Interlaced)
            })
            .collect()
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        interlaced_sched(
            model,
            spec.pp.max(1),
            spec.micro.max(1),
            spec.recompute,
            spec.block_recompute,
            spec.sched.as_ref(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::mbart;

    #[test]
    fn interlaced_validates_and_shards_embedding() {
        let out = interlaced_pipeline(&mbart(0, 8, 128), 4, 4, false, false).unwrap();
        let c = crate::cost::Cluster::v100(4);
        let vs = crate::schedule::validate(&out.graph, &out.schedule).unwrap();
        let plan = crate::materialize::materialize(&out.graph, &vs, &c, CommMode::InterRvd);
        let r = crate::sim::simulate(&out.graph, &vs, &plan, &c);
        assert!(r.makespan > 0.0 && !r.makespan.is_nan());
        // Static memory (weights/grads/Adam state incl. the vocab-sharded
        // embedding) must be spread across devices: no device holds more
        // than half of the total static footprint.
        let total: u64 = plan.static_mem.values().sum();
        for (dev, &bytes) in &plan.static_mem {
            assert!(
                bytes * 2 < total + 1,
                "device {dev} holds {bytes} of {total} static bytes"
            );
        }
    }

    #[test]
    fn explicit_1f1b_schedule_matches_the_default_bitwise() {
        // The DSL path must emit the same edge stream as the legacy
        // planner-coded 1F1B when handed equivalent rows.
        let model = mbart(0, 8, 128);
        let a = interlaced_pipeline(&model, 4, 4, false, false).unwrap();
        let spec = SchedSpec::Explicit(ScheduleSpec::one_f_one_b(4, 4));
        let b = interlaced_sched(&model, 4, 4, false, false, Some(&spec)).unwrap();
        assert_eq!(a.schedule.order_edges(), b.schedule.order_edges());
        assert!(b.name.ends_with("-custom"), "name: {}", b.name);
    }

    #[test]
    fn w_slot_schedules_are_rejected() {
        let model = mbart(0, 8, 128);
        let spec = SchedSpec::Named(SchedName::ZeroBubble);
        let err = interlaced_sched(&model, 4, 4, false, false, Some(&spec)).unwrap_err();
        assert!(format!("{err}").contains("W-slot"), "got: {err}");
    }

    #[test]
    fn fine_grained_recompute_beats_il_block() {
        // Fig. 15: SuperScaler (fine deps) vs IL-block (coarse recompute
        // barrier) — the barrier adds bubble time.
        let c = crate::cost::Cluster::v100(4);
        let fine = interlaced_pipeline(&mbart(0, 8, 128), 4, 4, true, false).unwrap();
        let block = interlaced_pipeline(&mbart(0, 8, 128), 4, 4, true, true).unwrap();
        let rf = crate::sim::run(&fine.graph, &fine.schedule, &c, CommMode::InterRvd).unwrap();
        let rb = crate::sim::run(&block.graph, &block.schedule, &c, CommMode::InterRvd).unwrap();
        // At this test scale the barrier binds only marginally; the
        // fig15_breakdown bench shows the full-scale gap. Allow greedy-
        // scheduler noise of 2%.
        assert!(
            rf.makespan <= rb.makespan * 1.02,
            "fine {} vs block {}",
            rf.makespan,
            rb.makespan
        );
    }
}
