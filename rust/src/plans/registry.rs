//! Central sProgram registry. Every [`Planner`] implementation registers
//! here; the CLI (`superscaler simulate|plans|search`), the benches and the
//! examples resolve plan names through this table instead of hand-rolled
//! string matches, and the search engine ([`crate::search`]) enumerates it
//! to build its candidate grid.

use super::coshard::CoshardPlanner;
use super::dap::DapPlanner;
use super::dp::DpPlanner;
use super::hetero::HeteroPlanner;
use super::interlaced::InterlacedPlanner;
use super::megatron::{GPipePlanner, MegatronPlanner, TpPlanner};
use super::pipe3f1b::ThreeFOneBPlanner;
use super::spec::{PlanKind, PlanSpec, Planner};
use super::zero::{Zero3OffloadPlanner, Zero3Planner};
use super::PlanResult;
use crate::models::Model;

/// Every registered sProgram, in display order.
pub static REGISTRY: [&dyn Planner; 11] = [
    &DpPlanner,
    &TpPlanner,
    &MegatronPlanner,
    &GPipePlanner,
    &Zero3Planner,
    &Zero3OffloadPlanner,
    &CoshardPlanner,
    &InterlacedPlanner,
    &ThreeFOneBPlanner,
    &DapPlanner,
    &HeteroPlanner,
];

/// All registered planners.
pub fn all() -> &'static [&'static dyn Planner] {
    &REGISTRY
}

/// Resolve a CLI/bench plan name to its planner: exact registry names
/// first (so a newly registered planner is resolvable without touching any
/// parse table), then the historical aliases via [`PlanKind::parse`].
pub fn find(name: &str) -> Option<&'static dyn Planner> {
    if let Some(p) = all().iter().copied().find(|p| p.name() == name) {
        return Some(p);
    }
    let kind = PlanKind::parse(name)?;
    all().iter().copied().find(|p| p.kind() == kind)
}

/// Build plan `name` from `spec`. The model is borrowed (see
/// [`Planner::build`] — one probe model serves any number of builds).
/// Panics on an unregistered name — that is a programming error in the
/// caller; user-facing code resolves names via [`find`] first and reports
/// gracefully.
pub fn build(name: &str, model: &Model, spec: &PlanSpec) -> PlanResult {
    find(name)
        .unwrap_or_else(|| panic!("unregistered plan '{name}' (see `superscaler plans`)"))
        .build(model, spec)
}
