//! DAP — Dynamic Axial Parallelism (FastFold), the paper's AlphaFold2
//! baseline (§6.1): partition *activations* along a non-batch axis across
//! devices while replicating every weight. Attention needs the full axis,
//! so the layout flips between token-sharded (elementwise/FFN) and
//! head-sharded (attention) — the all-to-alls materialization inserts are
//! exactly DAP's communication. Combined with data parallelism (DAP+DP).

use super::*;
use crate::graph::OpKind;
use crate::trans::autograd;

/// `dap_dp(model, dap, dp)`: `dap × dp` devices; activations split `dap`
/// ways along the token axis inside each DP replica.
pub fn dap_dp(model: &Model, dap: usize, dp: usize) -> PlanResult {
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();
    let device = |dpg: usize, a: usize| dpg * dap + a;

    let fwd_ops: Vec<OpId> = g.live_ops().filter(|o| o.is_forward).map(|o| o.id).collect();
    for op in fwd_ops {
        let kind = g.op(op).kind.clone();
        let dim = g
            .op(op)
            .signature
            .as_ref()
            .and_then(|s| s.batch.clone())
            .expect("fwd op without batch");
        let dp_parts = op_trans(g, op, &TransformAlgo::split(&dim, dp))?;
        for (dpg, p) in dp_parts.into_iter().enumerate() {
            // Attention shards by heads; everything else by tokens.
            let axis = if kind == OpKind::Attention { "a" } else { "s" };
            let parts = op_trans(g, p, &TransformAlgo::split(axis, dap))
                .or_else(|_| op_trans(g, p, &TransformAlgo::replicate(dap)))?;
            for (a, shard) in parts.into_iter().enumerate() {
                sched.assign(shard, device(dpg, a));
            }
        }
    }

    let ag = autograd::complete(g);
    for (f, b) in &ag.bwd_of {
        if let Some(d) = sched.device_of(*f) {
            sched.assign(*b, d);
        }
    }
    align_optimizers(g);
    assign_optimizers(g, &mut sched);

    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!("dap{dap}dp{dp}"),
    })
}

/// [`Planner`] for DAP + DP (the FastFold baseline).
pub struct DapPlanner;

impl Planner for DapPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::Dap
    }

    fn description(&self) -> &'static str {
        "Dynamic Axial Parallelism + DP (AlphaFold2 baseline)"
    }

    fn applicable(&self, model: &Model) -> bool {
        // DAP's token/head axis flips are the AlphaFold2 baseline; other
        // zoo models express the same family through megatron.
        model.name.starts_with("alphafold")
    }

    fn default_spec(&self, gpus: usize, _micro: usize) -> PlanSpec {
        PlanSpec { tp: gpus.max(1), ..PlanSpec::new(PlanKind::Dap) }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        let n = cluster.num_gpus();
        let mut out = Vec::new();
        for dp in 1..=n {
            if n % dp != 0 {
                continue;
            }
            let axial = n / dp;
            if axial > 1 {
                out.push(PlanSpec { dp, tp: axial, ..PlanSpec::new(PlanKind::Dap) });
            }
        }
        out
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        dap_dp(model, spec.tp.max(1), spec.dp.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::alphafold2;
    use crate::plans::pipeline_3f1b;

    #[test]
    fn dap_replicates_weights_and_pays_alltoall() {
        let out = dap_dp(&alphafold2(0, 8), 4, 1).unwrap();
        let c = crate::cost::Cluster::v100(4);
        let r = crate::sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(r.comm_bytes > 0, "DAP must communicate around attention");
        // Weights fully replicated on every device.
        let wb = out.graph.weight_bytes();
        for d in &r.per_device {
            assert!(d.peak_mem as u64 >= wb, "device {} lacks full weights", d.device);
        }
    }

    #[test]
    fn f3b1_beats_dap_on_larger_models() {
        // Fig. 12d's crossover: at bigger scales 3F1B's boundary-only comm
        // beats DAP's per-layer all-to-alls.
        let c = crate::cost::Cluster::v100(4);
        let dap = dap_dp(&alphafold2(1, 8), 4, 1).unwrap();
        let f31 = pipeline_3f1b(&alphafold2(1, 8), 4, 4).unwrap();
        let rd = crate::sim::run(&dap.graph, &dap.schedule, &c, CommMode::InterRvd).unwrap();
        let rf = crate::sim::run(&f31.graph, &f31.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(
            rf.comm_bytes < rd.comm_bytes,
            "3f1b comm {} vs dap {}",
            rf.comm_bytes,
            rd.comm_bytes
        );
    }
}
