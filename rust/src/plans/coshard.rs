//! co-shard — the paper's new plan (§2, Fig. 3): partition operators along
//! the multi-head / hidden dim, but **co-locate all partitions on the same
//! GPU** and run them *sequentially*, combined with recompute. Peak
//! activation memory drops to one shard's working set, which lets plain
//! (communication-cheap) data parallelism replace tensor parallelism across
//! GPUs — the source of the 3.5× Swin-Transformer win (Fig. 12a).
//!
//! This plan is only expressible because transformation (the same `op-trans`
//! split tensor parallelism uses) is decoupled from scheduling (same-device
//! assignment + sequential `op-order` instead of disjoint devices).

use super::*;
use crate::trans::{autograd, recompute};

/// `coshard(model, ndev, shards)`: DP across `ndev` devices, co-shard each
/// attention/FFN block into `shards` sequential pieces with recompute.
/// `coshard_layers` limits co-sharding to the first N layers (the paper
/// applies it to Swin's first four memory-heavy layers; `None` = all).
pub fn coshard(
    model: &Model,
    ndev: usize,
    shards: usize,
    coshard_layers: Option<usize>,
) -> PlanResult {
    coshard_opt(model, ndev, shards, coshard_layers, false)
}

/// [`coshard`] with optional ZeRO-style optimizer/gradient sharding across
/// the DP group (composes the paper's co-shard with DeepSpeed-style state
/// partitioning — how the large weak-scaling points fit in 32 GB).
pub fn coshard_opt(
    model: &Model,
    ndev: usize,
    shards: usize,
    coshard_layers: Option<usize>,
    zero_opt: bool,
) -> PlanResult {
    let coshard_dim = &model.coshard_dim;
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();

    // ---- DP split over devices, preserving layer op order ----
    // Co-shardable ops are grouped into *contiguous runs* (the attention
    // block is one run, the FFN another): a plain op (residual/layernorm)
    // between them consumes ALL shards of the previous run, so chaining
    // across runs would deadlock.
    // blocks[(device, layer, run)][shard] = ops of that shard.
    let mut blocks: HashMap<(usize, usize, usize), Vec<Vec<OpId>>> = HashMap::new();
    let mut plain: Vec<(usize, OpId)> = Vec::new(); // (device, op) not co-sharded
    for (li, ops) in model.layers.iter().enumerate() {
        let eligible_layer = coshard_layers.map(|n| li < n + 1).unwrap_or(true) && shards > 1;
        let mut run = 0usize;
        let mut in_run = false;
        for &op in ops {
            let eligible = eligible_layer && coshard_dim.contains_key(&op);
            if !eligible && in_run {
                run += 1;
                in_run = false;
            }
            let dim = g
                .op(op)
                .signature
                .as_ref()
                .and_then(|s| s.batch.clone())
                .expect("fwd op without batch");
            let parts = op_trans(g, op, &TransformAlgo::split(&dim, ndev))?;
            for (d, p) in parts.into_iter().enumerate() {
                if eligible {
                    let sdim = coshard_dim[&op];
                    // Never split finer than the dim allows (early Swin
                    // stages have few heads).
                    let eff = dim_size(g, p, sdim)
                        .map(|sz| feasible_split(sz, shards))
                        .unwrap_or(1);
                    let sparts = op_trans(g, p, &TransformAlgo::split(sdim, eff))?;
                    let entry = blocks
                        .entry((d, li, run))
                        .or_insert_with(|| vec![Vec::new(); sparts.len()]);
                    let cap = entry.len() - 1;
                    for (si, sp) in sparts.into_iter().enumerate() {
                        entry[si.min(cap)].push(sp);
                    }
                } else {
                    plain.push((d, p));
                }
            }
            if eligible {
                in_run = true;
            }
        }
    }

    let ag = autograd::complete(g);

    // ---- recompute the co-sharded forward blocks ----
    // One recompute() call per (device, layer) so all shard twins share the
    // recomputed-activation pTensors; each shard's backward then reads only
    // its own shard's twin region (separate calls would rewire every
    // backward to the *last* twin and deadlock against the shard ordering).
    let bwd_all: Vec<OpId> = ag.bwd_of.values().copied().collect();
    let mut rc_of_block: HashMap<(usize, usize, usize, usize), Vec<OpId>> = HashMap::new();
    for (&(d, li, run), shard_blocks) in &blocks {
        let flat: Vec<OpId> = shard_blocks.iter().flatten().copied().collect();
        let rc = recompute(g, &flat, &bwd_all);
        let mut cursor = 0;
        for (si, ops) in shard_blocks.iter().enumerate() {
            rc_of_block.insert((d, li, run, si), rc[cursor..cursor + ops.len()].to_vec());
            cursor += ops.len();
        }
    }

    // ---- assignment ----
    for (&(d, li, run), shard_blocks) in &blocks {
        for (si, ops) in shard_blocks.iter().enumerate() {
            for &op in ops {
                sched.assign(op, d);
                if let Some(&b) = ag.bwd_of.get(&op) {
                    sched.assign(b, d);
                }
            }
            for &rc in &rc_of_block[&(d, li, run, si)] {
                sched.assign(rc, d);
            }
        }
    }
    for &(d, op) in &plain {
        sched.assign(op, d);
        if let Some(&b) = ag.bwd_of.get(&op) {
            sched.assign(b, d);
        }
    }
    align_optimizers(g);
    if zero_opt && ndev > 1 {
        // Shard every optimizer op (and with it grads + Adam state) across
        // the DP group along the weight's leading dim.
        let opt_ops: Vec<OpId> = g
            .live_ops()
            .filter(|o| o.kind == crate::graph::OpKind::Optimizer)
            .map(|o| o.id)
            .collect();
        for op in opt_ops {
            let sz = g.vtensor_shape(g.op(op).outputs[0])[0];
            let eff = feasible_split(sz, ndev);
            if let Ok(piecewise) = op_trans(g, op, &TransformAlgo::split("p", eff)) {
                for (i, p) in piecewise.into_iter().enumerate() {
                    sched.assign(p, i % ndev);
                }
            }
        }
    }
    assign_optimizers(g, &mut sched);

    // ---- sequential ordering of shard blocks ----
    for (&(d, li, run), shard_blocks) in &blocks {
        // Forward: shard i fully before shard i+1.
        for si in 1..shard_blocks.len() {
            let prev = span(&shard_blocks[si - 1]);
            let next = span(&shard_blocks[si]);
            sched.order(prev.1, next.0);
        }
        // Backward + recompute: (rc_i, bwd_i) before (rc_{i+1}, bwd_{i+1}),
        // so only one shard's recomputed activations live at a time.
        for si in 1..shard_blocks.len() {
            let prev_bwd: Vec<OpId> = shard_blocks[si - 1]
                .iter()
                .filter_map(|op| ag.bwd_of.get(op).copied())
                .collect();
            let next_rc = &rc_of_block[&(d, li, run, si)];
            if !prev_bwd.is_empty() && !next_rc.is_empty() {
                sched.order(span(&prev_bwd).1, span(next_rc).0);
            }
        }
    }

    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!("coshard{ndev}x{shards}"),
    })
}

/// [`Planner`] for the paper's co-shard plan (DP across devices, co-located
/// sequential shards + recompute within each).
pub struct CoshardPlanner;

impl Planner for CoshardPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::Coshard
    }

    fn description(&self) -> &'static str {
        "NEW: co-located shards + recompute (paper Fig. 3)"
    }

    fn applicable(&self, model: &Model) -> bool {
        // Needs ops tagged with a co-shardable dim (attention heads / FFN
        // hidden).
        !model.coshard_dim.is_empty()
    }

    fn default_spec(&self, gpus: usize, _micro: usize) -> PlanSpec {
        PlanSpec { dp: gpus.max(1), shards: 4, ..PlanSpec::new(PlanKind::Coshard) }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        let n = cluster.num_gpus();
        // The full shard range 2..=8 (shards = 1 degenerates to plain DP,
        // which the megatron grid already owns); dominance pruning keeps
        // the finer grid affordable.
        let mut out: Vec<PlanSpec> = (2usize..=8)
            .map(|s| PlanSpec { dp: n, shards: s, ..PlanSpec::new(PlanKind::Coshard) })
            .collect();
        // The composed variants: co-shard + ZeRO-style optimizer sharding
        // (how the large weak-scaling points fit in memory).
        for s in [4usize, 8] {
            out.push(PlanSpec {
                dp: n,
                shards: s,
                zero_shard: true,
                ..PlanSpec::new(PlanKind::Coshard)
            });
        }
        out
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        coshard_opt(
            model,
            spec.dp.max(1),
            spec.shards.max(1),
            spec.coshard_layers,
            spec.zero_shard,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::gpt3;
    use crate::plans::data_parallel;

    #[test]
    fn coshard_cuts_peak_memory_vs_dp() {
        let c = crate::cost::Cluster::v100(2);
        // Long sequence -> attention activations dominate.
        let cs = coshard(&gpt3(0, 4, 2048), 2, 4, None).unwrap();
        let dp = data_parallel(&gpt3(0, 4, 2048), 2).unwrap();
        let rc = crate::sim::run(&cs.graph, &cs.schedule, &c, CommMode::InterRvd).unwrap();
        let rd = crate::sim::run(&dp.graph, &dp.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(
            (rc.max_peak_mem() as f64) < 0.8 * rd.max_peak_mem() as f64,
            "coshard {} vs dp {}",
            rc.max_peak_mem(),
            rd.max_peak_mem()
        );
        // Cost: a bounded slowdown from recompute + smaller kernels.
        assert!(rc.makespan < rd.makespan * 2.0);
        assert!(rc.makespan > rd.makespan);
    }

    #[test]
    fn coshard_no_extra_communication() {
        // Co-shard stays on-device: comm equals plain DP's gradient sync.
        let c = crate::cost::Cluster::v100(2);
        let cs = coshard(&gpt3(0, 4, 512), 2, 4, None).unwrap();
        let dp = data_parallel(&gpt3(0, 4, 512), 2).unwrap();
        let rc = crate::sim::run(&cs.graph, &cs.schedule, &c, CommMode::InterRvd).unwrap();
        let rd = crate::sim::run(&dp.graph, &dp.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(
            rc.comm_bytes <= rd.comm_bytes * 11 / 10,
            "coshard comm {} vs dp {}",
            rc.comm_bytes,
            rd.comm_bytes
        );
    }
}
