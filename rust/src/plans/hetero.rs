//! Heterogeneous pipeline — the §5 / Fig. 18 plan family: a pipeline whose
//! stages each apply their *own* intra-stage transformation. One stage may
//! run Megatron tensor parallelism over four devices while its neighbour
//! runs co-located shards + recompute on a single device and a third
//! offloads its optimizer to the host. Empirical plan generators cannot
//! reach these points because they bake one intra-stage choice into the
//! whole grid; with transformation decoupled from space-time scheduling the
//! combination is just another sProgram.
//!
//! The plan is declaratively a [`PlanSpec`] of kind [`PlanKind::Hetero`]
//! whose `stages` field carries one [`StageSpec`] per pipeline stage
//! (tp width, co-shard count, recompute and optimizer-offload flags), and
//! a `dp` degree replicating the whole per-stage pipeline.
//! [`HeteroPlanner::candidates`] performs the inner levels of the
//! **three-level search** — dp × pp-composition × per-stage choice: the
//! outer loop composes `dp` replicas of a pipeline over `n / dp` devices,
//! the middle loop enumerates stage-width compositions per pipeline depth,
//! and the inner choice picks each stage's transformation by analytic
//! cost-model ranking ([`crate::cost::ModelStats`] + α–β/compute
//! estimates, plus the modeled cross-replica gradient-sync time at
//! dp > 1). Only the best-ranked combinations per dp are emitted — the
//! final level (feasibility, dominance pruning, simulation) lives in
//! [`crate::search`].
//!
//! At dp > 1 every gradient region must synchronize across the replicas.
//! The planner does not insert explicit sync ops: the replicas' backward
//! value-partials and the replicated optimizer reads form the
//! `V(dp) → R(dp)` RVD shape, which materialization
//! ([`crate::materialize`]) turns into collective tasks — RVD-decomposed
//! (reduce-scatter within servers, all-reduce across, all-gather back,
//! [`crate::rvd::grad_sync_plan`]) whenever the dp group spans servers, so
//! the simulators watch sync traffic contend on real links instead of one
//! flat group-wide collective.
//!
//! Temporal ordering rides the shared [`order_1f1b`] helper, which since
//! the schedule DSL landed is itself a lowering of
//! [`crate::schedule::ScheduleSpec::one_f_one_b`] rows — hetero pipelines
//! therefore emit the same edge stream as before, and the `sched{...}`
//! search axis is restricted to 1F1B for this family (per-stage backward
//! splitting under mixed intra-stage transforms is future work).

use super::*;
use crate::cost::{Cluster, ModelStats};
use crate::schedule::CPU_DEVICE;
use crate::trans::autograd::BWD_FLOP_RATIO;
use crate::trans::{autograd, recompute, TransError};

/// Layer partition from explicit per-stage `layers` counts. `Some` only
/// when every stage sets one and they sum to the model's layer count;
/// otherwise the caller falls back to the FLOP-balanced split. This is the
/// re-materialization path for the refinement loop's stage-boundary moves.
fn explicit_partition(layers: &[Vec<OpId>], stages: &[StageSpec]) -> Option<Vec<Vec<usize>>> {
    if stages.iter().any(|s| s.layers == 0)
        || stages.iter().map(|s| s.layers).sum::<usize>() != layers.len()
    {
        return None;
    }
    let mut out = Vec::with_capacity(stages.len());
    let mut next = 0usize;
    for s in stages {
        out.push((next..next + s.layers).collect());
        next += s.layers;
    }
    Some(out)
}

/// Build a heterogeneous pipeline: `dp` replicas of a `stages.len()`-stage
/// pipeline with `k` micro-batches, where stage `s` applies `stages[s]`'s
/// intra-stage transformation. Layers are FLOP-balanced across stages
/// (unless every stage pins an explicit [`StageSpec::layers`] count); a
/// stage of width `w` occupies `w` consecutive devices.
///
/// The model is borrowed (only the graph is cloned), and the transform is
/// single-pass over replicas: [`transform_layer_op`] emits every dp
/// replica's pieces from one call per layer op, so replicas are never
/// re-transformed; the split-factor rule is additionally memoized per
/// `(dim size, stage width)` pair below.
pub fn hetero(model: &Model, dp: usize, k: usize, stages: &[StageSpec]) -> PlanResult {
    if stages.is_empty() {
        return Err(TransError::Invalid("hetero plan needs at least one stage".into()));
    }
    for (i, st) in stages.iter().enumerate() {
        if st.tp.max(1) > 1 && st.shards.max(1) > 1 {
            return Err(TransError::Invalid(format!(
                "stage {i}: tp {} and shards {} are mutually exclusive (co-shard is single-device)",
                st.tp, st.shards
            )));
        }
    }
    let dp = dp.max(1);
    let k = k.max(1);
    let pp = stages.len();
    if model.layers.len() < pp {
        return Err(TransError::Invalid(format!(
            "{} stages over {} layers",
            pp,
            model.layers.len()
        )));
    }
    let tp_dim = &model.tp_dim;
    let coshard_dim = &model.coshard_dim;
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();
    let layer_stages = explicit_partition(&model.layers, stages)
        .unwrap_or_else(|| balance_stages(g, &model.layers, pp));
    let stage_of_layer: HashMap<usize, usize> = layer_stages
        .iter()
        .enumerate()
        .flat_map(|(s, ls)| ls.iter().map(move |&l| (l, s)))
        .collect();
    let widths: Vec<usize> = stages.iter().map(|s| s.width()).collect();
    let mut offsets = Vec::with_capacity(pp);
    let mut total = 0usize;
    for &w in &widths {
        offsets.push(total);
        total += w;
    }
    let device = |dpg: usize, s: usize, t: usize| dpg * total + offsets[s] + t;

    // Weight pTensor -> stage, for per-stage optimizer offload. Gathered
    // before transformation, while `model.layers` still names live ops.
    let mut weight_stage: HashMap<PTensorId, usize> = HashMap::new();
    if stages.iter().any(|s| s.offload) {
        for (li, ops) in model.layers.iter().enumerate() {
            let s = stage_of_layer[&li];
            for &op in ops {
                for &v in &g.op(op).inputs {
                    let pt = g.vtensor(v).ptensor;
                    if g.ptensor(pt).kind == TensorKind::Weight {
                        weight_stage.insert(pt, s);
                    }
                }
            }
        }
    }

    // ---- transformation: dp split -> K micro-batches -> per-stage ----
    // pieces[(layer, dpg, mb)] = that micro-batch's ops on the layer's
    // stage (tp shards laid out across the stage group, or co-shard pieces
    // co-located on the stage device).
    let mut pieces: HashMap<(usize, usize, usize), Vec<OpId>> = HashMap::new();
    // sblocks[(dpg, layer, run, mb)][shard] = ops of one sequential
    // co-shard block (the coshard plan's contiguous-run structure).
    let mut sblocks: HashMap<(usize, usize, usize, usize), Vec<Vec<OpId>>> = HashMap::new();
    // Megatron-style TP split via the shared dp→micro→tp helper, with
    // hetero's stricter factor rule: it must divide BOTH the dim size and
    // the stage width so every op contributes exactly `tp` pieces — the
    // `idx % tp` device layout below would misalign corresponding shards
    // of producer/consumer ops otherwise. The factor depends only on the
    // `(dim size, stage width)` pair, and the dp × micro × layer loop asks
    // the same handful of pairs over and over on deep models — memoized.
    let align_cache = std::cell::RefCell::new(HashMap::<(Option<usize>, usize), usize>::new());
    let strict_align = |sz: Option<usize>, tp: usize| {
        *align_cache.borrow_mut().entry((sz, tp)).or_insert_with(|| {
            (1..=tp).rev().find(|&c| tp % c == 0 && sz.map_or(false, |s| s % c == 0)).unwrap_or(1)
        })
    };
    for (li, ops) in model.layers.iter().enumerate() {
        let s = stage_of_layer[&li];
        let st = &stages[s];
        let tp = st.width();
        let want_shards = if tp == 1 { st.shards.max(1) } else { 1 };
        let mut run = 0usize;
        let mut in_run = false;
        for &op in ops {
            let eligible = want_shards > 1 && coshard_dim.contains_key(&op);
            if !eligible && in_run {
                run += 1;
                in_run = false;
            }
            let shard_lists =
                transform_layer_op(g, op, dp, k, tp, tp_dim.get(&op).copied(), &strict_align)?;
            for (idx, shards) in shard_lists.into_iter().enumerate() {
                let (dpg, mi) = (idx / k, idx % k);
                if tp > 1 {
                    pieces.entry((li, dpg, mi)).or_default().extend(shards);
                } else if eligible {
                    // Single-device stage: co-shard the micro-batch piece
                    // sequentially along its co-shard dim.
                    let m = shards[0];
                    let sdim = coshard_dim[&op];
                    let eff = dim_size(g, m, sdim)
                        .map(|sz| feasible_split(sz, want_shards))
                        .unwrap_or(1);
                    let sparts = op_trans(g, m, &TransformAlgo::split(sdim, eff))?;
                    let entry = sblocks
                        .entry((dpg, li, run, mi))
                        .or_insert_with(|| vec![Vec::new(); sparts.len()]);
                    let cap = entry.len() - 1;
                    for (si, sp) in sparts.into_iter().enumerate() {
                        entry[si.min(cap)].push(sp);
                        pieces.entry((li, dpg, mi)).or_default().push(sp);
                    }
                } else {
                    pieces.entry((li, dpg, mi)).or_default().push(shards[0]);
                }
            }
            if eligible {
                in_run = true;
            }
        }
    }

    let ag = autograd::complete(g);
    let mut bwd_all: Vec<OpId> = ag.bwd_of.values().copied().collect();
    bwd_all.sort_unstable();

    // ---- per-stage recompute ----
    // One recompute() call per (dpg, layer) — all micro-batches (and, for
    // co-shard stages, all runs and shards) together — so the twins share
    // recomputed-activation pTensors and every backward reads its own twin
    // region (the interlaced/coshard pattern).
    let mut rc_pieces: HashMap<(usize, usize, usize), Vec<OpId>> = HashMap::new();
    let mut rc_blocks: HashMap<(usize, usize, usize, usize), Vec<Vec<OpId>>> = HashMap::new();
    let mut sblock_keys: Vec<(usize, usize, usize, usize)> = sblocks.keys().copied().collect();
    sblock_keys.sort_unstable();
    for li in 0..model.layers.len() {
        let s = stage_of_layer[&li];
        let st = &stages[s];
        let sharded = st.width() == 1 && st.shards.max(1) > 1;
        if sharded {
            for dpg in 0..dp {
                let keys: Vec<&(usize, usize, usize, usize)> = sblock_keys
                    .iter()
                    .filter(|&&(d, l, _, _)| d == dpg && l == li)
                    .collect();
                let mut flat: Vec<OpId> = Vec::new();
                let mut lens: Vec<((usize, usize, usize, usize), Vec<usize>)> = Vec::new();
                for &&key in &keys {
                    let blocks = &sblocks[&key];
                    lens.push((key, blocks.iter().map(|b| b.len()).collect()));
                    for b in blocks {
                        flat.extend_from_slice(b);
                    }
                }
                if flat.is_empty() {
                    continue;
                }
                let rc = recompute(g, &flat, &bwd_all);
                let mut cur = 0;
                for (key, shard_lens) in lens {
                    let mut blocks_rc = Vec::with_capacity(shard_lens.len());
                    for n in shard_lens {
                        blocks_rc.push(rc[cur..cur + n].to_vec());
                        cur += n;
                    }
                    rc_blocks.insert(key, blocks_rc);
                }
            }
        } else if st.recompute {
            for dpg in 0..dp {
                let mut flat: Vec<OpId> = Vec::new();
                let mut lens = Vec::with_capacity(k);
                for mi in 0..k {
                    let ops = &pieces[&(li, dpg, mi)];
                    flat.extend_from_slice(ops);
                    lens.push(ops.len());
                }
                if flat.is_empty() {
                    continue;
                }
                let rc = recompute(g, &flat, &bwd_all);
                let mut cur = 0;
                for (mi, n) in lens.into_iter().enumerate() {
                    rc_pieces.insert((li, dpg, mi), rc[cur..cur + n].to_vec());
                    cur += n;
                }
            }
        }
    }

    // ---- spatial assignment ----
    let mut piece_keys: Vec<(usize, usize, usize)> = pieces.keys().copied().collect();
    piece_keys.sort_unstable();
    for &(li, dpg, mi) in &piece_keys {
        let s = stage_of_layer[&li];
        let tpw = stages[s].width();
        for (idx, &op) in pieces[&(li, dpg, mi)].iter().enumerate() {
            let t = idx % tpw;
            sched.assign(op, device(dpg, s, t));
            if let Some(&b) = ag.bwd_of.get(&op) {
                sched.assign(b, device(dpg, s, t));
            }
        }
        if let Some(rc) = rc_pieces.get(&(li, dpg, mi)) {
            for (idx, &op) in rc.iter().enumerate() {
                sched.assign(op, device(dpg, s, idx % tpw));
            }
        }
    }
    for &(dpg, li, run, mi) in &sblock_keys {
        let s = stage_of_layer[&li];
        if let Some(blocks_rc) = rc_blocks.get(&(dpg, li, run, mi)) {
            for b in blocks_rc {
                for &op in b {
                    sched.assign(op, device(dpg, s, 0));
                }
            }
        }
    }

    // ---- optimizers: align, then per-stage offload, then placement ----
    let opt_regions = align_optimizers(g);
    if stages.iter().any(|s| s.offload) {
        let mut wpts: Vec<PTensorId> = opt_regions.keys().copied().collect();
        wpts.sort_unstable();
        for w_pt in wpts {
            let Some(&s) = weight_stage.get(&w_pt) else { continue };
            if stages[s].offload {
                for &op in &opt_regions[&w_pt] {
                    sched.assign(op, CPU_DEVICE);
                }
            }
        }
    }
    assign_optimizers(g, &mut sched);

    // ---- temporal ordering: 1F1B across stages ----
    for dpg in 0..dp {
        for (s, ls) in layer_stages.iter().enumerate() {
            let mut fwd_spans = Vec::with_capacity(k);
            let mut bwd_spans = Vec::with_capacity(k);
            let mut fwd_only: Vec<(OpId, OpId)> = Vec::with_capacity(k);
            for m in 0..k {
                let fops: Vec<OpId> = ls
                    .iter()
                    .flat_map(|&li| pieces[&(li, dpg, m)].iter().copied())
                    .collect();
                if fops.is_empty() {
                    continue;
                }
                let fs = span(&fops);
                fwd_only.push(fs);
                let bops: Vec<OpId> =
                    fops.iter().filter_map(|op| ag.bwd_of.get(op).copied()).collect();
                if bops.is_empty() {
                    continue;
                }
                fwd_spans.push(fs);
                bwd_spans.push(span(&bops));
            }
            if fwd_spans.len() == k {
                order_1f1b(&mut sched, s, pp, k, &fwd_spans, &bwd_spans);
            } else {
                // A stage without a complete backward per micro-batch
                // (no_grad passes): still serialize the forwards so the
                // micro-batches cannot all run concurrently.
                for w in fwd_only.windows(2) {
                    sched.order(w[0].1, w[1].0);
                }
            }
        }
    }
    // ---- sequential co-shard ordering within each block run ----
    for &(dpg, li, run, mi) in &sblock_keys {
        let blocks = &sblocks[&(dpg, li, run, mi)];
        for si in 1..blocks.len() {
            if blocks[si - 1].is_empty() || blocks[si].is_empty() {
                continue;
            }
            let prev = span(&blocks[si - 1]);
            let next = span(&blocks[si]);
            sched.order(prev.1, next.0);
        }
        if let Some(blocks_rc) = rc_blocks.get(&(dpg, li, run, mi)) {
            // Shard i's backward before shard i+1's recompute, so only one
            // shard's recomputed activations are live at a time.
            for si in 1..blocks.len() {
                let prev_bwd: Vec<OpId> = blocks[si - 1]
                    .iter()
                    .filter_map(|op| ag.bwd_of.get(op).copied())
                    .collect();
                let next_rc = &blocks_rc[si];
                if !prev_bwd.is_empty() && !next_rc.is_empty() {
                    sched.order(span(&prev_bwd).1, span(next_rc).0);
                }
            }
        }
    }

    let stage_lbl: Vec<String> = stages.iter().map(|s| s.label()).collect();
    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!("hetero-dp{dp}k{k}[{}]", stage_lbl.join("|")),
    })
}

/// Widths a stage may occupy in the candidate grid.
const STAGE_WIDTHS: [usize; 4] = [8, 4, 2, 1];
/// Cost-ranked non-uniform combinations kept *per dp value* (each is
/// emitted with up to two micro-batch counts), so a replication degree can
/// never crowd another out of the grid before simulation sees both.
const HETERO_TOP: usize = 12;
/// Cap on width compositions explored per (dp, pipeline depth).
const MAX_COMPOSITIONS: usize = 128;
/// Largest replication degree the dp outer loop enumerates — a deliberate
/// grid truncation (like [`MAX_COMPOSITIONS`]), not a feasibility bound:
/// on clusters past `8 × MAX_DP` GPUs, wider-dp pipelines exist but are
/// not enumerated here (pure data parallelism at any width stays covered
/// by the `dp`/`megatron` planners). Raise alongside cluster scale.
const MAX_DP: usize = 8;

fn compositions(n: usize, parts: usize, prefix: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
    if out.len() >= MAX_COMPOSITIONS {
        return;
    }
    if parts == 0 {
        if n == 0 {
            out.push(prefix.clone());
        }
        return;
    }
    for &w in &STAGE_WIDTHS {
        if w <= n && n - w >= parts - 1 {
            prefix.push(w);
            compositions(n - w, parts - 1, prefix, out);
            prefix.pop();
        }
    }
}

/// The per-stage transformation vocabulary for a stage of `width` devices.
fn stage_choices(width: usize, can_coshard: bool) -> Vec<StageSpec> {
    let mut out = vec![StageSpec::tp(width), StageSpec { recompute: true, ..StageSpec::tp(width) }];
    if width == 1 && can_coshard {
        for s in [2usize, 4, 8] {
            out.push(StageSpec::coshard(s));
        }
    }
    if width <= 2 {
        out.push(StageSpec { offload: true, ..StageSpec::tp(width) });
    }
    out
}

/// Analytic (seconds, bytes) estimate for one stage choice given the
/// stage's share of the model — the inner-level ranking key. This is a
/// *heuristic* (recompute re-runs the forward, co-shard pays a small-kernel
/// tax, TP pays an activation-collective tax, offload pays CPU Adam + PCIe);
/// soundness is not required here because every emitted candidate is still
/// simulated (or dominance-checked against the sound bound) by the search.
/// Memory models both static state and the stashed activations — that is
/// what makes recompute/co-shard *selectable*: they trade the time taxes
/// above for an activation footprint plain TP cannot reach, so they win a
/// stage exactly when the plain variant no longer fits the device.
fn stage_cost(
    cluster: &Cluster,
    st: &StageSpec,
    fwd: f64,
    grad: f64,
    weight: u64,
    act: u64,
) -> (f64, u64) {
    let d = &cluster.spec;
    let tpw = st.width() as f64;
    let shards = st.shards.max(1) as u64;
    let mut work = fwd + BWD_FLOP_RATIO * grad;
    if st.recompute || shards > 1 {
        work += fwd;
    }
    let mut t = work / tpw / (d.peak_flops * d.max_util);
    if shards > 1 {
        t *= 1.0 + 0.03 * shards as f64;
    }
    if st.width() > 1 {
        t *= 1.05;
    }
    let mut stat = 4 * weight / st.width() as u64;
    let mut act_mem = act / st.width() as u64;
    if st.recompute {
        // Only layer-boundary inputs stay stashed.
        act_mem /= 8;
    } else if shards > 1 {
        // One shard's working set live at a time, plus boundary stashes.
        act_mem = act_mem / shards + act_mem / 8;
    }
    if st.offload {
        let params = weight as f64 / 4.0;
        t += 16.0 * params / (cluster.cpu_spec.peak_flops * cluster.cpu_spec.max_util);
        t += 2.0 * weight as f64 / cluster.pcie_bw;
        stat = weight;
    }
    (t, stat + act_mem)
}

/// The best-ranked (cost, choice) for one stage of `width` devices given
/// the stage's model shares — the inner level of the three-level search,
/// factored out so [`hetero_candidates`] can memoize it per `(dp, pp,
/// width)` instead of re-ranking the same vocabulary for every one of the
/// up-to-[`MAX_COMPOSITIONS`] width compositions a pipeline depth explores.
#[allow(clippy::too_many_arguments)]
fn best_stage_choice(
    cluster: &Cluster,
    width: usize,
    can_coshard: bool,
    fwd: f64,
    grad: f64,
    wsh: u64,
    ash: u64,
    cap: u64,
) -> Option<(f64, StageSpec)> {
    let mut best: Option<(f64, StageSpec)> = None;
    for st in stage_choices(width, can_coshard) {
        let (t, mem) = stage_cost(cluster, &st, fwd, grad, wsh, ash);
        if mem > cap {
            continue;
        }
        if best.map(|(bt, _)| t < bt).unwrap_or(true) {
            best = Some((t, st));
        }
    }
    best
}

/// The inner levels of the three-level search. The *outer* loop composes
/// `dp` replicas of a pipeline over `n / dp` devices (divisors of the
/// cluster bounded by the global batch); the *middle* loop enumerates
/// stage-width compositions per pipeline depth; the *inner* choice picks
/// each stage's transformation by cost-model ranking. Non-uniform
/// combinations are ranked by pipeline-bottleneck time **plus the modeled
/// cross-replica gradient-sync time** ([`crate::rvd::grad_sync_time`] —
/// RVD-decomposed when the replica groups span servers), so a dp that buys
/// compute scaling but pays a flat cross-server all-reduce ranks honestly
/// against a dp whose sync decomposes. Uniform (homogeneous-equivalent)
/// combinations are always included so the heterogeneous space is a strict
/// superset of the megatron pipeline grid at every dp.
///
/// The inner choice is **memoized**: a stage's best-ranked transformation
/// depends only on `(dp, pp, width)` — the model shares are fixed per
/// `(dp, pp)` — so it is computed once per width and looked up across all
/// compositions and dp replicas instead of re-ranked per stage slot
/// (`hetero_candidates_impl(.., memoize = false)` keeps the direct path
/// for the equivalence unit test).
pub fn hetero_candidates(model: &Model, cluster: &Cluster) -> Vec<PlanSpec> {
    hetero_candidates_impl(model, cluster, true)
}

fn hetero_candidates_impl(model: &Model, cluster: &Cluster, memoize: bool) -> Vec<PlanSpec> {
    let n = cluster.num_gpus();
    let layers = model.layers.len().max(1);
    let batch = model.global_batch.max(1);
    if n < 2 || layers < 2 {
        return Vec::new();
    }
    let stats = ModelStats::of(&model.graph);
    let can_coshard = !model.coshard_dim.is_empty();
    // Rank against the roomiest device kind: candidate generation must not
    // discard shapes a mixed fleet's larger devices could still hold.
    let cap = cluster.max_mem_bytes();
    let micros = [1usize, 2, 4, 8, 16];
    let mut out: Vec<PlanSpec> = Vec::new();
    for dp in (1..=n.min(batch).min(MAX_DP)).filter(|d| n % d == 0) {
        let per = n / dp;
        let min_pp = if dp == 1 { 2 } else { 1 };
        let max_pp = per.min(layers).min(8);
        let mut ranked: Vec<(f64, PlanSpec)> = Vec::new();
        for pp in min_pp..=max_pp {
            // Per-replica, per-stage shares: a replica sees 1/dp of the
            // batch's FLOPs and activations; weights replicate across dp.
            let fwd = stats.fwd_flops / (dp * pp) as f64;
            let grad = stats.grad_fwd_flops / (dp * pp) as f64;
            let wsh = stats.weight_bytes / pp as u64;
            let ash = stats.act_bytes / (dp * pp) as u64;
            if per % pp == 0 {
                for &kk in &micros {
                    if dp * kk <= batch {
                        out.push(PlanSpec::hetero_dp(dp, vec![StageSpec::tp(per / pp); pp], kk));
                    }
                }
            }
            // Inner-level memo: one ranked choice per stage width for this
            // (dp, pp) point, shared by every composition below.
            let memo: Vec<(usize, Option<(f64, StageSpec)>)> = STAGE_WIDTHS
                .iter()
                .map(|&w| {
                    (w, best_stage_choice(cluster, w, can_coshard, fwd, grad, wsh, ash, cap))
                })
                .collect();
            let choice_of = |w: usize| -> Option<(f64, StageSpec)> {
                if memoize {
                    memo.iter().find(|e| e.0 == w).and_then(|e| e.1)
                } else {
                    best_stage_choice(cluster, w, can_coshard, fwd, grad, wsh, ash, cap)
                }
            };
            let mut comps = Vec::new();
            compositions(per, pp, &mut Vec::new(), &mut comps);
            for comp in comps {
                let mut combo: Vec<StageSpec> = Vec::with_capacity(pp);
                let mut bottleneck = 0.0f64;
                let mut feasible = true;
                for &w in &comp {
                    match choice_of(w) {
                        Some((t, st)) => {
                            bottleneck = bottleneck.max(t);
                            combo.push(st);
                        }
                        None => {
                            feasible = false;
                            break;
                        }
                    }
                }
                if !feasible {
                    continue;
                }
                // All-plain uniform combos are already in `out`.
                let uniform = combo.iter().all(|st| *st == StageSpec::tp(combo[0].tp));
                if uniform && per % pp == 0 && combo[0].tp.max(1) == per / pp {
                    continue;
                }
                // Rank by bottleneck stage time + modeled gradient sync
                // across replicas (zero at dp = 1). The representative dp
                // group is the widest stage's first device in each replica —
                // at its actual device offset, so whether the group spans
                // servers (and the sync decomposes) reflects the real
                // layout; its per-device gradient buffer is the stage share
                // spread over the stage width.
                let mut cost = bottleneck;
                if dp > 1 {
                    let wmax = combo.iter().map(|s| s.width()).max().unwrap_or(1);
                    let widest_off: usize = combo
                        .iter()
                        .take_while(|s| s.width() != wmax)
                        .map(|s| s.width())
                        .sum();
                    let group: Vec<usize> = (0..dp).map(|r| r * per + widest_off).collect();
                    cost += crate::rvd::grad_sync_time(cluster, &group, wsh / wmax as u64);
                }
                ranked.push((cost, PlanSpec::hetero_dp(dp, combo, 4)));
            }
        }
        ranked.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.1.label().cmp(&b.1.label()))
        });
        for (_, spec) in ranked.into_iter().take(HETERO_TOP) {
            // Always emit each kept combination with a feasible micro count
            // (dp × micro <= batch) — a small-batch model still explores
            // heterogeneous points rather than silently skipping the space.
            let mut s4 = spec.clone();
            s4.micro = (batch / dp).min(4).max(1);
            out.push(s4);
            if batch / dp >= 8 {
                let mut s8 = spec;
                s8.micro = 8;
                out.push(s8);
            }
        }
    }
    out
}

/// [`Planner`] for the heterogeneous per-stage pipeline.
pub struct HeteroPlanner;

impl Planner for HeteroPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::Hetero
    }

    fn description(&self) -> &'static str {
        "NEW: heterogeneous pipeline (per-stage tp/coshard/recompute/offload)"
    }

    fn applicable(&self, model: &Model) -> bool {
        model.layers.len() >= 2
    }

    fn default_spec(&self, gpus: usize, micro: usize) -> PlanSpec {
        let g = gpus.max(1);
        let stages = if g >= 2 {
            let half = g / 2;
            vec![StageSpec::tp(g - half), StageSpec::tp(half)]
        } else {
            vec![StageSpec::tp(1)]
        };
        PlanSpec::hetero(stages, micro.max(1))
    }

    fn candidates(&self, model: &Model, cluster: &Cluster) -> Vec<PlanSpec> {
        hetero_candidates(model, cluster)
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        let Some(stages) = spec.stages.as_deref() else {
            return Err(TransError::Invalid("hetero spec carries no per-stage list".into()));
        };
        hetero(model, spec.dp.max(1), spec.micro.max(1), stages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::gpt3;
    use crate::plans::megatron;
    use crate::plans::PipeOrder;
    use crate::schedule::validate;

    #[test]
    fn uniform_hetero_matches_megatron_pipeline() {
        let c = crate::cost::Cluster::v100(4);
        let h = hetero(&gpt3(0, 8, 256), 1, 4, &[StageSpec::tp(2), StageSpec::tp(2)]).unwrap();
        let m = megatron(&gpt3(0, 8, 256), 1, 2, 2, 4, PipeOrder::OneFOneB).unwrap();
        let rh = crate::sim::run(&h.graph, &h.schedule, &c, CommMode::InterRvd).unwrap();
        let rm = crate::sim::run(&m.graph, &m.schedule, &c, CommMode::InterRvd).unwrap();
        let rel = (rh.makespan - rm.makespan).abs() / rm.makespan.max(1e-12);
        assert!(rel < 0.01, "uniform hetero {} vs megatron {}", rh.makespan, rm.makespan);
        assert_eq!(rh.per_device.len(), rm.per_device.len());
    }

    #[test]
    fn mixed_width_pipeline_builds_and_validates() {
        let out =
            hetero(&gpt3(0, 8, 256), 1, 4, &[StageSpec::tp(2), StageSpec::tp(1), StageSpec::tp(1)])
                .unwrap();
        let vs = validate(&out.graph, &out.schedule).expect("mixed hetero schedule valid");
        assert!(!vs.topo.is_empty());
        let c = crate::cost::Cluster::v100(4);
        let r = crate::sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(!r.oom);
        assert_eq!(r.per_device.len(), 4);
    }

    #[test]
    fn coshard_stage_cuts_stage_memory() {
        // Same 2-stage shape, second stage co-sharded: its device's peak
        // must drop vs. the plain variant (that is co-shard's whole point).
        let c = crate::cost::Cluster::v100(2);
        let plain = hetero(&gpt3(0, 4, 2048), 1, 2, &[StageSpec::tp(1), StageSpec::tp(1)]).unwrap();
        let cs =
            hetero(&gpt3(0, 4, 2048), 1, 2, &[StageSpec::tp(1), StageSpec::coshard(4)]).unwrap();
        let rp = crate::sim::run(&plain.graph, &plain.schedule, &c, CommMode::InterRvd).unwrap();
        let rc = crate::sim::run(&cs.graph, &cs.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(
            rc.per_device[1].peak_mem < rp.per_device[1].peak_mem,
            "coshard stage {} vs plain {}",
            rc.per_device[1].peak_mem,
            rp.per_device[1].peak_mem
        );
    }

    #[test]
    fn conflicting_stage_spec_is_rejected() {
        let bad = StageSpec { tp: 2, shards: 4, ..StageSpec::default() };
        let err = hetero(&gpt3(0, 8, 256), 1, 4, &[bad, StageSpec::tp(2)]).unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn candidates_include_dp_replicated_pipelines() {
        let model = gpt3(0, 8, 256);
        let cluster = crate::cost::Cluster::v100(8);
        let cands = hetero_candidates(&model, &cluster);
        // Every emitted spec tiles the cluster through dp × sum(widths)...
        for s in &cands {
            let widths: usize = s.stages.as_ref().unwrap().iter().map(|st| st.width()).sum();
            assert_eq!(s.devices(), s.dp.max(1) * widths, "{}", s.label());
            assert_eq!(s.devices(), 8, "{}", s.label());
            assert!(s.dp.max(1) * s.micro.max(1) <= 8, "{}", s.label());
        }
        // ...and the dp outer loop actually reaches dp >= 2 replicas.
        assert!(cands.iter().any(|s| s.dp >= 2), "no replicated pipeline emitted");
        // dp = 1 heterogeneous compositions are still explored.
        let varied = |st: &[StageSpec]| st.iter().any(|x| x.width() != st[0].width());
        assert!(cands
            .iter()
            .any(|s| s.dp <= 1 && s.stages.as_deref().map_or(false, varied)));
    }

    #[test]
    fn dp_replicated_hetero_builds_and_names_dp() {
        let out = hetero(&gpt3(0, 8, 256), 2, 2, &[StageSpec::tp(2), StageSpec::tp(2)]).unwrap();
        assert!(out.name.contains("dp2"), "{}", out.name);
        let vs = validate(&out.graph, &out.schedule).expect("dp hetero schedule valid");
        assert!(!vs.topo.is_empty());
        let c = crate::cost::Cluster::v100(8);
        let r = crate::sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        assert_eq!(r.per_device.len(), 8, "2 replicas x 4 devices");
        assert!(r.comm_bytes > 0, "cross-replica gradient sync must move bytes");
    }

    #[test]
    fn stage_memoization_is_behavior_preserving() {
        // The memoized inner-choice table must emit exactly the spec list
        // the direct (re-ranked per stage slot) path emits...
        let model = gpt3(0, 8, 256);
        let cluster = crate::cost::Cluster::v100(8);
        let memo = hetero_candidates_impl(&model, &cluster, true);
        let plain = hetero_candidates_impl(&model, &cluster, false);
        assert_eq!(memo, plain, "memoized candidate grid diverged from the unmemoized path");
        assert!(!memo.is_empty());
        // ...and building a memo-chosen spec is a pure function of the spec:
        // two builds from the same borrowed model produce bitwise-identical
        // simulated plans (the "cache and splice" path changes nothing).
        let spec = memo.iter().find(|s| s.dp >= 2).expect("a replicated candidate");
        let c = crate::cost::Cluster::v100(spec.devices());
        let mk = || {
            hetero(&model, spec.dp, spec.micro, spec.stages.as_deref().unwrap()).unwrap()
        };
        let (a, b) = (mk(), mk());
        let ra = crate::sim::run(&a.graph, &a.schedule, &c, CommMode::InterRvd).unwrap();
        let rb = crate::sim::run(&b.graph, &b.schedule, &c, CommMode::InterRvd).unwrap();
        assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
        assert_eq!(ra.comm_bytes, rb.comm_bytes);
        assert_eq!(ra.max_peak_mem(), rb.max_peak_mem());
    }

    #[test]
    fn explicit_layer_split_overrides_balanced_partition() {
        let model = gpt3(0, 8, 256);
        let c = crate::cost::Cluster::v100(2);
        let auto = [StageSpec::tp(1), StageSpec::tp(1)];
        // 26 layer groups (embed + 24 + head): force a heavily skewed 4|22
        // split that no FLOP-balanced partition would pick.
        let skew = [
            StageSpec { layers: 4, ..StageSpec::tp(1) },
            StageSpec { layers: 22, ..StageSpec::tp(1) },
        ];
        let a = hetero(&model, 1, 2, &auto).unwrap();
        let s = hetero(&model, 1, 2, &skew).unwrap();
        let ra = crate::sim::run(&a.graph, &a.schedule, &c, CommMode::InterRvd).unwrap();
        let rs = crate::sim::run(&s.graph, &s.schedule, &c, CommMode::InterRvd).unwrap();
        assert_ne!(
            ra.makespan.to_bits(),
            rs.makespan.to_bits(),
            "a skewed explicit partition must change the pipeline timeline"
        );
        // An incomplete/inconsistent explicit split falls back to balanced.
        let partial = [StageSpec { layers: 2, ..StageSpec::tp(1) }, StageSpec::tp(1)];
        let p = hetero(&model, 1, 2, &partial).unwrap();
        let rp = crate::sim::run(&p.graph, &p.schedule, &c, CommMode::InterRvd).unwrap();
        assert_eq!(ra.makespan.to_bits(), rp.makespan.to_bits());
    }

    #[test]
    fn candidates_cover_uniform_and_heterogeneous_points() {
        let model = gpt3(0, 8, 256);
        let cluster = crate::cost::Cluster::v100(8);
        let cands = hetero_candidates(&model, &cluster);
        assert!(!cands.is_empty());
        assert!(cands.iter().all(|s| s.devices() == 8), "all candidates tile the cluster");
        // The homogeneous-equivalent uniform point megatron defaults to.
        assert!(cands.iter().any(|s| {
            s.micro == 4
                && s.stages.as_ref().map_or(false, |st| {
                    st.len() == 2 && st.iter().all(|x| *x == StageSpec::tp(4))
                })
        }));
        // And at least one genuinely heterogeneous composition.
        assert!(cands.iter().any(|s| {
            s.stages
                .as_ref()
                .map_or(false, |st| st.iter().any(|x| x.width() != st[0].width()))
        }));
    }
}
