//! 3F1B pipeline — the paper's new schedule for AlphaFold2 (§2, Fig. 2).
//! AlphaFold2 recycles: three forward passes chain into one backward pass.
//! No existing pipeline discipline expresses this; with decoupled
//! scheduling it is just a different `op-order` pattern: each micro-batch's
//! three forward transits and single backward transit interleave across
//! stages like virtual micro-batches.
//!
//! This family is the deliberate exception to the schedule DSL
//! ([`crate::schedule::dsl`]): its recycling passes give each micro-batch
//! *three* F transits, which the (micro × F/B/W) slot vocabulary cannot
//! name, so the ordering below stays bespoke and the `sched{...}` search
//! axis rejects [`PlanKind::ThreeFOneB`].

use super::*;
use crate::trans::autograd;

/// `pipeline_3f1b(model, s, k)`: `s` stages = devices, `k` micro-batches.
/// The model must be built with recycled passes (ops of passes 0..n-1
/// tagged `no_grad`, all passes sharing layer tags) — see
/// [`crate::models::alphafold2`].
pub fn pipeline_3f1b(model: &Model, s: usize, k: usize) -> PlanResult {
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();
    let stages = balance_stages(g, &model.layers, s);

    // Split every fwd op into K micro-batches. pieces[(layer, mb)] = ops
    // (all passes mixed; pass identity preserved via op name/no_grad).
    let mut pieces: HashMap<(usize, usize), Vec<OpId>> = HashMap::new();
    for (li, ops) in model.layers.iter().enumerate() {
        for &op in ops {
            let dim = g
                .op(op)
                .signature
                .as_ref()
                .and_then(|sg| sg.batch.clone())
                .expect("fwd op without batch");
            for (m, p) in op_trans(g, op, &TransformAlgo::split(&dim, k))?.into_iter().enumerate() {
                pieces.entry((li, m)).or_default().push(p);
            }
        }
    }

    let ag = autograd::complete(g);

    // Assignment: stage devices own their layers across all three passes.
    let stage_of: HashMap<usize, usize> = stages
        .iter()
        .enumerate()
        .flat_map(|(si, ls)| ls.iter().map(move |&l| (l, si)))
        .collect();
    for (&(li, _m), ops) in &pieces {
        let dev = stage_of[&li];
        for &op in ops {
            sched.assign(op, dev);
            if let Some(&b) = ag.bwd_of.get(&op) {
                sched.assign(b, dev);
            }
        }
    }
    align_optimizers(g);
    assign_optimizers(g, &mut sched);

    // 3F1B ordering per stage: forward transits of (pass, mb) are virtual
    // micro-batches ordered (pass-major is forced by recycling data deps;
    // mb-minor keeps the pipe full); the single backward interleaves 1F1B
    // style against the *third* pass.
    for (si, ls) in stages.iter().enumerate() {
        let mut fwd_units: Vec<(OpId, OpId)> = Vec::new(); // 3K units
        let mut bwd_units: Vec<(OpId, OpId)> = Vec::new(); // K units
        for pass in 0..crate::models::alphafold::N_PASSES {
            for m in 0..k {
                let fops: Vec<OpId> = ls
                    .iter()
                    .flat_map(|&l| pieces[&(l, m)].iter().copied())
                    .filter(|&o| g.op(o).name.starts_with(&format!("p{pass}")))
                    .collect();
                if fops.is_empty() {
                    continue;
                }
                fwd_units.push(span(&fops));
                if pass + 1 == crate::models::alphafold::N_PASSES {
                    let bops: Vec<OpId> = fops
                        .iter()
                        .filter_map(|o| ag.bwd_of.get(o).copied())
                        .collect();
                    if !bops.is_empty() {
                        bwd_units.push(span(&bops));
                    }
                }
            }
        }
        // Chain forward transits; hang each backward after its pass-3 fwd.
        for w in fwd_units.windows(2) {
            sched.order(w[0].1, w[1].0);
        }
        // 1F1B-style: backward of mb m goes right after fwd3 of mb m on this
        // stage (the data deps + device serialization interleave the rest).
        let base = fwd_units.len() - bwd_units.len();
        for (m, b) in bwd_units.iter().enumerate() {
            sched.order(fwd_units[base + m].1, b.0);
        }
        let _ = si;
    }

    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!("3f1b-s{s}k{k}"),
    })
}

/// [`Planner`] for the 3F1B recycling pipeline.
pub struct ThreeFOneBPlanner;

impl Planner for ThreeFOneBPlanner {
    fn kind(&self) -> PlanKind {
        PlanKind::ThreeFOneB
    }

    fn description(&self) -> &'static str {
        "NEW: 3F1B recycling pipeline for AlphaFold2 (Fig. 2)"
    }

    fn applicable(&self, model: &Model) -> bool {
        // Needs recycled forward passes (no_grad passes chained into one
        // backward) — the structure `pipeline_3f1b` interleaves.
        model.graph.live_ops().any(|o| o.is_forward && o.no_grad)
    }

    fn default_spec(&self, gpus: usize, micro: usize) -> PlanSpec {
        PlanSpec {
            pp: gpus.max(1),
            micro: micro.max(1),
            ..PlanSpec::new(PlanKind::ThreeFOneB)
        }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<PlanSpec> {
        [4usize, 8]
            .iter()
            .map(|&k| PlanSpec {
                pp: cluster.num_gpus(),
                micro: k,
                ..PlanSpec::new(PlanKind::ThreeFOneB)
            })
            .collect()
    }

    fn build(&self, model: &Model, spec: &PlanSpec) -> PlanResult {
        pipeline_3f1b(model, spec.pp.max(1), spec.micro.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::alphafold2;

    #[test]
    fn f3b1_runs_and_shards_weights_across_stages() {
        let out = pipeline_3f1b(&alphafold2(0, 8), 4, 4).unwrap();
        let c = crate::cost::Cluster::v100(4);
        let vs = crate::schedule::validate(&out.graph, &out.schedule).unwrap();
        let plan = crate::materialize::materialize(&out.graph, &vs, &c, CommMode::InterRvd);
        let r = crate::sim::simulate(&out.graph, &vs, &plan, &c);
        assert!(r.makespan > 0.0);
        // Pipeline shards weights: each stage's *static* memory (weights +
        // grads + Adam state) is a fraction of the whole model's, unlike
        // DAP's full replication. Whole model static = 4x weight bytes.
        let total_static = 4 * out.graph.weight_bytes();
        for (dev, &bytes) in &plan.static_mem {
            assert!(
                bytes < total_static * 6 / 10,
                "stage {dev} holds {bytes} of {total_static} static bytes"
            );
        }
    }

    #[test]
    fn f3b1_pipeline_comm_is_boundary_only() {
        // 3F1B communicates activations at stage boundaries only — far less
        // than the total activation volume.
        let out = pipeline_3f1b(&alphafold2(0, 8), 4, 4).unwrap();
        let c = crate::cost::Cluster::v100(4);
        let r = crate::sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        let act_bytes: u64 = out
            .graph
            .ptensors
            .iter()
            .filter(|p| p.kind == crate::graph::TensorKind::Activation)
            .map(|p| p.bytes())
            .sum();
        assert!(r.comm_bytes < act_bytes / 4, "comm {} vs acts {act_bytes}", r.comm_bytes);
    }
}
