//! Data parallelism — the paper's Algorithm 1, verbatim structure:
//! partition every forward op along its batch dim, replicate the optimizer
//! ops, zip the pieces onto devices. Autograd completion then yields
//! value-split weight gradients whose materialization is the DP all-reduce.

use super::{PlanOutput, PlanResult};
use crate::graph::OpKind;
use crate::models::Model;
use crate::schedule::Schedule;
use crate::trans::{autograd, op_trans, TransformAlgo};

/// `data_parallel(model, ndev)`: one replica per device. The model is
/// borrowed; only its graph (the structure the transformation rewrites) is
/// cloned into the plan under construction.
pub fn data_parallel(model: &Model, ndev: usize) -> PlanResult {
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();

    // Algorithm 1 line 2-7: partition forward ops, replicate optimizers.
    let fwd_ops: Vec<_> = g.live_ops().filter(|o| o.is_forward).map(|o| o.id).collect();
    let mut fwd_pieces = Vec::new();
    for op in fwd_ops {
        let dim = g
            .op(op)
            .signature
            .as_ref()
            .and_then(|s| s.batch.clone())
            .expect("forward op without batch dim");
        fwd_pieces.push(op_trans(g, op, &TransformAlgo::split(&dim, ndev))?);
    }
    let opt_ops: Vec<_> = g
        .live_ops()
        .filter(|o| o.kind == OpKind::Optimizer)
        .map(|o| o.id)
        .collect();
    let mut opt_pieces = Vec::new();
    for op in opt_ops {
        opt_pieces.push(op_trans(g, op, &TransformAlgo::replicate(ndev))?);
    }

    // Backward ops adapt automatically (paper §5).
    let ag = autograd::complete(g);

    // Algorithm 1 line 8-9: zip pieces onto devices.
    for pieces in &fwd_pieces {
        for (d, &op) in pieces.iter().enumerate() {
            sched.assign(op, d);
            if let Some(&b) = ag.bwd_of.get(&op) {
                sched.assign(b, d);
            }
        }
    }
    for pieces in &opt_pieces {
        for (d, &op) in pieces.iter().enumerate() {
            sched.assign(op, d);
        }
    }

    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!("dp{ndev}"),
    })
}

/// [`Planner`] for Algorithm-1 data parallelism.
pub struct DpPlanner;

impl super::Planner for DpPlanner {
    fn kind(&self) -> super::PlanKind {
        super::PlanKind::Dp
    }

    fn description(&self) -> &'static str {
        "Algorithm 1 data parallelism"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, _micro: usize) -> super::PlanSpec {
        super::PlanSpec { dp: gpus.max(1), ..super::PlanSpec::new(super::PlanKind::Dp) }
    }

    fn candidates(&self, _model: &Model, _cluster: &crate::cost::Cluster) -> Vec<super::PlanSpec> {
        // The megatron grid's (n, 1, 1) point degenerates to Algorithm-1
        // data parallelism (see plans/megatron.rs docs), so contributing a
        // dp candidate here would make every search evaluate it twice.
        Vec::new()
    }

    fn build(&self, model: &Model, spec: &super::PlanSpec) -> PlanResult {
        data_parallel(model, spec.dp.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::gpt3;

    #[test]
    fn dp_simulates_with_allreduce_comm() {
        let model = gpt3(0, 8, 512);
        let total_flops_serial = model.graph.total_flops();
        let out = data_parallel(&model, 4).unwrap();
        let c = crate::cost::Cluster::v100(4);
        let r = crate::sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(r.comm_bytes > 0, "DP must all-reduce gradients");
        // All forward flops conserved (x3 with bwd, + optimizer).
        assert!(r.total_flops > total_flops_serial * 2.9);
        // Compute spread across 4 devices.
        assert_eq!(r.per_device.len(), 4);
        let c0 = r.per_device[0].compute;
        for d in &r.per_device {
            assert!((d.compute - c0).abs() < 0.05 * c0, "balanced compute");
        }
    }

    #[test]
    fn dp_speedup_vs_serial_is_sublinear_but_real() {
        // One borrowed model serves both plans — the zero-rebuild pipeline.
        let m = gpt3(0, 8, 512);
        let c = crate::cost::Cluster::v100(4);
        let s1 = data_parallel(&m, 1).unwrap();
        let s4 = data_parallel(&m, 4).unwrap();
        let r1 = crate::sim::run(&s1.graph, &s1.schedule, &c, CommMode::InterRvd).unwrap();
        let r4 = crate::sim::run(&s4.graph, &s4.schedule, &c, CommMode::InterRvd).unwrap();
        let speedup = r1.makespan / r4.makespan;
        assert!(speedup > 2.0 && speedup < 4.05, "speedup {speedup}");
    }
}
