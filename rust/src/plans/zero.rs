//! ZeRO-3 (DeepSpeed) — data parallelism with optimizer/gradient/weight
//! sharding (Rajbhandari et al.), expressed as an sProgram: Algorithm 1's
//! DP transformation, but the optimizer ops are *split* along the flattened
//! weight dim instead of replicated. Each device then owns 1/n of every
//! weight, its Adam states and its gradient shard; materialization derives
//! the reduce-scatter (grads) and all-gather (weights before use) that
//! DeepSpeed hand-codes.
//!
//! `offload = true` additionally assigns the optimizer ops to the host
//! ([`CPU_DEVICE`]), so master weights/moments live in host memory and the
//! PCIe transfers appear in the plan (ZeRO-Offload).

use super::{PlanOutput, PlanResult};
use crate::graph::OpKind;
use crate::models::Model;
use crate::schedule::{Schedule, CPU_DEVICE};
use crate::trans::{autograd, op_trans, TransformAlgo};

/// `zero3(model, ndev, offload)`. Borrows the model; only the graph is
/// cloned into the plan under construction.
pub fn zero3(model: &Model, ndev: usize, offload: bool) -> PlanResult {
    let mut graph = model.graph.clone();
    let g = &mut graph;
    let mut sched = Schedule::new();

    let fwd_ops: Vec<_> = g.live_ops().filter(|o| o.is_forward).map(|o| o.id).collect();
    let mut fwd_pieces = Vec::new();
    for op in fwd_ops {
        let dim = g
            .op(op)
            .signature
            .as_ref()
            .and_then(|s| s.batch.clone())
            .expect("forward op without batch dim");
        fwd_pieces.push(op_trans(g, op, &TransformAlgo::split(&dim, ndev))?);
    }
    // ZeRO: shard the optimizer along the weight's leading dim ("p" in the
    // optimizer signature maps to axis 0 of the weight masks).
    let opt_ops: Vec<_> = g
        .live_ops()
        .filter(|o| o.kind == OpKind::Optimizer)
        .map(|o| o.id)
        .collect();
    let mut opt_pieces = Vec::new();
    for op in opt_ops {
        // Cap by the weight's leading-dim size (e.g. Swin's wo[a, d, h] has
        // a tiny first axis); leftover group slots keep fewer, larger shards.
        let sz = g.vtensor_shape(g.op(op).outputs[0])[0];
        let eff = super::feasible_split(sz, ndev);
        opt_pieces.push(op_trans(g, op, &TransformAlgo::split("p", eff))?);
    }

    let ag = autograd::complete(g);

    for pieces in &fwd_pieces {
        for (d, &op) in pieces.iter().enumerate() {
            sched.assign(op, d);
            if let Some(&b) = ag.bwd_of.get(&op) {
                sched.assign(b, d);
            }
        }
    }
    for pieces in &opt_pieces {
        for (d, &op) in pieces.iter().enumerate() {
            sched.assign(op, if offload { CPU_DEVICE } else { d });
        }
    }

    Ok(PlanOutput {
        graph,
        schedule: sched,
        name: format!("zero3{}{ndev}", if offload { "-offload" } else { "" }),
    })
}

/// [`Planner`] for ZeRO-3 (device-resident optimizer shards).
pub struct Zero3Planner;

/// [`Planner`] for ZeRO-3 with the optimizer offloaded to the host.
pub struct Zero3OffloadPlanner;

impl super::Planner for Zero3Planner {
    fn kind(&self) -> super::PlanKind {
        super::PlanKind::Zero3
    }

    fn description(&self) -> &'static str {
        "DeepSpeed ZeRO-3 sharded optimizer"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, _micro: usize) -> super::PlanSpec {
        super::PlanSpec { dp: gpus.max(1), ..super::PlanSpec::new(super::PlanKind::Zero3) }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<super::PlanSpec> {
        vec![self.default_spec(cluster.num_gpus(), 1)]
    }

    fn build(&self, model: &Model, spec: &super::PlanSpec) -> PlanResult {
        zero3(model, spec.dp.max(1), spec.offload)
    }
}

impl super::Planner for Zero3OffloadPlanner {
    fn kind(&self) -> super::PlanKind {
        super::PlanKind::Zero3Offload
    }

    fn description(&self) -> &'static str {
        "ZeRO-3 with CPU-offloaded optimizer"
    }

    fn applicable(&self, _model: &Model) -> bool {
        true
    }

    fn default_spec(&self, gpus: usize, _micro: usize) -> super::PlanSpec {
        super::PlanSpec {
            dp: gpus.max(1),
            offload: true,
            ..super::PlanSpec::new(super::PlanKind::Zero3Offload)
        }
    }

    fn candidates(&self, _model: &Model, cluster: &crate::cost::Cluster) -> Vec<super::PlanSpec> {
        vec![self.default_spec(cluster.num_gpus(), 1)]
    }

    fn build(&self, model: &Model, spec: &super::PlanSpec) -> PlanResult {
        // default_spec sets offload = true; honoring the field keeps
        // `--offload false` truthful instead of silently ignored.
        zero3(model, spec.dp.max(1), spec.offload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::materialize::CommMode;
    use crate::models::gpt3;
    use crate::plans::data_parallel;

    #[test]
    fn zero_shards_static_memory_vs_dp() {
        let c = crate::cost::Cluster::v100(4);
        let z = zero3(&gpt3(0, 8, 256), 4, false).unwrap();
        let d = data_parallel(&gpt3(0, 8, 256), 4).unwrap();
        let rz = crate::sim::run(&z.graph, &z.schedule, &c, CommMode::InterRvd).unwrap();
        let rd = crate::sim::run(&d.graph, &d.schedule, &c, CommMode::InterRvd).unwrap();
        // ZeRO's optimizer state is sharded 4 ways -> much smaller static
        // footprint; peaks must reflect that.
        assert!(
            rz.max_peak_mem() < rd.max_peak_mem(),
            "zero {} vs dp {}",
            rz.max_peak_mem(),
            rd.max_peak_mem()
        );
        // But it pays more communication (weight gathers).
        assert!(rz.comm_bytes > rd.comm_bytes / 2);
    }

    #[test]
    fn offload_moves_optimizer_to_cpu() {
        let z = zero3(&gpt3(0, 4, 256), 2, true).unwrap();
        let opt_devices: Vec<_> = z
            .graph
            .live_ops()
            .filter(|o| o.kind == OpKind::Optimizer)
            .map(|o| z.schedule.device_of(o.id).unwrap())
            .collect();
        assert!(!opt_devices.is_empty());
        assert!(opt_devices.iter().all(|&d| d == CPU_DEVICE));
        // And it simulates (PCIe traffic + CPU compute).
        let c = crate::cost::Cluster::v100(2);
        let r = crate::sim::run(&z.graph, &z.schedule, &c, CommMode::InterRvd).unwrap();
        assert!(r.makespan > 0.0);
    }
}
