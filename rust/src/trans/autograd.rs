//! Autograd completion (paper §5, "Autograd for forward operator
//! transformation").
//!
//! Model builders describe only the forward pass (plus optimizer ops);
//! plans transform the forward ops; then [`complete`] derives the backward
//! ops *from the transformed forward graph*, so backward parallelism always
//! mirrors forward parallelism — exactly the paper's "SuperScaler will adapt
//! them to their forward operators automatically".
//!
//! Chain-rule mask inference:
//! * grad-of-output inputs mirror the forward op's output masks (on the
//!   gradient pTensor of the activation);
//! * stashed-activation inputs mirror the forward inputs (this pins
//!   activation lifetimes for the memory model);
//! * grad outputs mirror the forward input masks — and when several forward
//!   ops read overlapping regions of the same pTensor, each backward op
//!   yields a *value partial* of that gradient (paper: "different operators
//!   consuming the same vTensor leads to the value-partition of its
//!   gradient, which will incur all-reduce").

use crate::graph::{DType, Graph, Op, OpId, OpKind, PTensorId, TensorKind, VTensorId};
use std::collections::HashMap;

/// Result of autograd completion.
pub struct Autograd {
    /// forward op -> its backward op.
    pub bwd_of: HashMap<OpId, OpId>,
    /// activation/input pTensor -> gradient pTensor (weights' gradient
    /// pTensors are expected to pre-exist; see [`grad_name`]).
    pub grad_of: HashMap<PTensorId, PTensorId>,
}

/// Naming convention linking a tensor to its gradient. Model builders create
/// `w.grad` pTensors for weights eagerly (so optimizer ops can reference
/// them before autograd runs); autograd reuses them by name.
pub fn grad_name(name: &str) -> String {
    format!("{name}.grad")
}

/// Ratio of backward to forward FLOPs. Standard for matmul-dominated nets:
/// backward computes grads w.r.t. both inputs -> 2x the forward work.
pub const BWD_FLOP_RATIO: f64 = 2.0;

/// Generate backward ops for every live forward op in `g`.
///
/// Backward ops are created in reverse forward order, named `<fwd>.bw`,
/// with `is_forward = false`, the forward op's layer/microbatch tags, and
/// `origin` pointing at the forward op. Ops whose outputs are only consumed
/// by `Optimizer` ops (or nothing) still get a backward twin — the graph is
/// one training iteration, so every forward op participates in the loss.
pub fn complete(g: &mut Graph) -> Autograd {
    // Pre-existing gradient pTensors by name (weights).
    let mut grad_of: HashMap<PTensorId, PTensorId> = HashMap::new();
    let by_name: HashMap<String, PTensorId> = g
        .ptensors
        .iter()
        .map(|p| (p.name.clone(), p.id))
        .collect();
    for p in 0..g.ptensors.len() {
        if let Some(&gid) = by_name.get(&grad_name(&g.ptensors[p].name.clone())) {
            grad_of.insert(p, gid);
        }
    }

    // Forward readers per pTensor, with their input masks. A gradient is
    // value-split only among readers whose masks *overlap*: e.g. in data
    // parallelism every replica reads the whole weight (k overlapping
    // readers ⇒ k grad partials ⇒ all-reduce at materialization), while in
    // tensor parallelism each shard reads a disjoint weight column (no
    // overlap ⇒ spatially disjoint grads ⇒ no reduce).
    let mut readers: HashMap<PTensorId, Vec<(OpId, crate::graph::mask::Mask)>> = HashMap::new();
    let fwd_ids: Vec<OpId> = g
        .live_ops()
        .filter(|o| o.is_forward && !o.no_grad)
        .map(|o| o.id)
        .collect();
    for &f in &fwd_ids {
        for &v in &g.op(f).inputs {
            let vt = g.vtensor(v);
            readers.entry(vt.ptensor).or_default().push((f, vt.mask.clone()));
        }
    }

    let mut bwd_of = HashMap::new();
    // Reverse order: gradients flow opposite to data.
    for &f in fwd_ids.iter().rev() {
        let fwd = g.op(f).clone();
        // Inputs of the backward op: grad of each fwd output + stashed fwd
        // inputs (activations/weights needed by the chain rule).
        let mut inputs: Vec<VTensorId> = Vec::new();
        for &ov in &fwd.outputs {
            let vt = g.vtensor(ov).clone();
            let gpt = ensure_grad(g, &mut grad_of, vt.ptensor);
            inputs.push(g.add_vtensor(gpt, vt.mask));
        }
        // Linear ops (residual adds) need no stashed inputs — their grad is
        // identity. Everything else stashes its forward inputs (this pins
        // activation lifetimes for the memory model).
        if fwd.kind != OpKind::Elementwise("add") {
            for &iv in &fwd.inputs {
                let vt = g.vtensor(iv).clone();
                inputs.push(g.add_vtensor(vt.ptensor, vt.mask));
            }
        }
        // Outputs: grad of each fwd input. Value-split by reader multiplicity.
        let mut outputs: Vec<VTensorId> = Vec::new();
        for &iv in &fwd.inputs {
            let vt = g.vtensor(iv).clone();
            let pt_kind = g.ptensor(vt.ptensor).kind;
            if pt_kind == TensorKind::Input {
                continue; // no gradient for raw data inputs
            }
            let gpt = ensure_grad(g, &mut grad_of, vt.ptensor);
            // Readers whose input masks overlap this one (incl. f itself).
            let overlapping: Vec<OpId> = readers[&vt.ptensor]
                .iter()
                .filter(|(_, m)| vt.mask.depends_on(m))
                .map(|(r, _)| *r)
                .collect();
            let k = overlapping.len();
            let j = overlapping.iter().position(|&r| r == f).unwrap();
            let mask = if k > 1 { vt.mask.split_value(j, k) } else { vt.mask };
            outputs.push(g.add_vtensor(gpt, mask));
        }
        let bop = Op {
            id: 0,
            name: format!("{}.bw", fwd.name),
            kind: fwd.kind.clone(),
            inputs,
            outputs,
            flops: fwd.flops * BWD_FLOP_RATIO,
            signature: None, // backward ops are never op-trans'ed directly
            is_forward: false,
            layer: fwd.layer,
            microbatch: fwd.microbatch,
            origin: Some(f),
            recompute: false,
            no_grad: false,
        };
        let bid = g.insert_op(bop);
        bwd_of.insert(f, bid);
    }
    Autograd { bwd_of, grad_of }
}

/// Split every backward op with both gradient classes into a **B** task
/// (activation gradients — the cross-stage critical path) and a **W** task
/// (weight gradients — consumed only by the optimizer, so free to fill
/// pipeline bubbles). This is the zero-bubble decomposition (ZB-H1): each
/// half costs `flops / 2` (= 1× the forward work under
/// [`BWD_FLOP_RATIO`] = 2), so splitting halves the backward critical path
/// without changing total per-device work.
///
/// Backward ops producing only one gradient class are left whole. Both
/// halves keep all stashed inputs (output-grad + forward inputs): B needs
/// the weights, W needs the activations, and the shared upstream gradient
/// feeds both — neither half depends on the other, which is exactly what
/// lets a schedule defer W. The double-listed upstream gradient does NOT
/// double its wire cost: `materialize`'s generic-P2P tier shares one recv
/// per (producer, destination device, overlap) among all consumers, so a
/// cross-stage dy lands once and both halves depend on that single
/// transfer.
///
/// `ag.bwd_of` is updated to point at the B half; the returned map gives
/// `forward op -> W op` for the ops that were split.
pub fn split_bw(g: &mut Graph, ag: &mut Autograd) -> HashMap<OpId, OpId> {
    let mut wmap: HashMap<OpId, OpId> = HashMap::new();
    let mut pairs: Vec<(OpId, OpId)> = ag.bwd_of.iter().map(|(&f, &b)| (f, b)).collect();
    pairs.sort_unstable(); // deterministic id allocation
    for (f, b) in pairs {
        let probe = g.op(b).clone();
        let mut act_outs = Vec::new();
        let mut w_outs = Vec::new();
        for &ov in &probe.outputs {
            let vt = g.vtensor(ov).clone();
            if g.ptensor(vt.ptensor).kind == TensorKind::Gradient {
                w_outs.push(vt);
            } else {
                act_outs.push(vt);
            }
        }
        if act_outs.is_empty() || w_outs.is_empty() {
            continue; // single-class backward: nothing to split
        }
        let old = g.remove_op(b);
        let clone_inputs = |g: &mut Graph| -> Vec<VTensorId> {
            old.inputs
                .iter()
                .map(|&v| {
                    let vt = g.vtensor(v).clone();
                    g.add_vtensor(vt.ptensor, vt.mask)
                })
                .collect()
        };
        let b_inputs = clone_inputs(g);
        let w_inputs = clone_inputs(g);
        let mut bop = old.clone();
        bop.id = 0;
        bop.inputs = b_inputs;
        bop.outputs =
            act_outs.iter().map(|vt| g.add_vtensor(vt.ptensor, vt.mask.clone())).collect();
        bop.flops = old.flops / 2.0;
        let bid = g.insert_op(bop);
        let mut wop = old.clone();
        wop.id = 0;
        wop.name = format!("{}.w", old.name);
        wop.inputs = w_inputs;
        wop.outputs = w_outs.iter().map(|vt| g.add_vtensor(vt.ptensor, vt.mask.clone())).collect();
        wop.flops = old.flops / 2.0;
        let wid = g.insert_op(wop);
        ag.bwd_of.insert(f, bid);
        wmap.insert(f, wid);
    }
    wmap
}

fn ensure_grad(
    g: &mut Graph,
    grad_of: &mut HashMap<PTensorId, PTensorId>,
    pt: PTensorId,
) -> PTensorId {
    if let Some(&gid) = grad_of.get(&pt) {
        return gid;
    }
    let p = g.ptensor(pt).clone();
    // Weight gradients persist until the optimizer step (TensorKind::
    // Gradient, counted as static memory); activation gradients are
    // transient like activations themselves.
    let kind = if p.kind == TensorKind::Weight {
        TensorKind::Gradient
    } else {
        TensorKind::Activation
    };
    let gid = g.add_ptensor(
        &grad_name(&p.name),
        &p.shape,
        // Gradients accumulate in the activation dtype.
        if p.dtype == DType::I32 { DType::F32 } else { p.dtype },
        kind,
    );
    grad_of.insert(pt, gid);
    gid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sig::sigs;
    use crate::graph::{DType, Graph, OpKind, TensorKind};
    use crate::trans::{op_trans, TransformAlgo};

    /// x -> lin(w) -> y, plus an eagerly-created w.grad + optimizer op,
    /// mirroring what the model builders do.
    fn tiny_model() -> (Graph, OpId, PTensorId) {
        let mut g = Graph::new();
        let x = g.add_ptensor("x", &[4, 8, 16], DType::F32, TensorKind::Input);
        let w = g.add_ptensor("w", &[16, 32], DType::F32, TensorKind::Weight);
        let wg = g.add_ptensor("w.grad", &[16, 32], DType::F32, TensorKind::Gradient);
        let y = g.add_ptensor("y", &[4, 8, 32], DType::F32, TensorKind::Activation);
        let (xv, wv, yv) = (g.full_view(x), g.full_view(w), g.full_view(y));
        let lin = g.add_op(
            "lin",
            OpKind::Matmul,
            vec![xv, wv],
            vec![yv],
            1000.0,
            Some(sigs::linear()),
            true,
            0,
        );
        // Optimizer consumes w.grad and updates w.
        let (gv, wv2, wv3) = (g.full_view(wg), g.full_view(w), g.full_view(w));
        g.add_op(
            "opt.w",
            OpKind::Optimizer,
            vec![gv, wv2],
            vec![wv3],
            64.0,
            Some(sigs::optimizer()),
            false,
            0,
        );
        (g, lin, wg)
    }

    #[test]
    fn backward_mirrors_forward() {
        let (mut g, lin, wg) = tiny_model();
        let ag = complete(&mut g);
        let b = ag.bwd_of[&lin];
        let bop = g.op(b);
        assert!(!bop.is_forward);
        assert!((bop.flops - 2000.0).abs() < 1e-9);
        // Outputs: grad x (skipped: Input has no grad? x is Input -> skipped)
        // and grad w, which must target the *pre-existing* w.grad pTensor.
        let out_pts: Vec<_> = bop.outputs.iter().map(|&v| g.vtensor(v).ptensor).collect();
        assert_eq!(out_pts, vec![wg]);
    }

    #[test]
    fn dp_transform_then_autograd_value_splits_weight_grad() {
        // Data parallelism: split batch 4 ways, then autograd. The 4
        // backward ops must each produce a value-partial of w.grad — this is
        // what materialization later turns into an all-reduce.
        let (mut g, lin, wg) = tiny_model();
        let ids = op_trans(&mut g, lin, &TransformAlgo::split("b", 4)).unwrap();
        let ag = complete(&mut g);
        let mut parts = Vec::new();
        for &f in &ids {
            let b = ag.bwd_of[&f];
            let gout = g
                .op(b)
                .outputs
                .iter()
                .map(|&v| g.vtensor(v).clone())
                .find(|vt| vt.ptensor == wg)
                .expect("w.grad output");
            assert_eq!(gout.mask.vsplit.parts, 4, "grad must be a 4-way value split");
            parts.push(gout.mask);
        }
        assert!(crate::graph::mask::tiles_full(&parts));
    }

    #[test]
    fn tensor_parallel_grad_masks_mirror_weight_shards() {
        // Split n (column parallel): each backward produces the grad of its
        // own w column shard — spatially split, NOT value split.
        let (mut g, lin, wg) = tiny_model();
        let ids = op_trans(&mut g, lin, &TransformAlgo::split("n", 2)).unwrap();
        let ag = complete(&mut g);
        for (i, &f) in ids.iter().enumerate() {
            let b = ag.bwd_of[&f];
            let gout = g
                .op(b)
                .outputs
                .iter()
                .map(|&v| g.vtensor(v).clone())
                .find(|vt| vt.ptensor == wg)
                .unwrap();
            assert!(gout.mask.vsplit.is_full());
            assert_eq!(gout.mask.concrete(&[16, 32]), vec![(0, 16), (16 * i, 16 * (i + 1))]);
        }
    }

    #[test]
    fn activation_grads_created_on_demand() {
        let (mut g, _lin, _) = tiny_model();
        let n_pt = g.ptensors.len();
        let ag = complete(&mut g);
        // y.grad was created (x is Input -> no grad).
        assert!(g.ptensors.len() > n_pt);
        let y = g.ptensors.iter().find(|p| p.name == "y").unwrap().id;
        let ygrad = ag.grad_of[&y];
        assert_eq!(g.ptensor(ygrad).name, "y.grad");
        // Activation gradient: transient like an activation.
        assert_eq!(g.ptensor(ygrad).kind, TensorKind::Activation);
    }

    #[test]
    fn split_bw_halves_flops_and_separates_gradient_classes() {
        // Two chained linears: lin2's backward emits h.grad (activation
        // class) AND w2.grad (weight class), so it must split; lin1's
        // backward emits only w1.grad (x is Input) and stays whole.
        let mut g = Graph::new();
        let x = g.add_ptensor("x", &[4, 8, 16], DType::F32, TensorKind::Input);
        let w1 = g.add_ptensor("w1", &[16, 16], DType::F32, TensorKind::Weight);
        let w2 = g.add_ptensor("w2", &[16, 32], DType::F32, TensorKind::Weight);
        let w2g = g.add_ptensor("w2.grad", &[16, 32], DType::F32, TensorKind::Gradient);
        let h = g.add_ptensor("h", &[4, 8, 16], DType::F32, TensorKind::Activation);
        let y = g.add_ptensor("y", &[4, 8, 32], DType::F32, TensorKind::Activation);
        let (xv, w1v, hv) = (g.full_view(x), g.full_view(w1), g.full_view(h));
        let lin1 =
            g.add_op("lin1", OpKind::Matmul, vec![xv, w1v], vec![hv], 1000.0, None, true, 0);
        let (hv2, w2v, yv) = (g.full_view(h), g.full_view(w2), g.full_view(y));
        let lin2 =
            g.add_op("lin2", OpKind::Matmul, vec![hv2, w2v], vec![yv], 1000.0, None, true, 0);
        let mut ag = complete(&mut g);
        let whole1 = ag.bwd_of[&lin1];
        let whole2 = ag.bwd_of[&lin2];
        let whole_flops = g.op(whole2).flops;
        let wmap = split_bw(&mut g, &mut ag);
        assert!(!wmap.contains_key(&lin1), "single-class backward stays whole");
        assert_eq!(ag.bwd_of[&lin1], whole1);
        let b = ag.bwd_of[&lin2];
        let w = wmap[&lin2];
        assert_ne!(b, whole2, "bwd_of must point at the new B half");
        let b_op = g.op(b).clone();
        let w_op = g.op(w).clone();
        assert!((b_op.flops - whole_flops / 2.0).abs() < 1e-9);
        assert!((w_op.flops - whole_flops / 2.0).abs() < 1e-9);
        assert!(w_op.name.ends_with(".w"));
        assert!(!b_op.is_forward && !w_op.is_forward);
        // W emits only weight-grad outputs (incl. the eager w2.grad); B
        // emits only activation grads.
        for &ov in &w_op.outputs {
            assert_eq!(g.ptensor(g.vtensor(ov).ptensor).kind, TensorKind::Gradient);
        }
        for &ov in &b_op.outputs {
            assert_ne!(g.ptensor(g.vtensor(ov).ptensor).kind, TensorKind::Gradient);
        }
        assert!(w_op.outputs.iter().any(|&ov| g.vtensor(ov).ptensor == w2g));
    }

    #[test]
    fn split_bw_leaves_single_class_backwards_whole() {
        // An op whose backward has only activation grads must not split.
        let mut g = Graph::new();
        let a = g.add_ptensor("a", &[4], DType::F32, TensorKind::Activation);
        let b = g.add_ptensor("b", &[4], DType::F32, TensorKind::Activation);
        let (av, bv) = (g.full_view(a), g.full_view(b));
        let id = g.add_op("copy", OpKind::Identity, vec![av], vec![bv], 1.0, None, true, 0);
        let mut ag = complete(&mut g);
        let before = ag.bwd_of[&id];
        let wmap = split_bw(&mut g, &mut ag);
        assert!(wmap.is_empty());
        assert_eq!(ag.bwd_of[&id], before);
    }

    #[test]
    fn backward_stashes_forward_inputs() {
        // The backward op must read the fwd activations (chain rule), which
        // is what keeps them alive in the memory model.
        let (mut g, lin, _) = tiny_model();
        let ag = complete(&mut g);
        let b = ag.bwd_of[&lin];
        let in_pts: Vec<String> = g
            .op(b)
            .inputs
            .iter()
            .map(|&v| g.ptensor_of(v).name.clone())
            .collect();
        assert!(in_pts.contains(&"y.grad".to_string()));
        assert!(in_pts.contains(&"x".to_string()));
        assert!(in_pts.contains(&"w".to_string()));
    }
}
