//! Phase 1 — operator transformation (`op-trans`, paper §3.1).
//!
//! `op_trans(graph, op, algo)` replaces one operator with a set of
//! functionally equivalent operators, partitioning its *own* input/output
//! vTensors (masks over the unchanged pTensors) and leaving every other
//! operator untouched. Alignment between mismatched producer/consumer views
//! is deferred to dependency materialization (phase 3).
//!
//! Transformation algorithms mirror the paper's sProgram vocabulary:
//! * [`TransformAlgo::Split`] — `SplitAlgo(dim, n)`: partition along a named
//!   dim of the op's signature. Splitting a *reduction* dim value-splits the
//!   outputs (each new op produces an additive partial).
//! * [`TransformAlgo::Replicate`] — `ReplicaAlgo(n)`: n identical copies.
//!   Each copy's *outputs* are marked as value-partials scaled by 1/n where
//!   the output is a gradient-like accumulation, or identical replicas for
//!   pure reads; for simplicity replicas keep identical masks (replica
//!   disambiguation happens in scheduling validation, paper §3.2).
//!
//! [`recompute`] implements the paper's recompute support (§5, Table 1):
//! forward ops are duplicated (marked `recompute`) onto fresh "recomputed
//! activation" pTensors and the backward consumers are rewired, so the
//! original activations can be freed after the forward pass.

pub mod autograd;

use crate::graph::{mask::Mask, Graph, Op, OpId, OpKind, PTensorId, TensorKind, VTensorId};
use std::collections::HashMap;

/// A transformation algorithm for `op-trans` (the paper's `algo` argument).
#[derive(Clone, Debug, PartialEq)]
pub enum TransformAlgo {
    /// Partition along the named signature dim into `parts` pieces.
    Split { dim: String, parts: usize },
    /// Replicate the operator `copies` times.
    Replicate { copies: usize },
}

impl TransformAlgo {
    pub fn split(dim: &str, parts: usize) -> TransformAlgo {
        TransformAlgo::Split { dim: dim.to_string(), parts }
    }
    pub fn replicate(copies: usize) -> TransformAlgo {
        TransformAlgo::Replicate { copies }
    }
}

/// Errors surfaced to the sProgram author.
#[derive(Debug, PartialEq)]
pub enum TransError {
    /// Op has no signature (structural/comm ops cannot be transformed).
    NoSignature(OpId),
    /// The signature has no such dim.
    NoSuchDim { op: OpId, dim: String },
    /// parts/copies must be >= 1.
    BadFactor(usize),
    /// Plan-level constraint violation (e.g. an inconsistent stage spec).
    Invalid(String),
}

impl std::fmt::Display for TransError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransError::NoSignature(op) => write!(f, "op {op} has no signature"),
            TransError::NoSuchDim { op, dim } => {
                write!(f, "op {op} has no dim '{dim}'")
            }
            TransError::BadFactor(n) => write!(f, "bad split factor {n}"),
            TransError::Invalid(msg) => f.write_str(msg),
        }
    }
}
impl std::error::Error for TransError {}

/// Apply `algo` to `op`, returning the new op ids (paper's
/// `op-trans(op, algo)`). The original op is tombstoned.
pub fn op_trans(g: &mut Graph, op: OpId, algo: &TransformAlgo) -> Result<Vec<OpId>, TransError> {
    match algo {
        TransformAlgo::Split { dim, parts } => split_op(g, op, dim, *parts),
        TransformAlgo::Replicate { copies } => replicate_op(g, op, *copies),
    }
}

fn split_op(g: &mut Graph, op_id: OpId, dim: &str, parts: usize) -> Result<Vec<OpId>, TransError> {
    if parts == 0 {
        return Err(TransError::BadFactor(parts));
    }
    {
        let op = g.op(op_id);
        let sig = op.signature.as_ref().ok_or(TransError::NoSignature(op_id))?;
        if !sig.can_split(dim) && !sig.is_reduce(dim) {
            return Err(TransError::NoSuchDim { op: op_id, dim: dim.to_string() });
        }
    }
    if parts == 1 {
        return Ok(vec![op_id]); // trivial split
    }
    let old = g.remove_op(op_id);
    let sig = old.signature.clone().unwrap();
    let is_reduce = sig.is_reduce(dim);
    let is_batch = sig.batch.as_deref() == Some(dim);
    let mut new_ids = Vec::with_capacity(parts);
    for i in 0..parts {
        // Inputs: slice where the dim appears, replicate (same mask) where not.
        let inputs: Vec<VTensorId> = old
            .inputs
            .iter()
            .enumerate()
            .map(|(t, &v)| {
                let vt = g.vtensor(v).clone();
                let mask = match sig.input_axis(t, dim) {
                    Some(axis) => vt.mask.split_dim(axis, i, parts),
                    None => vt.mask.clone(),
                };
                g.add_vtensor(vt.ptensor, mask)
            })
            .collect();
        // Outputs: slice where the dim appears; value-split if contracted.
        let outputs: Vec<VTensorId> = old
            .outputs
            .iter()
            .enumerate()
            .map(|(t, &v)| {
                let vt = g.vtensor(v).clone();
                let mask = match sig.output_axis(t, dim) {
                    Some(axis) => vt.mask.split_dim(axis, i, parts),
                    None if is_reduce => vt.mask.split_value(i, parts),
                    None => vt.mask.clone(),
                };
                g.add_vtensor(vt.ptensor, mask)
            })
            .collect();
        let mut op = Op {
            id: 0,
            name: format!("{}/{dim}{i}", old.name),
            kind: old.kind.clone(),
            inputs,
            outputs,
            flops: old.flops / parts as f64,
            signature: old.signature.clone(),
            is_forward: old.is_forward,
            layer: old.layer,
            microbatch: old.microbatch,
            origin: Some(old.origin.unwrap_or(old.id)),
            recompute: old.recompute,
            no_grad: old.no_grad,
        };
        if is_batch {
            // Track micro-batch identity through (possibly nested) batch
            // splits: piece i of a previously-tagged micro-batch m becomes
            // micro-batch m*parts + i.
            op.microbatch = Some(old.microbatch.unwrap_or(0) * parts + i);
        }
        new_ids.push(g.insert_op(op));
    }
    Ok(new_ids)
}

fn replicate_op(g: &mut Graph, op_id: OpId, copies: usize) -> Result<Vec<OpId>, TransError> {
    if copies == 0 {
        return Err(TransError::BadFactor(copies));
    }
    if copies == 1 {
        return Ok(vec![op_id]);
    }
    let old = g.remove_op(op_id);
    let mut new_ids = Vec::with_capacity(copies);
    for i in 0..copies {
        let inputs: Vec<VTensorId> = old
            .inputs
            .iter()
            .map(|&v| {
                let vt = g.vtensor(v).clone();
                g.add_vtensor(vt.ptensor, vt.mask)
            })
            .collect();
        let outputs: Vec<VTensorId> = old
            .outputs
            .iter()
            .map(|&v| {
                let vt = g.vtensor(v).clone();
                g.add_vtensor(vt.ptensor, vt.mask)
            })
            .collect();
        let mut op = old.clone();
        op.id = 0;
        op.name = format!("{}@r{i}", old.name);
        op.inputs = inputs;
        op.outputs = outputs;
        op.origin = Some(old.origin.unwrap_or(old.id));
        new_ids.push(g.insert_op(op));
    }
    Ok(new_ids)
}

/// Recompute (paper §5, Table 1 "Recompute"): duplicate the given forward
/// ops as recompute twins writing to fresh recomputed-activation pTensors,
/// and rewire backward ops to read the recomputed copies. Returns the new
/// recompute op ids. `bwd_ops` is the set of backward ops whose inputs
/// should be rewired (typically all ops with `!is_forward`).
pub fn recompute(g: &mut Graph, fwd_ops: &[OpId], bwd_ops: &[OpId]) -> Vec<OpId> {
    // Map each activation pTensor produced by a recomputed fwd op to its
    // recomputed twin pTensor.
    let mut twin: HashMap<PTensorId, PTensorId> = HashMap::new();
    let mut new_ids = Vec::new();
    for &f in fwd_ops {
        let old = g.op(f).clone();
        assert!(old.is_forward, "recompute() takes forward ops");
        // Duplicate outputs onto twin pTensors.
        let outputs: Vec<VTensorId> = old
            .outputs
            .iter()
            .map(|&v| {
                let vt = g.vtensor(v).clone();
                let pt = g.ptensor(vt.ptensor).clone();
                let tid = *twin.entry(vt.ptensor).or_insert_with(|| {
                    g.add_ptensor(
                        &format!("{}.rc", pt.name),
                        &pt.shape,
                        pt.dtype,
                        TensorKind::Activation,
                    )
                });
                g.add_vtensor(tid, vt.mask)
            })
            .collect();
        // Inputs: read recomputed twins where available (chained recompute),
        // otherwise the original pTensor (e.g. the layer boundary input,
        // which *is* stashed).
        let inputs: Vec<VTensorId> = old
            .inputs
            .iter()
            .map(|&v| {
                let vt = g.vtensor(v).clone();
                let pt = twin.get(&vt.ptensor).copied().unwrap_or(vt.ptensor);
                g.add_vtensor(pt, vt.mask)
            })
            .collect();
        let mut op = old.clone();
        op.id = 0;
        op.name = format!("{}.rc", old.name);
        op.inputs = inputs;
        op.outputs = outputs;
        op.recompute = true;
        op.origin = Some(old.origin.unwrap_or(old.id));
        new_ids.push(g.insert_op(op));
    }
    // Rewire backward readers of recomputed activations to the twins.
    for &b in bwd_ops {
        let op_inputs = g.op(b).inputs.clone();
        for (slot, v) in op_inputs.into_iter().enumerate() {
            let vt = g.vtensor(v).clone();
            if let Some(&tid) = twin.get(&vt.ptensor) {
                let nv = g.add_vtensor(tid, vt.mask);
                g.op_mut(b).inputs[slot] = nv;
            }
        }
    }
    new_ids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::sig::sigs;
    use crate::graph::{DType, Graph, OpKind, TensorKind};

    /// x[4,8,16] @ w[16,32] -> y[4,8,32]
    fn linear_graph() -> (Graph, OpId) {
        let mut g = Graph::new();
        let x = g.add_ptensor("x", &[4, 8, 16], DType::F32, TensorKind::Input);
        let w = g.add_ptensor("w", &[16, 32], DType::F32, TensorKind::Weight);
        let y = g.add_ptensor("y", &[4, 8, 32], DType::F32, TensorKind::Activation);
        let xv = g.full_view(x);
        let wv = g.full_view(w);
        let yv = g.full_view(y);
        let op = g.add_op(
            "lin",
            OpKind::Matmul,
            vec![xv, wv],
            vec![yv],
            2.0 * 4.0 * 8.0 * 16.0 * 32.0,
            Some(sigs::linear()),
            true,
            0,
        );
        (g, op)
    }

    #[test]
    fn split_batch_dim_slices_x_and_y_replicates_w() {
        let (mut g, op) = linear_graph();
        let ids = op_trans(&mut g, op, &TransformAlgo::split("b", 4)).unwrap();
        assert_eq!(ids.len(), 4);
        for (i, &id) in ids.iter().enumerate() {
            let o = g.op(id);
            assert_eq!(g.vtensor_shape(o.inputs[0]), vec![1, 8, 16]); // x sliced
            assert_eq!(g.vtensor_shape(o.inputs[1]), vec![16, 32]); // w replicated
            assert_eq!(g.vtensor_shape(o.outputs[0]), vec![1, 8, 32]); // y sliced
            assert_eq!(o.microbatch, Some(i));
            assert!(g.vtensor(o.outputs[0]).mask.vsplit.is_full());
        }
        // FLOPs conserved.
        assert!((g.total_flops() - 2.0 * 4.0 * 8.0 * 16.0 * 32.0).abs() < 1e-6);
    }

    #[test]
    fn split_reduce_dim_value_splits_output() {
        let (mut g, op) = linear_graph();
        let ids = op_trans(&mut g, op, &TransformAlgo::split("k", 2)).unwrap();
        for (i, &id) in ids.iter().enumerate() {
            let o = g.op(id);
            assert_eq!(g.vtensor_shape(o.inputs[0]), vec![4, 8, 8]); // x k-sliced
            assert_eq!(g.vtensor_shape(o.inputs[1]), vec![8, 32]); // w k-sliced
            let om = &g.vtensor(o.outputs[0]).mask;
            assert_eq!(g.vtensor_shape(o.outputs[0]), vec![4, 8, 32]); // full spatial
            assert_eq!(om.vsplit.parts, 2); // but a value partial
            assert_eq!(om.vsplit.index, i as u32);
        }
    }

    #[test]
    fn split_output_dim_tensor_parallel_style() {
        // Megatron column parallelism: split n.
        let (mut g, op) = linear_graph();
        let ids = op_trans(&mut g, op, &TransformAlgo::split("n", 2)).unwrap();
        for &id in &ids {
            let o = g.op(id);
            assert_eq!(g.vtensor_shape(o.inputs[0]), vec![4, 8, 16]); // x replicated
            assert_eq!(g.vtensor_shape(o.inputs[1]), vec![16, 16]); // w col-sliced
            assert_eq!(g.vtensor_shape(o.outputs[0]), vec![4, 8, 16]); // y col-sliced
        }
    }

    #[test]
    fn nested_splits_compose() {
        // Fig. 6: split twice; masks compose exactly.
        let (mut g, op) = linear_graph();
        let ids = op_trans(&mut g, op, &TransformAlgo::split("b", 2)).unwrap();
        let ids2 = op_trans(&mut g, ids[0], &TransformAlgo::split("n", 2)).unwrap();
        let o = g.op(ids2[1]);
        assert_eq!(g.vtensor_shape(o.outputs[0]), vec![2, 8, 16]);
        let c = g
            .vtensor(o.outputs[0])
            .mask
            .concrete(&[4, 8, 32]);
        assert_eq!(c, vec![(0, 2), (0, 8), (16, 32)]);
    }

    #[test]
    fn replicate_makes_identical_views() {
        let (mut g, op) = linear_graph();
        let ids = op_trans(&mut g, op, &TransformAlgo::replicate(3)).unwrap();
        assert_eq!(ids.len(), 3);
        let m0 = g.vtensor(g.op(ids[0]).outputs[0]).mask.clone();
        for &id in &ids[1..] {
            assert_eq!(g.vtensor(g.op(id).outputs[0]).mask, m0);
        }
    }

    #[test]
    fn errors_are_reported() {
        let (mut g, op) = linear_graph();
        assert_eq!(
            op_trans(&mut g, op, &TransformAlgo::split("zz", 2)),
            Err(TransError::NoSuchDim { op, dim: "zz".into() })
        );
        assert_eq!(
            op_trans(&mut g, op, &TransformAlgo::split("b", 0)),
            Err(TransError::BadFactor(0))
        );
        // op still alive after failed trans
        assert!(g.contains_op(op));
    }

    #[test]
    fn trivial_split_is_identity() {
        let (mut g, op) = linear_graph();
        let ids = op_trans(&mut g, op, &TransformAlgo::split("b", 1)).unwrap();
        assert_eq!(ids, vec![op]);
        assert!(g.contains_op(op));
    }

    #[test]
    fn prop_split_preserves_flops_and_tiles_output() {
        crate::util::prop::check("op-trans-conservation", 100, |gen| {
            let (mut g, op) = linear_graph();
            let dims = ["b", "m", "k", "n"];
            let dim = dims[gen.int(0, 4)];
            let parts = gen.int(2, 5);
            let total = g.total_flops();
            let ids = op_trans(&mut g, op, &TransformAlgo::split(dim, parts)).unwrap();
            if (g.total_flops() - total).abs() > 1e-6 * total {
                return Err(format!("flops changed for dim {dim} x{parts}"));
            }
            let masks: Vec<_> = ids
                .iter()
                .map(|&i| g.vtensor(g.op(i).outputs[0]).mask.clone())
                .collect();
            if !crate::graph::mask::tiles_full(&masks) {
                return Err(format!("outputs of split {dim} x{parts} don't tile"));
            }
            Ok(())
        });
    }

    #[test]
    fn recompute_duplicates_and_rewires() {
        // fwd: x -> A -> t -> B -> y ; bwd consumes t.
        let mut g = Graph::new();
        let x = g.add_ptensor("x", &[4], DType::F32, TensorKind::Input);
        let t = g.add_ptensor("t", &[4], DType::F32, TensorKind::Activation);
        let y = g.add_ptensor("y", &[4], DType::F32, TensorKind::Activation);
        let gy = g.add_ptensor("gy", &[4], DType::F32, TensorKind::Gradient);
        let gx = g.add_ptensor("gx", &[4], DType::F32, TensorKind::Gradient);
        let (xv, t_o) = (g.full_view(x), g.full_view(t));
        let a = g.add_op("A", OpKind::Identity, vec![xv], vec![t_o], 4.0, None, true, 0);
        let (t_i, yv) = (g.full_view(t), g.full_view(y));
        let b = g.add_op("B", OpKind::Identity, vec![t_i], vec![yv], 4.0, None, true, 0);
        let (gyv, t_i2, gxv) = (g.full_view(gy), g.full_view(t), g.full_view(gx));
        let bw =
            g.add_op("B.bw", OpKind::Identity, vec![gyv, t_i2], vec![gxv], 8.0, None, false, 0);
        let _ = b;
        let rc = recompute(&mut g, &[a], &[bw]);
        assert_eq!(rc.len(), 1);
        let rc_op = g.op(rc[0]);
        assert!(rc_op.recompute);
        // Recompute writes a twin pTensor named t.rc…
        let twin_pt = g.vtensor(rc_op.outputs[0]).ptensor;
        assert_eq!(g.ptensor(twin_pt).name, "t.rc");
        // …and backward now reads the twin, not the original t.
        let bw_in_pts: Vec<_> = g
            .op(bw)
            .inputs
            .iter()
            .map(|&v| g.vtensor(v).ptensor)
            .collect();
        assert!(bw_in_pts.contains(&twin_pt));
        assert!(!bw_in_pts.contains(&t));
    }
}
