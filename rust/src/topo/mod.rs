//! Cluster fabric topology: switch/link graphs, deterministic routing and
//! heterogeneous device fleets.
//!
//! The seed cluster model was flat — `n_servers × gpus_per_server` uniform
//! devices, one NIC per server, every inter-server path identical. Real
//! fleets are multi-tier fabrics (leaf/spine fat-trees, rail-optimized
//! GPU pods) with mixed device generations. This module makes the fabric
//! explicit while keeping the flat model bitwise-intact:
//!
//! * [`Topology`] is the fabric graph: named builders for [`Topology::flat`]
//!   (exactly the legacy link sets), [`Topology::fat_tree`] (`k` servers per
//!   rack switch, racks joined by per-rack spine uplinks —
//!   [`LinkId::Up`]) and [`Topology::rail_optimized`] (`r` rail switches per
//!   pod; GPU `i` of every server injects into rail `i mod r` through its
//!   own NIC — [`LinkId::Rail`]).
//! * [`Topology::route`] resolves the deterministic link path between two
//!   devices. Routes only *vary* at the tier granularity (rack pair /
//!   rail pair) — endpoint ports (`NvLink`/`Nic`/`Pcie`) are O(1) arithmetic
//!   on the device index — so the cached dense route table is the **spine
//!   table**: a `Vec` CSR indexed by tier-pair slot (`ta * n_tiers + tb`).
//!   A full device-pair table at 10k devices would be 10⁸ slots of pure
//!   redundancy; the tier-pair table is `racks²`/`rails²` entries and
//!   [`Topology::route_into`] composes a route with zero allocation.
//! * [`DeviceKind`] carries per-device-type compute/memory specs
//!   (V100/A100/H100) so a server row can be heterogeneous;
//!   `--device-mix a100:8,h100:8` assigns kinds to servers in order.
//! * [`ClusterShapeError`] is the typed rejection for CLI shapes that don't
//!   divide evenly (`--gpus`/`--servers`/`--topology`/`--device-mix`),
//!   replacing panics and silent truncation.
//!
//! # How each fidelity tier consumes routes
//!
//! * **analytic** ([`crate::cost`]): `Cluster::link`/`group_link` price a
//!   path by its slowest hop (bottleneck bandwidth, with per-hop shares for
//!   collectives) and its summed switch latency — cross-rack/cross-rail
//!   paths cost 2× the α of an in-rack path. The plan lower bound keeps
//!   using the fastest link and fastest device kind, so dominance pruning
//!   stays sound on any fabric.
//! * **list scheduler** ([`crate::sim`]): inherits the analytic per-task
//!   durations; heterogeneous kinds price each compute task by its
//!   device's spec.
//! * **DES** ([`crate::des`]): `Cluster::group_links` returns every link on
//!   a transfer's resolved route — NICs *and* the spanned rack uplinks /
//!   rails — so a transfer holds its whole route and concurrent transfers
//!   sharing any hop fair-share it (repriced at start/finish, as before).

use crate::cost::{Cluster, DeviceSpec, LinkId};
use crate::schedule::{DeviceId, CPU_DEVICE};

/// The fabric family of a [`Topology`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TopoKind {
    /// Legacy single-tier fabric: one NIC per server, all NICs on one
    /// non-blocking switch. Bitwise-identical to the pre-topology model.
    Flat,
    /// Leaf/spine fat-tree: `k` servers per rack (leaf) switch; racks are
    /// joined through per-rack spine uplinks ([`LinkId::Up`]). In-rack
    /// traffic behaves exactly like [`TopoKind::Flat`]; cross-rack traffic
    /// additionally crosses both racks' uplinks (shared by every member in
    /// the rack) and pays one extra switch hop of latency.
    FatTree { k: usize },
    /// Rail-optimized pod: `rails` rail switches; GPU `i` of every server
    /// has its own NIC into rail `i mod rails` ([`LinkId::Rail`]), so
    /// inter-server traffic bypasses the per-server NIC bottleneck.
    /// Same-rail traffic crosses one rail switch; cross-rail traffic
    /// bridges two rails and pays one extra hop of latency.
    Rail { rails: usize },
}

/// A fabric graph of switches and links over `n_servers × gpus_per_server`
/// devices, with deterministic route resolution. Construction validates the
/// shape (typed [`ClusterShapeError`]s) and precomputes the dense spine
/// route table; all queries afterwards are allocation-free O(1) lookups
/// plus O(route length) copies.
#[derive(Clone, Debug)]
pub struct Topology {
    kind: TopoKind,
    n_servers: usize,
    gpus_per_server: usize,
    /// Tier count: racks (fat-tree), rails (rail), 1 (flat).
    n_tiers: usize,
    /// Dense CSR spine table indexed by tier-pair slot `ta * n_tiers + tb`:
    /// the fabric hops between tier `ta` and tier `tb` are
    /// `spine_links[spine_off[slot] .. spine_off[slot + 1]]`.
    spine_off: Vec<u32>,
    spine_links: Vec<LinkId>,
}

impl Topology {
    /// The legacy single-tier fabric (bitwise-equivalent link sets).
    pub fn flat(n_servers: usize, gpus_per_server: usize) -> Topology {
        Self::build(TopoKind::Flat, n_servers, gpus_per_server, 1)
    }

    /// Fat-tree with `k` servers per rack switch. `k` must divide
    /// `n_servers` evenly.
    pub fn fat_tree(
        n_servers: usize,
        gpus_per_server: usize,
        k: usize,
    ) -> Result<Topology, ClusterShapeError> {
        if k == 0 || n_servers % k != 0 {
            return Err(ClusterShapeError::RackMismatch { servers: n_servers, k });
        }
        Ok(Self::build(TopoKind::FatTree { k }, n_servers, gpus_per_server, n_servers / k))
    }

    /// Rail-optimized pod with `rails` rail switches. `rails` must divide
    /// `gpus_per_server` evenly (each local GPU index maps to one rail).
    pub fn rail_optimized(
        n_servers: usize,
        gpus_per_server: usize,
        rails: usize,
    ) -> Result<Topology, ClusterShapeError> {
        if rails == 0 || gpus_per_server % rails != 0 {
            return Err(ClusterShapeError::RailMismatch { gpus_per_server, rails });
        }
        Ok(Self::build(TopoKind::Rail { rails }, n_servers, gpus_per_server, rails))
    }

    /// Parse a `--topology` argument: `flat`, `fat-tree:K` or `rail:R`.
    pub fn parse(
        s: &str,
        n_servers: usize,
        gpus_per_server: usize,
    ) -> Result<Topology, ClusterShapeError> {
        let bad = || ClusterShapeError::BadTopology(s.to_string());
        if s == "flat" {
            return Ok(Self::flat(n_servers, gpus_per_server));
        }
        let (family, param) = s.split_once(':').ok_or_else(bad)?;
        let n: usize = param.parse().map_err(|_| bad())?;
        match family {
            "fat-tree" => Self::fat_tree(n_servers, gpus_per_server, n),
            "rail" => Self::rail_optimized(n_servers, gpus_per_server, n),
            _ => Err(bad()),
        }
    }

    /// Build the dense spine table: every tier pair's fabric segment, laid
    /// out as CSR so a route lookup is two `Vec` index operations.
    fn build(kind: TopoKind, n_servers: usize, gpus_per_server: usize, n_tiers: usize) -> Topology {
        let mut off: Vec<u32> = Vec::with_capacity(n_tiers * n_tiers + 1);
        let mut links: Vec<LinkId> = Vec::new();
        off.push(0);
        for ta in 0..n_tiers {
            for tb in 0..n_tiers {
                match kind {
                    TopoKind::Flat => {}
                    TopoKind::FatTree { .. } => {
                        if ta != tb {
                            links.push(LinkId::Up(ta));
                            links.push(LinkId::Up(tb));
                        }
                    }
                    TopoKind::Rail { .. } => {
                        links.push(LinkId::Rail(ta));
                        if ta != tb {
                            links.push(LinkId::Rail(tb));
                        }
                    }
                }
                off.push(links.len() as u32);
            }
        }
        Topology { kind, n_servers, gpus_per_server, n_tiers, spine_off: off, spine_links: links }
    }

    pub fn kind(&self) -> TopoKind {
        self.kind
    }

    pub fn n_servers(&self) -> usize {
        self.n_servers
    }

    pub fn gpus_per_server(&self) -> usize {
        self.gpus_per_server
    }

    pub fn is_flat(&self) -> bool {
        self.kind == TopoKind::Flat
    }

    /// The CLI-facing name: `flat`, `fat-tree:K` or `rail:R`.
    pub fn label(&self) -> String {
        match self.kind {
            TopoKind::Flat => "flat".to_string(),
            TopoKind::FatTree { k } => format!("fat-tree:{k}"),
            TopoKind::Rail { rails } => format!("rail:{rails}"),
        }
    }

    /// Rack index of a server (0 outside fat-trees).
    pub fn rack_of(&self, server: usize) -> usize {
        match self.kind {
            TopoKind::FatTree { k } => server / k,
            _ => 0,
        }
    }

    /// Number of racks: `n_servers / k` on fat-trees, 1 everywhere else
    /// (the whole fabric is one failure domain without rack switches).
    pub fn n_racks(&self) -> usize {
        match self.kind {
            TopoKind::FatTree { k } => self.n_servers / k,
            _ => 1,
        }
    }

    /// The device range of fat-tree rack `r` — its blast radius as a
    /// failure domain. `None` outside fat-trees or for out-of-range racks.
    pub fn rack_devices(&self, r: usize) -> Option<std::ops::Range<DeviceId>> {
        match self.kind {
            TopoKind::FatTree { k } => {
                let per_rack = k * self.gpus_per_server;
                (r < self.n_racks()).then(|| r * per_rack..(r + 1) * per_rack)
            }
            _ => None,
        }
    }

    /// Rail index of a device (0 outside rail fabrics).
    pub fn rail_of(&self, d: DeviceId) -> usize {
        match self.kind {
            TopoKind::Rail { rails } => (d % self.gpus_per_server) % rails,
            _ => 0,
        }
    }

    /// Fabric tier a device injects into: its rack (fat-tree), its rail
    /// (rail), 0 (flat).
    fn tier_of(&self, d: DeviceId) -> usize {
        match self.kind {
            TopoKind::Flat => 0,
            TopoKind::FatTree { k } => d / self.gpus_per_server / k,
            TopoKind::Rail { rails } => (d % self.gpus_per_server) % rails,
        }
    }

    /// Whether an inter-server path between GPUs `a` and `b` crosses the
    /// spine (cross-rack / cross-rail) and therefore pays the extra switch
    /// hop. Always false on flat fabrics.
    pub fn cross_tier(&self, a: DeviceId, b: DeviceId) -> bool {
        !self.is_flat() && self.tier_of(a) != self.tier_of(b)
    }

    /// The cached spine segment between two fabric tiers.
    fn spine(&self, ta: usize, tb: usize) -> &[LinkId] {
        let slot = ta * self.n_tiers + tb;
        let lo = self.spine_off[slot] as usize;
        let hi = self.spine_off[slot + 1] as usize;
        &self.spine_links[lo..hi]
    }

    /// Deterministic route between two devices, as the ordered link path
    /// src-port → fabric → dst-port. Same-device routes are empty; host
    /// routes cross the GPU's PCIe lane; intra-server routes cross both
    /// NVLink ports; inter-server routes cross the injection ports plus the
    /// cached spine segment. Allocates the result — use
    /// [`Topology::route_into`] on hot paths.
    pub fn route(&self, src: DeviceId, dst: DeviceId) -> Vec<LinkId> {
        let mut out = Vec::with_capacity(4);
        self.route_into(src, dst, &mut out);
        out
    }

    /// [`Topology::route`] into a caller-owned buffer (cleared first): no
    /// per-call allocation once the buffer has grown to the longest route.
    pub fn route_into(&self, src: DeviceId, dst: DeviceId, out: &mut Vec<LinkId>) {
        out.clear();
        if src == dst {
            return;
        }
        if src == CPU_DEVICE || dst == CPU_DEVICE {
            let gpu = if src == CPU_DEVICE { dst } else { src };
            if gpu != CPU_DEVICE {
                out.push(LinkId::Pcie(gpu));
            }
            return;
        }
        let (sa, sb) = (src / self.gpus_per_server, dst / self.gpus_per_server);
        if sa == sb {
            out.push(LinkId::NvLink(src));
            out.push(LinkId::NvLink(dst));
            return;
        }
        match self.kind {
            // Rail fabrics give every GPU its own NIC into its rail: the
            // spine segment *is* the route (per-device injection ports are
            // serialized by the device's comm stream, like NVLink ports).
            TopoKind::Rail { .. } => {
                out.extend_from_slice(self.spine(self.tier_of(src), self.tier_of(dst)));
            }
            _ => {
                out.push(LinkId::Nic(sa));
                out.extend_from_slice(self.spine(self.tier_of(src), self.tier_of(dst)));
                out.push(LinkId::Nic(sb));
            }
        }
    }

    /// Fabric links occupied by an inter-server group transfer (callers
    /// guarantee: ≥ 2 sorted deduped GPU members spanning ≥ 2 servers).
    /// The union of every member's injection path: per-server NICs, plus
    /// the spanned rack uplinks when a fat-tree group crosses racks, or the
    /// members' rails on a rail fabric. Output order is arbitrary —
    /// [`Cluster::group_links`] sorts and dedups.
    pub fn group_fabric_links(&self, devs: &[DeviceId], out: &mut Vec<LinkId>) {
        match self.kind {
            TopoKind::Flat | TopoKind::FatTree { .. } => {
                for &d in devs {
                    out.push(LinkId::Nic(d / self.gpus_per_server));
                }
                if let TopoKind::FatTree { .. } = self.kind {
                    let t0 = self.tier_of(devs[0]);
                    if devs.iter().any(|&d| self.tier_of(d) != t0) {
                        for &d in devs {
                            out.push(LinkId::Up(self.tier_of(d)));
                        }
                    }
                }
            }
            TopoKind::Rail { .. } => {
                for &d in devs {
                    out.push(LinkId::Rail(self.rail_of(d)));
                }
            }
        }
    }
}

/// A device generation: a named [`DeviceSpec`]. A [`Cluster`]'s fleet maps
/// each server row to one kind, so A100 and H100 rows can coexist; every
/// fidelity tier prices compute and memory per device through
/// `Cluster::device_spec`.
#[derive(Clone, Debug)]
pub struct DeviceKind {
    pub name: String,
    pub spec: DeviceSpec,
}

impl DeviceKind {
    /// The seed default (the paper's testbed generation).
    pub fn v100() -> DeviceKind {
        DeviceKind { name: "v100".to_string(), spec: DeviceSpec::default() }
    }

    /// A100-40GB-ish: ~2.8× V100 tensor throughput, 40 GiB.
    pub fn a100() -> DeviceKind {
        DeviceKind {
            name: "a100".to_string(),
            spec: DeviceSpec {
                peak_flops: 312e12,
                mem_bytes: 40 * (1 << 30) as u64,
                kernel_overhead: 8e-6,
                sat_knee_flops: 4e9,
                max_util: 0.65,
            },
        }
    }

    /// H100-80GB-ish: ~9× V100 tensor throughput, 80 GiB.
    pub fn h100() -> DeviceKind {
        DeviceKind {
            name: "h100".to_string(),
            spec: DeviceSpec {
                peak_flops: 989e12,
                mem_bytes: 80 * (1 << 30) as u64,
                kernel_overhead: 8e-6,
                sat_knee_flops: 8e9,
                max_util: 0.7,
            },
        }
    }

    /// Look a kind up by its `--device-mix` name.
    pub fn named(name: &str) -> Option<DeviceKind> {
        match name {
            "v100" => Some(Self::v100()),
            "a100" => Some(Self::a100()),
            "h100" => Some(Self::h100()),
            _ => None,
        }
    }
}

/// Typed rejection of a cluster shape the CLI cannot honor: every variant
/// names the numbers that failed to divide, so `--gpus`/`--servers`/
/// `--topology`/`--device-mix` mistakes fail with an actionable message
/// instead of a panic or a silently truncated fleet.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ClusterShapeError {
    ZeroGpus,
    ZeroServers,
    /// `--servers` does not divide `--gpus`.
    ServersDontDivide { gpus: usize, servers: usize },
    /// `--gpus` does not tile whole servers (without `--servers`, servers
    /// hold `min(gpus, 8)` GPUs — so 1..=8 or a multiple of 8).
    UnevenServers { gpus: usize, gpus_per_server: usize },
    /// Unparsable `--topology` argument.
    BadTopology(String),
    /// Fat-tree rack size `k` does not divide the server count.
    RackMismatch { servers: usize, k: usize },
    /// Rail count does not divide the per-server GPU count.
    RailMismatch { gpus_per_server: usize, rails: usize },
    /// Unparsable `--device-mix` argument.
    BadDeviceMix(String),
    /// `--device-mix` counts do not sum to `--gpus`.
    MixSumMismatch { mix_gpus: usize, gpus: usize },
    /// A `--device-mix` count does not tile whole server rows.
    MixNotServerAligned { name: String, count: usize, gpus_per_server: usize },
}

impl std::fmt::Display for ClusterShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterShapeError::ZeroGpus => write!(f, "--gpus must be at least 1"),
            ClusterShapeError::ZeroServers => write!(f, "--servers must be at least 1"),
            ClusterShapeError::ServersDontDivide { gpus, servers } => {
                write!(f, "--servers {servers} does not divide --gpus {gpus} evenly")
            }
            ClusterShapeError::UnevenServers { gpus, gpus_per_server } => write!(
                f,
                "--gpus {gpus} does not tile {gpus_per_server}-GPU servers \
                 (use 1..=8, a multiple of 8, or pass --servers)"
            ),
            ClusterShapeError::BadTopology(s) => {
                write!(f, "--topology expects flat, fat-tree:K or rail:R, got '{s}'")
            }
            ClusterShapeError::RackMismatch { servers, k } => {
                write!(f, "fat-tree rack size {k} does not divide {servers} servers evenly")
            }
            ClusterShapeError::RailMismatch { gpus_per_server, rails } => {
                write!(f, "rail count {rails} does not divide {gpus_per_server} GPUs/server")
            }
            ClusterShapeError::BadDeviceMix(s) => write!(
                f,
                "--device-mix expects comma-separated kind:count pairs \
                 (kinds: v100, a100, h100), got '{s}'"
            ),
            ClusterShapeError::MixSumMismatch { mix_gpus, gpus } => {
                write!(f, "--device-mix counts sum to {mix_gpus} GPUs but --gpus is {gpus}")
            }
            ClusterShapeError::MixNotServerAligned { name, count, gpus_per_server } => write!(
                f,
                "--device-mix {name}:{count} does not tile whole {gpus_per_server}-GPU \
                 server rows (servers are homogeneous)"
            ),
        }
    }
}

impl std::error::Error for ClusterShapeError {}

/// Parse a `--device-mix` argument (`a100:8,h100:8`, counts in GPUs) into
/// the per-server kind assignment. Counts are assigned to server rows in
/// order and must tile whole rows; the total must equal `gpus`.
pub fn parse_device_mix(
    mix: &str,
    gpus: usize,
    gpus_per_server: usize,
) -> Result<Vec<DeviceKind>, ClusterShapeError> {
    let mut per_server: Vec<DeviceKind> = Vec::with_capacity(gpus / gpus_per_server.max(1));
    let mut total = 0usize;
    for part in mix.split(',') {
        let bad = || ClusterShapeError::BadDeviceMix(mix.to_string());
        let (name, count) = part.split_once(':').ok_or_else(bad)?;
        let count: usize = count.parse().map_err(|_| bad())?;
        let kind = DeviceKind::named(name).ok_or_else(bad)?;
        if count == 0 || count % gpus_per_server != 0 {
            return Err(ClusterShapeError::MixNotServerAligned {
                name: name.to_string(),
                count,
                gpus_per_server,
            });
        }
        total += count;
        for _ in 0..count / gpus_per_server {
            per_server.push(kind.clone());
        }
    }
    if total != gpus {
        return Err(ClusterShapeError::MixSumMismatch { mix_gpus: total, gpus });
    }
    Ok(per_server)
}

/// Build a [`Cluster`] from the CLI shape flags, with every divisibility
/// constraint validated up front. `servers: None` keeps the legacy shape
/// (`min(gpus, 8)` GPUs per server); `topology` is a
/// `flat|fat-tree:K|rail:R` string; `device_mix` assigns [`DeviceKind`]s to
/// server rows.
pub fn build_cluster(
    gpus: usize,
    servers: Option<usize>,
    topology: &str,
    device_mix: Option<&str>,
) -> Result<Cluster, ClusterShapeError> {
    if gpus == 0 {
        return Err(ClusterShapeError::ZeroGpus);
    }
    let gpus_per_server = match servers {
        Some(0) => return Err(ClusterShapeError::ZeroServers),
        Some(s) => {
            if gpus % s != 0 {
                return Err(ClusterShapeError::ServersDontDivide { gpus, servers: s });
            }
            gpus / s
        }
        None => gpus.min(8),
    };
    if gpus % gpus_per_server != 0 {
        return Err(ClusterShapeError::UnevenServers { gpus, gpus_per_server });
    }
    let n_servers = gpus / gpus_per_server;
    let mut c = Cluster::with_shape(n_servers, gpus_per_server);
    c.topo = Topology::parse(topology, n_servers, gpus_per_server)?;
    if let Some(mix) = device_mix {
        c.server_kind = parse_device_mix(mix, gpus, gpus_per_server)?;
    }
    Ok(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_routes_match_legacy_link_sets() {
        let t = Topology::flat(2, 8);
        assert_eq!(t.route(0, 3), vec![LinkId::NvLink(0), LinkId::NvLink(3)]);
        assert_eq!(t.route(0, 8), vec![LinkId::Nic(0), LinkId::Nic(1)]);
        assert_eq!(t.route(4, CPU_DEVICE), vec![LinkId::Pcie(4)]);
        assert!(t.route(5, 5).is_empty());
    }

    #[test]
    fn fat_tree_routes_cross_rack_uplinks() {
        // 4 servers × 4 GPUs, 2 servers per rack.
        let t = Topology::fat_tree(4, 4, 2).unwrap();
        // In-rack inter-server: NICs only, like flat.
        assert_eq!(t.route(0, 4), vec![LinkId::Nic(0), LinkId::Nic(1)]);
        // Cross-rack: NICs plus both racks' spine uplinks.
        assert_eq!(
            t.route(0, 8),
            vec![LinkId::Nic(0), LinkId::Up(0), LinkId::Up(1), LinkId::Nic(2)]
        );
        assert!(t.cross_tier(0, 8));
        assert!(!t.cross_tier(0, 4));
    }

    #[test]
    fn rail_routes_use_rails_not_nics() {
        // 2 servers × 4 GPUs, 2 rails: local GPUs 0,2 → rail 0; 1,3 → rail 1.
        let t = Topology::rail_optimized(2, 4, 2).unwrap();
        assert_eq!(t.route(0, 6), vec![LinkId::Rail(0)]); // same rail
        assert_eq!(t.route(0, 5), vec![LinkId::Rail(0), LinkId::Rail(1)]); // cross
        assert!(t.cross_tier(0, 5));
        // Intra-server stays NVLink regardless of rails.
        assert_eq!(t.route(0, 1), vec![LinkId::NvLink(0), LinkId::NvLink(1)]);
    }

    #[test]
    fn parse_accepts_the_cli_grammar() {
        assert!(Topology::parse("flat", 2, 8).unwrap().is_flat());
        assert_eq!(Topology::parse("fat-tree:2", 4, 8).unwrap().label(), "fat-tree:2");
        assert_eq!(Topology::parse("rail:4", 2, 8).unwrap().label(), "rail:4");
        for bad in ["mesh", "fat-tree", "fat-tree:x", "rail:", ""] {
            assert!(Topology::parse(bad, 4, 8).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn shape_errors_are_typed() {
        assert_eq!(build_cluster(0, None, "flat", None).unwrap_err(), ClusterShapeError::ZeroGpus);
        assert!(matches!(
            build_cluster(12, None, "flat", None).unwrap_err(),
            ClusterShapeError::UnevenServers { gpus: 12, .. }
        ));
        assert!(matches!(
            build_cluster(12, Some(5), "flat", None).unwrap_err(),
            ClusterShapeError::ServersDontDivide { gpus: 12, servers: 5 }
        ));
        assert!(matches!(
            build_cluster(32, None, "fat-tree:3", None).unwrap_err(),
            ClusterShapeError::RackMismatch { servers: 4, k: 3 }
        ));
        assert!(matches!(
            build_cluster(16, None, "rail:3", None).unwrap_err(),
            ClusterShapeError::RailMismatch { gpus_per_server: 8, rails: 3 }
        ));
        assert!(matches!(
            build_cluster(16, None, "flat", Some("a100:8")).unwrap_err(),
            ClusterShapeError::MixSumMismatch { mix_gpus: 8, gpus: 16 }
        ));
        assert!(matches!(
            build_cluster(16, None, "flat", Some("a100:12,h100:4")).unwrap_err(),
            ClusterShapeError::MixNotServerAligned { .. }
        ));
        assert!(matches!(
            build_cluster(16, None, "flat", Some("b200:16")).unwrap_err(),
            ClusterShapeError::BadDeviceMix(_)
        ));
    }

    #[test]
    fn build_cluster_assigns_kinds_per_server_row() {
        let c = build_cluster(16, None, "flat", Some("a100:8,h100:8")).unwrap();
        assert_eq!(c.n_servers, 2);
        assert_eq!(c.server_kind.len(), 2);
        assert_eq!(c.server_kind[0].name, "a100");
        assert_eq!(c.server_kind[1].name, "h100");
        assert_eq!(c.device_spec(0).peak_flops, DeviceKind::a100().spec.peak_flops);
        assert_eq!(c.device_spec(8).mem_bytes, DeviceKind::h100().spec.mem_bytes);
        // Narrow servers via --servers.
        let c = build_cluster(8, Some(4), "rail:2", None).unwrap();
        assert_eq!((c.n_servers, c.gpus_per_server), (4, 2));
        assert_eq!(c.topo.label(), "rail:2");
    }

    #[test]
    fn route_into_reuses_the_buffer() {
        let t = Topology::fat_tree(8, 8, 2).unwrap();
        let mut buf = Vec::new();
        t.route_into(0, 63, &mut buf);
        let cap = buf.capacity();
        assert_eq!(buf.len(), 4);
        for dst in 8..64 {
            t.route_into(0, dst, &mut buf);
            assert!(!buf.is_empty());
        }
        assert_eq!(buf.capacity(), cap, "steady-state routing must not reallocate");
    }

    #[test]
    fn prop_every_pair_routes_and_is_symmetric() {
        crate::util::prop::check("topo-route-pairs", 200, |g| {
            let gps = *g.rng.choose(&[2usize, 4, 8]);
            let servers = *g.rng.choose(&[1usize, 2, 4, 8]);
            let t = match g.int(0, 3) {
                0 => Topology::flat(servers, gps),
                1 => {
                    let k = *g.rng.choose(&[1usize, 2]);
                    if servers % k != 0 {
                        return Ok(());
                    }
                    Topology::fat_tree(servers, gps, k).unwrap()
                }
                _ => Topology::rail_optimized(servers, gps, *g.rng.choose(&[1usize, 2])).unwrap(),
            };
            let n = servers * gps;
            let a = g.int(0, n);
            let b = g.int(0, n);
            let (fwd, mut rev) = (t.route(a, b), t.route(b, a));
            if a != b && fwd.is_empty() {
                return Err(format!("{} -> {} resolved no route", a, b));
            }
            let mut fwd = fwd;
            fwd.sort_unstable();
            rev.sort_unstable();
            if fwd != rev {
                return Err(format!("route {a}<->{b} not symmetric: {fwd:?} vs {rev:?}"));
            }
            Ok(())
        });
    }
}
