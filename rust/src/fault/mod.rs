//! Fault-domain-aware resilience: seeded fault traces, checkpoint/restart
//! goodput modeling, and rack-spreading placement.
//!
//! The DES ([`crate::des`]) scores plans under a fault-free cluster; this
//! module makes *survival* a scoring axis. A [`FaultSpec`] is a
//! deterministic, seeded fault trace — device crashes, whole-server or
//! whole-rack losses, rack-uplink outages, transient stragglers — either
//! parsed from a `--faults` CLI token or sampled from per-device-kind MTBF
//! ([`FaultSpec::sample`]). [`FaultSpec::resolve`] validates the trace
//! against a concrete [`Cluster`] and lowers it to a [`FaultPlan`] of
//! device-kill / link-outage / slowdown events the DES engine injects
//! ([`crate::des::execute_faulted`]).
//!
//! # Trace grammar (`--faults`)
//!
//! Comma-separated events, each `kind:target@time[+duration]` (seconds;
//! duration defaults to [`DEFAULT_DURATION`]):
//!
//! * `crash:d3@0.5+0.2` — device 3 fails at t=0.5, hardware repair 0.2 s
//! * `server:1@0.5+0.2` — every device of server 1 fails
//! * `rack:1@1.0+0.2` — every device in fat-tree rack 1 fails
//! * `uplink:0@0.5+0.1` — rack 0's spine uplink is cut for 0.1 s
//! * `slow:d2x0.5@0.2+0.3` — device 2 runs at 0.5× rate from t=0.2 for 0.3 s
//!
//! # Failure and recovery model
//!
//! A killed device aborts its in-flight compute *and* every communication
//! task it participates in (collectives abort cluster-wide, like NCCL);
//! aborted work is lost and re-executes from scratch once the device
//! returns. The device is down for `repair + reload + replay`: the
//! hardware repair from the trace, reloading the last checkpoint over the
//! host link (priced by [`Cluster::checkpoint_time`], i.e. the existing
//! PCIe cost tier), and replaying the work since the last checkpoint
//! (`now - last_commit`; with checkpointing off the replay spans the whole
//! run so far). A cut link stalls every transfer crossing it — routes are
//! deterministic ([`crate::topo::Topology::route`]), and a fat-tree has a
//! single uplink per rack, so "reroute or stall" resolves to *stall*: the
//! transfer holds its route and resumes at the cut's end. A straggler
//! reprices the device's in-flight and future compute by the degradation
//! factor for the event's duration.
//!
//! # Checkpointing
//!
//! With a checkpoint interval `I > 0` the engine takes a coordinated
//! snapshot every `I` seconds of progress: all streams freeze for the
//! *stall* (the slowest device's weights+optimizer transfer to host,
//! [`Cluster::checkpoint_time`]), then the commit point becomes the new
//! replay origin. [`CkptPolicy::Auto`] picks the interval by Young's
//! approximation `sqrt(2 · stall · MTBF)` when an MTBF is known, else a
//! quarter of the fault-free makespan, clamped to `[max(makespan/16,
//! stall), makespan]`.
//!
//! # Goodput
//!
//! `goodput = fault-free makespan / faulted makespan` (≤ 1): the fraction
//! of wall-clock the faulted run spends on *useful* work — everything
//! else is lost re-execution, checkpoint stalls, repair idle time and
//! stalled transfers. [`evaluate_resilience`] runs the engine twice (base,
//! then faulted) and reports goodput, time-to-recover and the loss
//! breakdown ([`ResilienceReport`]).
//!
//! [`placement::rack_spread_map`] closes the placement loop: it re-maps a
//! plan's contiguous dp-replica device blocks onto whole racks so a single
//! rack loss degrades as few replicas as possible.

pub mod placement;

use crate::cost::{Cluster, LinkId};
use crate::des::{self, DesReport};
use crate::graph::Graph;
use crate::materialize::Plan;
use crate::schedule::{DeviceId, CPU_DEVICE};
use crate::sim::TaskGraph;
use crate::util::rng::Rng;

/// Fault duration (seconds) when a trace token omits `+<duration>`.
pub const DEFAULT_DURATION: f64 = 0.05;

/// What fails, before resolution against a concrete cluster.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// One device crashes and restarts from the last checkpoint.
    Crash { device: DeviceId },
    /// Every device of one server crashes.
    Server { server: usize },
    /// Every device in one fat-tree rack crashes (rack power loss).
    Rack { rack: usize },
    /// A rack's spine uplink is cut; cross-rack transfers through it stall.
    Uplink { rack: usize },
    /// A device runs at `factor` (in `(0, 1]`) of its nominal compute rate.
    Slow { device: DeviceId, factor: f64 },
}

/// One event of a fault trace: `kind` happens at `at` and lasts `duration`
/// (hardware-repair time for crashes, outage length for links, degradation
/// window for stragglers).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    pub duration: f64,
    pub kind: FaultKind,
}

/// A deterministic fault trace: the cluster-independent description, parsed
/// from `--faults` or sampled from MTBF. [`FaultSpec::resolve`] lowers it
/// to a [`FaultPlan`] against a concrete cluster.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub events: Vec<FaultEvent>,
}

/// Typed rejection of a fault trace: unparsable tokens and targets the
/// cluster does not have.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultError {
    /// A `--faults` token failed to parse.
    Parse { token: String, why: String },
    /// A trace names a device the cluster does not have.
    DeviceOutOfRange { device: DeviceId, gpus: usize },
    /// A trace names a server the cluster does not have.
    ServerOutOfRange { server: usize, servers: usize },
    /// A trace names a rack the topology does not have (flat and rail
    /// fabrics have no racks; fat-trees have `n_servers / k`).
    RackUnavailable { rack: usize, racks: usize, topology: String },
}

impl std::fmt::Display for FaultError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultError::Parse { token, why } => {
                write!(f, "bad fault token '{token}': {why}")
            }
            FaultError::DeviceOutOfRange { device, gpus } => {
                write!(f, "fault targets device {device} but the cluster has {gpus} GPUs")
            }
            FaultError::ServerOutOfRange { server, servers } => {
                write!(f, "fault targets server {server} but the cluster has {servers} servers")
            }
            FaultError::RackUnavailable { rack, racks, topology } => write!(
                f,
                "fault targets rack {rack} but topology '{topology}' has {racks} rack(s) \
                 (rack/uplink faults need fat-tree:K)"
            ),
        }
    }
}

impl std::error::Error for FaultError {}

fn parse_dev(s: &str) -> Option<DeviceId> {
    s.strip_prefix('d')?.parse().ok()
}

impl FaultSpec {
    /// Parse a `--faults` trace token (see the module doc for the grammar).
    pub fn parse(s: &str) -> Result<FaultSpec, FaultError> {
        let mut events = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            events.push(Self::parse_token(tok)?);
        }
        Ok(FaultSpec { events })
    }

    fn parse_token(tok: &str) -> Result<FaultEvent, FaultError> {
        let err = |why: &str| FaultError::Parse { token: tok.to_string(), why: why.to_string() };
        let (head, when) = tok.split_once('@').ok_or_else(|| err("missing '@<time>'"))?;
        let (at_s, dur_s) = match when.split_once('+') {
            Some((a, d)) => (a, Some(d)),
            None => (when, None),
        };
        let at: f64 = at_s.parse().map_err(|_| err("unparsable time"))?;
        if !at.is_finite() || at < 0.0 {
            return Err(err("time must be finite and >= 0"));
        }
        let duration = match dur_s {
            Some(d) => {
                let d: f64 = d.parse().map_err(|_| err("unparsable duration"))?;
                if !d.is_finite() || d <= 0.0 {
                    return Err(err("duration must be finite and > 0"));
                }
                d
            }
            None => DEFAULT_DURATION,
        };
        let (kind_s, arg) = head.split_once(':').ok_or_else(|| err("missing ':<target>'"))?;
        let kind = match kind_s {
            "crash" => FaultKind::Crash {
                device: parse_dev(arg).ok_or_else(|| err("crash wants a d<N> device"))?,
            },
            "server" => FaultKind::Server {
                server: arg.parse().map_err(|_| err("server wants an index"))?,
            },
            "rack" => {
                FaultKind::Rack { rack: arg.parse().map_err(|_| err("rack wants an index"))? }
            }
            "uplink" => {
                FaultKind::Uplink { rack: arg.parse().map_err(|_| err("uplink wants an index"))? }
            }
            "slow" => {
                let (dev_s, fac_s) =
                    arg.split_once('x').ok_or_else(|| err("slow wants d<N>x<factor>"))?;
                let device = parse_dev(dev_s).ok_or_else(|| err("slow wants a d<N> device"))?;
                let factor: f64 = fac_s.parse().map_err(|_| err("unparsable factor"))?;
                if !factor.is_finite() || factor <= 0.0 || factor > 1.0 {
                    return Err(err("factor must be in (0, 1]"));
                }
                FaultKind::Slow { device, factor }
            }
            _ => return Err(err("unknown kind (crash/server/rack/uplink/slow)")),
        };
        Ok(FaultEvent { at, duration, kind })
    }

    /// Sample a seeded fault trace over `[0, horizon)` from a per-device
    /// exponential failure process. `mtbf` is the mean time between
    /// failures of a baseline (V100) device; sturdier generations scale it
    /// up (A100 1.5×, H100 2×). Per-device generators are seeded from
    /// `seed`, so the trace is deterministic and independent of iteration
    /// order; 25% of arrivals are transient stragglers (0.5× for 10% of
    /// the horizon), the rest crashes (repair 5% of the horizon).
    pub fn sample(cluster: &Cluster, mtbf: f64, horizon: f64, seed: u64) -> FaultSpec {
        let mut events: Vec<FaultEvent> = Vec::new();
        if !(mtbf > 0.0) || !(horizon > 0.0) {
            return FaultSpec { events };
        }
        for d in 0..cluster.num_gpus() {
            let rel = if cluster.server_kind.is_empty() {
                1.0
            } else {
                match cluster.server_kind[cluster.server_of(d)].name.as_str() {
                    "h100" => 2.0,
                    "a100" => 1.5,
                    _ => 1.0,
                }
            };
            let dev_mtbf = mtbf * rel;
            let mut rng = Rng::new(seed.wrapping_add(d as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let mut t = 0.0f64;
            loop {
                t += -(1.0 - rng.f64()).ln() * dev_mtbf;
                if !(t < horizon) {
                    break;
                }
                let (kind, duration) = if rng.f64() < 0.25 {
                    (FaultKind::Slow { device: d, factor: 0.5 }, 0.1 * horizon)
                } else {
                    (FaultKind::Crash { device: d }, 0.05 * horizon)
                };
                events.push(FaultEvent { at: t, duration, kind });
            }
        }
        // Stable chronological order keeps the trace readable and the
        // resolved plan independent of the device loop above.
        events.sort_by_key(|e| e.at.to_bits());
        FaultSpec { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Validate the trace against a concrete cluster and lower it to the
    /// DES-facing [`FaultPlan`]: rack/server targets expand to device
    /// lists, uplink targets to [`LinkId::Up`] outages.
    pub fn resolve(&self, cluster: &Cluster) -> Result<FaultPlan, FaultError> {
        let gpus = cluster.num_gpus();
        let gps = cluster.gpus_per_server;
        let mut plan = FaultPlan::default();
        for e in &self.events {
            match e.kind {
                FaultKind::Crash { device } => {
                    if device >= gpus {
                        return Err(FaultError::DeviceOutOfRange { device, gpus });
                    }
                    plan.kills.push(KillEvent { at: e.at, devices: vec![device], repair: e.duration });
                }
                FaultKind::Server { server } => {
                    if server >= cluster.n_servers {
                        return Err(FaultError::ServerOutOfRange {
                            server,
                            servers: cluster.n_servers,
                        });
                    }
                    plan.kills.push(KillEvent {
                        at: e.at,
                        devices: (server * gps..(server + 1) * gps).collect(),
                        repair: e.duration,
                    });
                }
                FaultKind::Rack { rack } => {
                    let range = cluster.topo.rack_devices(rack).ok_or_else(|| {
                        FaultError::RackUnavailable {
                            rack,
                            racks: cluster.topo.n_racks(),
                            topology: cluster.topo.label(),
                        }
                    })?;
                    plan.kills.push(KillEvent {
                        at: e.at,
                        devices: range.collect(),
                        repair: e.duration,
                    });
                }
                FaultKind::Uplink { rack } => {
                    if cluster.topo.rack_devices(rack).is_none() {
                        return Err(FaultError::RackUnavailable {
                            rack,
                            racks: cluster.topo.n_racks(),
                            topology: cluster.topo.label(),
                        });
                    }
                    plan.outages.push(OutageEvent {
                        at: e.at,
                        link: LinkId::Up(rack),
                        duration: e.duration,
                    });
                }
                FaultKind::Slow { device, factor } => {
                    if device >= gpus {
                        return Err(FaultError::DeviceOutOfRange { device, gpus });
                    }
                    plan.slowdowns.push(SlowEvent {
                        at: e.at,
                        device,
                        factor,
                        duration: e.duration,
                    });
                }
            }
        }
        Ok(plan)
    }
}

/// A device-kill event resolved against a cluster: `devices` all fail at
/// `at` and need `repair` seconds of hardware repair before the
/// checkpoint-reload + replay phases of recovery begin.
#[derive(Clone, Debug, PartialEq)]
pub struct KillEvent {
    pub at: f64,
    pub devices: Vec<DeviceId>,
    pub repair: f64,
}

/// A link outage: every transfer whose route crosses `link` stalls for
/// `duration` (fat-tree routes are unique, so there is nothing to reroute
/// onto — see the module doc).
#[derive(Clone, Debug, PartialEq)]
pub struct OutageEvent {
    pub at: f64,
    pub link: LinkId,
    pub duration: f64,
}

/// A transient straggler: `device` computes at `factor`× its nominal rate
/// during the window.
#[derive(Clone, Debug, PartialEq)]
pub struct SlowEvent {
    pub at: f64,
    pub device: DeviceId,
    pub factor: f64,
    pub duration: f64,
}

/// The DES-facing fault schedule: resolved kill/outage/slowdown events plus
/// the checkpoint cadence (`ckpt_interval` of 0 disables checkpointing).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub kills: Vec<KillEvent>,
    pub outages: Vec<OutageEvent>,
    pub slowdowns: Vec<SlowEvent>,
    pub ckpt_interval: f64,
}

impl FaultPlan {
    /// True when the plan injects nothing: no faults and no checkpoints.
    /// The engine's no-fault equivalence guarantee (bitwise-identical
    /// timelines) holds exactly for this case.
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty()
            && self.outages.is_empty()
            && self.slowdowns.is_empty()
            && self.ckpt_interval <= 0.0
    }
}

/// When (and whether) the engine takes coordinated checkpoints.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CkptPolicy {
    /// No checkpoints: a crash replays the whole run so far.
    Off,
    /// Pick the interval from the checkpoint stall and MTBF (Young's
    /// approximation; see [`auto_interval`]).
    Auto,
    /// A fixed interval in seconds.
    Every(f64),
}

impl CkptPolicy {
    /// Parse a `--ckpt-interval` argument: `off`, `auto`, or seconds.
    pub fn parse(s: &str) -> Option<CkptPolicy> {
        match s {
            "off" => Some(CkptPolicy::Off),
            "auto" => Some(CkptPolicy::Auto),
            _ => {
                let v: f64 = s.parse().ok()?;
                (v.is_finite() && v > 0.0).then_some(CkptPolicy::Every(v))
            }
        }
    }
}

/// How the search scores resilience: an explicit trace, or an MTBF to
/// sample one from, plus the checkpoint policy and whether the
/// rack-spreading placement pass runs.
#[derive(Clone, Debug)]
pub struct ResilienceConfig {
    /// An explicit fault trace (`--faults`). Takes precedence over `mtbf`.
    pub trace: Option<FaultSpec>,
    /// Baseline-device MTBF in seconds (`--mtbf`): a trace is sampled per
    /// candidate over its fault-free makespan.
    pub mtbf: Option<f64>,
    /// Seed for MTBF sampling (`--fault-seed`).
    pub seed: u64,
    /// Checkpoint cadence (`--ckpt-interval`).
    pub ckpt: CkptPolicy,
    /// Spread dp replicas across racks before scoring (`--no-rack-spread`
    /// disables).
    pub spread: bool,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        ResilienceConfig { trace: None, mtbf: None, seed: 1, ckpt: CkptPolicy::Auto, spread: true }
    }
}

/// Resilience verdict of one plan under one fault trace.
#[derive(Clone, Debug)]
pub struct ResilienceReport {
    /// Useful-work fraction: fault-free makespan / faulted makespan (≤ 1).
    pub goodput: f64,
    pub base_makespan: f64,
    pub faulted_makespan: f64,
    /// Longest single outage-to-recovered window (repair + reload + replay).
    pub recovery_time: f64,
    /// Seconds of in-flight work aborted by kills.
    pub lost_work: f64,
    /// Seconds spent frozen in checkpoint stalls.
    pub ckpt_time: f64,
    /// Device-kill events that fired.
    pub n_kills: usize,
    /// All fault events that fired (kills + outages + slowdowns).
    pub n_faults: usize,
    /// The checkpoint interval the run used (0 = off).
    pub ckpt_interval: f64,
}

/// The coordinated-checkpoint stall: the slowest device's weights+optimizer
/// snapshot to host, priced by the existing PCIe cost tier
/// ([`Cluster::checkpoint_time`]). Every stream freezes for this long per
/// checkpoint, and a recovering device pays it again as the reload phase.
pub fn checkpoint_stall(plan: &Plan, cluster: &Cluster) -> f64 {
    plan.static_mem
        .iter()
        .filter(|(&d, _)| d != CPU_DEVICE)
        .map(|(&d, &bytes)| {
            let grad = plan.static_grad_mem.get(&d).copied().unwrap_or(0);
            cluster.checkpoint_time(d, bytes.saturating_sub(grad))
        })
        .fold(0.0, f64::max)
}

/// Checkpoint interval for [`CkptPolicy::Auto`]: Young's approximation
/// `sqrt(2 · stall · MTBF)` when an MTBF is known and the stall is
/// positive, else a quarter of the fault-free makespan; clamped to
/// `[max(makespan/16, stall), makespan]` so checkpoints neither dominate
/// the timeline nor never fire.
pub fn auto_interval(base_makespan: f64, stall: f64, mtbf: Option<f64>) -> f64 {
    if !(base_makespan > 0.0) {
        return 0.0;
    }
    let raw = match mtbf {
        Some(m) if m > 0.0 && stall > 0.0 => (2.0 * stall * m).sqrt(),
        _ => base_makespan / 4.0,
    };
    raw.clamp((base_makespan / 16.0).max(stall), base_makespan)
}

/// Score one prepared plan's resilience: run the DES fault-free for the
/// base makespan, derive the fault trace (explicit, or MTBF-sampled over
/// that horizon) and checkpoint interval, run the DES again under the
/// [`FaultPlan`], and report goodput / recovery / loss breakdown plus the
/// faulted [`DesReport`] (whose `faults` field carries the event log for
/// trace export).
pub fn evaluate_resilience(
    g: &Graph,
    plan: &Plan,
    cluster: &Cluster,
    tg: &TaskGraph,
    cfg: &ResilienceConfig,
) -> Result<(ResilienceReport, DesReport), FaultError> {
    let base = des::execute(g, plan, cluster, tg);
    let spec = match (&cfg.trace, cfg.mtbf) {
        (Some(t), _) => t.clone(),
        (None, Some(m)) => FaultSpec::sample(cluster, m, base.makespan, cfg.seed),
        (None, None) => FaultSpec::default(),
    };
    let mut fp = spec.resolve(cluster)?;
    let stall = checkpoint_stall(plan, cluster);
    fp.ckpt_interval = match cfg.ckpt {
        CkptPolicy::Off => 0.0,
        CkptPolicy::Every(s) => s.max(0.0),
        CkptPolicy::Auto => auto_interval(base.makespan, stall, cfg.mtbf),
    };
    let faulted = des::execute_faulted(g, plan, cluster, tg, &fp);
    let out = faulted.faults.clone().unwrap_or_default();
    let goodput = if faulted.makespan > 0.0 { (base.makespan / faulted.makespan).min(1.0) } else { 1.0 };
    let report = ResilienceReport {
        goodput,
        base_makespan: base.makespan,
        faulted_makespan: faulted.makespan,
        recovery_time: out.recovery_time,
        lost_work: out.lost_work,
        ckpt_time: out.ckpt_time,
        n_kills: out.n_kills,
        n_faults: out.n_faults,
        ckpt_interval: fp.ckpt_interval,
    };
    Ok((report, faulted))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::build_cluster;

    #[test]
    fn parse_accepts_the_grammar() {
        let spec = FaultSpec::parse("crash:d3@0.5+0.2, server:1@0.5, rack:1@1.0+0.2").unwrap();
        assert_eq!(spec.events.len(), 3);
        assert_eq!(
            spec.events[0],
            FaultEvent { at: 0.5, duration: 0.2, kind: FaultKind::Crash { device: 3 } }
        );
        assert_eq!(spec.events[1].duration, DEFAULT_DURATION);
        let spec = FaultSpec::parse("uplink:0@0.5+0.1,slow:d2x0.5@0.2+0.3").unwrap();
        assert_eq!(spec.events[1].kind, FaultKind::Slow { device: 2, factor: 0.5 });
        assert!(FaultSpec::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_tokens() {
        for bad in [
            "crash:d3",          // no time
            "crash:3@0.5",       // device without the d prefix
            "crash:d3@-1.0",     // negative time
            "crash:d3@0.5+0",    // non-positive duration
            "slow:d2@0.1",       // slow without a factor
            "slow:d2x1.5@0.1",   // factor > 1
            "slow:d2x0@0.1",     // factor 0
            "meteor:d2@0.1",     // unknown kind
            "rack:x@0.1",        // unparsable index
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn resolve_validates_targets_against_the_cluster() {
        let flat = build_cluster(8, None, "flat", None).unwrap();
        let tree = build_cluster(16, Some(4), "fat-tree:2", None).unwrap();
        assert!(matches!(
            FaultSpec::parse("crash:d9@0.1").unwrap().resolve(&flat).unwrap_err(),
            FaultError::DeviceOutOfRange { device: 9, gpus: 8 }
        ));
        assert!(matches!(
            FaultSpec::parse("server:4@0.1").unwrap().resolve(&tree).unwrap_err(),
            FaultError::ServerOutOfRange { server: 4, servers: 4 }
        ));
        // Rack faults need a fat-tree.
        assert!(matches!(
            FaultSpec::parse("rack:0@0.1").unwrap().resolve(&flat).unwrap_err(),
            FaultError::RackUnavailable { racks: 1, .. }
        ));
        assert!(matches!(
            FaultSpec::parse("uplink:2@0.1").unwrap().resolve(&tree).unwrap_err(),
            FaultError::RackUnavailable { rack: 2, racks: 2, .. }
        ));
        // Rack 1 of 4 servers x 4 GPUs with k=2 covers devices 8..16.
        let fp = FaultSpec::parse("rack:1@0.1+0.2").unwrap().resolve(&tree).unwrap();
        assert_eq!(fp.kills.len(), 1);
        assert_eq!(fp.kills[0].devices, (8..16).collect::<Vec<_>>());
        // Server 1 covers devices 4..8.
        let fp = FaultSpec::parse("server:1@0.1").unwrap().resolve(&tree).unwrap();
        assert_eq!(fp.kills[0].devices, vec![4, 5, 6, 7]);
        // Uplink resolves to the rack's spine link.
        let fp = FaultSpec::parse("uplink:1@0.1").unwrap().resolve(&tree).unwrap();
        assert_eq!(fp.outages[0].link, LinkId::Up(1));
    }

    #[test]
    fn sample_is_deterministic_and_respects_the_horizon() {
        let c = build_cluster(16, None, "flat", None).unwrap();
        let a = FaultSpec::sample(&c, 0.5, 1.0, 7);
        let b = FaultSpec::sample(&c, 0.5, 1.0, 7);
        assert_eq!(a, b, "same seed must sample the same trace");
        assert!(!a.is_empty(), "MTBF 0.5 over a 1 s horizon on 16 devices must fire");
        assert!(a.events.iter().all(|e| e.at < 1.0));
        assert!(a.events.windows(2).all(|w| w[0].at <= w[1].at), "chronological");
        let c2 = FaultSpec::sample(&c, 0.5, 1.0, 8);
        assert_ne!(a, c2, "different seeds must differ");
        // Sampled traces always resolve (devices come from the cluster).
        a.resolve(&c).unwrap();
    }

    #[test]
    fn auto_interval_follows_young_and_clamps() {
        // Known MTBF + stall: Young's sqrt(2 * stall * mtbf), inside clamp.
        let i = auto_interval(10.0, 0.8, Some(4.0));
        assert!((i - (2.0f64 * 0.8 * 4.0).sqrt()).abs() < 1e-12);
        // No MTBF: a quarter of the makespan.
        assert!((auto_interval(8.0, 0.1, None) - 2.0).abs() < 1e-12);
        // Clamp floor: never below the stall itself.
        assert!(auto_interval(1.0, 0.9, Some(0.001)) >= 0.9);
        // Clamp ceiling: never above the makespan.
        assert!(auto_interval(1.0, 0.5, Some(1e9)) <= 1.0);
        assert_eq!(auto_interval(0.0, 0.5, None), 0.0);
    }

    #[test]
    fn ckpt_policy_parses() {
        assert_eq!(CkptPolicy::parse("off"), Some(CkptPolicy::Off));
        assert_eq!(CkptPolicy::parse("auto"), Some(CkptPolicy::Auto));
        assert_eq!(CkptPolicy::parse("0.25"), Some(CkptPolicy::Every(0.25)));
        assert_eq!(CkptPolicy::parse("-1"), None);
        assert_eq!(CkptPolicy::parse("soon"), None);
    }
}
