//! Fault-domain-aware placement: spread dp replicas across racks.
//!
//! Planners assign dp replicas to *contiguous* device blocks (replica `r`
//! of width `w` owns logical devices `r·w .. (r+1)·w`), which ignores rack
//! boundaries: on a fat-tree a replica can straddle two racks, so a single
//! rack loss degrades every replica it touches. With equal-width replicas
//! filling the whole cluster no permutation can lower the *maximum*
//! replicas-per-rack (pigeonhole), so the honest objective is containment:
//! **maximize the number of replicas whose devices all sit in one rack**,
//! minimizing how many replicas a rack's blast radius can reach.
//! [`rack_spread_map`] packs replicas whole-rack-first — each replica
//! draws from the rack with the most free devices — which provably beats
//! contiguous packing whenever the replica width does not divide the rack
//! capacity (e.g. dp=4 × width-6 replicas over three 8-device racks:
//! greedy contains 3 replicas, contiguous only 2).

use crate::cost::Cluster;
use crate::schedule::DeviceId;
use std::collections::VecDeque;

/// Permutation mapping a plan's *logical* device ids onto physical devices
/// so each dp replica's block lands on as few racks as possible. Returns
/// `None` when spreading cannot help: dp < 2, a single (or no) rack, a
/// replica width that does not tile the cluster, or a greedy result equal
/// to the identity (contiguous packing was already optimal). The result is
/// always a bijection on `0..cluster.num_gpus()`; apply it with
/// [`crate::schedule::Schedule::remap_devices`].
pub fn rack_spread_map(dp: usize, cluster: &Cluster) -> Option<Vec<DeviceId>> {
    let n = cluster.num_gpus();
    let racks = cluster.topo.n_racks();
    if dp < 2 || racks < 2 || n == 0 || n % dp != 0 {
        return None;
    }
    let w = n / dp;
    let mut free: Vec<VecDeque<DeviceId>> =
        (0..racks).map(|r| cluster.topo.rack_devices(r).expect("rack in range").collect()).collect();
    let mut map = vec![0usize; n];
    for rep in 0..dp {
        let mut need = w;
        while need > 0 {
            // The rack with the most free devices, ties to the lowest index
            // (deterministic: plain loops, no hash iteration).
            let (mut best, mut best_len) = (0usize, 0usize);
            for (i, q) in free.iter().enumerate() {
                if q.len() > best_len {
                    best = i;
                    best_len = q.len();
                }
            }
            if best_len == 0 {
                return None; // unreachable: rack capacities sum to n
            }
            let take = best_len.min(need);
            for j in 0..take {
                map[rep * w + (w - need) + j] = free[best].pop_front().expect("non-empty rack");
            }
            need -= take;
        }
    }
    if map.iter().enumerate().all(|(i, &d)| i == d) {
        None
    } else {
        Some(map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::build_cluster;
    use std::collections::BTreeSet;

    /// Racks each replica's devices land on, under `map` (identity when
    /// `map` is `None`).
    fn racks_per_replica(
        dp: usize,
        c: &Cluster,
        map: Option<&Vec<DeviceId>>,
    ) -> Vec<BTreeSet<usize>> {
        let n = c.num_gpus();
        let w = n / dp;
        (0..dp)
            .map(|rep| {
                (rep * w..(rep + 1) * w)
                    .map(|logical| {
                        let phys = map.map_or(logical, |m| m[logical]);
                        c.topo.rack_of(c.server_of(phys))
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn greedy_contains_more_replicas_than_contiguous() {
        // 24 GPUs as 6 servers x 4, k=2 => three 8-device racks; dp=4 means
        // width-6 replicas that do not divide the rack capacity.
        let c = build_cluster(24, Some(6), "fat-tree:2", None).unwrap();
        let map = rack_spread_map(4, &c).expect("spreading must help here");
        // Bijection on 0..24.
        let mut seen: Vec<DeviceId> = map.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..24).collect::<Vec<_>>());
        let contained = |sets: &[BTreeSet<usize>]| sets.iter().filter(|s| s.len() == 1).count();
        let greedy = racks_per_replica(4, &c, Some(&map));
        let contiguous = racks_per_replica(4, &c, None);
        assert_eq!(contained(&contiguous), 2, "contiguous packing straddles two replicas");
        assert_eq!(contained(&greedy), 3, "greedy must contain three of four replicas");
    }

    #[test]
    fn dividing_shapes_spread_one_replica_per_rack_group() {
        // 16 GPUs, 4 servers x 4, k=2 => two racks; dp=2 width-8 replicas
        // tile the racks exactly: contiguous is already optimal, so the
        // greedy result equals the identity and the pass declines.
        let c = build_cluster(16, Some(4), "fat-tree:2", None).unwrap();
        assert_eq!(rack_spread_map(2, &c), None);
    }

    #[test]
    fn declines_when_spreading_cannot_help() {
        let flat = build_cluster(16, None, "flat", None).unwrap();
        assert_eq!(rack_spread_map(4, &flat), None, "no racks on a flat fabric");
        let tree = build_cluster(16, Some(4), "fat-tree:2", None).unwrap();
        assert_eq!(rack_spread_map(1, &tree), None, "dp=1 has nothing to spread");
        assert_eq!(rack_spread_map(3, &tree), None, "width must tile the cluster");
    }

    #[test]
    fn map_is_always_a_bijection() {
        for (gpus, servers, k, dp) in [(24usize, 6usize, 2usize, 2usize), (24, 6, 3, 4), (32, 8, 2, 8)] {
            let c = build_cluster(gpus, Some(servers), &format!("fat-tree:{k}"), None).unwrap();
            if let Some(map) = rack_spread_map(dp, &c) {
                let mut seen = map.clone();
                seen.sort_unstable();
                assert_eq!(seen, (0..gpus).collect::<Vec<_>>(), "{gpus}/{servers}/{k}/{dp}");
            }
        }
    }
}
