//! CPU reference executor: actually *run* a materialized plan's task graph
//! with real f32 tensors, one OS thread per simulated device.
//!
//! This is the ground-truth tier under the simulators: compute tasks are
//! interpreted against full pTensor stores with the native kernels in
//! [`super::kernels`], P2P transfers move staged payload buffers between
//! device threads, and collective tasks run through the same
//! [`AllReducer`] machinery the data-parallel trainer uses. The per-device
//! serial order comes from the prepared [`TaskGraph`]'s global topological
//! order, so cross-device dependencies are honored exactly as the
//! simulators assume them.
//!
//! Numeric conventions (shared with the serial oracle in [`super::diff`]):
//!
//! - Every device materializes every pTensor at full size; stores are
//!   initialized deterministically from a hash of the pTensor *name*
//!   (weights small-uniform, inputs integer-valued, Adam moments zero,
//!   grads zero except the loss grad which seeds the backward pass with
//!   ones), so replicas agree across devices and across plans.
//! - A value-split output view (`vsplit.parts > 1`) accumulates (`+=`)
//!   into its region; a full view overwrites. Value partials produced by
//!   *replicated* operators are the full value, not a share of it, so each
//!   replica's gradient contribution is scaled by `1/r` (`r` = live
//!   forward replicas of the same base op reading the pTensor) — the
//!   "value-partials scaled by 1/n" semantics `trans` declares.
//! - Weight reads outside the optimizer come from a frozen snapshot of the
//!   initial values: plans legitimately order weight-gradient work before
//!   or after the optimizer step (zero-bubble W slots), and within one
//!   training step every consumer of a weight must see the same bytes.
//! - P2P payloads are staged from the *producing task's own kernel
//!   output* (not the accumulated store), so a receiver summing several
//!   partial transfers never double-counts a co-located producer.
//!
//! Every executed task records its measured wall duration next to the
//! analytic `cost::` prediction carried on the task; the pairs feed
//! [`crate::cost::calibrate`].

use crate::cost::calibrate::TaskSample;
use crate::exec::collective::AllReducer;
use crate::exec::kernels;
use crate::exec::Adam;
use crate::graph::{Graph, Op, OpId, OpKind, PTensorId, TensorKind};
use crate::materialize::{Plan, TaskId, TaskKind};
use crate::schedule::{DeviceId, ValidatedSchedule};
use crate::sim::TaskGraph;
use crate::trans::autograd::grad_name;
use crate::util::pool::GenBarrier;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How long a device thread may sit on the dependency condvar before the
/// run is declared wedged. Generous: real reference-tier tasks finish in
/// milliseconds, so half a minute of no progress is a scheduling bug
/// (missing producer, cross-device cycle), not a slow kernel.
pub const DEADLOCK_TIMEOUT_SECS: f64 = 30.0;

/// Why a plan cannot be executed by the reference tier.
#[derive(Debug, Clone)]
pub enum ExecError {
    /// The plan uses a feature the reference executor does not interpret
    /// (e.g. a non-all-reduce collective).
    Unsupported { task: String, what: String },
    /// The plan is internally inconsistent (cyclic, unresolvable regions).
    BadPlan(String),
    /// A device thread waited past [`DEADLOCK_TIMEOUT_SECS`] for a
    /// dependency that never completed. Names the stuck device and task so
    /// the wedge is diagnosable from the error alone — previously this
    /// hung `verify-exec` forever on the condvar.
    DeadlockSuspected { device: DeviceId, task: String },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Unsupported { task, what } => {
                write!(f, "unsupported by reference executor: {what} (task {task})")
            }
            ExecError::BadPlan(why) => write!(f, "bad plan: {why}"),
            ExecError::DeadlockSuspected { device, task } => write!(
                f,
                "suspected deadlock: device {device} made no progress for {DEADLOCK_TIMEOUT_SECS}s \
                 waiting on dependencies of task {task}"
            ),
        }
    }
}

impl std::error::Error for ExecError {}

/// Result of executing a plan: the live per-device pTensor stores (full
/// tensors, keyed by pTensor id), the measured task samples, and the wall
/// time of the threaded run.
pub struct ExecResult {
    pub stores: HashMap<DeviceId, HashMap<PTensorId, Vec<f32>>>,
    pub samples: Vec<TaskSample>,
    pub wall: f64,
    pub n_threads: usize,
}

// ---------------------------------------------------------------------------
// Deterministic store initialization
// ---------------------------------------------------------------------------

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn name_seed(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Build the initial full-size store shared (by value) by every device and
/// the serial oracle: keyed purely by pTensor *name* so transformed plans
/// and the oracle agree.
pub fn init_store(g: &Graph) -> HashMap<PTensorId, Vec<f32>> {
    let mut store = HashMap::new();
    for p in &g.ptensors {
        let n = p.num_elements();
        let seed = name_seed(&p.name);
        let buf: Vec<f32> = match p.kind {
            TensorKind::Weight => (0..n)
                .map(|i| {
                    let u = splitmix64(seed ^ i as u64) as f64 / (u64::MAX as f64 + 1.0);
                    ((u - 0.5) * 0.2) as f32
                })
                .collect(),
            TensorKind::Input => {
                (0..n).map(|i| (splitmix64(seed ^ i as u64) % 1021) as f32).collect()
            }
            TensorKind::OptState => vec![0.0; n],
            TensorKind::Activation | TensorKind::Gradient => {
                if p.name.ends_with(".loss.grad") {
                    // Seed dL/dL = 1: without it every gradient is zero and
                    // the differential test is vacuous.
                    vec![1.0; n]
                } else {
                    vec![0.0; n]
                }
            }
        };
        store.insert(p.id, buf);
    }
    store
}

// ---------------------------------------------------------------------------
// Prepared actions
// ---------------------------------------------------------------------------

#[derive(Clone)]
struct ReadSpec {
    pt: PTensorId,
    region: Vec<(usize, usize)>,
    frozen: bool,
}

#[derive(Clone)]
struct WriteSpec {
    pt: PTensorId,
    region: Vec<(usize, usize)>,
    accumulate: bool,
    /// Replica-partial scaling (1/r); applied to the kernel output before
    /// it is scattered or staged.
    scale: f32,
}

/// How a compute task's kernel is dispatched (fully resolved at prepare
/// time so the device threads never consult the graph).
#[derive(Clone)]
enum ComputeKind {
    MatmulFwd { m: usize, k: usize, n: usize },
    /// `roles[i]`: which forward input write `i` is the gradient of
    /// (0 = data operand -> dx, 1 = weight operand -> dw).
    MatmulBwd { m: usize, k: usize, n: usize, roles: Vec<u8> },
    LayerNormFwd { h: usize },
    LayerNormBwd { h: usize },
    GeluFwd,
    GeluBwd,
    AddFwd,
    /// Writes are each a copy of dy (per-write scale applies on top).
    AddBwd,
    AttnFwd { b: usize, s: usize, a: usize, d: usize },
    AttnBwd { b: usize, s: usize, a: usize, d: usize },
    EmbedFwd { vocab: usize, v0: usize, v1: usize, h: usize },
    EmbedBwd { vocab: usize, v0: usize, v1: usize, h: usize },
    CeFwd { b: usize, s: usize, h: usize },
    CeBwd { b: usize, s: usize, h: usize },
    IdentityFwd,
    IdentityBwd,
    AdamStep,
}

enum Action {
    Compute {
        kind: ComputeKind,
        reads: Vec<ReadSpec>,
        writes: Vec<WriteSpec>,
        tag: &'static str,
    },
    /// P2P executed by the receiver: take the staged payload, scatter it.
    Recv { pt: PTensorId, region: Vec<(usize, usize)>, accumulate: bool },
    /// All-reduce over `group` (deduped, sorted) of a store region.
    AllReduce { pt: PTensorId, region: Vec<(usize, usize)>, group: Vec<DeviceId> },
    /// Cross-iteration (weight / optimizer-state) comm: every participant
    /// skips it — initial values are identical on all devices by
    /// construction.
    Noop,
}

/// Producer-side staging order: after the producing compute task `p2p`'s
/// dep runs, slice `rel` out of its `write_idx`-th kernel output (shaped
/// `wlens`) and deposit it in the transfer's slot.
struct StageSpec {
    p2p: TaskId,
    write_idx: usize,
    rel: Vec<(usize, usize)>,
    wlens: Vec<usize>,
}

struct Prepared {
    actions: Vec<Action>,
    stage_after: Vec<Vec<StageSpec>>,
    /// (device, its tasks in global-topo order) — one thread each.
    device_tasks: Vec<(DeviceId, Vec<TaskId>)>,
    pre_done: Vec<bool>,
    reducers: Vec<Option<Arc<AllReducer>>>,
    arrivals: Vec<AtomicUsize>,
    shapes: Vec<Vec<usize>>,
}

fn unsupported(task: impl std::fmt::Display, what: impl Into<String>) -> ExecError {
    ExecError::Unsupported { task: task.to_string(), what: what.into() }
}

/// Strip trailing `@r<digits>` replica suffixes from an op name.
fn replica_base(name: &str) -> &str {
    let mut s = name;
    loop {
        match s.rfind("@r") {
            Some(i)
                if i + 2 < s.len()
                    && s.as_bytes()[i + 2..].iter().all(|b| b.is_ascii_digit()) =>
            {
                s = &s[..i];
            }
            _ => return s,
        }
    }
}

/// The forward op name a backward op was generated from (`{fwd}.bw` /
/// `{fwd}.bw.w` after zero-bubble splitting).
fn fwd_name_of(bwd: &str) -> &str {
    bwd.strip_suffix(".bw")
        .or_else(|| bwd.strip_suffix(".bw.w"))
        .unwrap_or(bwd)
}

/// Number of live forward replicas of `base` reading `pt` — the divisor
/// for replica-produced gradient partials.
fn replica_count(g: &Graph, base: &str, pt: PTensorId) -> usize {
    g.live_ops()
        .filter(|o| {
            o.is_forward
                && !o.no_grad
                && replica_base(&o.name) == base
                && o.inputs.iter().any(|&v| g.vtensor(v).ptensor == pt)
        })
        .count()
}

fn tag_of(op: &Op) -> &'static str {
    match &op.kind {
        OpKind::Matmul => "compute:matmul",
        OpKind::LayerNorm => "compute:layernorm",
        OpKind::Attention => "compute:attention",
        OpKind::Elementwise(n) if *n == "gelu" => "compute:gelu",
        OpKind::Elementwise(n) if *n == "add" => "compute:add",
        OpKind::Elementwise(_) => "compute:elementwise",
        OpKind::Embed => "compute:embed",
        OpKind::CrossEntropy => "compute:cross_entropy",
        OpKind::Optimizer => "compute:optimizer",
        OpKind::Identity => "compute:identity",
        _ => "compute:other",
    }
}

fn dim_lens(region: &[(usize, usize)]) -> Vec<usize> {
    region.iter().map(|&(lo, hi)| hi - lo).collect()
}

fn full_dim(region: &[(usize, usize)], shape: &[usize], d: usize) -> bool {
    region[d] == (0, shape[d])
}

/// Resolve one compute op into a kernel dispatch + read/write specs.
fn resolve_compute(g: &Graph, op: &Op) -> Result<Action, ExecError> {
    let reads: Vec<ReadSpec> = op
        .inputs
        .iter()
        .map(|&v| {
            let vt = g.vtensor(v);
            let p = g.ptensor(vt.ptensor);
            ReadSpec {
                pt: p.id,
                region: vt.mask.concrete(&p.shape),
                frozen: p.kind == TensorKind::Weight,
            }
        })
        .collect();
    let mut writes: Vec<WriteSpec> = op
        .outputs
        .iter()
        .map(|&v| {
            let vt = g.vtensor(v);
            let p = g.ptensor(vt.ptensor);
            WriteSpec {
                pt: p.id,
                region: vt.mask.concrete(&p.shape),
                accumulate: vt.mask.vsplit.parts > 1,
                scale: 1.0,
            }
        })
        .collect();
    let name = &op.name;

    if op.kind == OpKind::Optimizer {
        if reads.len() < 4 || writes.is_empty() {
            return Err(unsupported(name, "optimizer without [g,w,m,v] -> [w] form"));
        }
        // Write back the updated moments through the m/v input regions.
        writes.push(reads[2].clone().into_write());
        writes.push(reads[3].clone().into_write());
        return Ok(Action::Compute {
            kind: ComputeKind::AdamStep,
            reads,
            writes,
            tag: tag_of(op),
        });
    }

    let kind = if op.is_forward {
        match &op.kind {
            OpKind::Matmul => {
                if reads.len() != 2 || writes.len() != 1 {
                    return Err(unsupported(name, "matmul arity"));
                }
                let (x, w, y) = (
                    kernels::region_len(&reads[0].region),
                    kernels::region_len(&reads[1].region),
                    kernels::region_len(&writes[0].region),
                );
                let (m, k, n) = kernels::matmul_dims(x, w, y)
                    .ok_or_else(|| unsupported(name, "matmul region shapes"))?;
                ComputeKind::MatmulFwd { m, k, n }
            }
            OpKind::LayerNorm => {
                let p = g.ptensor(reads[0].pt);
                let last = reads[0].region.len() - 1;
                if !full_dim(&reads[0].region, &p.shape, last) {
                    return Err(unsupported(name, "layernorm split on the norm dim"));
                }
                ComputeKind::LayerNormFwd { h: p.shape[last] }
            }
            OpKind::Elementwise(n) if *n == "gelu" => ComputeKind::GeluFwd,
            OpKind::Elementwise(n) if *n == "add" => ComputeKind::AddFwd,
            OpKind::Attention => {
                let lens = dim_lens(&writes[0].region);
                if lens.len() != 4 {
                    return Err(unsupported(name, "attention region rank"));
                }
                let (b, s, a, d) = (lens[0], lens[1], lens[2], lens[3]);
                if writes[0].region[1].0 != 0 {
                    return Err(unsupported(name, "attention split on sequence dim"));
                }
                if kernels::region_len(&reads[0].region) != b * s * a * 3 * d {
                    return Err(unsupported(name, "attention qkv region"));
                }
                ComputeKind::AttnFwd { b, s, a, d }
            }
            OpKind::Embed => {
                if reads.len() != 2 {
                    return Err(unsupported(name, "embed arity"));
                }
                let table = g.ptensor(reads[1].pt);
                let (v0, v1) = reads[1].region[0];
                if !full_dim(&reads[1].region, &table.shape, 1) {
                    return Err(unsupported(name, "embed split on hidden dim"));
                }
                if reads[0].region[..] != writes[0].region[..2] {
                    return Err(unsupported(name, "embed ids/out region mismatch"));
                }
                ComputeKind::EmbedFwd { vocab: table.shape[0], v0, v1, h: table.shape[1] }
            }
            OpKind::CrossEntropy => {
                let p = g.ptensor(reads[0].pt);
                if reads[0].region.len() != 3
                    || !full_dim(&reads[0].region, &p.shape, 1)
                    || !full_dim(&reads[0].region, &p.shape, 2)
                {
                    return Err(unsupported(name, "cross-entropy split beyond batch"));
                }
                let lens = dim_lens(&reads[0].region);
                ComputeKind::CeFwd { b: lens[0], s: lens[1], h: lens[2] }
            }
            OpKind::Identity => ComputeKind::IdentityFwd,
            other => return Err(unsupported(name, format!("forward op kind {other:?}"))),
        }
    } else {
        // Backward op: inputs are [dy(s) of the forward outputs] ++ the
        // stashed forward inputs (every forward kind here has one output).
        match &op.kind {
            OpKind::Matmul => {
                if reads.len() != 3 {
                    return Err(unsupported(name, "matmul backward arity"));
                }
                let (x, w, dy) = (
                    kernels::region_len(&reads[1].region),
                    kernels::region_len(&reads[2].region),
                    kernels::region_len(&reads[0].region),
                );
                let (m, k, n) = kernels::matmul_dims(x, w, dy)
                    .ok_or_else(|| unsupported(name, "matmul backward region shapes"))?;
                let roles = writes
                    .iter()
                    .map(|wr| {
                        let gname = &g.ptensor(wr.pt).name;
                        if *gname == grad_name(&g.ptensor(reads[1].pt).name) {
                            Ok(0u8)
                        } else if *gname == grad_name(&g.ptensor(reads[2].pt).name) {
                            Ok(1u8)
                        } else {
                            Err(unsupported(name, format!("unmatched grad output {gname}")))
                        }
                    })
                    .collect::<Result<Vec<u8>, ExecError>>()?;
                ComputeKind::MatmulBwd { m, k, n, roles }
            }
            OpKind::LayerNorm => {
                let p = g.ptensor(reads[1].pt);
                ComputeKind::LayerNormBwd { h: *p.shape.last().unwrap() }
            }
            OpKind::Elementwise(n) if *n == "gelu" => ComputeKind::GeluBwd,
            OpKind::Elementwise(n) if *n == "add" => ComputeKind::AddBwd,
            OpKind::Attention => {
                let lens = dim_lens(&reads[0].region);
                if lens.len() != 4 {
                    return Err(unsupported(name, "attention backward region rank"));
                }
                ComputeKind::AttnBwd { b: lens[0], s: lens[1], a: lens[2], d: lens[3] }
            }
            OpKind::Embed => {
                if reads.len() != 3 || writes.len() != 1 {
                    return Err(unsupported(name, "embed backward arity"));
                }
                let dt = g.ptensor(writes[0].pt);
                let (v0, v1) = writes[0].region[0];
                ComputeKind::EmbedBwd { vocab: dt.shape[0], v0, v1, h: dt.shape[1] }
            }
            OpKind::CrossEntropy => {
                let lens = dim_lens(&reads[1].region);
                if lens.len() != 3 {
                    return Err(unsupported(name, "cross-entropy backward region rank"));
                }
                ComputeKind::CeBwd { b: lens[0], s: lens[1], h: lens[2] }
            }
            OpKind::Identity => ComputeKind::IdentityBwd,
            other => return Err(unsupported(name, format!("backward op kind {other:?}"))),
        }
    };

    // Replica-partial scaling: a value-split gradient produced by a
    // *replicated* forward op is the full gradient value — divide by the
    // number of live replicas so the partials sum back to one copy.
    if !op.is_forward {
        let base = replica_base(fwd_name_of(name)).to_string();
        for wr in writes.iter_mut() {
            if !wr.accumulate {
                continue;
            }
            // The grad pTensor "<x>.grad" mirrors forward-input pTensor <x>.
            let gname = &g.ptensor(wr.pt).name;
            let src = g
                .ptensors
                .iter()
                .find(|p| *gname == grad_name(&p.name))
                .map(|p| p.id);
            if let Some(src_pt) = src {
                let r = replica_count(g, &base, src_pt);
                if r > 1 {
                    wr.scale = 1.0 / r as f32;
                }
            }
        }
    }

    Ok(Action::Compute { kind, reads, writes, tag: tag_of(op) })
}

impl ReadSpec {
    fn into_write(self) -> WriteSpec {
        WriteSpec { pt: self.pt, region: self.region, accumulate: false, scale: 1.0 }
    }
}

/// Global topological position of every task (Kahn with a min-heap so the
/// order is deterministic). Errors if the prepared graph is cyclic.
fn topo_positions(tg: &TaskGraph) -> Result<Vec<usize>, ExecError> {
    let n = tg.indeg.len();
    let mut indeg = tg.indeg.clone();
    let mut heap: BinaryHeap<Reverse<TaskId>> = indeg
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d == 0)
        .map(|(i, _)| Reverse(i))
        .collect();
    let mut pos = vec![usize::MAX; n];
    let mut k = 0usize;
    while let Some(Reverse(t)) = heap.pop() {
        pos[t] = k;
        k += 1;
        for &c in &tg.consumers[t] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                heap.push(Reverse(c));
            }
        }
    }
    if k != n {
        return Err(ExecError::BadPlan("task graph is cyclic".into()));
    }
    Ok(pos)
}

fn prepare(g: &Graph, vs: &ValidatedSchedule, plan: &Plan, tg: &TaskGraph) -> Result<Prepared, ExecError> {
    let n_tasks = plan.tasks.len();
    let shapes: Vec<Vec<usize>> = g.ptensors.iter().map(|p| p.shape.clone()).collect();
    let pos = topo_positions(tg)?;

    // Pass 1: compute tasks.
    let mut actions: Vec<Action> = Vec::with_capacity(n_tasks);
    for task in &plan.tasks {
        match &task.kind {
            TaskKind::Compute { op, .. } => actions.push(resolve_compute(g, g.op(*op))?),
            _ => actions.push(Action::Noop),
        }
    }

    // Pass 2 (in topo order): resolve P2P / collective regions against the
    // producing / consuming compute ops.
    let mut order: Vec<TaskId> = (0..n_tasks).collect();
    order.sort_by_key(|&t| pos[t]);
    let mut stage_after: Vec<Vec<StageSpec>> = (0..n_tasks).map(|_| Vec::new()).collect();
    let mut coll_region: HashMap<TaskId, Vec<(usize, usize)>> = HashMap::new();
    let mut reducers: Vec<Option<Arc<AllReducer>>> = (0..n_tasks).map(|_| None).collect();
    let mut arrivals: Vec<AtomicUsize> = (0..n_tasks).map(|_| AtomicUsize::new(0)).collect();

    for &t in &order {
        let task = &plan.tasks[t];
        match &task.kind {
            TaskKind::Compute { .. } => {}
            TaskKind::P2P { to, bytes, ptensor, .. } => {
                let p = g.ptensor(*ptensor);
                if matches!(p.kind, TensorKind::Weight | TensorKind::OptState)
                    || task.deps.is_empty()
                {
                    continue; // cross-iteration sync: Noop on every side.
                }
                let prod_task = task.deps[0];
                let prod_op = match &plan.tasks[prod_task].kind {
                    TaskKind::Compute { op, .. } => g.op(*op),
                    _ => {
                        return Err(unsupported(&task.label, "P2P from a non-compute task"))
                    }
                };
                // Match the materializer's byte formula: the overlap of a
                // producer output view with a consumer (on `to`) input view
                // whose element count prices to exactly `bytes`.
                let mut found: Option<(Vec<(usize, usize)>, bool, usize)> = None;
                'outer: for (wi, &ov) in prod_op.outputs.iter().enumerate() {
                    let pv = g.vtensor(ov);
                    if pv.ptensor != *ptensor {
                        continue;
                    }
                    let consumers: &[OpId] = vs
                        .device_order
                        .get(to)
                        .map(|v| v.as_slice())
                        .unwrap_or(&[]);
                    for &c in consumers {
                        for &iv in &g.op(c).inputs {
                            let cv = g.vtensor(iv);
                            if cv.ptensor != *ptensor {
                                continue;
                            }
                            if let Some(m) = cv.mask.intersect(&pv.mask) {
                                let nb = m.num_elements(&p.shape) as u64
                                    * p.dtype.size_bytes() as u64;
                                if nb == *bytes {
                                    found = Some((
                                        m.concrete(&p.shape),
                                        pv.mask.vsplit.parts > 1,
                                        wi,
                                    ));
                                    break 'outer;
                                }
                            }
                        }
                    }
                }
                let (region, accumulate, write_idx) = found.ok_or_else(|| {
                    unsupported(&task.label, format!("unresolvable P2P region of {}", p.name))
                })?;
                // Producer-side staging slice, relative to the write region.
                let wr_region = match &actions[prod_task] {
                    Action::Compute { writes, .. } => writes[write_idx].region.clone(),
                    _ => return Err(ExecError::BadPlan("P2P producer not compute".into())),
                };
                let rel: Vec<(usize, usize)> = region
                    .iter()
                    .zip(&wr_region)
                    .map(|(&(lo, hi), &(wlo, _))| (lo - wlo, hi - wlo))
                    .collect();
                stage_after[prod_task].push(StageSpec {
                    p2p: t,
                    write_idx,
                    rel,
                    wlens: dim_lens(&wr_region),
                });
                actions[t] = Action::Recv { pt: *ptensor, region, accumulate };
            }
            TaskKind::Collective { kind, group, bytes: _, ptensor } => {
                let p = g.ptensor(*ptensor);
                if matches!(p.kind, TensorKind::Weight | TensorKind::OptState) {
                    continue; // cross-iteration weight sync: Noop.
                }
                if *kind != crate::graph::CollKind::AllReduce {
                    return Err(unsupported(
                        &task.label,
                        format!("collective kind {kind:?} (only AllReduce is interpreted)"),
                    ));
                }
                // Region: bounding box of neighboring compute views on the
                // pTensor (producers via deps, consumers via the task
                // graph), inheriting from chained collectives.
                let mut lo = vec![usize::MAX; p.shape.len()];
                let mut hi = vec![0usize; p.shape.len()];
                let mut any = false;
                let mut absorb = |r: &[(usize, usize)]| {
                    for (d, &(a, b)) in r.iter().enumerate() {
                        lo[d] = lo[d].min(a);
                        hi[d] = hi[d].max(b);
                    }
                    any = true;
                };
                let mut op_views = |op: &Op, outputs: bool| {
                    let views = if outputs { &op.outputs } else { &op.inputs };
                    let mut rs = Vec::new();
                    for &v in views {
                        let vt = g.vtensor(v);
                        if vt.ptensor == *ptensor {
                            rs.push(vt.mask.concrete(&p.shape));
                        }
                    }
                    rs
                };
                for &d in &task.deps {
                    match &plan.tasks[d].kind {
                        TaskKind::Compute { op, .. } => {
                            for r in op_views(g.op(*op), true) {
                                absorb(&r);
                            }
                        }
                        TaskKind::Collective { ptensor: dpt, .. } if dpt == ptensor => {
                            if let Some(r) = coll_region.get(&d) {
                                let r = r.clone();
                                absorb(&r);
                            }
                        }
                        _ => {}
                    }
                }
                for &c in &tg.consumers[t] {
                    if let TaskKind::Compute { op, .. } = &plan.tasks[c].kind {
                        for r in op_views(g.op(*op), false) {
                            absorb(&r);
                        }
                    }
                }
                let region: Vec<(usize, usize)> = if any {
                    lo.into_iter().zip(hi).collect()
                } else {
                    p.shape.iter().map(|&s| (0, s)).collect()
                };
                coll_region.insert(t, region.clone());
                let mut members = group.clone();
                members.sort_unstable();
                members.dedup();
                reducers[t] = Some(Arc::new(AllReducer::new(members.len())));
                arrivals[t] = AtomicUsize::new(members.len());
                actions[t] = Action::AllReduce { pt: *ptensor, region, group: members };
            }
        }
    }

    // Per-device task lists (in global topo order) + pre-done noops.
    let mut by_dev: HashMap<DeviceId, Vec<TaskId>> = HashMap::new();
    let mut pre_done = vec![false; n_tasks];
    for &t in &order {
        match (&actions[t], &plan.tasks[t].kind) {
            (Action::Compute { .. }, TaskKind::Compute { device, .. }) => {
                by_dev.entry(*device).or_default().push(t)
            }
            (Action::Recv { .. }, TaskKind::P2P { to, .. }) => {
                by_dev.entry(*to).or_default().push(t)
            }
            (Action::AllReduce { group, .. }, _) => {
                for &d in group {
                    by_dev.entry(d).or_default().push(t);
                }
            }
            _ => pre_done[t] = true,
        }
    }
    let mut device_tasks: Vec<(DeviceId, Vec<TaskId>)> = by_dev.into_iter().collect();
    device_tasks.sort_by_key(|(d, _)| *d);

    Ok(Prepared { actions, stage_after, device_tasks, pre_done, reducers, arrivals, shapes })
}

// ---------------------------------------------------------------------------
// Runtime
// ---------------------------------------------------------------------------

struct Shared<'a> {
    plan: &'a Plan,
    prep: &'a Prepared,
    frozen: &'a HashMap<PTensorId, Vec<f32>>,
    slots: Vec<Mutex<Option<Vec<f32>>>>,
    done: Mutex<Vec<bool>>,
    cv: Condvar,
    start: Arc<GenBarrier>,
    /// Set by the first thread that times out (or errors): every other
    /// thread still parked on the condvar bails out on its next wake
    /// instead of waiting for dependencies that will never arrive.
    abort: AtomicBool,
}

/// The timeout-guarded dependency wait, factored free of [`Shared`]'s
/// borrowed plan state so the timeout path is unit-testable. `Err(())`
/// means no progress for `timeout` seconds (or a peer aborted first);
/// the caller attaches device/task identity.
fn wait_until_done(
    done: &Mutex<Vec<bool>>,
    cv: &Condvar,
    abort: &AtomicBool,
    deps: &[TaskId],
    timeout: f64,
) -> Result<(), ()> {
    let mut d = done.lock().unwrap();
    let t0 = Instant::now();
    while !deps.iter().all(|&t| d[t]) {
        if abort.load(Ordering::SeqCst) {
            return Err(());
        }
        // Chunked waits so a lost notification cannot wedge the thread
        // past the deadline either.
        let (guard, _) = cv.wait_timeout(d, Duration::from_millis(50)).unwrap();
        d = guard;
        if t0.elapsed().as_secs_f64() > timeout {
            abort.store(true, Ordering::SeqCst);
            cv.notify_all();
            return Err(());
        }
    }
    Ok(())
}

impl Shared<'_> {
    fn wait_deps(&self, dev: DeviceId, t: TaskId) -> Result<(), ExecError> {
        let task = &self.plan.tasks[t];
        wait_until_done(&self.done, &self.cv, &self.abort, &task.deps, DEADLOCK_TIMEOUT_SECS)
            .map_err(|()| ExecError::DeadlockSuspected {
                device: dev,
                task: task.label.to_string(),
            })
    }

    fn mark_done(&self, t: TaskId) {
        let mut d = self.done.lock().unwrap();
        d[t] = true;
        drop(d);
        self.cv.notify_all();
    }
}

fn run_kernel(kind: &ComputeKind, bufs: Vec<Vec<f32>>, n_writes: usize) -> Vec<Vec<f32>> {
    match kind {
        ComputeKind::MatmulFwd { m, k, n } => {
            vec![kernels::matmul_fwd(&bufs[0], &bufs[1], *m, *k, *n)]
        }
        ComputeKind::MatmulBwd { m, k, n, roles } => roles
            .iter()
            .map(|&r| {
                if r == 0 {
                    kernels::matmul_dx(&bufs[0], &bufs[2], *m, *k, *n)
                } else {
                    kernels::matmul_dw(&bufs[0], &bufs[1], *m, *k, *n)
                }
            })
            .collect(),
        ComputeKind::LayerNormFwd { h } => vec![kernels::layernorm_fwd(&bufs[0], *h)],
        ComputeKind::LayerNormBwd { h } => {
            vec![kernels::layernorm_dx(&bufs[0], &bufs[1], *h)]
        }
        ComputeKind::GeluFwd => vec![kernels::gelu_fwd(&bufs[0])],
        ComputeKind::GeluBwd => vec![kernels::gelu_dx(&bufs[0], &bufs[1])],
        ComputeKind::AddFwd => {
            let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
            vec![kernels::add_n(&refs)]
        }
        ComputeKind::AddBwd => (0..n_writes).map(|_| bufs[0].clone()).collect(),
        ComputeKind::AttnFwd { b, s, a, d } => {
            vec![kernels::attention_fwd(&bufs[0], *b, *s, *a, *d)]
        }
        ComputeKind::AttnBwd { b, s, a, d } => {
            vec![kernels::attention_dqkv(&bufs[0], &bufs[1], *b, *s, *a, *d)]
        }
        ComputeKind::EmbedFwd { vocab, v0, v1, h } => {
            vec![kernels::embed_fwd(&bufs[0], &bufs[1], *vocab, *v0, *v1, *h)]
        }
        ComputeKind::EmbedBwd { vocab, v0, v1, h } => {
            vec![kernels::embed_dtable(&bufs[0], &bufs[1], *vocab, *v0, *v1, *h)]
        }
        ComputeKind::CeFwd { b, s, h } => {
            vec![kernels::cross_entropy_fwd(&bufs[0], *b, *s, *h)]
        }
        ComputeKind::CeBwd { b, s, h } => {
            vec![kernels::cross_entropy_dx(&bufs[0], &bufs[1], *b, *s, *h)]
        }
        ComputeKind::IdentityFwd | ComputeKind::IdentityBwd => vec![bufs[0].clone()],
        ComputeKind::AdamStep => {
            let mut it = bufs.into_iter();
            let gbuf = it.next().unwrap();
            let mut w = it.next().unwrap();
            let mut m = it.next().unwrap();
            let mut v = it.next().unwrap();
            Adam::default().update(1, &mut w, &gbuf, &mut m, &mut v);
            vec![w, m, v]
        }
    }
}

fn run_device(
    dev: DeviceId,
    tasks: &[TaskId],
    mut store: HashMap<PTensorId, Vec<f32>>,
    sh: &Shared<'_>,
) -> Result<(HashMap<PTensorId, Vec<f32>>, Vec<TaskSample>), ExecError> {
    let prep = sh.prep;
    let mut samples = Vec::new();
    sh.start.wait();
    for &t in tasks {
        let task = &sh.plan.tasks[t];
        sh.wait_deps(dev, t)?;
        // A peer may have declared the run wedged while we were runnable;
        // entering a collective now would park us on its barrier forever.
        if sh.abort.load(Ordering::SeqCst) {
            return Err(ExecError::DeadlockSuspected { device: dev, task: task.label.to_string() });
        }
        let t0 = Instant::now();
        match &prep.actions[t] {
            Action::Compute { kind, reads, writes, tag } => {
                let bufs: Vec<Vec<f32>> = reads
                    .iter()
                    .map(|r| {
                        let src = if r.frozen { &sh.frozen[&r.pt] } else { &store[&r.pt] };
                        kernels::gather(src, &prep.shapes[r.pt], &r.region)
                    })
                    .collect();
                let mut outs = run_kernel(kind, bufs, writes.len());
                for (wr, out) in writes.iter().zip(outs.iter_mut()) {
                    if wr.scale != 1.0 {
                        for v in out.iter_mut() {
                            *v *= wr.scale;
                        }
                    }
                    let dst = store.get_mut(&wr.pt).unwrap();
                    kernels::scatter(dst, &prep.shapes[wr.pt], &wr.region, out, wr.accumulate, 1.0);
                }
                // Stage outgoing P2P payloads from this task's own
                // (scaled) outputs before anyone can see it as done.
                for sp in &prep.stage_after[t] {
                    let payload = kernels::gather(&outs[sp.write_idx], &sp.wlens, &sp.rel);
                    *sh.slots[sp.p2p].lock().unwrap() = Some(payload);
                }
                let secs = t0.elapsed().as_secs_f64();
                samples.push(TaskSample {
                    kind: tag.to_string(),
                    label: task.label.to_string(),
                    measured: secs,
                    predicted: task.duration,
                });
                sh.mark_done(t);
            }
            Action::Recv { pt, region, accumulate } => {
                let payload = sh.slots[t].lock().unwrap().take().expect("unstaged P2P");
                let dst = store.get_mut(pt).unwrap();
                kernels::scatter(dst, &prep.shapes[*pt], region, &payload, *accumulate, 1.0);
                let secs = t0.elapsed().as_secs_f64();
                samples.push(TaskSample {
                    kind: "p2p".into(),
                    label: task.label.to_string(),
                    measured: secs,
                    predicted: task.duration,
                });
                sh.mark_done(t);
            }
            Action::AllReduce { pt, region, group } => {
                let rank = group.binary_search(&dev).expect("device not in its group");
                let reducer = prep.reducers[t].as_ref().unwrap();
                let mut buf = kernels::gather(&store[pt], &prep.shapes[*pt], region);
                reducer.allreduce(rank, &mut buf);
                let dst = store.get_mut(pt).unwrap();
                kernels::scatter(dst, &prep.shapes[*pt], region, &buf, false, 1.0);
                let secs = t0.elapsed().as_secs_f64();
                if rank == 0 {
                    samples.push(TaskSample {
                        kind: "collective:allreduce".into(),
                        label: task.label.to_string(),
                        measured: secs,
                        predicted: task.duration,
                    });
                }
                if prep.arrivals[t].fetch_sub(1, Ordering::SeqCst) == 1 {
                    sh.mark_done(t);
                }
            }
            Action::Noop => {
                sh.mark_done(t);
            }
        }
    }
    Ok((store, samples))
}

/// Execute a materialized plan with real tensors. `g` must be the planner's
/// output graph (autograd-completed), `vs` its validated schedule, `plan`
/// its materialization.
pub fn execute(g: &Graph, vs: &ValidatedSchedule, plan: &Plan) -> Result<ExecResult, ExecError> {
    let tg = TaskGraph::prepare(vs, plan);
    let prep = prepare(g, vs, plan, &tg)?;
    let base_store = init_store(g);
    let frozen: HashMap<PTensorId, Vec<f32>> = base_store
        .iter()
        .filter(|(&pt, _)| g.ptensor(pt).kind == TensorKind::Weight)
        .map(|(&pt, v)| (pt, v.clone()))
        .collect();

    let n_threads = prep.device_tasks.len().max(1);
    let shared = Shared {
        plan,
        prep: &prep,
        frozen: &frozen,
        slots: (0..plan.tasks.len()).map(|_| Mutex::new(None)).collect(),
        done: Mutex::new(prep.pre_done.clone()),
        cv: Condvar::new(),
        start: GenBarrier::new(n_threads),
        abort: AtomicBool::new(false),
    };

    let t0 = Instant::now();
    let results: Vec<Result<(DeviceId, HashMap<PTensorId, Vec<f32>>, Vec<TaskSample>), ExecError>> =
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for (dev, tasks) in &prep.device_tasks {
                let store = base_store.clone();
                let sh = &shared;
                handles.push(s.spawn(move || {
                    let (store, samples) = run_device(*dev, tasks, store, sh)?;
                    Ok((*dev, store, samples))
                }));
            }
            handles.into_iter().map(|h| h.join().expect("device thread panicked")).collect()
        });
    let wall = t0.elapsed().as_secs_f64();

    let mut stores = HashMap::new();
    let mut samples = Vec::new();
    // Threads are joined in device order, so the surfaced error is
    // deterministic even when several threads bail out of the same wedge.
    let mut first_err: Option<ExecError> = None;
    for r in results {
        match r {
            Ok((dev, store, mut s)) => {
                stores.insert(dev, store);
                samples.append(&mut s);
            }
            Err(e) => {
                first_err.get_or_insert(e);
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(ExecResult { stores, samples, wall, n_threads })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_base_strips_stacked_suffixes() {
        assert_eq!(replica_base("h0.ln1"), "h0.ln1");
        assert_eq!(replica_base("h0.ln1@r3"), "h0.ln1");
        assert_eq!(replica_base("h0.ln1/b0@r1@r12"), "h0.ln1/b0");
        assert_eq!(replica_base("h0.ln1@rx"), "h0.ln1@rx");
    }

    #[test]
    fn fwd_name_strips_backward_suffixes() {
        assert_eq!(fwd_name_of("h0.at.proj.bw"), "h0.at.proj");
        assert_eq!(fwd_name_of("h0.at.proj.bw.w"), "h0.at.proj");
        assert_eq!(fwd_name_of("h0.at.proj"), "h0.at.proj");
    }

    #[test]
    fn init_store_is_name_keyed_and_seeds_loss_grad() {
        use crate::models::builder::ModelBuilder;
        let mut mb = ModelBuilder::new();
        let x = mb.input("ids", &[2, 2]);
        let (y, _) = mb.embedding("emb", x, 0, 2, 2, 8, 4);
        let (_, _) = mb.loss("lmloss", y, 1, &[2, 2, 4]);
        let store = init_store(&mb.g);
        let by_name = |n: &str| {
            let p = mb.g.ptensors.iter().find(|p| p.name == n).unwrap();
            store[&p.id].clone()
        };
        let table = by_name("emb.table");
        assert!(table.iter().any(|&v| v != 0.0));
        assert!(table.iter().all(|&v| v.abs() <= 0.1 + 1e-6));
        let ids = by_name("ids");
        assert!(ids.iter().all(|&v| v >= 0.0 && v.fract() == 0.0));
        assert!(by_name("lmloss.loss.grad").iter().all(|&v| v == 1.0));
        assert!(by_name("emb.table.m").iter().all(|&v| v == 0.0));
        // Same names -> same values on a rebuild (determinism).
        let store2 = init_store(&mb.g);
        assert_eq!(store[&0], store2[&0]);
    }

    #[test]
    fn dep_wait_times_out_instead_of_hanging() {
        let done = Mutex::new(vec![false]);
        let cv = Condvar::new();
        let abort = AtomicBool::new(false);
        // Dependency 0 never completes: the wait must give up after the
        // (tiny, test-sized) deadline rather than block forever.
        let t0 = Instant::now();
        assert!(wait_until_done(&done, &cv, &abort, &[0], 0.05).is_err());
        assert!(t0.elapsed().as_secs_f64() < 5.0, "returned promptly");
        assert!(abort.load(Ordering::SeqCst), "timeout raises the abort flag for peers");
    }

    #[test]
    fn dep_wait_returns_ok_when_deps_are_done() {
        let done = Mutex::new(vec![true, false]);
        let cv = Condvar::new();
        let abort = AtomicBool::new(false);
        assert!(wait_until_done(&done, &cv, &abort, &[0], 0.05).is_ok());
        assert!(wait_until_done(&done, &cv, &abort, &[], 0.05).is_ok());
        assert!(!abort.load(Ordering::SeqCst));
    }

    #[test]
    fn dep_wait_bails_out_when_a_peer_aborted() {
        let done = Mutex::new(vec![false]);
        let cv = Condvar::new();
        let abort = AtomicBool::new(true);
        let t0 = Instant::now();
        // Deadline is generous; the pre-set abort flag must win immediately.
        assert!(wait_until_done(&done, &cv, &abort, &[0], 30.0).is_err());
        assert!(t0.elapsed().as_secs_f64() < 5.0, "peer abort short-circuits the wait");
    }
}
