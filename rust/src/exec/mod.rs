//! Real executors: run plans with real numerics instead of simulated
//! durations. Two tiers share the [`collective`] machinery (host-f32
//! all-reduce, generation barriers), one OS thread per simulated device:
//!
//! - **PJRT data-parallel trainer** (this module's `train_dp`): each device
//!   thread owns a PJRT engine with the compiled `grad_step` artifact and
//!   its parameter replica; the end-to-end proof that Pallas kernels (L1)
//!   inside the jax model (L2) AOT-lowered to HLO are drivable from the
//!   rust coordinator (L3) with a decreasing loss curve (EXPERIMENTS.md
//!   §E2E). Data-parallel only.
//!
//! - **CPU reference executor** ([`reference`] + [`kernels`]): a pure-Rust
//!   interpreter for *any* materialized plan's task graph — compute tasks
//!   run native f32 kernels against real tensors, P2P and collective tasks
//!   move real payloads, the plan's per-device serial order and
//!   cross-device dependencies are honored exactly. The differential
//!   harness ([`diff`], `superscaler verify-exec`) uses it to prove every
//!   planner family elementwise-equivalent to a single-device serial
//!   oracle, and feeds the measured per-task durations to
//!   [`crate::cost::calibrate`] so the analytic cost model gains an error
//!   bar.

pub mod collective;
pub mod diff;
pub mod kernels;
pub mod reference;

use crate::runtime::{Engine, Manifest};
use crate::util::rng::Rng;
use anyhow::Result;
use collective::AllReducer;
use std::path::Path;
use std::sync::Arc;

/// Adam hyper-parameters (the same rule the python test suite validates).
#[derive(Clone, Copy, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for Adam {
    fn default() -> Self {
        Adam { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

impl Adam {
    /// In-place update of one parameter tensor.
    pub fn update(&self, t: u64, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
        let b1c = 1.0 - self.beta1.powi(t as i32);
        let b2c = 1.0 - self.beta2.powi(t as i32);
        for i in 0..p.len() {
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g[i];
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g[i] * g[i];
            let mh = m[i] / b1c;
            let vh = v[i] / b2c;
            p[i] -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }
}

/// Synthetic corpus: a noisy affine token chain (`next = a*tok + b mod V`
/// with occasional noise) — learnable, non-trivial, reproducible.
pub struct Corpus {
    vocab: usize,
    rng: Rng,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        Corpus { vocab, rng: Rng::new(seed) }
    }

    /// One (x, y) pair of `[batch, seq]` token tensors.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let v = self.vocab as i64;
        let mut x = Vec::with_capacity(batch * seq);
        let mut y = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut tok = self.rng.below(self.vocab as u64) as i64;
            for _ in 0..seq {
                x.push(tok as i32);
                let mut next = (5 * tok + 17) % v;
                if self.rng.below(20) == 0 {
                    next = self.rng.below(self.vocab as u64) as i64; // 5% noise
                }
                y.push(next as i32);
                tok = next;
            }
        }
        (x, y)
    }
}

/// Per-step training record from the leader device.
#[derive(Clone, Copy, Debug)]
pub struct StepStat {
    pub step: u64,
    pub loss: f32,
    pub step_time: f64,
    pub allreduce_time: f64,
}

/// Train `steps` steps of the artifact model data-parallel over
/// `n_devices` threads. Returns the leader's loss curve.
pub fn train_dp(
    artifacts: &Path,
    n_devices: usize,
    steps: u64,
    adam: Adam,
    seed: u64,
    log_every: u64,
) -> Result<Vec<StepStat>> {
    let manifest = Manifest::load(artifacts)?;
    let reducer = Arc::new(AllReducer::new(n_devices));
    let manifest = Arc::new(manifest);

    // Identical init on every replica (same seed) — DP invariant: replicas
    // stay bit-identical because they apply the same update to the same
    // all-reduced gradient.
    let stats: Vec<Result<Vec<StepStat>>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for dev in 0..n_devices {
            let manifest = manifest.clone();
            let reducer = reducer.clone();
            let artifacts = artifacts.to_path_buf();
            handles.push(s.spawn(move || -> Result<Vec<StepStat>> {
                let engine = Engine::cpu(&artifacts)?;
                let exe = engine.load("grad_step")?;
                let mut init_rng = Rng::new(seed);
                let mut params: Vec<Vec<f32>> = manifest
                    .params
                    .iter()
                    .map(|p| {
                        let scale = if p.name == "embed" {
                            0.02
                        } else if p.shape.len() == 1 {
                            return if p.name.ends_with('g') || p.name.ends_with("1g") {
                                vec![1.0; p.numel()]
                            } else {
                                vec![0.0; p.numel()]
                            };
                        } else {
                            1.0 / (p.shape[0] as f32).sqrt()
                        };
                        (0..p.numel()).map(|_| scale * init_rng.normal() as f32).collect()
                    })
                    .collect();
                let mut m: Vec<Vec<f32>> =
                    params.iter().map(|p| vec![0.0; p.len()]).collect();
                let mut v: Vec<Vec<f32>> =
                    params.iter().map(|p| vec![0.0; p.len()]).collect();
                // Distinct data shard per device.
                let mut corpus = Corpus::new(manifest.vocab, seed ^ (dev as u64 + 1) * 0x9E37);
                let mut curve = Vec::new();
                for step in 1..=steps {
                    let t0 = std::time::Instant::now();
                    let (x, y) = corpus.batch(manifest.batch, manifest.seq);
                    let f32_ins: Vec<(&[f32], &[usize])> = manifest
                        .params
                        .iter()
                        .zip(&params)
                        .map(|(spec, d)| (d.as_slice(), spec.shape.as_slice()))
                        .collect();
                    let shape_xy = [manifest.batch, manifest.seq];
                    let outs =
                        exe.run(&f32_ins, &[(&x, &shape_xy), (&y, &shape_xy)])?;
                    let local_loss = outs[0][0];
                    // ---- coordinator collectives: all-reduce (mean) ----
                    let t_ar = std::time::Instant::now();
                    let mut flat: Vec<f32> = Vec::with_capacity(manifest.n_params + 1);
                    flat.push(local_loss);
                    for g in &outs[1..] {
                        flat.extend_from_slice(g);
                    }
                    reducer.allreduce_mean(dev, &mut flat);
                    let allreduce_time = t_ar.elapsed().as_secs_f64();
                    let loss = flat[0];
                    // ---- Adam on the reduced grads ----
                    let mut off = 1usize;
                    for (i, spec) in manifest.params.iter().enumerate() {
                        let n = spec.numel();
                        adam.update(
                            step,
                            &mut params[i],
                            &flat[off..off + n],
                            &mut m[i],
                            &mut v[i],
                        );
                        off += n;
                    }
                    let step_time = t0.elapsed().as_secs_f64();
                    if dev == 0 {
                        curve.push(StepStat { step, loss, step_time, allreduce_time });
                        if log_every > 0 && step % log_every == 0 {
                            eprintln!(
                                "step {step:4}  loss {loss:.4}  {:.2} s/step (allreduce {:.1} ms)",
                                step_time,
                                allreduce_time * 1e3
                            );
                        }
                    }
                }
                Ok(curve)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("device thread panicked")).collect()
    });

    for r in &stats {
        if let Err(e) = r {
            anyhow::bail!("device failed: {e}");
        }
    }
    Ok(stats.into_iter().next().unwrap().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_moves_toward_minimum() {
        // Minimize f(p) = (p-3)^2 by feeding its gradient.
        let adam = Adam { lr: 0.1, ..Default::default() };
        let mut p = vec![0.0f32];
        let mut m = vec![0.0];
        let mut v = vec![0.0];
        for t in 1..=200 {
            let g = vec![2.0 * (p[0] - 3.0)];
            adam.update(t, &mut p, &g, &mut m, &mut v);
        }
        assert!((p[0] - 3.0).abs() < 0.1, "p = {}", p[0]);
    }

    #[test]
    fn corpus_is_deterministic_and_mostly_affine() {
        let mut c1 = Corpus::new(64, 7);
        let mut c2 = Corpus::new(64, 7);
        let (x1, y1) = c1.batch(2, 32);
        let (x2, y2) = c2.batch(2, 32);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let affine = x1
            .iter()
            .zip(&y1)
            .filter(|(&x, &y)| (5 * x as i64 + 17) % 64 == y as i64)
            .count();
        assert!(affine * 10 > x1.len() * 8, "{} affine of {}", affine, x1.len());
    }

    #[test]
    fn e2e_training_reduces_loss() {
        // The full three-layer stack: needs artifacts.
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("grad_step.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let curve = train_dp(&dir, 2, 12, Adam::default(), 42, 0).unwrap();
        assert_eq!(curve.len(), 12);
        let first = curve[0].loss;
        let last = curve.last().unwrap().loss;
        assert!(
            last < first,
            "loss did not decrease: {first} -> {last}"
        );
        assert!(curve.iter().all(|s| s.loss.is_finite()));
    }
}
