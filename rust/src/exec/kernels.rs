//! Native f32 CPU kernels for the reference executor (the Rust mirror of
//! `python/compile/kernels/{matmul,layernorm,attention}.py`).
//!
//! Every kernel operates on flat row-major buffers that the executor has
//! already gathered from a pTensor store region, with the relevant dims
//! passed explicitly. Accumulations run in f64 so that the *order* in which
//! a plan materializes partial sums (micro-batches, tensor-parallel shards,
//! all-reduce groups) perturbs the result far below the differential
//! harness's 1e-4 relative tolerance.
//!
//! Shape inference for matmul is deliberately generic: the builder's three
//! matmul signatures (`b s h, h n -> b s n`, `b s h, h a n -> b s a n` and
//! `b s a d, a d h -> b s h`) all keep the contraction dims *trailing* in
//! the data input and *leading* in the weight, so under row-major
//! flattening each is an `[m,k] @ [k,n] -> [m,n]` product with
//! `k = sqrt(|x|·|w| / |y|)`.

// ---------------------------------------------------------------------------
// Region gather/scatter
// ---------------------------------------------------------------------------

/// Number of elements in a concrete region (list of per-dim `[lo, hi)`).
pub fn region_len(region: &[(usize, usize)]) -> usize {
    region.iter().map(|&(lo, hi)| hi - lo).product()
}

/// Iterate the flat offsets of each contiguous row (innermost-dim run) of
/// `region` inside a row-major tensor of `shape`, calling `f(base)` with the
/// offset of the row's first element.
fn for_each_row(shape: &[usize], region: &[(usize, usize)], mut f: impl FnMut(usize)) {
    debug_assert_eq!(shape.len(), region.len());
    if region.iter().any(|&(lo, hi)| lo >= hi) {
        return;
    }
    let last = region.len() - 1;
    let mut idx: Vec<usize> = region.iter().map(|r| r.0).collect();
    loop {
        let mut base = 0usize;
        for d in 0..last {
            base = base * shape[d] + idx[d];
        }
        base = base * shape[last] + region[last].0;
        f(base);
        // Advance the outer-dim odometer (the innermost dim is the row).
        let mut d = last;
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < region[d].1 {
                break;
            }
            idx[d] = region[d].0;
        }
    }
}

/// Copy a region of `src` (shape `shape`) into a fresh contiguous buffer.
pub fn gather(src: &[f32], shape: &[usize], region: &[(usize, usize)]) -> Vec<f32> {
    let row = region.last().map(|&(lo, hi)| hi - lo).unwrap_or(0);
    let mut out = Vec::with_capacity(region_len(region));
    for_each_row(shape, region, |base| out.extend_from_slice(&src[base..base + row]));
    out
}

/// Write `buf` (contiguous, `region_len` elements, scaled by `scale`) into
/// the region of `dst`: `+=` when `accumulate` (value partials) else `=`.
pub fn scatter(
    dst: &mut [f32],
    shape: &[usize],
    region: &[(usize, usize)],
    buf: &[f32],
    accumulate: bool,
    scale: f32,
) {
    let row = region.last().map(|&(lo, hi)| hi - lo).unwrap_or(0);
    let mut at = 0usize;
    for_each_row(shape, region, |base| {
        let src = &buf[at..at + row];
        let tgt = &mut dst[base..base + row];
        if accumulate {
            for (t, &s) in tgt.iter_mut().zip(src) {
                *t += scale * s;
            }
        } else {
            for (t, &s) in tgt.iter_mut().zip(src) {
                *t = scale * s;
            }
        }
        at += row;
    });
}

// ---------------------------------------------------------------------------
// Matmul
// ---------------------------------------------------------------------------

/// Infer `(m, k, n)` for a flattened `[m,k] @ [k,n] -> [m,n]` product from
/// the three buffer lengths (see module docs for why this is exact for all
/// builder matmul signatures). `None` if the lengths are inconsistent.
pub fn matmul_dims(x_len: usize, w_len: usize, y_len: usize) -> Option<(usize, usize, usize)> {
    if x_len == 0 || w_len == 0 || y_len == 0 {
        return None;
    }
    let prod = (x_len as u128) * (w_len as u128);
    if prod % y_len as u128 != 0 {
        return None;
    }
    let k2 = prod / y_len as u128;
    let k = (k2 as f64).sqrt().round() as u128;
    if k == 0 || k * k != k2 {
        return None;
    }
    let k = k as usize;
    if x_len % k != 0 || w_len % k != 0 {
        return None;
    }
    let (m, n) = (x_len / k, w_len / k);
    if m * n != y_len {
        return None;
    }
    Some((m, k, n))
}

/// `y[m,n] = x[m,k] @ w[k,n]`.
pub fn matmul_fwd(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut y = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f64;
            for p in 0..k {
                acc += x[i * k + p] as f64 * w[p * n + j] as f64;
            }
            y[i * n + j] = acc as f32;
        }
    }
    y
}

/// `dx[m,k] = dy[m,n] @ w^T`.
pub fn matmul_dx(dy: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut dx = vec![0f32; m * k];
    for i in 0..m {
        for p in 0..k {
            let mut acc = 0f64;
            for j in 0..n {
                acc += dy[i * n + j] as f64 * w[p * n + j] as f64;
            }
            dx[i * k + p] = acc as f32;
        }
    }
    dx
}

/// `dw[k,n] = x^T @ dy`.
pub fn matmul_dw(dy: &[f32], x: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut dw = vec![0f32; k * n];
    for p in 0..k {
        for j in 0..n {
            let mut acc = 0f64;
            for i in 0..m {
                acc += x[i * k + p] as f64 * dy[i * n + j] as f64;
            }
            dw[p * n + j] = acc as f32;
        }
    }
    dw
}

// ---------------------------------------------------------------------------
// LayerNorm (no affine params, matching the builder's layernorm op)
// ---------------------------------------------------------------------------

const LN_EPS: f64 = 1e-5;

/// Normalize each row of `h` elements to zero mean / unit variance.
pub fn layernorm_fwd(x: &[f32], h: usize) -> Vec<f32> {
    let rows = x.len() / h;
    let mut y = vec![0f32; x.len()];
    for r in 0..rows {
        let row = &x[r * h..(r + 1) * h];
        let mean = row.iter().map(|&v| v as f64).sum::<f64>() / h as f64;
        let var = row.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / h as f64;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for c in 0..h {
            y[r * h + c] = ((row[c] as f64 - mean) * inv) as f32;
        }
    }
    y
}

/// No-affine layernorm backward:
/// `dx = inv * (dy - mean(dy) - xhat * mean(dy * xhat))`.
pub fn layernorm_dx(dy: &[f32], x: &[f32], h: usize) -> Vec<f32> {
    let rows = x.len() / h;
    let mut dx = vec![0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * h..(r + 1) * h];
        let dyr = &dy[r * h..(r + 1) * h];
        let mean = xr.iter().map(|&v| v as f64).sum::<f64>() / h as f64;
        let var = xr.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / h as f64;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let xhat: Vec<f64> = xr.iter().map(|&v| (v as f64 - mean) * inv).collect();
        let mdy = dyr.iter().map(|&v| v as f64).sum::<f64>() / h as f64;
        let mdyx =
            dyr.iter().zip(&xhat).map(|(&d, &xh)| d as f64 * xh).sum::<f64>() / h as f64;
        for c in 0..h {
            dx[r * h + c] = (inv * (dyr[c] as f64 - mdy - xhat[c] * mdyx)) as f32;
        }
    }
    dx
}

// ---------------------------------------------------------------------------
// Elementwise
// ---------------------------------------------------------------------------

const GELU_C: f64 = 0.7978845608028654; // sqrt(2/pi)
const GELU_A: f64 = 0.044715;

/// Tanh-approximated GELU.
pub fn gelu_fwd(x: &[f32]) -> Vec<f32> {
    x.iter()
        .map(|&v| {
            let v = v as f64;
            (0.5 * v * (1.0 + (GELU_C * (v + GELU_A * v.powi(3))).tanh())) as f32
        })
        .collect()
}

/// `dx = dy * gelu'(x)` for the tanh approximation.
pub fn gelu_dx(dy: &[f32], x: &[f32]) -> Vec<f32> {
    dy.iter()
        .zip(x)
        .map(|(&d, &v)| {
            let v = v as f64;
            let u = GELU_C * (v + GELU_A * v.powi(3));
            let t = u.tanh();
            let du = GELU_C * (1.0 + 3.0 * GELU_A * v * v);
            (d as f64 * (0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * du)) as f32
        })
        .collect()
}

/// Elementwise sum of equally-sized buffers (residual add).
pub fn add_n(xs: &[&[f32]]) -> Vec<f32> {
    let n = xs[0].len();
    let mut y = vec![0f32; n];
    for x in xs {
        for (t, &s) in y.iter_mut().zip(x.iter()) {
            *t += s;
        }
    }
    y
}

// ---------------------------------------------------------------------------
// Attention (fused composite, causal)
// ---------------------------------------------------------------------------

/// Causal multi-head attention over a packed `qkv[b,s,a,3d]` region,
/// producing `out[b,s,a,d]`. `a` is the number of heads *in the region*
/// (tensor parallelism slices heads before the kernel sees them).
pub fn attention_fwd(qkv: &[f32], b: usize, s: usize, a: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0f32; b * s * a * d];
    let scale = 1.0 / (d as f64).sqrt();
    let at = |bi: usize, si: usize, ai: usize, c: usize| ((bi * s + si) * a + ai) * 3 * d + c;
    for bi in 0..b {
        for ai in 0..a {
            for qi in 0..s {
                // scores over key positions <= qi (causal), max-subtracted softmax.
                let mut scores = vec![0f64; qi + 1];
                let mut maxs = f64::NEG_INFINITY;
                for ki in 0..=qi {
                    let mut acc = 0f64;
                    for c in 0..d {
                        acc += qkv[at(bi, qi, ai, c)] as f64 * qkv[at(bi, ki, ai, d + c)] as f64;
                    }
                    let v = acc * scale;
                    scores[ki] = v;
                    maxs = maxs.max(v);
                }
                let mut denom = 0f64;
                for sc in scores.iter_mut() {
                    *sc = (*sc - maxs).exp();
                    denom += *sc;
                }
                for c in 0..d {
                    let mut acc = 0f64;
                    for ki in 0..=qi {
                        acc += scores[ki] / denom * qkv[at(bi, ki, ai, 2 * d + c)] as f64;
                    }
                    out[((bi * s + qi) * a + ai) * d + c] = acc as f32;
                }
            }
        }
    }
    out
}

/// Backward of [`attention_fwd`]: `dqkv[b,s,a,3d]` from `dy[b,s,a,d]`.
pub fn attention_dqkv(dy: &[f32], qkv: &[f32], b: usize, s: usize, a: usize, d: usize) -> Vec<f32> {
    let mut dqkv = vec![0f64; b * s * a * 3 * d];
    let scale = 1.0 / (d as f64).sqrt();
    let at = |bi: usize, si: usize, ai: usize, c: usize| ((bi * s + si) * a + ai) * 3 * d + c;
    for bi in 0..b {
        for ai in 0..a {
            for qi in 0..s {
                // Recompute the softmax row.
                let mut p = vec![0f64; qi + 1];
                let mut maxs = f64::NEG_INFINITY;
                for ki in 0..=qi {
                    let mut acc = 0f64;
                    for c in 0..d {
                        acc += qkv[at(bi, qi, ai, c)] as f64 * qkv[at(bi, ki, ai, d + c)] as f64;
                    }
                    p[ki] = acc * scale;
                    maxs = maxs.max(p[ki]);
                }
                let mut denom = 0f64;
                for v in p.iter_mut() {
                    *v = (*v - maxs).exp();
                    denom += *v;
                }
                for v in p.iter_mut() {
                    *v /= denom;
                }
                let dyr: Vec<f64> = (0..d)
                    .map(|c| dy[((bi * s + qi) * a + ai) * d + c] as f64)
                    .collect();
                // dv[ki] += p[ki] * dy ; dp[ki] = dy . v[ki]
                let mut dp = vec![0f64; qi + 1];
                for ki in 0..=qi {
                    let mut acc = 0f64;
                    for c in 0..d {
                        dqkv[at(bi, ki, ai, 2 * d + c)] += p[ki] * dyr[c];
                        acc += dyr[c] * qkv[at(bi, ki, ai, 2 * d + c)] as f64;
                    }
                    dp[ki] = acc;
                }
                // Softmax backward: ds = p * (dp - sum(p*dp)), then 1/sqrt(d).
                let dot: f64 = p.iter().zip(&dp).map(|(&a, &b)| a * b).sum();
                for ki in 0..=qi {
                    let ds = p[ki] * (dp[ki] - dot) * scale;
                    for c in 0..d {
                        dqkv[at(bi, qi, ai, c)] += ds * qkv[at(bi, ki, ai, d + c)] as f64;
                        dqkv[at(bi, ki, ai, d + c)] += ds * qkv[at(bi, qi, ai, c)] as f64;
                    }
                }
            }
        }
    }
    dqkv.into_iter().map(|v| v as f32).collect()
}

// ---------------------------------------------------------------------------
// Embedding
// ---------------------------------------------------------------------------

/// Vocab-sharded embedding lookup: `ids` hold (float-encoded) row indices,
/// reduced mod `vocab`; the kernel owns table rows `[v0, v1)` (the region's
/// slice of the `[vocab, h]` table) and contributes zero rows for ids
/// outside its shard — the value-partials then sum across shards.
pub fn embed_fwd(ids: &[f32], table: &[f32], vocab: usize, v0: usize, v1: usize, h: usize) -> Vec<f32> {
    let mut y = vec![0f32; ids.len() * h];
    for (i, &idf) in ids.iter().enumerate() {
        let id = (idf.max(0.0) as usize) % vocab;
        if id >= v0 && id < v1 {
            let row = (id - v0) * h;
            y[i * h..(i + 1) * h].copy_from_slice(&table[row..row + h]);
        }
    }
    y
}

/// Gradient of the table shard: `dtable[id - v0, :] += dy[i, :]`.
pub fn embed_dtable(
    dy: &[f32],
    ids: &[f32],
    vocab: usize,
    v0: usize,
    v1: usize,
    h: usize,
) -> Vec<f32> {
    let mut dt = vec![0f32; (v1 - v0) * h];
    for (i, &idf) in ids.iter().enumerate() {
        let id = (idf.max(0.0) as usize) % vocab;
        if id >= v0 && id < v1 {
            let row = (id - v0) * h;
            for c in 0..h {
                dt[row + c] += dy[i * h + c];
            }
        }
    }
    dt
}

// ---------------------------------------------------------------------------
// Cross-entropy head (single-input builder form: `b s h -> b`)
// ---------------------------------------------------------------------------

/// Per-sequence-position cross-entropy summed per batch row. The synthetic
/// target of position `si` is class `si % h` (deterministic, so the serial
/// oracle and every parallel plan agree without a label tensor).
pub fn cross_entropy_fwd(x: &[f32], b: usize, s: usize, h: usize) -> Vec<f32> {
    let mut loss = vec![0f32; b];
    for bi in 0..b {
        let mut acc = 0f64;
        for si in 0..s {
            let row = &x[(bi * s + si) * h..(bi * s + si + 1) * h];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let lse =
                maxv + row.iter().map(|&v| ((v as f64) - maxv).exp()).sum::<f64>().ln();
            acc += lse - row[si % h] as f64;
        }
        loss[bi] = acc as f32;
    }
    loss
}

/// `dx[bi,si,:] = dloss[bi] * (softmax(x[bi,si,:]) - onehot(si % h))`.
pub fn cross_entropy_dx(dloss: &[f32], x: &[f32], b: usize, s: usize, h: usize) -> Vec<f32> {
    let mut dx = vec![0f32; b * s * h];
    for bi in 0..b {
        for si in 0..s {
            let row = &x[(bi * s + si) * h..(bi * s + si + 1) * h];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let exps: Vec<f64> = row.iter().map(|&v| ((v as f64) - maxv).exp()).collect();
            let denom: f64 = exps.iter().sum();
            let t = si % h;
            for c in 0..h {
                let soft = exps[c] / denom;
                let onehot = if c == t { 1.0 } else { 0.0 };
                dx[(bi * s + si) * h + c] = (dloss[bi] as f64 * (soft - onehot)) as f32;
            }
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[f32], b: &[f32], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let rel = (x as f64 - y as f64).abs() / (y as f64).abs().max(1.0);
            assert!(rel < tol, "elem {i}: {x} vs {y}");
        }
    }

    /// Central-difference gradient of `f` w.r.t. `x`, contracted with `dy`.
    fn fdiff(f: &dyn Fn(&[f32]) -> Vec<f32>, x: &[f32], dy: &[f32], eps: f32) -> Vec<f32> {
        (0..x.len())
            .map(|i| {
                let mut xp = x.to_vec();
                let mut xm = x.to_vec();
                xp[i] += eps;
                xm[i] -= eps;
                let (yp, ym) = (f(&xp), f(&xm));
                yp.iter()
                    .zip(&ym)
                    .zip(dy)
                    .map(|((&p, &m), &d)| ((p - m) / (2.0 * eps)) as f64 * d as f64)
                    .sum::<f64>() as f32
            })
            .collect()
    }

    fn seq(n: usize, scale: f32, shift: f32) -> Vec<f32> {
        (0..n).map(|i| ((i * 37 % 19) as f32 - 9.0) * scale + shift).collect()
    }

    #[test]
    fn gather_scatter_round_trip() {
        let shape = [3, 4, 5];
        let src: Vec<f32> = (0..60).map(|i| i as f32).collect();
        let region = [(1, 3), (0, 4), (2, 5)];
        let buf = gather(&src, &shape, &region);
        assert_eq!(buf.len(), region_len(&region));
        assert_eq!(buf[0], src[1 * 20 + 0 * 5 + 2]);
        let mut dst = vec![0f32; 60];
        scatter(&mut dst, &shape, &region, &buf, false, 1.0);
        let back = gather(&dst, &shape, &region);
        assert_eq!(back, buf);
        // Accumulate with a scale adds on top.
        scatter(&mut dst, &shape, &region, &buf, true, 0.5);
        let acc = gather(&dst, &shape, &region);
        for (a, b) in acc.iter().zip(&buf) {
            assert!((a - 1.5 * b).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_dims_inference_covers_builder_signatures() {
        // linear: [2,3,8] @ [8,16] -> [2,3,16]
        assert_eq!(matmul_dims(48, 128, 96), Some((6, 8, 16)));
        // qkv: [2,3,8] @ [8,4,6] -> [2,3,4,6]
        assert_eq!(matmul_dims(48, 192, 144), Some((6, 8, 24)));
        // proj: [2,3,4,2] @ [4,2,8] -> [2,3,8]
        assert_eq!(matmul_dims(48, 64, 48), Some((6, 8, 8)));
        assert_eq!(matmul_dims(48, 128, 95), None);
    }

    #[test]
    fn matmul_fwd_and_grads() {
        let (m, k, n) = (3, 4, 2);
        let x = seq(m * k, 0.1, 0.0);
        let w = seq(k * n, 0.05, 0.01);
        let y = matmul_fwd(&x, &w, m, k, n);
        // Hand-check one element.
        let mut y00 = 0.0;
        for p in 0..k {
            y00 += x[p] * w[p * n];
        }
        assert!((y[0] - y00).abs() < 1e-6);
        let dy = seq(m * n, 0.2, 0.3);
        let dx = matmul_dx(&dy, &w, m, k, n);
        let dw = matmul_dw(&dy, &x, m, k, n);
        let fx = |xv: &[f32]| matmul_fwd(xv, &w, m, k, n);
        let fw = |wv: &[f32]| matmul_fwd(&x, wv, m, k, n);
        close(&dx, &fdiff(&fx, &x, &dy, 1e-2), 1e-3);
        close(&dw, &fdiff(&fw, &w, &dy, 1e-2), 1e-3);
    }

    #[test]
    fn layernorm_normalizes_and_backward_matches_fdiff() {
        let h = 8;
        let x = seq(2 * h, 0.3, 0.5);
        let y = layernorm_fwd(&x, h);
        for r in 0..2 {
            let row = &y[r * h..(r + 1) * h];
            let mean: f32 = row.iter().sum::<f32>() / h as f32;
            let var: f32 = row.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / h as f32;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
        let dy = seq(2 * h, 0.1, -0.2);
        let dx = layernorm_dx(&dy, &x, h);
        close(&dx, &fdiff(&|v| layernorm_fwd(v, h), &x, &dy, 1e-2), 2e-2);
    }

    #[test]
    fn gelu_values_and_gradient() {
        let x = vec![-2.0, -0.5, 0.0, 0.5, 2.0];
        let y = gelu_fwd(&x);
        assert!(y[2].abs() < 1e-7);
        assert!((y[4] - 1.954).abs() < 1e-2); // gelu(2) ~ 1.9546
        let dy = vec![1.0; 5];
        let dx = gelu_dx(&dy, &x);
        close(&dx, &fdiff(&|v| gelu_fwd(v), &x, &dy, 1e-3), 1e-2);
    }

    #[test]
    fn attention_is_causal_and_backward_matches_fdiff() {
        let (b, s, a, d) = (1, 4, 2, 3);
        let qkv = seq(b * s * a * 3 * d, 0.15, 0.0);
        let out = attention_fwd(&qkv, b, s, a, d);
        // Causality: perturbing position 3's inputs must not move position 0.
        let mut qkv2 = qkv.clone();
        for ai in 0..a {
            for c in 0..3 * d {
                qkv2[((3 * a) + ai) * 3 * d + c] += 1.0;
            }
        }
        let out2 = attention_fwd(&qkv2, b, s, a, d);
        for c in 0..a * d {
            assert_eq!(out[c], out2[c], "position 0 output moved");
        }
        let dy = seq(b * s * a * d, 0.2, 0.1);
        let dq = attention_dqkv(&dy, &qkv, b, s, a, d);
        close(&dq, &fdiff(&|v| attention_fwd(v, b, s, a, d), &qkv, &dy, 1e-2), 2e-2);
    }

    #[test]
    fn embed_partials_tile_the_vocab() {
        let (vocab, h) = (8, 3);
        let ids = vec![0.0, 5.0, 13.0, 7.0]; // 13 % 8 = 5
        let table = seq(vocab * h, 0.1, 0.0);
        let full = embed_fwd(&ids, &table, vocab, 0, vocab, h);
        // Two half-shards sum to the full lookup.
        let lo = embed_fwd(&ids, &table[..4 * h], vocab, 0, 4, h);
        let hi = embed_fwd(&ids, &table[4 * h..], vocab, 4, 8, h);
        let sum = add_n(&[&lo, &hi]);
        close(&sum, &full, 1e-7);
        // Backward scatters dy into the owning rows.
        let dy = seq(ids.len() * h, 0.2, 0.0);
        let dt = embed_dtable(&dy, &ids, vocab, 0, vocab, h);
        for c in 0..h {
            // Row 5 receives ids[1] and ids[2].
            assert!((dt[5 * h + c] - (dy[h + c] + dy[2 * h + c])).abs() < 1e-6);
        }
    }

    #[test]
    fn cross_entropy_backward_matches_fdiff() {
        let (b, s, h) = (2, 3, 5);
        let x = seq(b * s * h, 0.3, 0.0);
        let loss = cross_entropy_fwd(&x, b, s, h);
        assert!(loss.iter().all(|&l| l > 0.0), "CE losses are positive");
        let dloss = vec![1.0, 0.5];
        let dx = cross_entropy_dx(&dloss, &x, b, s, h);
        close(
            &dx,
            &fdiff(&|v| cross_entropy_fwd(v, b, s, h), &x, &dloss, 1e-2),
            2e-2,
        );
    }
}
