//! In-process collectives over host `f32` buffers — the runtime realization
//! of the collective tasks materialization derives. Simulated devices are
//! threads; a [`GenBarrier`](crate::util::pool::GenBarrier) synchronizes
//! rounds and a shared slot table moves the data.
//!
//! Reduction is leader-sequential (rank 0 sums after the deposit barrier):
//! simple, deterministic (no floating-point reorder across runs), and fast
//! enough that the artifact execution dominates by orders of magnitude —
//! the §Perf log tracks its share of step time.

use crate::util::pool::GenBarrier;
use std::sync::{Arc, Mutex};

/// N-participant all-reduce/gather engine.
pub struct AllReducer {
    n: usize,
    barrier: Arc<GenBarrier>,
    slots: Vec<Mutex<Vec<f32>>>,
    result: Mutex<Vec<f32>>,
}

impl AllReducer {
    pub fn new(n: usize) -> AllReducer {
        AllReducer {
            n,
            barrier: GenBarrier::new(n),
            slots: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            result: Mutex::new(Vec::new()),
        }
    }

    pub fn world(&self) -> usize {
        self.n
    }

    /// All-reduce with mean: every rank passes its buffer, all return with
    /// the element-wise mean. Single-rank worlds are a no-op.
    pub fn allreduce_mean(&self, rank: usize, buf: &mut [f32]) {
        self.allreduce(rank, buf);
        let inv = 1.0 / self.n as f32;
        for x in buf.iter_mut() {
            *x *= inv;
        }
    }

    /// All-reduce (sum).
    pub fn allreduce(&self, rank: usize, buf: &mut [f32]) {
        if self.n == 1 {
            return;
        }
        *self.slots[rank].lock().unwrap() = buf.to_vec();
        let (_, leader) = self.barrier.wait();
        if leader {
            let mut acc = self.slots[0].lock().unwrap().clone();
            for s in 1..self.n {
                let other = self.slots[s].lock().unwrap();
                for (a, b) in acc.iter_mut().zip(other.iter()) {
                    *a += *b;
                }
            }
            *self.result.lock().unwrap() = acc;
        }
        self.barrier.wait();
        buf.copy_from_slice(&self.result.lock().unwrap());
        // Final barrier so the leader can't race ahead and overwrite
        // `result` in the next round while laggards still read.
        self.barrier.wait();
    }

    /// All-gather: each rank contributes `buf`, returns the rank-ordered
    /// concatenation.
    pub fn allgather(&self, rank: usize, buf: &[f32]) -> Vec<f32> {
        if self.n == 1 {
            return buf.to_vec();
        }
        *self.slots[rank].lock().unwrap() = buf.to_vec();
        self.barrier.wait();
        let mut out = Vec::with_capacity(buf.len() * self.n);
        for s in 0..self.n {
            out.extend_from_slice(&self.slots[s].lock().unwrap());
        }
        self.barrier.wait();
        out
    }

    /// Reduce-scatter (sum): `buf.len()` must divide evenly by world size;
    /// returns this rank's reduced shard.
    pub fn reduce_scatter(&self, rank: usize, buf: &[f32]) -> Vec<f32> {
        if self.n == 1 {
            return buf.to_vec();
        }
        assert_eq!(buf.len() % self.n, 0, "reduce_scatter shard mismatch");
        *self.slots[rank].lock().unwrap() = buf.to_vec();
        self.barrier.wait();
        let shard = buf.len() / self.n;
        let lo = rank * shard;
        let mut out = vec![0.0f32; shard];
        for s in 0..self.n {
            let other = self.slots[s].lock().unwrap();
            for i in 0..shard {
                out[i] += other[lo + i];
            }
        }
        self.barrier.wait();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::par_map;

    #[test]
    fn allreduce_sums_across_ranks() {
        let r = Arc::new(AllReducer::new(4));
        let outs = par_map(4, 4, |rank| {
            let mut buf = vec![rank as f32 + 1.0; 8];
            r.allreduce(rank, &mut buf);
            buf
        });
        for o in outs {
            assert_eq!(o, vec![10.0; 8]); // 1+2+3+4
        }
    }

    #[test]
    fn allreduce_mean_divides() {
        let r = Arc::new(AllReducer::new(2));
        let outs = par_map(2, 2, |rank| {
            let mut buf = vec![if rank == 0 { 2.0 } else { 4.0 }; 3];
            r.allreduce_mean(rank, &mut buf);
            buf
        });
        for o in outs {
            assert_eq!(o, vec![3.0; 3]);
        }
    }

    #[test]
    fn repeated_rounds_do_not_cross_talk() {
        let r = Arc::new(AllReducer::new(3));
        let outs = par_map(3, 3, |rank| {
            let mut total = 0.0;
            for round in 0..50 {
                let mut buf = vec![(rank + round) as f32];
                r.allreduce(rank, &mut buf);
                total += buf[0];
            }
            total
        });
        // Each round sums to 3*round + 3; total over 50 rounds identical on
        // every rank.
        let want: f32 = (0..50).map(|r| 3.0 * r as f32 + 3.0).sum();
        for o in outs {
            assert_eq!(o, want);
        }
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        let r = Arc::new(AllReducer::new(3));
        let outs = par_map(3, 3, |rank| r.allgather(rank, &[rank as f32; 2]));
        for o in outs {
            assert_eq!(o, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        }
    }

    #[test]
    fn reduce_scatter_shards_the_sum() {
        let r = Arc::new(AllReducer::new(2));
        let outs = par_map(2, 2, |rank| {
            // rank 0: [1,1,1,1]; rank 1: [2,2,2,2] -> sum [3,3,3,3]
            r.reduce_scatter(rank, &[(rank + 1) as f32; 4])
        });
        assert_eq!(outs[0], vec![3.0, 3.0]);
        assert_eq!(outs[1], vec![3.0, 3.0]);
    }

    #[test]
    fn single_rank_world_is_identity() {
        let r = AllReducer::new(1);
        let mut buf = vec![5.0, 6.0];
        r.allreduce_mean(0, &mut buf);
        assert_eq!(buf, vec![5.0, 6.0]);
        assert_eq!(r.allgather(0, &buf), buf);
    }
}
