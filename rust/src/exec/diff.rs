//! Differential plan-execution harness (`superscaler verify-exec`).
//!
//! SuperScaler's transformation phase is only useful if it is
//! semantics-preserving: operator transformation + space-time scheduling +
//! dependency preservation must compute the same function as the serial
//! model. This module turns that claim into one executable property. For
//! every planner family on 2–8 devices it builds the plan, runs it on the
//! CPU reference executor ([`super::reference`]), and asserts elementwise
//! equivalence of the observable training step — updated weights, summed
//! gradients, and losses — against a single-device serial oracle, at
//! ≤ 1e-4 relative error.
//!
//! Every run also feeds its measured per-task wall durations into
//! [`crate::cost::calibrate`], so the same harness that proves correctness
//! prices the analytic cost model's error bar.

use std::collections::HashMap;

use super::kernels;
use super::reference::{self, ExecResult};
use crate::cost::calibrate::{calibrate, CalibrationReport, TaskSample};
use crate::cost::Cluster;
use crate::graph::{Graph, OpKind, TensorKind};
use crate::materialize::{materialize, CommMode, Plan, TaskKind};
use crate::models::builder::ModelBuilder;
use crate::models::Model;
use crate::plans::{registry, PlanKind, PlanSpec, SchedName, SchedSpec, StageSpec};
use crate::schedule::{validate, Schedule};
use crate::trans::autograd;
use crate::util::json::Value;

/// Elementwise pass criterion: `|a - b| <= max(REL_TOL * |b|, ABS_TOL)`.
pub const REL_TOL: f64 = 1e-4;
const ABS_TOL: f64 = 1e-6;

/// Planner families the equivalence matrix covers, in display order.
pub const FAMILIES: [&str; 8] =
    ["dp", "tp", "megatron", "gpipe", "zb", "coshard", "hetero", "dp-rvd"];

/// All matrix families as owned strings (CLI default).
pub fn default_families() -> Vec<String> {
    FAMILIES.iter().map(|f| f.to_string()).collect()
}

// ---------------------------------------------------------------------------
// The probe model

/// The differential probe: a 4-layer GPT-style model small enough to
/// execute in milliseconds but wide enough to exercise every transformation
/// axis (4 layers / 8 heads / shardable ff and vocab dims).
pub fn tiny_model() -> Model {
    let (batch, seq, hidden, heads, ff, vocab) = (8, 4, 32, 8, 128, 32);
    let mut mb = ModelBuilder::new();
    let mut layers: Vec<Vec<crate::graph::OpId>> = Vec::new();

    let ids = mb.input("ids", &[batch, seq]);
    let (mut x, emb) = mb.embedding("embed", ids, 0, batch, seq, vocab, hidden);
    layers.push(vec![emb]);
    for li in 0..4 {
        let (y, ops) =
            mb.transformer_layer(&format!("h{li}"), x, li + 1, batch, seq, hidden, heads, ff, None);
        layers.push(ops);
        x = y;
    }
    let (_, loss) = mb.loss("lmloss", x, 5, &[batch, seq, hidden]);
    layers.push(vec![loss]);

    Model {
        graph: mb.g,
        name: "tiny-gpt".to_string(),
        layers,
        emb_ops: Vec::new(),
        tp_dim: mb.tp_dim,
        coshard_dim: mb.coshard_dim,
        global_batch: batch,
    }
}

// ---------------------------------------------------------------------------
// Family → spec matrix

/// Resolve one (family, device-count) cell of the equivalence matrix to a
/// registered planner name, a spec occupying exactly `n` devices, and the
/// comm mode to materialize under. `None` when the family has no
/// configuration at that device count (the matrix covers n ∈ {2, 4, 8}).
pub fn family_case(family: &str, n: usize) -> Option<(&'static str, PlanSpec, CommMode)> {
    let grid = |dp: usize, pp: usize, tp: usize, micro: usize, kind: PlanKind| PlanSpec {
        dp,
        pp,
        tp,
        micro,
        ..PlanSpec::new(kind)
    };
    let case = match (family, n) {
        ("dp", _) => ("dp", PlanSpec { dp: n, ..PlanSpec::new(PlanKind::Dp) }, CommMode::P2POnly),
        // Same plan, but gradients synchronized through materialized
        // all-reduce collectives instead of the generic P2P tier.
        ("dp-rvd", _) => {
            ("dp", PlanSpec { dp: n, ..PlanSpec::new(PlanKind::Dp) }, CommMode::IntraRvd)
        }
        ("tp", _) => ("tp", PlanSpec { tp: n, ..PlanSpec::new(PlanKind::Tp) }, CommMode::P2POnly),
        ("megatron", 2) => ("megatron", grid(1, 2, 1, 2, PlanKind::Megatron), CommMode::P2POnly),
        ("megatron", 4) => ("megatron", grid(1, 2, 2, 2, PlanKind::Megatron), CommMode::P2POnly),
        ("megatron", 8) => ("megatron", grid(2, 2, 2, 2, PlanKind::Megatron), CommMode::P2POnly),
        ("gpipe", 2) => ("gpipe", grid(1, 2, 1, 2, PlanKind::GPipe), CommMode::P2POnly),
        ("gpipe", 4) => ("gpipe", grid(1, 4, 1, 2, PlanKind::GPipe), CommMode::P2POnly),
        ("gpipe", 8) => ("gpipe", grid(1, 4, 2, 2, PlanKind::GPipe), CommMode::P2POnly),
        ("zb", _) => {
            let mut spec = match n {
                2 => grid(1, 2, 1, 2, PlanKind::Megatron),
                4 => grid(1, 4, 1, 4, PlanKind::Megatron),
                8 => grid(1, 4, 2, 4, PlanKind::Megatron),
                _ => return None,
            };
            spec.sched = Some(SchedSpec::Named(SchedName::ZeroBubble));
            ("megatron", spec, CommMode::P2POnly)
        }
        ("coshard", _) => (
            "coshard",
            PlanSpec { dp: n, shards: 2, ..PlanSpec::new(PlanKind::Coshard) },
            CommMode::P2POnly,
        ),
        ("hetero", 2) => {
            ("hetero", PlanSpec::hetero(vec![StageSpec::tp(1); 2], 2), CommMode::P2POnly)
        }
        ("hetero", 4) => {
            ("hetero", PlanSpec::hetero(vec![StageSpec::tp(2); 2], 2), CommMode::P2POnly)
        }
        ("hetero", 8) => {
            ("hetero", PlanSpec::hetero(vec![StageSpec::tp(2); 4], 2), CommMode::P2POnly)
        }
        _ => return None,
    };
    if !matches!(n, 2 | 4 | 8) {
        return None;
    }
    debug_assert_eq!(case.1.devices(), n, "matrix cell must occupy exactly n devices");
    Some(case)
}

// ---------------------------------------------------------------------------
// Serial oracle

/// The single-device serial ground truth: every observable value of one
/// training step (all pTensors of the autograd-completed serial graph),
/// keyed by pTensor *name* so transformed plans can look values up across
/// graph clones and replica renames.
pub struct Oracle {
    pub values: HashMap<String, Vec<f32>>,
    pub samples: Vec<TaskSample>,
}

/// Run the serial model on one device and snapshot every pTensor.
pub fn run_oracle(model: &Model) -> Result<Oracle, String> {
    let mut g = model.graph.clone();
    autograd::complete(&mut g);
    let mut sched = Schedule::new();
    sched.assign_all(&g.live_op_ids(), 0);
    let vs = validate(&g, &sched).map_err(|e| format!("oracle schedule: {e:?}"))?;
    let cluster = Cluster::v100(1);
    let plan = materialize(&g, &vs, &cluster, CommMode::P2POnly);
    let res = reference::execute(&g, &vs, &plan).map_err(|e| format!("oracle exec: {e}"))?;
    let store = res.stores.get(&0).ok_or_else(|| "oracle produced no device-0 store".to_string())?;
    let values = store
        .iter()
        .map(|(&pt, buf)| (g.ptensor(pt).name.clone(), buf.clone()))
        .collect();
    Ok(Oracle { values, samples: res.samples })
}

// ---------------------------------------------------------------------------
// Case execution + comparison

/// Build one matrix cell's plan and execute it on the reference executor.
fn build_and_exec(
    model: &Model,
    planner: &str,
    spec: &PlanSpec,
    n: usize,
    mode: CommMode,
) -> Result<(Graph, Plan, ExecResult), String> {
    let out = registry::build(planner, model, spec).map_err(|e| format!("build: {e}"))?;
    let vs = validate(&out.graph, &out.schedule).map_err(|e| format!("validate: {e:?}"))?;
    let cluster = Cluster::v100(n);
    let plan = materialize(&out.graph, &vs, &cluster, mode);
    let res = reference::execute(&out.graph, &vs, &plan).map_err(|e| format!("exec: {e}"))?;
    Ok((out.graph, plan, res))
}

/// Strip replica suffixes (`@r<digits>`, possibly stacked) from a
/// transformed pTensor name to recover the serial oracle's name.
fn replica_base(name: &str) -> &str {
    let mut base = name;
    loop {
        let Some(at) = base.rfind("@r") else { return base };
        if base[at + 2..].chars().all(|c| c.is_ascii_digit()) && at + 2 < base.len() {
            base = &base[..at];
        } else {
            return base;
        }
    }
}

/// Outcome of comparing one region of one executed tensor to the oracle.
struct RegionDiff {
    n: usize,
    max_rel: f64,
    ok: bool,
}

/// Compare the `region` of `pt` in device `dev`'s store against the
/// oracle's serial value of the same tensor.
fn compare_region(
    g: &Graph,
    res: &ExecResult,
    oracle: &Oracle,
    dev: usize,
    pt: crate::graph::PTensorId,
    region: &[(usize, usize)],
) -> Result<RegionDiff, String> {
    let p = g.ptensor(pt);
    let store = res
        .stores
        .get(&dev)
        .ok_or_else(|| format!("no store for device {dev}"))?;
    let buf = store.get(&pt).ok_or_else(|| format!("device {dev} never held '{}'", p.name))?;
    let base = replica_base(&p.name);
    let want = oracle
        .values
        .get(base)
        .ok_or_else(|| format!("oracle has no tensor named '{base}'"))?;
    let got = kernels::gather(buf, &p.shape, region);
    let exp = kernels::gather(want, &p.shape, region);
    let mut max_rel = 0.0f64;
    let mut ok = true;
    for (a, b) in got.iter().zip(exp.iter()) {
        let diff = (*a as f64 - *b as f64).abs();
        let scale = (*b as f64).abs();
        if diff > (REL_TOL * scale).max(ABS_TOL) {
            ok = false;
        }
        max_rel = max_rel.max(diff / scale.max(ABS_TOL));
    }
    Ok(RegionDiff { n: got.len(), max_rel, ok })
}

/// One cell of the equivalence matrix.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub family: String,
    pub label: String,
    pub devices: usize,
    pub comm: &'static str,
    pub passed: bool,
    /// Worst relative error over every compared element.
    pub max_rel: f64,
    /// Elements compared (0 would make the property vacuous → fail).
    pub compared: usize,
    pub error: Option<String>,
}

impl CaseResult {
    fn failed(family: &str, label: String, devices: usize, comm: &'static str, err: String) -> Self {
        CaseResult {
            family: family.to_string(),
            label,
            devices,
            comm,
            passed: false,
            max_rel: f64::INFINITY,
            compared: 0,
            error: Some(err),
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("family", Value::Str(self.family.clone())),
            ("label", Value::Str(self.label.clone())),
            ("devices", Value::Num(self.devices as f64)),
            ("comm", Value::Str(self.comm.to_string())),
            ("passed", Value::Bool(self.passed)),
            ("max_rel", Value::Num(self.max_rel)),
            ("compared", Value::Num(self.compared as f64)),
            (
                "error",
                self.error.as_ref().map(|e| Value::Str(e.clone())).unwrap_or(Value::Null),
            ),
        ])
    }
}

/// Compare every observable of one executed plan against the oracle: the
/// updated weight and summed gradient at each optimizer step, and the loss
/// at each forward cross-entropy. These close over the whole step — a wrong
/// activation, collective, or schedule shows up in one of them.
fn compare_case(
    g: &Graph,
    plan: &Plan,
    res: &ExecResult,
    oracle: &Oracle,
) -> Result<(bool, f64, usize), String> {
    let mut compared = 0usize;
    let mut max_rel = 0.0f64;
    let mut passed = true;
    for task in &plan.tasks {
        let TaskKind::Compute { op, device } = task.kind else { continue };
        let o = g.op(op);
        // (vtensor, is it an observable of this op?) pairs to check.
        let mut views: Vec<crate::graph::VTensorId> = Vec::new();
        match o.kind {
            OpKind::Optimizer => {
                // outputs[0] = updated weight; inputs[0] = the fully
                // synchronized gradient this device applied.
                if let Some(&w) = o.outputs.first() {
                    views.push(w);
                }
                if let Some(&dw) = o.inputs.first() {
                    views.push(dw);
                }
            }
            OpKind::CrossEntropy if o.is_forward => {
                if let Some(&l) = o.outputs.first() {
                    views.push(l);
                }
            }
            _ => continue,
        }
        for v in views {
            let vt = g.vtensor(v);
            let p = g.ptensor(vt.ptensor);
            let region = vt.mask.concrete(&p.shape);
            let d = compare_region(g, res, oracle, device, vt.ptensor, &region)?;
            compared += d.n;
            max_rel = max_rel.max(d.max_rel);
            if !d.ok {
                passed = false;
            }
        }
    }
    if compared == 0 {
        return Err("plan exposed no optimizer/loss observables to compare".to_string());
    }
    Ok((passed, max_rel, compared))
}

// ---------------------------------------------------------------------------
// The matrix driver

/// Full `verify-exec` outcome: the equivalence pass matrix plus the
/// measured-vs-analytic calibration report over every executed task.
pub struct DiffOutcome {
    pub cases: Vec<CaseResult>,
    pub calibration: CalibrationReport,
    pub all_passed: bool,
}

impl DiffOutcome {
    pub fn to_json(&self) -> Value {
        Value::obj([
            ("model", Value::Str("tiny-gpt".to_string())),
            ("rel_tol", Value::Num(REL_TOL)),
            ("all_passed", Value::Bool(self.all_passed)),
            ("cases", Value::Arr(self.cases.iter().map(|c| c.to_json()).collect())),
            ("calibration", self.calibration.to_json()),
        ])
    }
}

/// Run the differential matrix: every requested family × device count,
/// each executed on the reference executor and compared elementwise to the
/// serial oracle. Infallible per cell — a cell that cannot build or
/// execute is reported as a failed [`CaseResult`], not an early return.
pub fn run_matrix(devices: &[usize], families: &[String]) -> Result<DiffOutcome, String> {
    let model = tiny_model();
    let oracle = run_oracle(&model)?;
    let mut samples: Vec<TaskSample> = oracle.samples.clone();
    let mut cases = Vec::new();
    for &n in devices {
        for family in families {
            let Some((planner, spec, mode)) = family_case(family, n) else {
                cases.push(CaseResult::failed(
                    family,
                    format!("{family}@{n}"),
                    n,
                    "-",
                    format!("no matrix cell for family '{family}' at {n} devices"),
                ));
                continue;
            };
            let comm = match mode {
                CommMode::P2POnly => "p2p",
                CommMode::IntraRvd => "intra-rvd",
                CommMode::InterRvd => "inter-rvd",
            };
            let label = spec.label();
            match build_and_exec(&model, planner, &spec, n, mode) {
                Err(e) => cases.push(CaseResult::failed(family, label, n, comm, e)),
                Ok((g, plan, res)) => {
                    samples.extend(res.samples.iter().cloned());
                    match compare_case(&g, &plan, &res, &oracle) {
                        Err(e) => cases.push(CaseResult::failed(family, label, n, comm, e)),
                        Ok((passed, max_rel, compared)) => cases.push(CaseResult {
                            family: family.clone(),
                            label,
                            devices: n,
                            comm,
                            passed,
                            max_rel,
                            compared,
                            error: None,
                        }),
                    }
                }
            }
        }
    }
    let all_passed = !cases.is_empty() && cases.iter().all(|c| c.passed);
    Ok(DiffOutcome { cases, calibration: calibrate(&samples), all_passed })
}

/// Render the pass matrix as a fixed-width table for the CLI.
pub fn render_matrix(out: &DiffOutcome) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<10} {:>4} {:<28} {:<10} {:>10} {:>9} {:<6}\n",
        "family", "dev", "spec", "comm", "compared", "max_rel", "status"
    ));
    for c in &out.cases {
        let status = if c.passed { "pass" } else { "FAIL" };
        s.push_str(&format!(
            "{:<10} {:>4} {:<28} {:<10} {:>10} {:>9.2e} {:<6}\n",
            c.family, c.devices, c.label, c.comm, c.compared, c.max_rel, status
        ));
        if let Some(e) = &c.error {
            s.push_str(&format!("           ! {e}\n"));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_family_has_a_cell_at_each_matrix_width() {
        for family in FAMILIES {
            for n in [2usize, 4, 8] {
                let (planner, spec, _) = family_case(family, n)
                    .unwrap_or_else(|| panic!("no cell for {family}@{n}"));
                assert_eq!(spec.devices(), n, "{family}@{n} must occupy {n} devices");
                assert!(
                    registry::find(planner).is_some(),
                    "{family}@{n} resolves unregistered planner '{planner}'"
                );
            }
        }
    }

    #[test]
    fn unknown_family_and_odd_widths_have_no_cell() {
        assert!(family_case("nope", 4).is_none());
        assert!(family_case("dp", 3).is_none());
        assert!(family_case("megatron", 16).is_none());
    }

    #[test]
    fn replica_base_strips_suffixes() {
        assert_eq!(replica_base("h0.fc1.w@r1"), "h0.fc1.w");
        assert_eq!(replica_base("h0.fc1.w@r1@r2"), "h0.fc1.w");
        assert_eq!(replica_base("h0.fc1.w"), "h0.fc1.w");
        assert_eq!(replica_base("w@r"), "w@r");
    }

    #[test]
    fn tiny_model_is_well_formed() {
        let m = tiny_model();
        assert_eq!(m.layers.len(), 6);
        assert!(m.graph.live_op_ids().len() > 10);
        assert!(m.graph.ptensors.iter().any(|p| p.name == "lmloss.loss"));
    }

    #[test]
    fn oracle_runs_serially_and_snapshots_by_name() {
        let m = tiny_model();
        let o = run_oracle(&m).expect("oracle");
        assert!(o.values.contains_key("lmloss.loss"));
        assert!(o.values.contains_key("embed.table"));
        let loss = &o.values["lmloss.loss"];
        assert!(loss.iter().all(|v| v.is_finite()));
        assert!(loss.iter().any(|v| *v != 0.0), "loss must be non-trivial");
        assert!(!o.samples.is_empty());
    }
}
