//! Einops-style operator signatures (paper §5, "Op-trans assistant").
//!
//! A signature annotates every input/output axis of an operator with a dim
//! name, and marks which names are *reduction* dims (contracted — splitting
//! them value-splits the outputs) and which name is the *batch* dim (what
//! data parallelism splits; the paper's `GetBatchDim`).
//!
//! Example — a batched matmul:
//! ```text
//! b m k, k n -> b m n | reduce k | batch b
//! ```
//! Splitting `n` slices the second input and the output; splitting `k`
//! slices both inputs and makes each new operator produce a value-partial of
//! the output (requiring a reduce at materialization); splitting `b` slices
//! the first input and the output and replicates the second input.

use std::collections::BTreeSet;

/// Parsed operator signature.
#[derive(Clone, Debug, PartialEq)]
pub struct OpSignature {
    /// Dim names per input tensor, in axis order. An axis named `_` is
    /// anonymous (never partitionable).
    pub inputs: Vec<Vec<String>>,
    /// Dim names per output tensor.
    pub outputs: Vec<Vec<String>>,
    /// Contracted dims.
    pub reduce: BTreeSet<String>,
    /// The batched dim, if the op has one.
    pub batch: Option<String>,
}

impl OpSignature {
    /// Parse `"b m k, k n -> b m n | reduce k | batch b"`. The `| reduce`
    /// and `| batch` sections are optional.
    pub fn parse(s: &str) -> OpSignature {
        let mut sections = s.split('|').map(str::trim);
        let main = sections.next().expect("empty signature");
        let (ins, outs) = main
            .split_once("->")
            .unwrap_or_else(|| panic!("signature '{s}' missing '->'"));
        let parse_side = |side: &str| -> Vec<Vec<String>> {
            side.split(',')
                .map(|t| {
                    t.split_whitespace()
                        .map(|d| d.to_string())
                        .collect::<Vec<_>>()
                })
                .filter(|v| !v.is_empty())
                .collect()
        };
        let mut sig = OpSignature {
            inputs: parse_side(ins),
            outputs: parse_side(outs),
            reduce: BTreeSet::new(),
            batch: None,
        };
        for sec in sections {
            if let Some(rest) = sec.strip_prefix("reduce") {
                sig.reduce = rest.split_whitespace().map(|d| d.to_string()).collect();
            } else if let Some(rest) = sec.strip_prefix("batch") {
                sig.batch = rest.split_whitespace().next().map(|d| d.to_string());
            } else {
                panic!("unknown signature section '{sec}'");
            }
        }
        sig.validate();
        sig
    }

    fn validate(&self) {
        for r in &self.reduce {
            assert!(
                self.inputs.iter().any(|t| t.contains(r)),
                "reduce dim '{r}' not present in any input"
            );
            assert!(
                !self.outputs.iter().any(|t| t.contains(r)),
                "reduce dim '{r}' must not appear in outputs"
            );
        }
        if let Some(b) = &self.batch {
            assert!(
                self.inputs.iter().any(|t| t.contains(b)),
                "batch dim '{b}' not in inputs"
            );
        }
    }

    /// All named (partitionable) dims.
    pub fn dims(&self) -> BTreeSet<String> {
        self.inputs
            .iter()
            .chain(&self.outputs)
            .flatten()
            .filter(|d| *d != "_")
            .cloned()
            .collect()
    }

    /// Axis of `dim` in input tensor `i`, if present.
    pub fn input_axis(&self, i: usize, dim: &str) -> Option<usize> {
        self.inputs[i].iter().position(|d| d == dim)
    }

    /// Axis of `dim` in output tensor `i`, if present.
    pub fn output_axis(&self, i: usize, dim: &str) -> Option<usize> {
        self.outputs[i].iter().position(|d| d == dim)
    }

    pub fn is_reduce(&self, dim: &str) -> bool {
        self.reduce.contains(dim)
    }

    /// Can this op be split along `dim`? (It must be a named dim somewhere.)
    pub fn can_split(&self, dim: &str) -> bool {
        self.dims().contains(dim)
    }

    /// Axis index of the batch dim in input 0 (the paper's `GetBatchDim`).
    pub fn batch_axis(&self) -> Option<usize> {
        self.batch.as_ref().and_then(|b| self.input_axis(0, b))
    }
}

/// Convenience constructors for common operator signatures used by the
/// model zoo.
pub mod sigs {
    use super::OpSignature;

    /// `x[b,m,k] @ w[k,n] -> y[b,m,n]` (the transformer linear layer).
    pub fn linear() -> OpSignature {
        OpSignature::parse("b m k, k n -> b m n | reduce k | batch b")
    }

    /// Batched matmul `x[b,m,k] @ y[b,k,n] -> z[b,m,n]`.
    pub fn bmm() -> OpSignature {
        OpSignature::parse("b m k, b k n -> b m n | reduce k | batch b")
    }

    /// Elementwise over `[b, s, h]`.
    pub fn eltwise3() -> OpSignature {
        OpSignature::parse("b s h -> b s h | batch b")
    }

    /// Binary elementwise over `[b, s, h]`.
    pub fn eltwise3_bin() -> OpSignature {
        OpSignature::parse("b s h, b s h -> b s h | batch b")
    }

    /// LayerNorm: normalizes over `h`, so `h` is *not* partitionable — we
    /// name it `_` to forbid splits there.
    pub fn layernorm() -> OpSignature {
        OpSignature::parse("b s _ -> b s _ | batch b")
    }

    /// Multi-head attention composite over `[b, s, a, d]` (a = heads).
    /// Heads are embarrassingly parallel — `a` is the co-shard dim.
    pub fn attention() -> OpSignature {
        OpSignature::parse("b s a d, b s a d, b s a d -> b s a d | batch b")
    }

    /// Embedding lookup: `ids[b, s], table[v, h] -> out[b, s, h]`; the vocab
    /// dim `v` is partitionable (Megatron-style vocab-parallel embedding →
    /// value-split output, since each shard contributes rows it owns).
    pub fn embed() -> OpSignature {
        OpSignature::parse("b s, v h -> b s h | reduce v | batch b")
    }

    /// Adam step: grad + weight + 2 moments -> weight (elementwise over a
    /// flattened weight dim `p`).
    pub fn optimizer() -> OpSignature {
        OpSignature::parse("p, p, p, p -> p")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_signature() {
        let s = OpSignature::parse("b m k, k n -> b m n | reduce k | batch b");
        assert_eq!(s.inputs, vec![vec!["b", "m", "k"], vec!["k", "n"]]);
        assert_eq!(s.outputs, vec![vec!["b", "m", "n"]]);
        assert!(s.is_reduce("k"));
        assert_eq!(s.batch.as_deref(), Some("b"));
        assert_eq!(s.batch_axis(), Some(0));
    }

    #[test]
    fn axis_lookup() {
        let s = sigs::linear();
        assert_eq!(s.input_axis(0, "k"), Some(2));
        assert_eq!(s.input_axis(1, "k"), Some(0));
        assert_eq!(s.input_axis(1, "b"), None);
        assert_eq!(s.output_axis(0, "n"), Some(2));
    }

    #[test]
    fn anonymous_dims_not_partitionable() {
        let s = sigs::layernorm();
        assert!(!s.can_split("_"));
        assert!(s.can_split("b"));
        assert!(s.can_split("s"));
    }

    #[test]
    #[should_panic(expected = "missing '->'")]
    fn rejects_malformed() {
        OpSignature::parse("a b c");
    }

    #[test]
    #[should_panic(expected = "must not appear in outputs")]
    fn rejects_reduce_in_output() {
        OpSignature::parse("k -> k | reduce k");
    }

    #[test]
    fn no_batch_section_ok() {
        let s = OpSignature::parse("p, p -> p");
        assert!(s.batch.is_none());
        assert!(s.reduce.is_empty());
    }

    #[test]
    fn dims_collects_all_names() {
        let s = sigs::linear();
        let d = s.dims();
        assert!(d.contains("b") && d.contains("m") && d.contains("k") && d.contains("n"));
        assert_eq!(d.len(), 4);
    }
}
