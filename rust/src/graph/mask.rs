//! Mask algebra for vTensors (paper §3.1, Figs. 6–7).
//!
//! A [`Mask`] records which portion of a pTensor a vTensor covers:
//! * a per-dimension half-open rational interval `[start, end)` expressed as
//!   exact fractions of the dimension (so repeated `op-trans` splits compose
//!   without floating-point error), and
//! * a *value split* `(index, parts)`: `parts > 1` means this vTensor holds
//!   one additive partial of the pTensor's values (e.g. a partial matmul sum
//!   over a contracted dimension) — spatially full, numerically 1/parts.
//!
//! Dependency detection (Fig. 7) is mask intersection: two vTensors linked
//! to the same pTensor depend on each other iff their spatial boxes overlap
//! with non-zero volume. Value splits never *satisfy* a full-value consumer
//! by themselves — materialization must insert a reduce — but they still
//! constitute a data dependency.

use crate::util::gcd;
use std::fmt;

/// An exact non-negative rational, always kept normalized (gcd = 1, den > 0).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Frac {
    pub num: u64,
    pub den: u64,
}

impl Frac {
    pub fn new(num: u64, den: u64) -> Frac {
        assert!(den != 0, "zero denominator");
        let g = gcd(num, den).max(1);
        Frac { num: num / g, den: den / g }
    }

    pub const ZERO: Frac = Frac { num: 0, den: 1 };
    pub const ONE: Frac = Frac { num: 1, den: 1 };

    pub fn mul(self, o: Frac) -> Frac {
        Frac::new(self.num * o.num, self.den * o.den)
    }

    pub fn add(self, o: Frac) -> Frac {
        Frac::new(self.num * o.den + o.num * self.den, self.den * o.den)
    }

    pub fn sub(self, o: Frac) -> Frac {
        let (a, b) = (self.num * o.den, o.num * self.den);
        assert!(a >= b, "negative fraction");
        Frac::new(a - b, self.den * o.den)
    }

    pub fn cmp_frac(self, o: Frac) -> std::cmp::Ordering {
        (self.num * o.den).cmp(&(o.num * self.den))
    }

    pub fn min(self, o: Frac) -> Frac {
        if self.cmp_frac(o).is_le() { self } else { o }
    }

    pub fn max(self, o: Frac) -> Frac {
        if self.cmp_frac(o).is_ge() { self } else { o }
    }

    pub fn to_f64(self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// `self * n` rounded to an integer; panics if not exact. Used to turn a
    /// fractional interval into concrete element indices of a dim of size n.
    pub fn scale_exact(self, n: usize) -> usize {
        let v = self.num as u128 * n as u128;
        assert!(
            v % self.den as u128 == 0,
            "mask {}/{} does not divide dim {} evenly",
            self.num,
            self.den,
            n
        );
        (v / self.den as u128) as usize
    }
}

impl fmt::Debug for Frac {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.num, self.den)
    }
}

/// Half-open interval `[lo, hi)` over a unit-normalized dimension.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct Interval {
    pub lo: Frac,
    pub hi: Frac,
}

impl Interval {
    pub const FULL: Interval = Interval { lo: Frac::ZERO, hi: Frac::ONE };

    pub fn new(lo: Frac, hi: Frac) -> Interval {
        assert!(lo.cmp_frac(hi).is_le(), "inverted interval");
        Interval { lo, hi }
    }

    pub fn len(&self) -> Frac {
        self.hi.sub(self.lo)
    }

    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    /// Intersection; `None` if empty (touching endpoints count as empty).
    pub fn intersect(&self, o: &Interval) -> Option<Interval> {
        let lo = self.lo.max(o.lo);
        let hi = self.hi.min(o.hi);
        if lo.cmp_frac(hi).is_lt() {
            Some(Interval { lo, hi })
        } else {
            None
        }
    }

    pub fn contains(&self, o: &Interval) -> bool {
        self.lo.cmp_frac(o.lo).is_le() && self.hi.cmp_frac(o.hi).is_ge()
    }

    /// The `i`-th of `n` equal sub-intervals.
    pub fn split(&self, i: usize, n: usize) -> Interval {
        assert!(n > 0 && i < n);
        let w = self.len().mul(Frac::new(1, n as u64));
        let lo = self.lo.add(w.mul(Frac::new(i as u64, 1)));
        Interval { lo, hi: lo.add(w) }
    }

    /// Express `o` (which must be contained in `self`) in coordinates
    /// relative to `self` — the inverse of viewing `self` as the whole.
    pub fn relative(&self, o: &Interval) -> Interval {
        assert!(self.contains(o), "relative() needs containment");
        let w = self.len();
        assert!(w.num > 0, "relative() on empty interval");
        let inv = Frac::new(w.den, w.num);
        Interval {
            lo: o.lo.sub(self.lo).mul(inv),
            hi: o.hi.sub(self.lo).mul(inv),
        }
    }
}

/// Value-split annotation: this vTensor holds partial `index` of `parts`
/// additive partials of the pTensor values. `parts == 1` means full values.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct VSplit {
    pub index: u32,
    pub parts: u32,
}

impl VSplit {
    pub const FULL: VSplit = VSplit { index: 0, parts: 1 };

    pub fn is_full(&self) -> bool {
        self.parts == 1
    }

    /// Refine: this partial is further split into `n` partials, taking the
    /// `i`-th. Partial (i of n) of partial (index of parts) is partial
    /// (index*n + i of parts*n).
    pub fn refine(&self, i: u32, n: u32) -> VSplit {
        VSplit { index: self.index * n + i, parts: self.parts * n }
    }
}

/// The full mask of a vTensor over its pTensor.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Mask {
    pub dims: Vec<Interval>,
    pub vsplit: VSplit,
}

impl Mask {
    /// Full coverage of a rank-`rank` pTensor.
    pub fn full(rank: usize) -> Mask {
        Mask { dims: vec![Interval::FULL; rank], vsplit: VSplit::FULL }
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Fraction of the pTensor's elements this mask covers spatially.
    pub fn volume(&self) -> Frac {
        self.dims
            .iter()
            .fold(Frac::ONE, |acc, iv| acc.mul(iv.len()))
    }

    /// Spatial intersection (ignoring value split); `None` when disjoint.
    pub fn intersect(&self, o: &Mask) -> Option<Mask> {
        assert_eq!(self.rank(), o.rank(), "rank mismatch in mask intersect");
        let mut dims = Vec::with_capacity(self.dims.len());
        for (a, b) in self.dims.iter().zip(&o.dims) {
            dims.push(a.intersect(b)?);
        }
        Some(Mask { dims, vsplit: self.vsplit })
    }

    /// Data dependency per Fig. 7: non-empty spatial overlap. (Value splits
    /// overlap on values by construction — every partial contributes.)
    pub fn depends_on(&self, producer: &Mask) -> bool {
        self.intersect(producer).is_some()
    }

    /// Take the `i`-th of `n` equal spatial slices along `axis`.
    pub fn split_dim(&self, axis: usize, i: usize, n: usize) -> Mask {
        assert!(axis < self.rank(), "axis {axis} out of rank {}", self.rank());
        let mut m = self.clone();
        m.dims[axis] = m.dims[axis].split(i, n);
        m
    }

    /// Take the `i`-th of `n` value partials (spatially unchanged).
    pub fn split_value(&self, i: usize, n: usize) -> Mask {
        let mut m = self.clone();
        m.vsplit = m.vsplit.refine(i as u32, n as u32);
        m
    }

    /// Does `self` spatially cover all of `o`?
    pub fn covers(&self, o: &Mask) -> bool {
        self.dims
            .iter()
            .zip(&o.dims)
            .all(|(a, b)| a.contains(b))
    }

    /// Concrete element-index ranges of this mask over a pTensor with the
    /// given shape: `(start, end)` per dim. Panics if the mask does not fall
    /// on element boundaries (transform algorithms only create even splits,
    /// so this is a program invariant, not a user-facing error).
    pub fn concrete(&self, shape: &[usize]) -> Vec<(usize, usize)> {
        assert_eq!(shape.len(), self.rank(), "shape rank mismatch");
        self.dims
            .iter()
            .zip(shape)
            .map(|(iv, &n)| (iv.lo.scale_exact(n), iv.hi.scale_exact(n)))
            .collect()
    }

    /// Number of elements selected from a pTensor of `shape`.
    pub fn num_elements(&self, shape: &[usize]) -> usize {
        self.concrete(shape).iter().map(|(a, b)| b - a).product()
    }

    /// Do `self` and `o` cover *identical* regions (including value split)?
    pub fn same_region(&self, o: &Mask) -> bool {
        self == o
    }

    /// Hash of the *spatial* region only (value splits ignored): the dedup
    /// key the memory-accounting paths share — value partials of one region
    /// are a single allocation. One definition, used by the simulators'
    /// activation/gradient event streams and materialization's static
    /// memory, so the region keying cannot silently diverge between them.
    pub fn region_hash(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        for iv in &self.dims {
            (iv.lo.num, iv.lo.den, iv.hi.num, iv.hi.den).hash(&mut h);
        }
        h.finish()
    }
}

/// Check that a set of masks exactly tiles the full tensor: spatial volumes
/// (weighted 1/parts for value splits) sum to 1 and the pieces are pairwise
/// non-overlapping unless they are distinct value-partials or replicas of
/// different ranges. Used by transform-algorithm validation.
pub fn tiles_full(masks: &[Mask]) -> bool {
    if masks.is_empty() {
        return false;
    }
    // Sum of volume/parts must equal 1 for an exact tiling (each value split
    // contributes a 1/parts "share" of its spatial region).
    let mut num: u128 = 0;
    let mut den: u128 = 1;
    for m in masks {
        let v = m.volume();
        let share_num = v.num as u128;
        let share_den = v.den as u128 * m.vsplit.parts as u128;
        num = num * share_den + share_num * den;
        den *= share_den;
        let g = crate::util::gcd(num.min(u64::MAX as u128) as u64, den.min(u64::MAX as u128) as u64)
            .max(1) as u128;
        if num % g == 0 && den % g == 0 {
            num /= g;
            den /= g;
        }
    }
    num == den
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(n: u64, d: u64) -> Frac {
        Frac::new(n, d)
    }

    #[test]
    fn frac_normalizes() {
        assert_eq!(f(2, 4), f(1, 2));
        assert_eq!(f(0, 5), Frac::ZERO);
        assert_eq!(f(6, 3), f(2, 1));
    }

    #[test]
    fn frac_arith() {
        assert_eq!(f(1, 2).add(f(1, 3)), f(5, 6));
        assert_eq!(f(1, 2).mul(f(2, 3)), f(1, 3));
        assert_eq!(f(3, 4).sub(f(1, 4)), f(1, 2));
        assert!(f(1, 3).cmp_frac(f(1, 2)).is_lt());
    }

    #[test]
    fn scale_exact_works_and_panics() {
        assert_eq!(f(1, 2).scale_exact(8), 4);
        assert_eq!(f(3, 4).scale_exact(16), 12);
        let r = std::panic::catch_unwind(|| f(1, 3).scale_exact(8));
        assert!(r.is_err(), "1/3 of 8 is not exact");
    }

    #[test]
    fn interval_split_tiles() {
        let full = Interval::FULL;
        let parts: Vec<Interval> = (0..4).map(|i| full.split(i, 4)).collect();
        assert_eq!(parts[0].lo, Frac::ZERO);
        assert_eq!(parts[3].hi, Frac::ONE);
        for w in parts.windows(2) {
            assert_eq!(w[0].hi, w[1].lo);
            assert!(w[0].intersect(&w[1]).is_none(), "touching != overlapping");
        }
    }

    #[test]
    fn interval_relative_roundtrip() {
        let outer = Interval::new(f(1, 4), f(3, 4));
        let inner = Interval::new(f(1, 4), f(1, 2));
        let rel = outer.relative(&inner);
        assert_eq!(rel, Interval::new(Frac::ZERO, f(1, 2)));
    }

    #[test]
    fn fig6_two_step_split() {
        // Paper Fig. 6: split horizontally (top half), then vertically (left
        // half) -> top-left quarter of the pTensor.
        let v1 = Mask::full(2);
        let v2 = v1.split_dim(0, 0, 2); // top half
        let v3 = v2.split_dim(1, 0, 2); // left half of that
        assert_eq!(v3.dims[0], Interval::new(Frac::ZERO, f(1, 2)));
        assert_eq!(v3.dims[1], Interval::new(Frac::ZERO, f(1, 2)));
        assert_eq!(v3.volume(), f(1, 4));
    }

    #[test]
    fn fig7_dependency_check() {
        // Producers hold left/right halves; consumer needs the top half.
        let a1 = Mask::full(2).split_dim(1, 0, 2); // left
        let a2 = Mask::full(2).split_dim(1, 1, 2); // right
        let b1 = Mask::full(2).split_dim(0, 0, 2); // top
        assert!(b1.depends_on(&a1));
        assert!(b1.depends_on(&a2));
        let i1 = b1.intersect(&a1).unwrap();
        assert_eq!(i1.volume(), f(1, 4)); // top-left quarter
        // Disjoint: left vs right.
        assert!(!a1.depends_on(&a2));
    }

    #[test]
    fn vsplit_refinement() {
        let v = VSplit::FULL.refine(1, 2); // partial 1 of 2
        assert_eq!(v, VSplit { index: 1, parts: 2 });
        let v2 = v.refine(0, 3); // further split -> partial 3 of 6
        assert_eq!(v2, VSplit { index: 3, parts: 6 });
    }

    #[test]
    fn concrete_indices() {
        let m = Mask::full(2).split_dim(0, 1, 2).split_dim(1, 0, 4);
        let c = m.concrete(&[8, 16]);
        assert_eq!(c, vec![(4, 8), (0, 4)]);
        assert_eq!(m.num_elements(&[8, 16]), 16);
    }

    #[test]
    fn tiling_checks() {
        let quads: Vec<Mask> = (0..2)
            .flat_map(|i| {
                (0..2).map(move |j| Mask::full(2).split_dim(0, i, 2).split_dim(1, j, 2))
            })
            .collect();
        assert!(tiles_full(&quads));
        assert!(!tiles_full(&quads[..3]));
        // Two value-partials of the full region also tile it.
        let vs = vec![
            Mask::full(2).split_value(0, 2),
            Mask::full(2).split_value(1, 2),
        ];
        assert!(tiles_full(&vs));
        assert!(!tiles_full(&vs[..1]));
    }

    #[test]
    fn prop_split_dim_tiles_and_is_disjoint() {
        crate::util::prop::check("mask-split-tiles", 200, |g| {
            let rank = g.int(1, 4);
            let axis = g.int(0, rank);
            let n = g.int(1, 9);
            let base = Mask::full(rank);
            let parts: Vec<Mask> = (0..n).map(|i| base.split_dim(axis, i, n)).collect();
            if !tiles_full(&parts) {
                return Err(format!("rank={rank} axis={axis} n={n} does not tile"));
            }
            for i in 0..n {
                for j in i + 1..n {
                    if parts[i].intersect(&parts[j]).is_some() {
                        return Err(format!("parts {i},{j} overlap"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_intersection_commutes_and_shrinks() {
        crate::util::prop::check("mask-intersect", 300, |g| {
            let rank = g.int(1, 4);
            let mk = |g: &mut crate::util::prop::Gen| {
                let mut m = Mask::full(rank);
                for _ in 0..g.int(0, 3) {
                    let axis = g.int(0, rank);
                    let n = g.int(1, 5);
                    let i = g.int(0, n);
                    m = m.split_dim(axis, i, n);
                }
                m
            };
            let a = mk(g);
            let b = mk(g);
            match (a.intersect(&b), b.intersect(&a)) {
                (None, None) => Ok(()),
                (Some(x), Some(y)) => {
                    if x.dims != y.dims {
                        return Err("intersection not commutative".into());
                    }
                    if !a.covers(&x) || !b.covers(&x) {
                        return Err("intersection not contained".into());
                    }
                    Ok(())
                }
                _ => Err("asymmetric intersection".into()),
            }
        });
    }
}
