//! Phase 2 — space-time scheduling (`op-assign` / `op-order`, paper §3.2).
//!
//! [`Schedule`] records the spatial mapping (op → device) and the temporal
//! happen-before constraints. [`validate`] rebuilds the *full dependency
//! graph* — derived data dependencies (mask intersections, Fig. 7) plus the
//! user's order edges — and:
//!
//! 1. detects cycles (deadlocks) and reports one offending cycle;
//! 2. resolves *replicated producers*: when several producers expose an
//!    identical region of a pTensor, the consumer may read **any one** of
//!    them — the validator searches producer choices that keep the graph
//!    acyclic (preferring a same-device producer, which also minimizes
//!    communication);
//! 3. completes ambiguous per-device orders with a deterministic topological
//!    sort (Kahn, smallest-op-id first) and returns the per-device serial
//!    execution order used by the simulator and the real executor.
//!
//! The *shape* of those order edges is itself data: [`dsl`] defines
//! [`ScheduleSpec`] — per-stage slot rows over (micro-batch ×
//! fwd/bwd/W-grad) — with named builders (`sync`, `1f1b`, `interlaced`,
//! zero-bubble, V-shape) that lower to `Schedule::order` edges. Planners
//! select a [`SchedSpec`] instead of hard-coding ordering loops, which is
//! what lets the search treat the schedule as a fourth axis.

pub mod dsl;

pub use dsl::{lower_row, DslError, SchedName, SchedSpec, ScheduleSpec, Slot, SlotKind};

use crate::graph::{Graph, OpId, PTensorId};
use std::collections::{BinaryHeap, HashMap, HashSet};

/// Device index. GPUs are `0..cluster.num_gpus()`; [`CPU_DEVICE`] is the
/// host (used by swap).
pub type DeviceId = usize;

/// Sentinel device id for the host CPU (swap target).
pub const CPU_DEVICE: DeviceId = usize::MAX;

/// The space-time schedule of a transformed graph.
#[derive(Clone, Debug, Default)]
pub struct Schedule {
    assign: HashMap<OpId, DeviceId>,
    order: Vec<(OpId, OpId)>,
}

impl Schedule {
    pub fn new() -> Schedule {
        Schedule::default()
    }

    /// `op-assign(op, device)`.
    pub fn assign(&mut self, op: OpId, device: DeviceId) {
        self.assign.insert(op, device);
    }

    /// Assign a batch of ops to one device.
    pub fn assign_all(&mut self, ops: &[OpId], device: DeviceId) {
        for &o in ops {
            self.assign(o, device);
        }
    }

    /// `op-order(a, b)`: `a` happens before `b`.
    pub fn order(&mut self, a: OpId, b: OpId) {
        self.order.push((a, b));
    }

    /// Order every op in `a` before every op in `b` (the paper's
    /// `op-order(previous_tasks, stage_tasks)` over task sets).
    pub fn order_sets(&mut self, a: &[OpId], b: &[OpId]) {
        for &x in a {
            for &y in b {
                self.order.push((x, y));
            }
        }
    }

    pub fn device_of(&self, op: OpId) -> Option<DeviceId> {
        self.assign.get(&op).copied()
    }

    pub fn order_edges(&self) -> &[(OpId, OpId)] {
        &self.order
    }

    pub fn assignments(&self) -> &HashMap<OpId, DeviceId> {
        &self.assign
    }

    /// Devices in use.
    pub fn devices(&self) -> Vec<DeviceId> {
        let mut d: Vec<DeviceId> =
            self.assign.values().copied().collect::<HashSet<_>>().into_iter().collect();
        d.sort_unstable();
        d
    }

    /// Rewrite every GPU assignment through `f` (the host stays put) —
    /// the hook for placement passes like
    /// [`crate::fault::placement::rack_spread_map`], which permute a
    /// plan's logical device blocks onto physical fault domains.
    pub fn remap_devices(&mut self, f: impl Fn(DeviceId) -> DeviceId) {
        for d in self.assign.values_mut() {
            if *d != CPU_DEVICE {
                *d = f(*d);
            }
        }
    }
}

/// Validation failure modes surfaced to the sProgram author.
#[derive(Debug)]
pub enum ScheduleError {
    /// An op is not assigned to any device.
    Unassigned(OpId),
    /// The dependency + order graph has a cycle (deadlock). Contains one
    /// cycle as op-id path for diagnosis.
    Deadlock(Vec<OpId>),
    /// A consumer needs a pTensor region no producer (or initial tensor)
    /// covers.
    MissingProducer { consumer: OpId, ptensor: PTensorId },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::Unassigned(op) => write!(f, "op {op} has no device assignment"),
            ScheduleError::Deadlock(path) => write!(f, "deadlock cycle through ops {path:?}"),
            ScheduleError::MissingProducer { consumer, ptensor } => {
                write!(f, "op {consumer} consumes ptensor {ptensor} that nothing produces")
            }
        }
    }
}
impl std::error::Error for ScheduleError {}

/// The validated, completed schedule.
#[derive(Clone, Debug)]
pub struct ValidatedSchedule {
    /// Global topological order over all ops.
    pub topo: Vec<OpId>,
    /// Serial execution order per device (the "completion" of §3.2).
    pub device_order: HashMap<DeviceId, Vec<OpId>>,
    /// The dependency edges actually used (after replicated-producer
    /// resolution): `(producer, consumer, ptensor)`.
    pub deps: Vec<(OpId, OpId, PTensorId)>,
}

/// Validate `sched` against `g` (paper §3.2 "Scheduling validation and
/// completion").
pub fn validate(g: &Graph, sched: &Schedule) -> Result<ValidatedSchedule, ScheduleError> {
    let live = g.live_op_ids();
    for &op in &live {
        if sched.device_of(op).is_none() {
            return Err(ScheduleError::Unassigned(op));
        }
    }

    // ---- 1. derive data dependencies, grouping replicated producers ----
    // For each (consumer input vTensor): collect producers whose output
    // masks overlap it. If several producers expose the *same region*
    // (identical mask incl. value split), they are replicas and the
    // consumer needs any ONE. Distinct-region producers are all required.
    let access = g.ptensor_access();
    let mut and_deps: Vec<(OpId, OpId, PTensorId)> = Vec::new();
    let mut or_groups: Vec<(Vec<OpId>, OpId, PTensorId)> = Vec::new();
    for &c in &live {
        for &iv in &g.op(c).inputs {
            let vt = g.vtensor(iv);
            let Some((prods, _)) = access.get(&vt.ptensor) else { continue };
            // Group overlapping producers by identical output region.
            let mut groups: Vec<(crate::graph::mask::Mask, Vec<OpId>)> = Vec::new();
            for &p in prods {
                if p == c || g.is_cross_iteration(p, vt.ptensor) {
                    continue;
                }
                for &ov in &g.op(p).outputs {
                    let ovt = g.vtensor(ov);
                    if ovt.ptensor == vt.ptensor && vt.mask.depends_on(&ovt.mask) {
                        match groups.iter_mut().find(|(m, _)| m.same_region(&ovt.mask)) {
                            Some((_, v)) => {
                                if !v.contains(&p) {
                                    v.push(p)
                                }
                            }
                            None => groups.push((ovt.mask.clone(), vec![p])),
                        }
                    }
                }
            }
            for (_, ps) in groups {
                if ps.len() == 1 {
                    and_deps.push((ps[0], c, vt.ptensor));
                } else {
                    or_groups.push((ps, c, vt.ptensor));
                }
            }
        }
    }

    // ---- 2. cycle detection over AND edges + order edges ----
    let n = g.ops_len();
    let alive: HashSet<OpId> = live.iter().copied().collect();
    let mut adj: Vec<Vec<OpId>> = vec![Vec::new(); n];
    let mut push_edge = |adj: &mut Vec<Vec<OpId>>, a: OpId, b: OpId| {
        if alive.contains(&a) && alive.contains(&b) && a != b {
            adj[a].push(b);
        }
    };
    for &(p, c, _) in &and_deps {
        push_edge(&mut adj, p, c);
    }
    for &(a, b) in sched.order_edges() {
        push_edge(&mut adj, a, b);
    }
    if let Some(cycle) = find_cycle(&adj, &live) {
        return Err(ScheduleError::Deadlock(cycle));
    }

    // ---- 3. replicated-producer resolution ----
    // Choose, for every OR group, one producer that keeps the graph acyclic.
    // Preference order: same device as consumer, then lowest op id. Fast
    // path: commit every group's preferred candidate and run ONE cycle
    // check — on real plans this almost always succeeds. Slow path (a cycle
    // appeared): retract everything and re-add greedily with a per-candidate
    // check, which is complete because an extra edge only adds constraints.
    let mut chosen: Vec<(OpId, OpId, PTensorId)> = and_deps.clone();
    let mut ordered_groups: Vec<(Vec<OpId>, OpId, PTensorId)> = Vec::with_capacity(or_groups.len());
    for (cands, c, pt) in or_groups {
        let cdev = sched.device_of(c);
        let mut ordered = cands;
        ordered.sort_by_key(|&p| (sched.device_of(p) != cdev, p));
        ordered_groups.push((ordered, c, pt));
    }
    for (ordered, c, _) in &ordered_groups {
        adj[ordered[0]].push(*c);
    }
    if find_cycle(&adj, &live).is_none() {
        for (ordered, c, pt) in &ordered_groups {
            chosen.push((ordered[0], *c, *pt));
        }
    } else {
        // Retract and re-resolve one group at a time.
        for (ordered, c, _) in &ordered_groups {
            let pos = adj[ordered[0]].iter().rposition(|&x| x == *c).unwrap();
            adj[ordered[0]].remove(pos);
        }
        for (ordered, c, pt) in &ordered_groups {
            let mut ok = false;
            for &p in ordered {
                adj[p].push(*c);
                if find_cycle(&adj, &live).is_none() {
                    chosen.push((p, *c, *pt));
                    ok = true;
                    break;
                }
                adj[p].pop();
            }
            if !ok {
                // Every replica choice deadlocks -> report through one.
                adj[ordered[0]].push(*c);
                let cycle = find_cycle(&adj, &live).unwrap_or_default();
                return Err(ScheduleError::Deadlock(cycle));
            }
        }
    }

    // ---- 4. completion: deterministic topo sort + per-device serialization ----
    // Same-device ops are implicitly serialized; interleave by adding the
    // device-serial edges emerging from the global topo order itself.
    let topo = topo_sort(&adj, &live).expect("acyclic by construction");
    let mut device_order: HashMap<DeviceId, Vec<OpId>> = HashMap::new();
    for &op in &topo {
        device_order
            .entry(sched.device_of(op).unwrap())
            .or_default()
            .push(op);
    }
    Ok(ValidatedSchedule { topo, device_order, deps: chosen })
}

/// DFS cycle finder; returns one cycle as a path of op ids.
fn find_cycle(adj: &[Vec<OpId>], live: &[OpId]) -> Option<Vec<OpId>> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        White,
        Grey,
        Black,
    }
    let mut st = vec![St::White; adj.len()];
    let mut parent: Vec<Option<OpId>> = vec![None; adj.len()];
    for &root in live {
        if st[root] != St::White {
            continue;
        }
        // Iterative DFS to avoid recursion limits on big graphs.
        let mut stack: Vec<(OpId, usize)> = vec![(root, 0)];
        st[root] = St::Grey;
        while let Some(&mut (u, ref mut i)) = stack.last_mut() {
            if *i < adj[u].len() {
                let v = adj[u][*i];
                *i += 1;
                match st[v] {
                    St::White => {
                        st[v] = St::Grey;
                        parent[v] = Some(u);
                        stack.push((v, 0));
                    }
                    St::Grey => {
                        // Found a cycle v -> ... -> u -> v.
                        let mut path = vec![v];
                        let mut cur = u;
                        while cur != v {
                            path.push(cur);
                            cur = parent[cur].expect("cycle path broken");
                        }
                        path.reverse();
                        return Some(path);
                    }
                    St::Black => {}
                }
            } else {
                st[u] = St::Black;
                stack.pop();
            }
        }
    }
    None
}

/// Kahn topological sort with a min-heap for deterministic output.
fn topo_sort(adj: &[Vec<OpId>], live: &[OpId]) -> Option<Vec<OpId>> {
    let mut indeg: HashMap<OpId, usize> = live.iter().map(|&o| (o, 0)).collect();
    for &u in live {
        for &v in &adj[u] {
            *indeg.get_mut(&v).unwrap() += 1;
        }
    }
    let mut heap: BinaryHeap<std::cmp::Reverse<OpId>> = indeg
        .iter()
        .filter(|(_, &d)| d == 0)
        .map(|(&o, _)| std::cmp::Reverse(o))
        .collect();
    let mut out = Vec::with_capacity(live.len());
    while let Some(std::cmp::Reverse(u)) = heap.pop() {
        out.push(u);
        for &v in &adj[u] {
            let d = indeg.get_mut(&v).unwrap();
            *d -= 1;
            if *d == 0 {
                heap.push(std::cmp::Reverse(v));
            }
        }
    }
    (out.len() == live.len()).then_some(out)
}

impl Graph {
    /// Upper bound of op-id space (for adjacency arrays).
    pub fn ops_len(&self) -> usize {
        self.ops.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DType, Graph, OpKind, TensorKind};

    /// Chain A -> B -> C through activations.
    fn chain3() -> (Graph, [OpId; 3]) {
        let mut g = Graph::new();
        let t0 = g.add_ptensor("t0", &[4], DType::F32, TensorKind::Input);
        let t1 = g.add_ptensor("t1", &[4], DType::F32, TensorKind::Activation);
        let t2 = g.add_ptensor("t2", &[4], DType::F32, TensorKind::Activation);
        let t3 = g.add_ptensor("t3", &[4], DType::F32, TensorKind::Activation);
        let mk = |g: &mut Graph, name: &str, i, o| {
            let iv = g.full_view(i);
            let ov = g.full_view(o);
            g.add_op(name, OpKind::Identity, vec![iv], vec![ov], 1.0, None, true, 0)
        };
        let a = mk(&mut g, "A", t0, t1);
        let b = mk(&mut g, "B", t1, t2);
        let c = mk(&mut g, "C", t2, t3);
        (g, [a, b, c])
    }

    #[test]
    fn unassigned_op_rejected() {
        let (g, [a, b, _c]) = chain3();
        let mut s = Schedule::new();
        s.assign(a, 0);
        s.assign(b, 0);
        match validate(&g, &s) {
            Err(ScheduleError::Unassigned(_)) => {}
            other => panic!("expected Unassigned, got {other:?}"),
        }
    }

    #[test]
    fn valid_chain_topo_and_device_order() {
        let (g, [a, b, c]) = chain3();
        let mut s = Schedule::new();
        s.assign_all(&[a, b, c], 0);
        let v = validate(&g, &s).unwrap();
        assert_eq!(v.topo, vec![a, b, c]);
        assert_eq!(v.device_order[&0], vec![a, b, c]);
        assert_eq!(v.deps.len(), 2);
    }

    #[test]
    fn order_against_dataflow_is_deadlock() {
        // op-order(C, A) contradicts A -> B -> C.
        let (g, [a, b, c]) = chain3();
        let mut s = Schedule::new();
        s.assign_all(&[a, b, c], 0);
        s.order(c, a);
        match validate(&g, &s) {
            Err(ScheduleError::Deadlock(path)) => {
                assert!(path.len() >= 3, "cycle path {path:?}");
            }
            other => panic!("expected Deadlock, got {other:?}"),
        }
    }

    #[test]
    fn order_edges_shape_the_topo() {
        // Two independent chains interleaved by op-order (pipeline-style).
        let mut g = Graph::new();
        let mk_chain = |g: &mut Graph, tag: &str| {
            let i = g.add_ptensor(&format!("{tag}.in"), &[2], DType::F32, TensorKind::Input);
            let o = g.add_ptensor(&format!("{tag}.out"), &[2], DType::F32, TensorKind::Activation);
            let iv = g.full_view(i);
            let ov = g.full_view(o);
            g.add_op(tag, OpKind::Identity, vec![iv], vec![ov], 1.0, None, true, 0)
        };
        let p = mk_chain(&mut g, "P");
        let q = mk_chain(&mut g, "Q");
        let mut s = Schedule::new();
        s.assign_all(&[p, q], 0);
        s.order(q, p); // force Q before P despite id order
        let v = validate(&g, &s).unwrap();
        assert_eq!(v.device_order[&0], vec![q, p]);
    }

    #[test]
    fn replicated_producers_need_only_one() {
        // Two replica producers (identical masks) of t; consumer C.
        // op-order(C, P1) forces choosing P0 — still feasible.
        let mut g = Graph::new();
        let x = g.add_ptensor("x", &[2], DType::F32, TensorKind::Input);
        let t = g.add_ptensor("t", &[2], DType::F32, TensorKind::Activation);
        let y = g.add_ptensor("y", &[2], DType::F32, TensorKind::Activation);
        let mut mk_prod = |g: &mut Graph, name: &str| {
            let iv = g.full_view(x);
            let ov = g.full_view(t);
            g.add_op(name, OpKind::Identity, vec![iv], vec![ov], 1.0, None, true, 0)
        };
        let p0 = mk_prod(&mut g, "P0");
        let p1 = mk_prod(&mut g, "P1");
        let tv = g.full_view(t);
        let yv = g.full_view(y);
        let c = g.add_op("C", OpKind::Identity, vec![tv], vec![yv], 1.0, None, true, 0);
        let mut s = Schedule::new();
        s.assign(p0, 0);
        s.assign(p1, 1);
        s.assign(c, 0);
        s.order(c, p1); // C must run before P1 -> C can only read P0's copy
        let v = validate(&g, &s).unwrap();
        let chosen: Vec<_> = v.deps.iter().filter(|(_, cc, _)| *cc == c).collect();
        assert_eq!(chosen.len(), 1);
        assert_eq!(chosen[0].0, p0, "validator must pick the non-deadlocking replica");
    }

    #[test]
    fn replicated_producers_all_cyclic_is_deadlock() {
        let mut g = Graph::new();
        let x = g.add_ptensor("x", &[2], DType::F32, TensorKind::Input);
        let t = g.add_ptensor("t", &[2], DType::F32, TensorKind::Activation);
        let y = g.add_ptensor("y", &[2], DType::F32, TensorKind::Activation);
        let mut mk_prod = |g: &mut Graph, name: &str| {
            let iv = g.full_view(x);
            let ov = g.full_view(t);
            g.add_op(name, OpKind::Identity, vec![iv], vec![ov], 1.0, None, true, 0)
        };
        let p0 = mk_prod(&mut g, "P0");
        let p1 = mk_prod(&mut g, "P1");
        let tv = g.full_view(t);
        let yv = g.full_view(y);
        let c = g.add_op("C", OpKind::Identity, vec![tv], vec![yv], 1.0, None, true, 0);
        let mut s = Schedule::new();
        s.assign_all(&[p0, p1, c], 0);
        s.order(c, p0);
        s.order(c, p1); // C before both producers: impossible
        assert!(matches!(validate(&g, &s), Err(ScheduleError::Deadlock(_))));
    }

    #[test]
    fn prop_random_order_edges_never_panic_and_topo_is_consistent() {
        crate::util::prop::check("schedule-validate", 100, |gen| {
            let (g, ops) = {
                let (g, o) = chain3();
                (g, o.to_vec())
            };
            let mut s = Schedule::new();
            for &o in &ops {
                s.assign(o, gen.int(0, 3));
            }
            for _ in 0..gen.int(0, 4) {
                let a = ops[gen.int(0, 3)];
                let b = ops[gen.int(0, 3)];
                if a != b {
                    s.order(a, b);
                }
            }
            match validate(&g, &s) {
                Err(ScheduleError::Deadlock(_)) => Ok(()), // fine: detected
                Err(e) => Err(format!("unexpected error {e}")),
                Ok(v) => {
                    // topo must respect every chosen dep and order edge.
                    let pos: HashMap<OpId, usize> =
                        v.topo.iter().enumerate().map(|(i, &o)| (o, i)).collect();
                    for &(p, c, _) in &v.deps {
                        if pos[&p] > pos[&c] {
                            return Err(format!("dep {p}->{c} violated"));
                        }
                    }
                    for &(a, b) in s.order_edges() {
                        if pos[&a] > pos[&b] {
                            return Err(format!("order {a}->{b} violated"));
                        }
                    }
                    Ok(())
                }
            }
        });
    }
}
