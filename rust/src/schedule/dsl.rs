//! Schedules as data — the programmable pipeline-schedule DSL.
//!
//! The paper's central claim (§3.2) is that *space-time scheduling* is a
//! free axis decoupled from model transformation, yet pipeline orderings
//! used to be hard-coded inside individual planners. This module makes the
//! temporal axis declarative: a [`ScheduleSpec`] is a per-stage list of
//! [`Slot`]s over (micro-batch × {forward, backward, weight-grad}) — plain
//! data that can be named in a `PlanSpec` label (`sched{zb}`,
//! `sched{f0b0;f0b0}`), enumerated by the search grid, permuted by the
//! refinement tier, and lowered to ordinary [`Schedule::order`] edges.
//! The existing [`super::validate`] cycle/producer resolution then checks
//! the lowered result against the real data dependencies, so an infeasible
//! schedule surfaces as a typed error ([`DslError`] structurally,
//! [`super::ScheduleError`] against the graph) — never as a silent
//! deadlock. (Grounded in "A Flexible Programmable Pipeline Parallelism
//! Framework", arXiv 2510.05112.)
//!
//! Named builders cover the schedules the planners used to hard-code —
//! [`ScheduleSpec::sync`] (GPipe), [`ScheduleSpec::one_f_one_b`],
//! [`ScheduleSpec::interlaced`] — plus the ones the DSL unlocks:
//! [`ScheduleSpec::zero_bubble`] (backward split into B/activation-grad
//! and W/weight-grad tasks, with W work filling the drain bubbles) and
//! [`ScheduleSpec::v_shape`] (depth-skewed warmup). The 1F1B and sync
//! builders reproduce the legacy `order_1f1b` / `order_gpipe` edge
//! sequences exactly — the planners now *delegate* to this module, so
//! equivalence holds by construction and is pinned by tests.

use super::Schedule;
use crate::graph::OpId;

/// Task class of one schedule slot.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum SlotKind {
    /// Forward pass of one micro-batch through this stage.
    F,
    /// Backward activation-gradient task — the cross-stage critical path.
    /// Before the B/W split this is the whole backward op.
    B,
    /// Backward weight-gradient task. Only exists on split graphs
    /// (`trans::autograd::split_bw`); has no cross-stage consumers, so it
    /// is free to fill pipeline bubbles.
    W,
}

impl SlotKind {
    fn ch(self) -> char {
        match self {
            SlotKind::F => 'f',
            SlotKind::B => 'b',
            SlotKind::W => 'w',
        }
    }
}

/// One scheduled unit: a task class applied to one micro-batch.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct Slot {
    pub micro: usize,
    pub kind: SlotKind,
}

impl Slot {
    pub fn f(micro: usize) -> Slot {
        Slot { micro, kind: SlotKind::F }
    }
    pub fn b(micro: usize) -> Slot {
        Slot { micro, kind: SlotKind::B }
    }
    pub fn w(micro: usize) -> Slot {
        Slot { micro, kind: SlotKind::W }
    }
}

/// Structural schedule failures, surfaced *before* any graph work.
///
/// [`ScheduleSpec::check`] rejects rows that could never lower to an
/// acyclic order, so planner/search callers get a typed diagnosis instead
/// of a [`super::ScheduleError::Deadlock`] cycle dump downstream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DslError {
    /// No stage rows, or zero micro-batches.
    Empty,
    /// A slot names a micro-batch outside `0..k`.
    MicroOutOfRange { stage: usize, kind: SlotKind, micro: usize, k: usize },
    /// The same (kind, micro) slot appears twice in one stage row.
    Duplicate { stage: usize, kind: SlotKind, micro: usize },
    /// A row schedules B before its own F, or W before its own B.
    OutOfOrder { stage: usize, kind: SlotKind, micro: usize },
    /// A row never runs a required F or B slot for some micro-batch.
    Missing { stage: usize, kind: SlotKind, micro: usize },
    /// The rows deadlock against cross-stage dataflow (F needs the
    /// upstream stage's F, B needs the downstream stage's B): the
    /// fixed-point replay got stuck at this slot.
    Stuck { stage: usize, kind: SlotKind, micro: usize },
    /// Lowering found no ops for a slot the row demands.
    NoWork { stage: usize, kind: SlotKind, micro: usize },
}

impl std::fmt::Display for DslError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DslError::Empty => write!(f, "schedule has no stage rows or no micro-batches"),
            DslError::MicroOutOfRange { stage, kind, micro, k } => {
                write!(f, "stage {stage}: slot {kind:?}{micro} outside 0..{k} micro-batches")
            }
            DslError::Duplicate { stage, kind, micro } => {
                write!(f, "stage {stage}: slot {kind:?}{micro} scheduled twice")
            }
            DslError::OutOfOrder { stage, kind, micro } => {
                write!(f, "stage {stage}: slot {kind:?}{micro} before its prerequisite task")
            }
            DslError::Missing { stage, kind, micro } => {
                write!(f, "stage {stage}: required slot {kind:?}{micro} never scheduled")
            }
            DslError::Stuck { stage, kind, micro } => {
                write!(
                    f,
                    "cross-stage deadlock: stage {stage} waits forever at slot {kind:?}{micro}"
                )
            }
            DslError::NoWork { stage, kind, micro } => {
                write!(f, "stage {stage}: slot {kind:?}{micro} has no ops to schedule")
            }
        }
    }
}
impl std::error::Error for DslError {}

/// A pipeline schedule as data: `rows[stage]` is that stage's ordered slot
/// sequence. Construct via the named builders, [`ScheduleSpec::decode`],
/// or directly; run [`ScheduleSpec::check`] before lowering.
#[derive(Clone, PartialEq, Eq, Debug, Hash)]
pub struct ScheduleSpec {
    pub rows: Vec<Vec<Slot>>,
}

/// The 1F1B row for one stage: warmup forwards, then strict B/F
/// alternation until both drain. With `warmup = n_stages - s` this is
/// exactly the slot sequence the legacy `order_1f1b` chained.
pub fn row_1f1b(s: usize, n_stages: usize, k: usize) -> Vec<Slot> {
    row_alternating((n_stages - s).min(k), k)
}

/// The synchronous (GPipe) row: all forwards, then all backwards —
/// exactly the legacy `order_gpipe` sequence.
pub fn row_sync(k: usize) -> Vec<Slot> {
    let mut row: Vec<Slot> = (0..k).map(Slot::f).collect();
    row.extend((0..k).map(Slot::b));
    row
}

/// Warmup-then-alternate skeleton shared by 1F1B and V-shape.
fn row_alternating(warmup: usize, k: usize) -> Vec<Slot> {
    let warmup = warmup.clamp(1, k.max(1));
    let mut row: Vec<Slot> = (0..warmup).map(Slot::f).collect();
    let mut next_f = warmup;
    for m in 0..k {
        row.push(Slot::b(m));
        if next_f < k {
            row.push(Slot::f(next_f));
            next_f += 1;
        }
    }
    row
}

/// The zero-bubble row: 1F1B's warmup and steady state, but once forwards
/// are exhausted each drain step runs a W (weight-grad) task instead of
/// idling, with any remainder appended at the end. Requires a B/W-split
/// graph to change anything (W slots lower to nothing otherwise).
fn row_zero_bubble(s: usize, n_stages: usize, k: usize) -> Vec<Slot> {
    let warmup = (n_stages - s).min(k).max(1);
    let mut row: Vec<Slot> = (0..warmup).map(Slot::f).collect();
    let mut next_f = warmup;
    let mut next_w = 0;
    for m in 0..k {
        row.push(Slot::b(m));
        if next_f < k {
            row.push(Slot::f(next_f));
            next_f += 1;
        } else {
            row.push(Slot::w(next_w));
            next_w += 1;
        }
    }
    row.extend((next_w..k).map(Slot::w));
    row
}

impl ScheduleSpec {
    /// Synchronous / GPipe: every stage runs all forwards then all
    /// backwards.
    pub fn sync(n_stages: usize, k: usize) -> ScheduleSpec {
        ScheduleSpec { rows: (0..n_stages.max(1)).map(|_| row_sync(k)).collect() }
    }

    /// 1F1B: depth-proportional warmup, then one-forward-one-backward
    /// steady state. Caps in-flight micro-batches at the stage's depth.
    pub fn one_f_one_b(n_stages: usize, k: usize) -> ScheduleSpec {
        let s = n_stages.max(1);
        ScheduleSpec { rows: (0..s).map(|si| row_1f1b(si, s, k)).collect() }
    }

    /// The interlaced plan's schedule. Its novelty is *spatial* (the
    /// vocab-sharded embedding interleaved across pipeline devices); its
    /// temporal rows are 1F1B.
    pub fn interlaced(n_stages: usize, k: usize) -> ScheduleSpec {
        ScheduleSpec::one_f_one_b(n_stages, k)
    }

    /// Zero-bubble (ZB-H1 style): backward is split into B
    /// (activation-grad, stays on the critical path at 1× forward cost)
    /// and W (weight-grad, 1× forward cost, no cross-stage consumers);
    /// W tasks fill the drain bubbles 1F1B leaves idle.
    pub fn zero_bubble(n_stages: usize, k: usize) -> ScheduleSpec {
        let s = n_stages.max(1);
        ScheduleSpec { rows: (0..s).map(|si| row_zero_bubble(si, s, k)).collect() }
    }

    /// V-shape: 1F1B alternation under a depth-skewed warmup
    /// (`2·depth − 1` in-flight micro-batches at the deepest stage),
    /// trading activation memory for earlier downstream starts.
    pub fn v_shape(n_stages: usize, k: usize) -> ScheduleSpec {
        let s = n_stages.max(1);
        ScheduleSpec {
            rows: (0..s).map(|si| row_alternating((2 * (s - si)).saturating_sub(1), k)).collect(),
        }
    }

    /// Whether any row schedules a split weight-grad task.
    pub fn uses_wgrad(&self) -> bool {
        self.rows.iter().flatten().any(|s| s.kind == SlotKind::W)
    }

    /// Compact row encoding for `sched{...}` label tokens: each slot is
    /// `[fbw]<micro>`, rows joined by `;` — e.g. two-stage 1F1B over two
    /// micro-batches is `f0f1b0b1;f0b0f1b1`. Inverse of
    /// [`ScheduleSpec::decode`].
    pub fn encode(&self) -> String {
        self.rows
            .iter()
            .map(|row| row.iter().map(|s| format!("{}{}", s.kind.ch(), s.micro)).collect())
            .collect::<Vec<String>>()
            .join(";")
    }

    /// Parse an [`ScheduleSpec::encode`]d row string. `None` on any
    /// malformed input (unknown slot char, missing micro index, empty
    /// row) — the spec layer maps that to a typed `SpecParseError`.
    pub fn decode(s: &str) -> Option<ScheduleSpec> {
        if s.is_empty() {
            return None;
        }
        let mut rows = Vec::new();
        for part in s.split(';') {
            let bytes = part.as_bytes();
            let mut row = Vec::new();
            let mut i = 0;
            while i < bytes.len() {
                let kind = match bytes[i] {
                    b'f' => SlotKind::F,
                    b'b' => SlotKind::B,
                    b'w' => SlotKind::W,
                    _ => return None,
                };
                i += 1;
                let start = i;
                while i < bytes.len() && bytes[i].is_ascii_digit() {
                    i += 1;
                }
                let micro = part[start..i].parse::<usize>().ok()?;
                row.push(Slot { micro, kind });
            }
            if row.is_empty() {
                return None;
            }
            rows.push(row);
        }
        Some(ScheduleSpec { rows })
    }

    /// Structural validation against a micro-batch count, *before* any
    /// graph exists.
    ///
    /// Per row: every slot's micro in range, no duplicates, each micro's
    /// F and B both present exactly once, B after its F and W after its B
    /// (W slots are optional — a partial or empty W set is fine). Across
    /// rows: a fixed-point replay under the pipeline dataflow (F(s,m)
    /// needs F(s−1,m); B(s,m) needs B(s+1,m)) must drain every row, else
    /// the stuck slot is reported. Rows that pass here can still fail
    /// [`super::validate`] against a concrete graph, but never the other
    /// way around for pure pipeline dependencies.
    pub fn check(&self, k: usize) -> Result<(), DslError> {
        let s = self.rows.len();
        if s == 0 || k == 0 {
            return Err(DslError::Empty);
        }
        for (si, row) in self.rows.iter().enumerate() {
            let mut seen = vec![vec![false; k]; 3];
            for slot in row {
                let (kind, m) = (slot.kind, slot.micro);
                if m >= k {
                    return Err(DslError::MicroOutOfRange { stage: si, kind, micro: m, k });
                }
                if seen[kind as usize][m] {
                    return Err(DslError::Duplicate { stage: si, kind, micro: m });
                }
                let in_order = match kind {
                    SlotKind::F => true,
                    SlotKind::B => seen[SlotKind::F as usize][m],
                    SlotKind::W => seen[SlotKind::B as usize][m],
                };
                if !in_order {
                    return Err(DslError::OutOfOrder { stage: si, kind, micro: m });
                }
                seen[kind as usize][m] = true;
            }
            for m in 0..k {
                for kind in [SlotKind::F, SlotKind::B] {
                    if !seen[kind as usize][m] {
                        return Err(DslError::Missing { stage: si, kind, micro: m });
                    }
                }
            }
        }
        // Cross-stage feasibility: replay all rows to a fixed point under
        // the pipeline deps. In-row prerequisites are already guaranteed
        // above, so only cross-stage readiness is simulated.
        let mut pos = vec![0usize; s];
        let mut done = vec![vec![vec![false; k]; 3]; s];
        loop {
            let mut progressed = false;
            let mut remaining = false;
            for si in 0..s {
                while pos[si] < self.rows[si].len() {
                    let slot = self.rows[si][pos[si]];
                    let m = slot.micro;
                    let ready = match slot.kind {
                        SlotKind::F => si == 0 || done[si - 1][SlotKind::F as usize][m],
                        SlotKind::B => si + 1 == s || done[si + 1][SlotKind::B as usize][m],
                        SlotKind::W => true,
                    };
                    if !ready {
                        break;
                    }
                    done[si][slot.kind as usize][m] = true;
                    pos[si] += 1;
                    progressed = true;
                }
                remaining |= pos[si] < self.rows[si].len();
            }
            if !remaining {
                return Ok(());
            }
            if !progressed {
                for si in 0..s {
                    if pos[si] < self.rows[si].len() {
                        let slot = self.rows[si][pos[si]];
                        return Err(DslError::Stuck {
                            stage: si,
                            kind: slot.kind,
                            micro: slot.micro,
                        });
                    }
                }
            }
        }
    }
}

/// Schedule names usable in a `sched{...}` label token, resolved to
/// concrete rows per pipeline shape by [`SchedSpec::resolve`].
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum SchedName {
    Sync,
    OneFOneB,
    Interlaced,
    ZeroBubble,
    VShape,
}

impl SchedName {
    pub fn as_str(&self) -> &'static str {
        match self {
            SchedName::Sync => "sync",
            SchedName::OneFOneB => "1f1b",
            SchedName::Interlaced => "interlaced",
            SchedName::ZeroBubble => "zb",
            SchedName::VShape => "vshape",
        }
    }

    /// Parse a schedule name (accepts aliases; labels always emit the
    /// canonical [`SchedName::as_str`] form, so round-trips are exact at
    /// the value level).
    pub fn parse(s: &str) -> Option<SchedName> {
        Some(match s {
            "sync" | "gpipe" => SchedName::Sync,
            "1f1b" => SchedName::OneFOneB,
            "interlaced" => SchedName::Interlaced,
            "zb" | "zero-bubble" => SchedName::ZeroBubble,
            "vshape" | "v-shape" => SchedName::VShape,
            _ => return None,
        })
    }

    /// Materialize the named schedule for a pipeline shape.
    pub fn rows(&self, n_stages: usize, k: usize) -> ScheduleSpec {
        match self {
            SchedName::Sync => ScheduleSpec::sync(n_stages, k),
            SchedName::OneFOneB => ScheduleSpec::one_f_one_b(n_stages, k),
            SchedName::Interlaced => ScheduleSpec::interlaced(n_stages, k),
            SchedName::ZeroBubble => ScheduleSpec::zero_bubble(n_stages, k),
            SchedName::VShape => ScheduleSpec::v_shape(n_stages, k),
        }
    }
}

/// The schedule choice a `PlanSpec` carries — the fourth search axis.
/// Either a named discipline (resolved per pipeline shape, so one spec
/// label works across pp/micro mutations) or explicit rows (how a
/// refine-accepted permutation persists in a label).
#[derive(Clone, PartialEq, Eq, Debug, Hash)]
pub enum SchedSpec {
    Named(SchedName),
    Explicit(ScheduleSpec),
}

impl SchedSpec {
    /// The `sched{...}` label token (no internal whitespace).
    pub fn token(&self) -> String {
        match self {
            SchedSpec::Named(n) => format!("sched{{{}}}", n.as_str()),
            SchedSpec::Explicit(s) => format!("sched{{{}}}", s.encode()),
        }
    }

    /// Inverse of [`SchedSpec::token`]: `None` when `tok` is not of the
    /// `sched{...}` shape or the body is neither a known name nor a
    /// well-formed row encoding.
    pub fn parse_token(tok: &str) -> Option<SchedSpec> {
        let inner = tok.strip_prefix("sched{")?.strip_suffix('}')?;
        if let Some(name) = SchedName::parse(inner) {
            return Some(SchedSpec::Named(name));
        }
        ScheduleSpec::decode(inner).map(SchedSpec::Explicit)
    }

    /// Concrete rows for a pipeline shape: named schedules materialize,
    /// explicit rows pass through (their arity is checked by the caller
    /// via [`ScheduleSpec::check`] and a row-count comparison).
    pub fn resolve(&self, n_stages: usize, k: usize) -> ScheduleSpec {
        match self {
            SchedSpec::Named(n) => n.rows(n_stages, k),
            SchedSpec::Explicit(s) => s.clone(),
        }
    }

    /// Whether this schedule wants the backward pass split into B/W tasks.
    pub fn uses_wgrad(&self) -> bool {
        match self {
            SchedSpec::Named(n) => *n == SchedName::ZeroBubble,
            SchedSpec::Explicit(s) => s.uses_wgrad(),
        }
    }
}

/// Lower one stage row to [`Schedule::order`] edges: each slot resolves to
/// its op span `(first, last)` and consecutive resolved spans chain
/// `prev.last → next.first` — exactly the edge stream the legacy
/// `seq.windows(2)` loops emitted.
///
/// `fwd`/`bwd` are indexed by micro-batch; `wgrad[m]` is `None` when micro
/// `m` has no split W task (un-split graph, or a stage without weights) —
/// such W slots are skipped, degrading gracefully to the plain B chain. A
/// missing F or B span is a typed error: the row demands work the stage
/// does not have.
pub fn lower_row(
    sched: &mut Schedule,
    stage: usize,
    row: &[Slot],
    fwd: &[(OpId, OpId)],
    bwd: &[(OpId, OpId)],
    wgrad: &[Option<(OpId, OpId)>],
) -> Result<(), DslError> {
    let missing = |slot: &Slot| DslError::NoWork { stage, kind: slot.kind, micro: slot.micro };
    let mut prev: Option<(OpId, OpId)> = None;
    for slot in row {
        let span = match slot.kind {
            SlotKind::F => Some(*fwd.get(slot.micro).ok_or_else(|| missing(slot))?),
            SlotKind::B => Some(*bwd.get(slot.micro).ok_or_else(|| missing(slot))?),
            SlotKind::W => wgrad.get(slot.micro).copied().flatten(),
        };
        let Some(span) = span else { continue };
        if let Some(p) = prev {
            sched.order(p.1, span.0);
        }
        prev = Some(span);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans(base: usize, k: usize) -> Vec<(OpId, OpId)> {
        (0..k).map(|m| (base + 2 * m, base + 2 * m + 1)).collect()
    }

    /// The legacy `order_1f1b` loop, verbatim, as the equivalence oracle.
    fn legacy_1f1b_edges(
        s: usize,
        n_stages: usize,
        k: usize,
        fwd: &[(OpId, OpId)],
        bwd: &[(OpId, OpId)],
    ) -> Vec<(OpId, OpId)> {
        let warmup = (n_stages - s).min(k);
        let mut seq: Vec<(OpId, OpId)> = Vec::new();
        for m in 0..warmup {
            seq.push(fwd[m]);
        }
        let mut next_f = warmup;
        for m in 0..k {
            seq.push(bwd[m]);
            if next_f < k {
                seq.push(fwd[next_f]);
                next_f += 1;
            }
        }
        seq.windows(2).map(|w| (w[0].1, w[1].0)).collect()
    }

    #[test]
    fn one_f_one_b_rows_lower_to_the_legacy_edge_stream() {
        for (n_stages, k) in [(2, 2), (4, 8), (4, 2), (3, 5), (1, 4)] {
            let spec = ScheduleSpec::one_f_one_b(n_stages, k);
            spec.check(k).unwrap();
            for s in 0..n_stages {
                let fwd = spans(100, k);
                let bwd = spans(500, k);
                let mut sched = Schedule::new();
                lower_row(&mut sched, s, &spec.rows[s], &fwd, &bwd, &[]).unwrap();
                assert_eq!(
                    sched.order_edges(),
                    legacy_1f1b_edges(s, n_stages, k, &fwd, &bwd),
                    "stage {s} of {n_stages}, k={k}"
                );
            }
        }
    }

    #[test]
    fn sync_rows_lower_to_the_legacy_gpipe_edge_stream() {
        let k = 4;
        let spec = ScheduleSpec::sync(3, k);
        spec.check(k).unwrap();
        let fwd = spans(10, k);
        let bwd = spans(90, k);
        // Legacy order_gpipe: all fwd then all bwd, windows(2).
        let mut seq = fwd.clone();
        seq.extend_from_slice(&bwd);
        let want: Vec<(OpId, OpId)> = seq.windows(2).map(|w| (w[0].1, w[1].0)).collect();
        let mut sched = Schedule::new();
        lower_row(&mut sched, 0, &spec.rows[0], &fwd, &bwd, &[]).unwrap();
        assert_eq!(sched.order_edges(), want);
    }

    #[test]
    fn named_builders_all_pass_check() {
        for (n_stages, k) in [(1, 1), (2, 2), (4, 8), (8, 4), (3, 7)] {
            for name in [
                SchedName::Sync,
                SchedName::OneFOneB,
                SchedName::Interlaced,
                SchedName::ZeroBubble,
                SchedName::VShape,
            ] {
                let spec = name.rows(n_stages, k);
                assert_eq!(spec.rows.len(), n_stages);
                spec.check(k).unwrap_or_else(|e| {
                    panic!("{} rows invalid for {n_stages}x{k}: {e}", name.as_str())
                });
            }
        }
    }

    #[test]
    fn zero_bubble_schedules_every_w_exactly_once() {
        let (n_stages, k) = (4, 8);
        let spec = ScheduleSpec::zero_bubble(n_stages, k);
        assert!(spec.uses_wgrad());
        for row in &spec.rows {
            let mut w = vec![0usize; k];
            for slot in row {
                if slot.kind == SlotKind::W {
                    w[slot.micro] += 1;
                }
            }
            assert!(w.iter().all(|&c| c == 1), "each micro's W once: {w:?}");
            // Total row length: k F + k B + k W.
            assert_eq!(row.len(), 3 * k);
        }
    }

    #[test]
    fn zero_bubble_fills_bubbles_before_the_drain() {
        // Stage 3 of 4, k=8: warmup 1, so after F7 the 1F1B drain would
        // idle between backwards; ZB must interleave W there, not only
        // append at the end.
        let spec = ScheduleSpec::zero_bubble(4, 8);
        let row = &spec.rows[0]; // deepest warmup: stage 0 has warmup 4
        let first_w = row.iter().position(|s| s.kind == SlotKind::W).unwrap();
        let last_b = row.iter().rposition(|s| s.kind == SlotKind::B).unwrap();
        assert!(first_w < last_b, "W work must start before the final B drains");
    }

    #[test]
    fn check_rejects_structurally_bad_rows() {
        let k = 2;
        // B before F.
        let spec =
            ScheduleSpec { rows: vec![vec![Slot::b(0), Slot::f(0), Slot::f(1), Slot::b(1)]] };
        assert!(matches!(spec.check(k), Err(DslError::OutOfOrder { .. })));
        // Missing B1.
        let spec = ScheduleSpec { rows: vec![vec![Slot::f(0), Slot::f(1), Slot::b(0)]] };
        assert!(matches!(spec.check(k), Err(DslError::Missing { .. })));
        // Duplicate F0.
        let spec =
            ScheduleSpec { rows: vec![vec![Slot::f(0), Slot::f(0), Slot::b(0), Slot::b(1)]] };
        assert!(matches!(spec.check(k), Err(DslError::Duplicate { .. })));
        // Micro out of range.
        let spec = ScheduleSpec { rows: vec![vec![Slot::f(7), Slot::b(7)]] };
        assert!(matches!(spec.check(k), Err(DslError::MicroOutOfRange { .. })));
        // Empty.
        assert!(matches!(ScheduleSpec { rows: vec![] }.check(k), Err(DslError::Empty)));
    }

    #[test]
    fn check_detects_cross_stage_deadlock() {
        // Stage 0 runs B0 before F1; stage 1 runs F1 before B0. Each row
        // is locally fine, but together they deadlock: stage 0's B0 waits
        // on stage 1's B0, which comes after stage 1's F1, which waits on
        // stage 0's F1, which comes after stage 0's B0.
        let spec = ScheduleSpec {
            rows: vec![
                vec![Slot::f(0), Slot::b(0), Slot::f(1), Slot::b(1)],
                vec![Slot::f(0), Slot::f(1), Slot::b(0), Slot::b(1)],
            ],
        };
        assert!(matches!(spec.check(2), Err(DslError::Stuck { .. })));
    }

    #[test]
    fn encode_decode_roundtrip() {
        for (n_stages, k) in [(2, 2), (4, 8), (3, 5)] {
            for name in [SchedName::OneFOneB, SchedName::ZeroBubble, SchedName::VShape] {
                let spec = name.rows(n_stages, k);
                let enc = spec.encode();
                assert_eq!(ScheduleSpec::decode(&enc), Some(spec), "{enc}");
            }
        }
        assert_eq!(ScheduleSpec::decode(""), None);
        assert_eq!(ScheduleSpec::decode("f0b0;"), None);
        assert_eq!(ScheduleSpec::decode("x0"), None);
        assert_eq!(ScheduleSpec::decode("f"), None);
        assert_eq!(ScheduleSpec::decode("fb0"), None);
    }

    #[test]
    fn encode_decode_roundtrip_all_named_builders() {
        // Every named builder, including multi-digit micro indices (k > 10)
        // so the digit-run parser is exercised, not just single chars.
        for (n_stages, k) in [(1, 1), (2, 2), (4, 8), (3, 12), (8, 16)] {
            for name in [
                SchedName::Sync,
                SchedName::OneFOneB,
                SchedName::Interlaced,
                SchedName::ZeroBubble,
                SchedName::VShape,
            ] {
                let spec = name.rows(n_stages, k);
                let enc = spec.encode();
                assert_eq!(
                    ScheduleSpec::decode(&enc),
                    Some(spec),
                    "{} {n_stages}x{k}: {enc}",
                    name.as_str()
                );
            }
        }
    }

    #[test]
    fn prop_random_valid_explicit_rows_roundtrip_encode_decode() {
        crate::util::prop::check("dsl-encode-roundtrip", 300, |g| {
            let k = g.int(1, 14);
            let n_stages = g.int(1, 5);
            // Build each row by interleaving the per-micro f→b→(w?) chains
            // at random: structurally valid by construction (no dups, every
            // F/B present, B after its F, W after its B).
            let rows: Vec<Vec<Slot>> = (0..n_stages)
                .map(|_| {
                    let mut progress = vec![0usize; k]; // 0=f next, 1=b next, 2=w next, 3=done
                    let want_w: Vec<bool> = (0..k).map(|_| g.bool()).collect();
                    let mut row = Vec::new();
                    loop {
                        let open: Vec<usize> = (0..k)
                            .filter(|&m| progress[m] < if want_w[m] { 3 } else { 2 })
                            .collect();
                        if open.is_empty() {
                            break;
                        }
                        let m = *g.rng.choose(&open);
                        row.push(match progress[m] {
                            0 => Slot::f(m),
                            1 => Slot::b(m),
                            _ => Slot::w(m),
                        });
                        progress[m] += 1;
                    }
                    row
                })
                .collect();
            let spec = ScheduleSpec { rows };
            // Per-row structural validity holds by construction; verify it
            // for single-stage specs where the cross-stage replay is
            // trivially satisfiable too.
            if n_stages == 1 {
                spec.check(k).map_err(|e| format!("constructed row rejected: {e}"))?;
            }
            let enc = spec.encode();
            match ScheduleSpec::decode(&enc) {
                Some(back) if back == spec => {}
                Some(back) => return Err(format!("'{enc}' decoded to {back:?}")),
                None => return Err(format!("'{enc}' failed to decode")),
            }
            // The sched{...} token wrapper round-trips the same rows.
            let tok = SchedSpec::Explicit(spec.clone()).token();
            match SchedSpec::parse_token(&tok) {
                Some(SchedSpec::Explicit(back)) if back == spec => Ok(()),
                other => Err(format!("token '{tok}' parsed to {other:?}")),
            }
        });
    }

    #[test]
    fn sched_tokens_roundtrip_named_and_explicit() {
        let cases = [
            SchedSpec::Named(SchedName::ZeroBubble),
            SchedSpec::Named(SchedName::Sync),
            SchedSpec::Explicit(ScheduleSpec::one_f_one_b(2, 2)),
        ];
        for s in cases {
            let tok = s.token();
            assert!(tok.starts_with("sched{") && tok.ends_with('}'));
            assert_eq!(SchedSpec::parse_token(&tok), Some(s), "{tok}");
        }
        assert_eq!(SchedSpec::parse_token("sched{}"), None);
        assert_eq!(SchedSpec::parse_token("sched{nope}"), None);
        assert_eq!(SchedSpec::parse_token("sched{f0b0"), None);
        assert_eq!(SchedSpec::parse_token("zb"), None);
    }

    #[test]
    fn w_slots_skip_gracefully_without_split_spans() {
        // A zb row lowered with no W spans must produce exactly the 1f1b
        // edge stream: W slots vanish, F/B chain intact.
        let (n_stages, k) = (3, 4);
        let zb = ScheduleSpec::zero_bubble(n_stages, k);
        let fwd = spans(10, k);
        let bwd = spans(200, k);
        for s in 0..n_stages {
            let mut with_none = Schedule::new();
            let empty_w = vec![None; k];
            lower_row(&mut with_none, s, &zb.rows[s], &fwd, &bwd, &empty_w).unwrap();
            let mut legacy = Schedule::new();
            let fb: Vec<Slot> =
                zb.rows[s].iter().copied().filter(|sl| sl.kind != SlotKind::W).collect();
            lower_row(&mut legacy, s, &fb, &fwd, &bwd, &[]).unwrap();
            assert_eq!(with_none.order_edges(), legacy.order_edges());
        }
    }

    #[test]
    fn lower_row_reports_missing_work_as_typed_error() {
        let mut sched = Schedule::new();
        let row = vec![Slot::f(0), Slot::f(1), Slot::b(0), Slot::b(1)];
        let err = lower_row(&mut sched, 2, &row, &spans(0, 1), &spans(10, 1), &[]).unwrap_err();
        assert_eq!(err, DslError::NoWork { stage: 2, kind: SlotKind::F, micro: 1 });
    }
}
