//! SuperScaler — a parallelization-plan engine for distributed DNN training.
//!
//! Reproduction of *"SuperScaler: Supporting Flexible DNN Parallelization via
//! a Unified Abstraction"* (Lin et al., 2023) as a three-layer Rust + JAX +
//! Pallas stack. The engine decouples parallelization into three phases:
//!
//! 1. **Operator transformation** ([`trans`]) — `op-trans` partitions each
//!    operator (and its input/output [`graph::VTensor`]s) into functionally
//!    equivalent pieces, tracking data relations through pTensor masks.
//! 2. **Space-time scheduling** ([`schedule`]) — `op-assign` maps operators
//!    to devices, `op-order` adds happen-before edges; validation detects
//!    deadlocks and completes ambiguous orders with a topological sort.
//! 3. **Dependency materialization** ([`materialize`]) — mask intersections
//!    between producer and consumer vTensors are turned into split / concat /
//!    reduce / send-recv operators, then optimized into collectives via the
//!    [`rvd`] representation and Dijkstra search.
//!
//! The materialized plan can then be:
//! * **simulated** ([`sim`]) on a modeled GPU cluster (V100-like, NVLink +
//!   InfiniBand hierarchy) to reproduce the paper's evaluation — a fast
//!   list scheduler in which communication blocks its devices;
//! * **replayed at high fidelity** ([`des`]): a deterministic
//!   discrete-event engine with separate compute/communication streams per
//!   device, fair-shared link contention and time-resolved memory
//!   timelines, exportable as a Chrome trace for visual debugging;
//! * **executed** ([`exec`]) with real numerics: either through the PJRT
//!   CPU client ([`runtime`]) running AOT-compiled JAX/Pallas artifacts
//!   (data-parallel trainer), or on the pure-Rust CPU reference executor
//!   ([`exec::reference`]) which interprets *any* materialized plan — one
//!   thread per device, native f32 kernels, real P2P/collective payloads.
//!   The differential harness ([`exec::diff`], `superscaler verify-exec`)
//!   proves every planner family elementwise-equivalent to a single-device
//!   serial oracle and calibrates the analytic cost model against measured
//!   per-task durations ([`cost::calibrate`]).
//!
//! # Plans as data: `Planner` / `PlanSpec` / search
//!
//! The sProgram library ([`plans`]) is exposed through a uniform plan
//! abstraction: every plan implements the [`plans::Planner`] trait
//! (`name` / `applicable` / `build`), is described by a declarative
//! [`plans::PlanSpec`] (kind + dp/pp/tp degrees + micro-batch / shard
//! counts + offload/recompute flags), and registers in
//! [`plans::registry`]. On top of that sits [`search`]: enumerate the
//! feasible spec grid for a model + cluster, prune by divisibility and the
//! cost model's memory bound, evaluate every survivor (transform →
//! validate → materialize → simulate) in parallel on [`util::pool`]
//! workers, and rank by iteration time — `superscaler search --model gpt3
//! --gpus 8` end to end. With `--fidelity des` the ranking's top
//! candidates are re-scored by the discrete-event engine, so schedules
//! that overlap communication with compute are credited for it.
//!
//! Pipeline *schedules* are data too ([`schedule::dsl`]): a
//! [`schedule::ScheduleSpec`] lists each stage's ordered
//! (micro × F/B/W) slots, named builders cover sync/1F1B/interlaced/
//! zero-bubble/V-shape, and a `sched{...}` token in the spec label makes
//! the temporal discipline the search's fourth axis alongside
//! dp × pp × tp.
//!
//! Downstream users should start from [`prelude`], which re-exports the
//! handful of types nearly every integration touches.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md` for
//! measured results.

pub mod cost;
pub mod des;
pub mod exec;
pub mod fault;
pub mod graph;
pub mod materialize;
pub mod models;
pub mod plans;
pub mod runtime;
pub mod rvd;
pub mod schedule;
pub mod search;
pub mod sim;
pub mod topo;
pub mod trans;
pub mod util;

pub use graph::{Graph, Op, OpId, OpKind, PTensor, VTensor};
pub use schedule::Schedule;

/// The crate's front door: one `use superscaler::prelude::*;` brings in
/// the types nearly every integration needs — the plan vocabulary
/// ([`plans::Planner`], [`plans::PlanSpec`], [`plans::registry`]), the
/// schedule vocabulary ([`schedule::ScheduleSpec`] and friends), the
/// modeled cluster, and the search entry points. Everything here is a
/// re-export; the defining modules stay the source of truth.
pub mod prelude {
    pub use crate::cost::Cluster;
    pub use crate::fault::{CkptPolicy, FaultSpec, ResilienceConfig};
    pub use crate::graph::Graph;
    pub use crate::materialize::CommMode;
    pub use crate::models::Model;
    pub use crate::plans::{
        registry, PlanKind, PlanSpec, Planner, SchedName, SchedSpec, SpecParseError, StageSpec,
    };
    pub use crate::schedule::{Schedule, ScheduleSpec};
    pub use crate::search::{self, Fidelity, Metrics, RefineConfig, SearchConfig, SearchReport};
    pub use crate::topo::{build_cluster, ClusterShapeError, DeviceKind, TopoKind, Topology};
}
