//! AlphaFold2 Evoformer (§6.1): 128 sequences × 256 residues, Table 2
//! layer/hidden/head configs. Trained with *recycling*: three forward passes
//! feed each other and only the last one backpropagates — the 3F1B pipeline
//! pattern of Fig. 2. The first two passes are built with `no_grad` so
//! autograd completion skips them.
//!
//! Each Evoformer layer = row attention (over residues) + column attention
//! (over sequences) + transition FFN, sharing one weight set across all
//! three passes (recycling reuses the same network).

use super::{table2, Model};
use crate::graph::sig::OpSignature;
use crate::graph::{DType, Graph, OpId, OpKind, PTensorId, TensorKind};
use crate::models::builder::ModelBuilder;

pub const N_SEQS: usize = 128;
pub const N_RES: usize = 256;
pub const N_PASSES: usize = 3;

/// Per-layer shared weight handles.
struct LayerWeights {
    wqkv_row: PTensorId,
    wo_row: PTensorId,
    wqkv_col: PTensorId,
    wo_col: PTensorId,
    fc1: PTensorId,
    fc2: PTensorId,
}

/// Build AlphaFold2 at Table-2 `scale` with the given global batch
/// (paper: 128).
pub fn alphafold2(scale: usize, batch: usize) -> Model {
    let cfg = table2("alphafold2", scale);
    let (l, c, a) = (cfg.layers, cfg.hidden, cfg.heads);
    let d = c / a;
    let (s, r) = (N_SEQS, N_RES);
    let tokens = s * r; // MSA activation is [b, s*r, c] flattened
    let ff = 6 * c; // transition + pair-stack compute folded in

    let mut mb = ModelBuilder::new();
    // Weights created once per layer, reused by all three passes.
    let weights: Vec<LayerWeights> = (0..l)
        .map(|li| LayerWeights {
            wqkv_row: mb.weight(&format!("e{li}.row.wqkv"), &[c, a, 3 * d]),
            wo_row: mb.weight(&format!("e{li}.row.wo"), &[a, d, c]),
            wqkv_col: mb.weight(&format!("e{li}.col.wqkv"), &[c, a, 3 * d]),
            wo_col: mb.weight(&format!("e{li}.col.wo"), &[a, d, c]),
            fc1: mb.weight(&format!("e{li}.fc1"), &[c, ff]),
            fc2: mb.weight(&format!("e{li}.fc2"), &[ff, c]),
        })
        .collect();

    let msa_in = mb.input("msa", &[batch, tokens, c]);
    let mut layers: Vec<Vec<OpId>> = vec![Vec::new(); l];
    let mut x = msa_in;
    for pass in 0..N_PASSES {
        let no_grad = pass + 1 < N_PASSES;
        for (li, w) in weights.iter().enumerate() {
            let ops = evoformer_layer(
                &mut mb.g,
                &format!("p{pass}e{li}"),
                x,
                w,
                li,
                batch,
                s,
                r,
                c,
                a,
                ff,
                no_grad,
            );
            // Returns (output, ops); re-borrow output from graph.
            x = mb
                .g
                .vtensor(mb.g.op(*ops.last().unwrap()).outputs[0])
                .ptensor;
            for &op in &ops {
                mb.tp_dim.insert(op, tp_dim_for(&mb.g, op));
            }
            layers[li].extend(ops);
        }
    }
    let (_, loss_op) = mb.loss("head", x, l, &[batch, tokens, c]);
    layers.last_mut().unwrap().push(loss_op);

    Model {
        graph: mb.g,
        name: format!("alphafold2-{scale}"),
        layers,
        emb_ops: Vec::new(),
        tp_dim: mb.tp_dim,
        coshard_dim: mb.coshard_dim,
        global_batch: batch,
    }
}

fn tp_dim_for(g: &Graph, op: OpId) -> &'static str {
    match g.op(op).kind {
        OpKind::Attention => "a",
        OpKind::Matmul => "a",
        _ => "s",
    }
}

/// One Evoformer layer for one pass, reusing the given weights.
#[allow(clippy::too_many_arguments)]
fn evoformer_layer(
    g: &mut Graph,
    name: &str,
    x: PTensorId,
    w: &LayerWeights,
    layer: usize,
    b: usize,
    s: usize,
    r: usize,
    c: usize,
    a: usize,
    ff: usize,
    no_grad: bool,
) -> Vec<OpId> {
    let d = c / a;
    let tokens = s * r;
    let mut ops = Vec::new();
    let mut add = |g: &mut Graph,
                   nm: &str,
                   kind: OpKind,
                   ins: Vec<PTensorId>,
                   out_shape: &[usize],
                   flops: f64,
                   sig: &str|
     -> PTensorId {
        let out = g.add_ptensor(
            &format!("{name}.{nm}.out"),
            out_shape,
            DType::F32,
            TensorKind::Activation,
        );
        let ivs: Vec<_> = ins.iter().map(|&p| g.full_view(p)).collect();
        let ov = g.full_view(out);
        let id = g.add_op(
            &format!("{name}.{nm}"),
            kind,
            ivs,
            vec![ov],
            flops,
            Some(OpSignature::parse(sig)),
            true,
            layer,
        );
        g.op_mut(id).no_grad = no_grad;
        ops.push(id);
        out
    };

    // Row attention: tokens attend within their row (r-long windows).
    let q1 = add(
        g,
        "row.qkv",
        OpKind::Matmul,
        vec![x, w.wqkv_row],
        &[b, tokens, a, 3 * d],
        2.0 * (b * tokens * c * 3 * c) as f64,
        "b s h, h a n -> b s a n | reduce h | batch b",
    );
    let at1 = add(
        g,
        "row.attn",
        OpKind::Attention,
        vec![q1],
        &[b, tokens, a, d],
        4.0 * (b * s * r * r * c) as f64,
        "b s a _ -> b s a _ | batch b",
    );
    let o1 = add(
        g,
        "row.proj",
        OpKind::Matmul,
        vec![at1, w.wo_row],
        &[b, tokens, c],
        2.0 * (b * tokens * c * c) as f64,
        "b s a d, a d h -> b s h | reduce a d | batch b",
    );
    // Column attention.
    let q2 = add(
        g,
        "col.qkv",
        OpKind::Matmul,
        vec![o1, w.wqkv_col],
        &[b, tokens, a, 3 * d],
        2.0 * (b * tokens * c * 3 * c) as f64,
        "b s h, h a n -> b s a n | reduce h | batch b",
    );
    let at2 = add(
        g,
        "col.attn",
        OpKind::Attention,
        vec![q2],
        &[b, tokens, a, d],
        4.0 * (b * r * s * s * c) as f64,
        "b s a _ -> b s a _ | batch b",
    );
    let o2 = add(
        g,
        "col.proj",
        OpKind::Matmul,
        vec![at2, w.wo_col],
        &[b, tokens, c],
        2.0 * (b * tokens * c * c) as f64,
        "b s a d, a d h -> b s h | reduce a d | batch b",
    );
    // Transition FFN.
    let f1 = add(
        g,
        "fc1",
        OpKind::Matmul,
        vec![o2, w.fc1],
        &[b, tokens, ff],
        2.0 * (b * tokens * c * ff) as f64,
        "b s k, k n -> b s n | reduce k | batch b",
    );
    let f2 = add(
        g,
        "fc2",
        OpKind::Matmul,
        vec![f1, w.fc2],
        &[b, tokens, c],
        2.0 * (b * tokens * ff * c) as f64,
        "b s k, k n -> b s n | reduce k | batch b",
    );
    let _ = f2;
    ops
}
