//! Shared model-building vocabulary: weights (with eagerly-created gradient
//! and optimizer-state pTensors plus an Adam op per weight, so sPrograms can
//! transform optimizer ops — paper Algorithm 1 line 6-7), linear layers,
//! attention blocks, layernorms and embeddings.

use crate::graph::sig::{sigs, OpSignature};
use crate::graph::{DType, Graph, OpId, OpKind, PTensorId, TensorKind, VTensorId};
use std::collections::HashMap;

/// Incrementally builds a model graph. Tracks the per-op tensor-parallel /
/// co-shard dims that the plan library consumes.
pub struct ModelBuilder {
    pub g: Graph,
    pub tp_dim: HashMap<OpId, &'static str>,
    pub coshard_dim: HashMap<OpId, &'static str>,
    /// Adam FLOPs per weight element (mul/add chains of the update rule).
    pub opt_flops_per_elem: f64,
}

impl Default for ModelBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelBuilder {
    pub fn new() -> ModelBuilder {
        ModelBuilder {
            g: Graph::new(),
            tp_dim: HashMap::new(),
            coshard_dim: HashMap::new(),
            opt_flops_per_elem: 10.0,
        }
    }

    /// Declare a trainable weight: creates the weight pTensor, its gradient,
    /// two Adam moment tensors, and the optimizer op
    /// `adam(w.grad, w, m, v) -> w`.
    pub fn weight(&mut self, name: &str, shape: &[usize]) -> PTensorId {
        let w = self.g.add_ptensor(name, shape, DType::F32, TensorKind::Weight);
        let wg = self.g.add_ptensor(
            &crate::trans::autograd::grad_name(name),
            shape,
            DType::F32,
            TensorKind::Gradient,
        );
        let m1 = self
            .g
            .add_ptensor(&format!("{name}.m"), shape, DType::F32, TensorKind::OptState);
        let m2 = self
            .g
            .add_ptensor(&format!("{name}.v"), shape, DType::F32, TensorKind::OptState);
        let numel: usize = shape.iter().product();
        let (gv, wv, m1v, m2v, wo) = (
            self.g.full_view(wg),
            self.g.full_view(w),
            self.g.full_view(m1),
            self.g.full_view(m2),
            self.g.full_view(w),
        );
        self.g.add_op(
            &format!("adam.{name}"),
            OpKind::Optimizer,
            vec![gv, wv, m1v, m2v],
            vec![wo],
            self.opt_flops_per_elem * numel as f64,
            Some(OpSignature::parse("p, p, p, p -> p")),
            false,
            0,
        );
        w
    }

    pub fn activation(&mut self, name: &str, shape: &[usize]) -> PTensorId {
        self.g
            .add_ptensor(name, shape, DType::F32, TensorKind::Activation)
    }

    pub fn input(&mut self, name: &str, shape: &[usize]) -> PTensorId {
        self.g.add_ptensor(name, shape, DType::F32, TensorKind::Input)
    }

    fn views(&mut self, pts: &[PTensorId]) -> Vec<VTensorId> {
        pts.iter().map(|&p| self.g.full_view(p)).collect()
    }

    /// `x[b,s,h] @ w[h,n] -> y[b,s,n]`.
    #[allow(clippy::too_many_arguments)]
    pub fn linear(
        &mut self,
        name: &str,
        x: PTensorId,
        layer: usize,
        batch: usize,
        seq: usize,
        h_in: usize,
        h_out: usize,
    ) -> (PTensorId, OpId) {
        let w = self.weight(&format!("{name}.w"), &[h_in, h_out]);
        let y = self.activation(&format!("{name}.out"), &[batch, seq, h_out]);
        let vs = self.views(&[x, w, y]);
        let flops = 2.0 * batch as f64 * seq as f64 * h_in as f64 * h_out as f64;
        let op = self.g.add_op(
            name,
            OpKind::Matmul,
            vec![vs[0], vs[1]],
            vec![vs[2]],
            flops,
            Some(sigs::linear()),
            true,
            layer,
        );
        (y, op)
    }

    /// Elementwise op over `[b,s,h]` (gelu / residual / dropout...). `tag`
    /// distinguishes *linear* elementwise ops ("add": backward needs no
    /// stashed input) from nonlinear ones ("gelu": stashes its input).
    pub fn eltwise(
        &mut self,
        name: &str,
        tag: &'static str,
        xs: &[PTensorId],
        layer: usize,
        shape: &[usize],
    ) -> (PTensorId, OpId) {
        let y = self.activation(&format!("{name}.out"), shape);
        let mut vs = self.views(xs);
        let yv = self.g.full_view(y);
        vs.push(yv);
        let numel: usize = shape.iter().product();
        let sig = if xs.len() == 1 { sigs::eltwise3() } else { sigs::eltwise3_bin() };
        let op = self.g.add_op(
            name,
            OpKind::Elementwise(tag),
            vs[..xs.len()].to_vec(),
            vec![vs[xs.len()]],
            2.0 * numel as f64,
            Some(sig),
            true,
            layer,
        );
        (y, op)
    }

    /// LayerNorm over the last dim of `[b,s,h]` (not partitionable on h).
    pub fn layernorm(
        &mut self,
        name: &str,
        x: PTensorId,
        layer: usize,
        shape: &[usize],
    ) -> (PTensorId, OpId) {
        let y = self.activation(&format!("{name}.out"), shape);
        let vs = self.views(&[x, y]);
        let numel: usize = shape.iter().product();
        let op = self.g.add_op(
            name,
            OpKind::LayerNorm,
            vec![vs[0]],
            vec![vs[1]],
            5.0 * numel as f64,
            Some(sigs::layernorm()),
            true,
            layer,
        );
        (y, op)
    }

    /// A full multi-head self-attention block over `x[b,s,h]` with `a`
    /// heads: qkv projection (weights `[h,a,3d]`), attention composite
    /// (`[b,s,a,3d] -> [b,s,a,d]`), output projection (`[a,d,h]`, reduced
    /// over `a d` — Megatron row parallelism falls out of the signature).
    ///
    /// `attn_flops` lets callers override the attention-composite cost
    /// (windowed attention in Swin, row/col attention in AlphaFold2).
    #[allow(clippy::too_many_arguments)]
    pub fn attention_block(
        &mut self,
        name: &str,
        x: PTensorId,
        layer: usize,
        batch: usize,
        seq: usize,
        hidden: usize,
        heads: usize,
        attn_flops: Option<f64>,
    ) -> (PTensorId, Vec<OpId>) {
        let d = hidden / heads;
        let (b, s, h, a) = (batch, seq, hidden, heads);
        let mut ops = Vec::new();

        // qkv: x[b,s,h] @ wqkv[h,a,3d] -> q3[b,s,a,3d]
        let wqkv = self.weight(&format!("{name}.wqkv"), &[h, a, 3 * d]);
        let q3 = self.activation(&format!("{name}.qkv"), &[b, s, a, 3 * d]);
        let vs = self.views(&[x, wqkv, q3]);
        let qkv_op = self.g.add_op(
            &format!("{name}.qkv"),
            OpKind::Matmul,
            vec![vs[0], vs[1]],
            vec![vs[2]],
            2.0 * b as f64 * s as f64 * h as f64 * (3 * h) as f64,
            Some(OpSignature::parse("b s h, h a n -> b s a n | reduce h | batch b")),
            true,
            layer,
        );
        self.tp_dim.insert(qkv_op, "a");
        self.coshard_dim.insert(qkv_op, "a");
        ops.push(qkv_op);

        // attention composite: q3[b,s,a,3d] -> att[b,s,a,d]
        let att = self.activation(&format!("{name}.att"), &[b, s, a, d]);
        let vs = self.views(&[q3, att]);
        let flops = attn_flops
            .unwrap_or(4.0 * b as f64 * s as f64 * s as f64 * h as f64);
        let att_op = self.g.add_op(
            &format!("{name}.attn"),
            OpKind::Attention,
            vec![vs[0]],
            vec![vs[1]],
            flops,
            Some(OpSignature::parse("b s a _ -> b s a _ | batch b")),
            true,
            layer,
        );
        self.tp_dim.insert(att_op, "a");
        self.coshard_dim.insert(att_op, "a");
        ops.push(att_op);

        // output projection: att[b,s,a,d] @ wo[a,d,h] -> y[b,s,h]
        let wo = self.weight(&format!("{name}.wo"), &[a, d, h]);
        let y = self.activation(&format!("{name}.proj"), &[b, s, h]);
        let vs = self.views(&[att, wo, y]);
        let proj_op = self.g.add_op(
            &format!("{name}.proj"),
            OpKind::Matmul,
            vec![vs[0], vs[1]],
            vec![vs[2]],
            2.0 * b as f64 * s as f64 * h as f64 * h as f64,
            Some(OpSignature::parse("b s a d, a d h -> b s h | reduce a d | batch b")),
            true,
            layer,
        );
        self.tp_dim.insert(proj_op, "a");
        self.coshard_dim.insert(proj_op, "a");
        ops.push(proj_op);

        (y, ops)
    }

    /// FFN block: `lin1 (h->f, column-parallel "n") -> gelu -> lin2 (f->h,
    /// row-parallel "k" with value-split output)`.
    #[allow(clippy::too_many_arguments)]
    pub fn ffn_block(
        &mut self,
        name: &str,
        x: PTensorId,
        layer: usize,
        batch: usize,
        seq: usize,
        hidden: usize,
        ff: usize,
    ) -> (PTensorId, Vec<OpId>) {
        let mut ops = Vec::new();
        let (y1, op1) = self.linear(&format!("{name}.fc1"), x, layer, batch, seq, hidden, ff);
        self.tp_dim.insert(op1, "n");
        self.coshard_dim.insert(op1, "n");
        ops.push(op1);
        let (y2, op2) =
            self.eltwise(&format!("{name}.gelu"), "gelu", &[y1], layer, &[batch, seq, ff]);
        self.tp_dim.insert(op2, "h"); // eltwise3 names the last dim "h"
        self.coshard_dim.insert(op2, "h");
        ops.push(op2);
        let (y3, op3) = self.linear(&format!("{name}.fc2"), y2, layer, batch, seq, ff, hidden);
        self.tp_dim.insert(op3, "k");
        self.coshard_dim.insert(op3, "k");
        ops.push(op3);
        (y3, ops)
    }

    /// A standard pre-LN transformer layer. Returns (output pTensor, fwd ops).
    #[allow(clippy::too_many_arguments)]
    pub fn transformer_layer(
        &mut self,
        name: &str,
        x: PTensorId,
        layer: usize,
        batch: usize,
        seq: usize,
        hidden: usize,
        heads: usize,
        ff: usize,
        attn_flops: Option<f64>,
    ) -> (PTensorId, Vec<OpId>) {
        let mut ops = Vec::new();
        let (n1, op) = self.layernorm(&format!("{name}.ln1"), x, layer, &[batch, seq, hidden]);
        ops.push(op);
        let (att, mut a_ops) = self.attention_block(
            &format!("{name}.at"),
            n1,
            layer,
            batch,
            seq,
            hidden,
            heads,
            attn_flops,
        );
        ops.append(&mut a_ops);
        let (r1, op) =
            self.eltwise(&format!("{name}.res1"), "add", &[x, att], layer, &[batch, seq, hidden]);
        ops.push(op);
        let (n2, op) = self.layernorm(&format!("{name}.ln2"), r1, layer, &[batch, seq, hidden]);
        ops.push(op);
        let (ffn, mut f_ops) =
            self.ffn_block(&format!("{name}.ff"), n2, layer, batch, seq, hidden, ff);
        ops.append(&mut f_ops);
        let (out, op) =
            self.eltwise(&format!("{name}.res2"), "add", &[r1, ffn], layer, &[batch, seq, hidden]);
        ops.push(op);
        (out, ops)
    }

    /// Vocab embedding lookup: `ids[b,s] , table[v,h] -> y[b,s,h]`, vocab
    /// dim "v" partitionable (vocab-parallel embedding ⇒ value-split output).
    #[allow(clippy::too_many_arguments)]
    pub fn embedding(
        &mut self,
        name: &str,
        ids: PTensorId,
        layer: usize,
        batch: usize,
        seq: usize,
        vocab: usize,
        hidden: usize,
    ) -> (PTensorId, OpId) {
        let table = self.weight(&format!("{name}.table"), &[vocab, hidden]);
        let y = self.activation(&format!("{name}.out"), &[batch, seq, hidden]);
        let vs = self.views(&[ids, table, y]);
        let op = self.g.add_op(
            name,
            OpKind::Embed,
            vec![vs[0], vs[1]],
            vec![vs[2]],
            // Lookup is bandwidth-bound; charge ~2 flops/output elem.
            2.0 * batch as f64 * seq as f64 * hidden as f64,
            Some(sigs::embed()),
            true,
            layer,
        );
        self.tp_dim.insert(op, "v");
        (y, op)
    }

    /// Cross-entropy head producing the scalar-ish loss.
    pub fn loss(
        &mut self,
        name: &str,
        x: PTensorId,
        layer: usize,
        shape: &[usize],
    ) -> (PTensorId, OpId) {
        let l = self.activation(&format!("{name}.loss"), &[shape[0]]);
        let xv = self.g.full_view(x);
        let lv = self.g.full_view(l);
        let numel: usize = shape.iter().product();
        let op = self.g.add_op(
            name,
            OpKind::CrossEntropy,
            vec![xv],
            vec![lv],
            5.0 * numel as f64,
            Some(OpSignature::parse("b s h -> b | batch b")),
            true,
            layer,
        );
        (l, op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weight_creates_optimizer_and_state() {
        let mut mb = ModelBuilder::new();
        mb.weight("w", &[64, 64]);
        let names: Vec<_> = mb.g.ptensors.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["w", "w.grad", "w.m", "w.v"]);
        let opt: Vec<_> = mb
            .g
            .live_ops()
            .filter(|o| o.kind == OpKind::Optimizer)
            .collect();
        assert_eq!(opt.len(), 1);
        assert_eq!(opt[0].inputs.len(), 4);
    }

    #[test]
    fn transformer_layer_flops_match_6nd() {
        // A transformer layer's fwd FLOPs ≈ 2 * params * tokens (plus
        // attention quadratic term).
        let mut mb = ModelBuilder::new();
        let (b, s, h) = (4, 128, 256);
        let x = mb.input("x", &[b, s, h]);
        let (_, ops) = mb.transformer_layer("l0", x, 0, b, s, h, 8, 4 * h, None);
        assert_eq!(ops.len(), 10); // 2 ln, 3 attn, 2 residual, 3 ffn
        let flops: f64 = ops.iter().map(|&o| mb.g.op(o).flops).sum();
        let params = mb.g.num_params() as f64;
        let tokens = (b * s) as f64;
        let expect = 2.0 * params * tokens + 4.0 * tokens * s as f64 * h as f64;
        assert!(
            (flops - expect).abs() < 0.15 * expect,
            "flops {flops:.3e} vs {expect:.3e}"
        );
    }

    #[test]
    fn attention_block_exposes_head_dim() {
        let mut mb = ModelBuilder::new();
        let x = mb.input("x", &[2, 16, 64]);
        let (_, ops) = mb.attention_block("at", x, 0, 2, 16, 64, 4, None);
        for &op in &ops {
            assert_eq!(mb.tp_dim[&op], "a");
            // All three ops can split along the head dim.
            assert!(mb.g.op(op).signature.as_ref().unwrap().can_split("a"));
        }
    }

    #[test]
    fn tp_split_on_heads_keeps_shapes_consistent() {
        use crate::trans::{op_trans, TransformAlgo};
        let mut mb = ModelBuilder::new();
        let x = mb.input("x", &[2, 16, 64]);
        let (_, ops) = mb.attention_block("at", x, 0, 2, 16, 64, 4, None);
        // Split each op 2-way on heads; qkv output shard [2,16,2,48]
        // feeds attention shard input exactly.
        let mut g = mb.g;
        for &op in &ops {
            op_trans(&mut g, op, &TransformAlgo::split("a", 2)).unwrap();
        }
        // proj outputs become value partials (reduce over a).
        let parts: Vec<_> = g
            .live_ops()
            .filter(|o| o.name.starts_with("at.proj/"))
            .map(|o| g.vtensor(o.outputs[0]).mask.vsplit.parts)
            .collect();
        assert_eq!(parts, vec![2, 2]);
    }
}
