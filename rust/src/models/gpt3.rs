//! GPT-3 (§6.1): homogeneous decoder-only transformer, evaluated by the
//! paper at sequence length 16384 (LongFormer-style long-document setting).
//! Table 2: {1.3B, 2.6B, 6.7B, 15B} over {24, 32, 32, 48} layers.

use super::{table2, Model, ModelBuilder};

/// GPT-2/3 BPE vocab (50257) padded to a multiple of 128 for even
/// vocab-parallel splits — the same padding Megatron-LM applies.
pub const GPT3_VOCAB: usize = 50_304;

/// Build GPT-3 at Table-2 `scale` (0..4) with the given global batch and
/// sequence length.
pub fn gpt3(scale: usize, batch: usize, seq: usize) -> Model {
    let cfg = table2("gpt3", scale);
    let (l, h, a) = (cfg.layers, cfg.hidden, cfg.heads);
    let mut mb = ModelBuilder::new();
    let ids = mb.input("ids", &[batch, seq]);
    let mut layers: Vec<Vec<crate::graph::OpId>> = Vec::new();

    let (mut x, emb_op) = mb.embedding("embed", ids, 0, batch, seq, GPT3_VOCAB, h);
    layers.push(vec![emb_op]);

    for li in 0..l {
        let (y, ops) = mb.transformer_layer(
            &format!("h{li}"),
            x,
            li + 1,
            batch,
            seq,
            h,
            a,
            4 * h,
            None,
        );
        layers.push(ops);
        x = y;
    }

    // LM head fused with the loss (avoids materializing [b,s,vocab]).
    let head_w = mb.weight("lm_head.w", &[GPT3_VOCAB, h]);
    let lossv = mb.activation("loss", &[batch]);
    let xv = mb.g.full_view(x);
    let wv = mb.g.full_view(head_w);
    let lv = mb.g.full_view(lossv);
    let head = mb.g.add_op(
        "lm_head",
        crate::graph::OpKind::CrossEntropy,
        vec![xv, wv],
        vec![lv],
        2.0 * batch as f64 * seq as f64 * h as f64 * GPT3_VOCAB as f64,
        Some(crate::graph::sig::OpSignature::parse(
            "b s h, v h -> b | reduce v h | batch b",
        )),
        true,
        l + 1,
    );
    mb.tp_dim.insert(head, "v");
    layers.push(vec![head]);

    Model {
        graph: mb.g,
        name: format!("gpt3-{scale}"),
        layers,
        emb_ops: Vec::new(),
        tp_dim: mb.tp_dim,
        coshard_dim: mb.coshard_dim,
        global_batch: batch,
    }
}
