//! Swin-Transformer V2 (§6.1): hierarchical vision transformer at input
//! resolution 1536×1536 (the paper's highest setting). Activation-heavy:
//! early stages hold ~150k patch tokens, which is what makes co-shard's
//! activation partitioning win over ZeRO-style weight sharding (Fig. 13).
//!
//! Structure: 4 stages; patch merging between stages quarters the sequence
//! and doubles the hidden size (base C from Table 2). Attention is windowed
//! (W×W tokens), so its FLOPs are linear in sequence length.

use super::{table2, Model, ModelBuilder};

/// Window size (tokens per side). Swin-V2 large-resolution setting.
pub const WINDOW: usize = 16;

/// Stage depths: Swin puts almost all layers in stage 3 (cf. Swin-L
/// [2,2,18,2] — scaled here so the depths sum to Table 2's layer count).
fn depths(total_layers: usize) -> [usize; 4] {
    assert!(total_layers >= 12);
    [2, 2, total_layers - 10, 6]
}

/// Build Swin at Table-2 `scale` with the given global batch and input
/// resolution (paper: 1536).
pub fn swin_transformer(scale: usize, batch: usize, resolution: usize) -> Model {
    let cfg = table2("swin", scale);
    swin_custom(cfg.layers, cfg.hidden, cfg.heads, batch, resolution)
}

/// Swin with explicit (layers, hidden, heads) — used by the Fig. 13 memory
/// sweep, whose model sizes (115M–1.3B) sit below Table 2's smallest column.
pub fn swin_custom(
    layers: usize,
    hidden: usize,
    heads: usize,
    batch: usize,
    resolution: usize,
) -> Model {
    let (l, c0, a0) = (layers, hidden, heads);
    let mut mb = ModelBuilder::new();
    let mut layers: Vec<Vec<crate::graph::OpId>> = Vec::new();

    // Patch embedding: 4x4 patches, 3 channels -> C.
    let seq0 = (resolution / 4) * (resolution / 4);
    let patches = mb.input("patches", &[batch, seq0, 48]);
    let (mut x, emb) = mb.linear("patch_embed", patches, 0, batch, seq0, 48, c0);
    let mut li = 0usize;
    layers.push(vec![emb]);

    let d = depths(l);
    let mut seq = seq0;
    let mut hidden = c0;
    // Heads double with hidden each stage, ending at Table 2's head count.
    let mut heads = (a0 / 8).max(1);
    for (stage, &depth) in d.iter().enumerate() {
        if stage > 0 {
            // Patch merging: seq /= 4, hidden *= 2 (linear 4C_prev -> 2C_prev).
            let merged_seq = seq / 4;
            let (y, op) = mb.linear(
                &format!("merge{stage}"),
                x,
                li + 1,
                batch,
                merged_seq,
                hidden * 4,
                hidden * 2,
            );
            layers.push(vec![op]);
            li += 1;
            x = y;
            seq = merged_seq;
            hidden *= 2;
            heads *= 2;
        }
        for bl in 0..depth {
            // Windowed attention: each token attends within a W^2 window.
            let win = WINDOW * WINDOW;
            let attn_flops =
                4.0 * batch as f64 * seq as f64 * win as f64 * hidden as f64;
            let (y, ops) = mb.transformer_layer(
                &format!("s{stage}b{bl}"),
                x,
                li + 1,
                batch,
                seq,
                hidden,
                heads.max(1),
                4 * hidden,
                Some(attn_flops),
            );
            layers.push(ops);
            li += 1;
            x = y;
        }
    }
    let (_, loss_op) = mb.loss("head", x, li + 1, &[batch, seq, hidden]);
    layers.push(vec![loss_op]);

    // Keep `layers` to exactly Table-2's layer count groups for stage math:
    // merge/embed/loss ops ride along with the nearest block.
    let mut grouped: Vec<Vec<crate::graph::OpId>> = Vec::new();
    for ops in layers {
        if grouped.is_empty() || grouped.len() < l && ops.len() > 1 {
            grouped.push(ops);
        } else if let Some(last) = grouped.last_mut() {
            last.extend(ops);
        }
    }

    Model {
        graph: mb.g,
        name: format!("swin-{l}l{c0}h"),
        layers: grouped,
        emb_ops: Vec::new(),
        tp_dim: mb.tp_dim,
        coshard_dim: mb.coshard_dim,
        global_batch: batch,
    }
}
