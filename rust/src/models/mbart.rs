//! mBART (§6.1): multilingual encoder-decoder with a 500k-entry vocabulary
//! (Zheng et al.'s large-vocab setting). The embedding table + tied LM head
//! hold gigabytes of weight but almost no compute, while the transformer
//! layers are the opposite — the imbalance that motivates the interlaced
//! pipeline (§3.4.2, Fig. 9).

use super::{table2, Model, ModelBuilder};

pub const MBART_VOCAB: usize = 500_000;

/// Build mBART at Table-2 `scale` with the given global batch and sequence
/// length (paper default: 1024).
pub fn mbart(scale: usize, batch: usize, seq: usize) -> Model {
    let cfg = table2("mbart", scale);
    let (l, h, a) = (cfg.layers, cfg.hidden, cfg.heads);
    let mut mb = ModelBuilder::new();
    let mut layers: Vec<Vec<crate::graph::OpId>> = Vec::new();
    let mut emb_ops = Vec::new();

    let ids = mb.input("ids", &[batch, seq]);
    let (mut x, emb) = mb.embedding("embed", ids, 0, batch, seq, MBART_VOCAB, h);
    emb_ops.push(emb);
    layers.push(vec![emb]);

    // Encoder-decoder stack modeled as `l` uniform transformer layers (the
    // decoder's cross-attention cost folds into the attention composite).
    for li in 0..l {
        let (y, ops) = mb.transformer_layer(
            &format!("h{li}"),
            x,
            li + 1,
            batch,
            seq,
            h,
            a,
            4 * h,
            None,
        );
        layers.push(ops);
        x = y;
    }

    // Tied LM head: reuses the embedding table (two readers of one weight —
    // autograd value-splits its gradient; the paper's §5 example).
    let table = mb
        .g
        .ptensors
        .iter()
        .find(|p| p.name == "embed.table")
        .unwrap()
        .id;
    let lossv = mb.activation("loss", &[batch]);
    let xv = mb.g.full_view(x);
    let wv = mb.g.full_view(table);
    let lv = mb.g.full_view(lossv);
    let head = mb.g.add_op(
        "lm_head",
        crate::graph::OpKind::CrossEntropy,
        vec![xv, wv],
        vec![lv],
        2.0 * batch as f64 * seq as f64 * h as f64 * MBART_VOCAB as f64,
        Some(crate::graph::sig::OpSignature::parse(
            "b s h, v h -> b | reduce v h | batch b",
        )),
        true,
        l + 1,
    );
    mb.tp_dim.insert(head, "v");
    emb_ops.push(head);
    layers.push(vec![head]);

    Model {
        graph: mb.g,
        name: format!("mbart-{scale}"),
        layers,
        emb_ops,
        tp_dim: mb.tp_dim,
        coshard_dim: mb.coshard_dim,
        global_batch: batch,
    }
}
