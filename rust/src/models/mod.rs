//! Model zoo: the four evaluation models of the paper (§6.1, Table 2) as
//! SuperScaler graphs, plus the builder vocabulary they share.
//!
//! Graphs are at *layer-operator* granularity: each transformer layer is a
//! handful of composite ops (qkv projection, attention, output projection,
//! two FFN linears, layernorms/residuals) — the granularity the paper's
//! sPrograms actually transform. Attention activations are shaped
//! `[b, s, a, d]` with the head dim `a` first-class, so co-shard and
//! Megatron tensor parallelism are plain `op-trans` splits (no reshapes).

pub mod builder;

pub mod alphafold;
mod gpt3;
mod mbart;
mod swin;

pub use alphafold::alphafold2;
pub use builder::ModelBuilder;
pub use gpt3::gpt3;
pub use mbart::mbart;
pub use swin::{swin_custom, swin_transformer};

use crate::graph::{Graph, OpId};
use std::collections::HashMap;

/// A built model: the forward graph + metadata plans need.
pub struct Model {
    pub graph: Graph,
    pub name: String,
    /// Forward ops grouped by layer, in execution order. Pipeline plans
    /// partition this list into stages.
    pub layers: Vec<Vec<OpId>>,
    /// Embedding-layer ops (mBART's imbalanced layers; empty otherwise).
    pub emb_ops: Vec<OpId>,
    /// Preferred tensor-parallel split dim per op (Megatron-style): "a" for
    /// attention pipelines, "n"/"k" for FFN column/row parallel, "v" for
    /// vocab-parallel embedding. Ops absent from the map are replicated
    /// under TP.
    pub tp_dim: HashMap<OpId, &'static str>,
    /// Dims that co-shard partitions (attention heads / FFN hidden), per op.
    pub coshard_dim: HashMap<OpId, &'static str>,
    pub global_batch: usize,
}

impl Model {
    pub fn num_params(&self) -> u64 {
        self.graph.num_params()
    }

    /// All forward op ids in layer order.
    pub fn fwd_ops(&self) -> Vec<OpId> {
        self.layers.iter().flatten().copied().collect()
    }
}

/// Table 2 of the paper: model architecture for each weak-scaling point.
/// `scale` indexes the GPU count {4 or fewer, 8, 16, 32}.
#[derive(Clone, Copy, Debug)]
pub struct ScaleConfig {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
}

/// Table 2 rows. `scale` in 0..4 picks the column.
pub fn table2(model: &str, scale: usize) -> ScaleConfig {
    let (l, h, a) = match model {
        "swin" => (
            [32, 48, 56, 64][scale],
            [512, 768, 1024, 1536][scale],
            [16, 24, 32, 32][scale],
        ),
        "gpt3" => (
            [24, 32, 32, 48][scale],
            [2048, 2560, 4096, 5120][scale],
            [32, 32, 32, 32][scale],
        ),
        "mbart" => (
            [24, 32, 48, 56][scale],
            [3072, 4096, 5120, 6144][scale],
            [16, 32, 32, 32][scale],
        ),
        "alphafold2" => (
            [48, 64, 96, 128][scale],
            [256, 512, 1024, 1024][scale],
            [8, 16, 32, 32][scale],
        ),
        other => panic!("unknown model '{other}'"),
    };
    ScaleConfig { layers: l, hidden: h, heads: a }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        let c = table2("gpt3", 2);
        assert_eq!((c.layers, c.hidden, c.heads), (32, 4096, 32));
        let c = table2("swin", 3);
        assert_eq!((c.layers, c.hidden, c.heads), (64, 1536, 32));
        let c = table2("alphafold2", 0);
        assert_eq!((c.layers, c.hidden, c.heads), (48, 256, 8));
    }

    #[test]
    fn gpt3_param_counts_are_in_band() {
        // Paper Table 2: GPT-3 {1.3B, 2.6B, 6.7B, 15B}.
        let want = [1.3e9, 2.6e9, 6.7e9, 15e9];
        for (scale, &w) in want.iter().enumerate() {
            let m = gpt3(scale, 8, 2048);
            let p = m.num_params() as f64;
            assert!(
                p > w * 0.75 && p < w * 1.35,
                "gpt3 scale {scale}: {p:.3e} params, want ~{w:.1e}"
            );
        }
    }

    #[test]
    fn alphafold_has_three_forward_passes() {
        let m = alphafold2(0, 4);
        let fwd: Vec<_> = m.graph.live_ops().filter(|o| o.is_forward).collect();
        let no_grad = fwd.iter().filter(|o| o.no_grad).count();
        let with_grad = fwd.len() - no_grad;
        // Two recycled passes have no_grad, the third (plus the loss head)
        // drives backward.
        assert!(no_grad > 0 && with_grad > 0);
        assert_eq!(no_grad, (with_grad - 1) * 2);
    }

    #[test]
    fn mbart_embedding_is_huge_and_tagged() {
        let m = mbart(1, 8, 1024);
        assert!(!m.emb_ops.is_empty());
        // 500k vocab x 4096 hidden x 4B >= 8 GB of embedding weight.
        let emb_w: u64 = m
            .graph
            .ptensors
            .iter()
            .filter(|p| p.name.contains("embed"))
            .map(|p| p.bytes())
            .sum();
        assert!(emb_w > 8_000_000_000, "embed bytes {emb_w}");
    }

    #[test]
    fn swin_layers_structured_in_stages() {
        let m = swin_transformer(0, 16, 1536);
        assert_eq!(m.layers.len(), 32);
        assert!(m.num_params() > 1.0e9 as u64 && m.num_params() < 3.0e9 as u64);
    }

    #[test]
    fn models_validate_on_one_device() {
        // Every zoo model, smallest scale, must pass scheduling validation
        // serially on one device after autograd.
        for name in ["gpt3", "swin", "mbart", "alphafold2"] {
            let mut m = match name {
                "gpt3" => gpt3(0, 2, 1024),
                "swin" => swin_transformer(0, 2, 512),
                "mbart" => mbart(0, 2, 512),
                _ => alphafold2(0, 2),
            };
            crate::trans::autograd::complete(&mut m.graph);
            let mut s = crate::schedule::Schedule::new();
            let ids = m.graph.live_op_ids();
            s.assign_all(&ids, 0);
            let v = crate::schedule::validate(&m.graph, &s);
            assert!(v.is_ok(), "{name}: {:?}", v.err().map(|e| e.to_string()));
        }
    }
}
