//! Cost model: devices, cluster fabric, collective communication costs.
//!
//! This substitutes for the paper's testbed (32× V100-32GB, 8 GPUs/server
//! on NVLink, servers on 100 Gbps InfiniBand — §6.1). All evaluation
//! numbers in the benches are produced against this model; the *shape* of
//! the paper's results (who wins, crossover points, OOM boundaries) depends
//! on the ratios encoded here — compute throughput vs. NVLink vs. IB — not
//! on absolute silicon speed.
//!
//! Bandwidth lookup is **topology-backed**: every path query
//! ([`Cluster::link`], [`Cluster::group_link`], [`Cluster::group_links`])
//! consults the cluster's [`crate::topo::Topology`] for the fabric
//! structure (which rack/rail a device injects into, whether a path
//! crosses the spine) while the *rates* stay here. A path is priced by its
//! **slowest hop** (bottleneck bandwidth, with per-hop shares for group
//! transfers) and its summed switch latency; cross-rack fat-tree and
//! cross-rail paths pay one extra hop of α. The `flat` topology takes the
//! exact legacy arithmetic branches, so the pre-topology model is
//! reproduced bitwise. Heterogeneous fleets route per-device pricing
//! through [`Cluster::device_spec`] / [`Cluster::mem_capacity`].
//!
//! Collective costs use the standard ring α–β model; `α` (latency) comes
//! from the slowest link in the group, `β` (inverse bandwidth) from the
//! bottleneck link. Compute costs use a saturation-efficiency curve: small
//! kernels run far from peak (this is what makes co-shard's smaller
//! operators slightly slower — Fig. 13's latency panel — while still
//! winning on memory).
//!
//! The analytic lower bound stays sound on any fabric by bounding from the
//! optimistic side: comm at the fastest link (`nvlink_bw`), compute at the
//! fastest device kind ([`Cluster::max_effective_flops`]).
//!
//! **Calibration** ([`calibrate`]): the CPU reference executor
//! ([`crate::exec::reference`]) measures real per-task wall durations when
//! it runs a plan; `cost::calibrate` aggregates measured-vs-analytic pairs
//! into per-task-kind ratios and within-kind log-deviation, giving every
//! simulated makespan an empirical error bar (`superscaler verify-exec`).

pub mod calibrate;

use crate::graph::{CollKind, Graph, TensorKind};
use crate::plans::{PlanKind, PlanSpec};
use crate::schedule::{DeviceId, CPU_DEVICE};
use crate::topo::{DeviceKind, TopoKind, Topology};
use crate::trans::autograd::BWD_FLOP_RATIO;

/// One contended physical transport of the cluster — the unit of the
/// discrete-event simulator's ([`crate::des`]) fair-sharing bandwidth
/// accounting. The α–β collective costs above assume every transfer has its
/// bottleneck link to itself; [`Cluster::group_links`] names the links a
/// transfer actually crosses so concurrent transfers that share one can be
/// slowed down proportionally.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub enum LinkId {
    /// A device's NVLink port (intra-server traffic).
    NvLink(DeviceId),
    /// A server's InfiniBand NIC (inter-server traffic).
    Nic(usize),
    /// A device's PCIe lane to the host (offload/swap traffic).
    Pcie(DeviceId),
    /// A rack's fat-tree uplink to the spine (cross-rack traffic). Shared
    /// by every transfer leaving or entering the rack.
    Up(usize),
    /// A rail switch's backbone in a rail-optimized pod. Same-rail traffic
    /// crosses one; cross-rail traffic bridges two.
    Rail(usize),
}

/// Per-device compute/memory characteristics (defaults: V100-ish).
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    /// Peak matmul throughput, FLOP/s (V100 tensor cores ~ 112e12 on fp16;
    /// the paper reports aggregate TFLOPS against this kind of peak).
    pub peak_flops: f64,
    /// Device memory capacity, bytes (V100: 32 GiB).
    pub mem_bytes: u64,
    /// Per-kernel launch/framework overhead, seconds.
    pub kernel_overhead: f64,
    /// FLOPs at which a kernel reaches half of peak efficiency — the
    /// saturation knee. Small ops ⇒ low utilization.
    pub sat_knee_flops: f64,
    /// Maximum achievable fraction of peak (real kernels don't hit 1.0).
    pub max_util: f64,
}

impl Default for DeviceSpec {
    fn default() -> Self {
        DeviceSpec {
            peak_flops: 112e12,
            mem_bytes: 32 * (1 << 30) as u64,
            kernel_overhead: 8e-6,
            sat_knee_flops: 2e9,
            max_util: 0.62,
        }
    }
}

impl DeviceSpec {
    /// Wall-clock seconds to execute a kernel of `flops` FLOPs.
    pub fn compute_time(&self, flops: f64) -> f64 {
        if flops <= 0.0 {
            return self.kernel_overhead;
        }
        let eff = self.max_util * flops / (flops + self.sat_knee_flops);
        self.kernel_overhead + flops / (self.peak_flops * eff.max(1e-6))
    }
}

/// Cluster model: `n_servers × gpus_per_server` devices on a fabric
/// [`Topology`] (flat by default), NVLink within a server. An empty
/// `server_kind` fleet means every device runs `spec`; a non-empty fleet
/// assigns one [`DeviceKind`] per server row.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub n_servers: usize,
    pub gpus_per_server: usize,
    pub spec: DeviceSpec,
    /// Host CPU characteristics (ZeRO-Offload's optimizer target).
    pub cpu_spec: DeviceSpec,
    /// Intra-server (NVLink) bandwidth per link, bytes/s.
    pub nvlink_bw: f64,
    /// Inter-server (IB) bandwidth per server NIC, bytes/s (100 Gbps).
    pub ib_bw: f64,
    /// Link latencies (α), seconds.
    pub nvlink_lat: f64,
    pub ib_lat: f64,
    /// Host<->device (PCIe) bandwidth for swap/offload, bytes/s.
    pub pcie_bw: f64,
    /// Fabric structure: which rack/rail each device injects into and
    /// which links a path crosses. Flat by default (legacy model).
    pub topo: Topology,
    /// Per-server device kinds; empty ⇒ homogeneous fleet of `spec`.
    pub server_kind: Vec<DeviceKind>,
}

impl Cluster {
    /// The paper's testbed shape: 8×V100 per server, NVLink 150 GB/s,
    /// 100 Gbps IB (12.5 GB/s), PCIe3 x16 ~ 12 GB/s.
    pub fn v100(n_gpus: usize) -> Cluster {
        let gpus_per_server = n_gpus.min(8);
        assert!(n_gpus % gpus_per_server == 0, "gpu count must tile servers");
        Self::with_shape(n_gpus / gpus_per_server, gpus_per_server)
    }

    /// V100 rates over an explicit `n_servers × gpus_per_server` shape,
    /// flat fabric, homogeneous fleet. The base every topology/fleet
    /// customization starts from (see [`crate::topo::build_cluster`]).
    pub fn with_shape(n_servers: usize, gpus_per_server: usize) -> Cluster {
        Cluster {
            n_servers,
            gpus_per_server,
            spec: DeviceSpec::default(),
            cpu_spec: DeviceSpec {
                peak_flops: 2e12,
                mem_bytes: 512 * (1 << 30) as u64,
                kernel_overhead: 2e-6,
                sat_knee_flops: 1e8,
                max_util: 0.5,
            },
            nvlink_bw: 150e9,
            ib_bw: 12.5e9,
            nvlink_lat: 3e-6,
            ib_lat: 12e-6,
            pcie_bw: 12e9,
            topo: Topology::flat(n_servers, gpus_per_server),
            server_kind: Vec::new(),
        }
    }

    pub fn num_gpus(&self) -> usize {
        self.n_servers * self.gpus_per_server
    }

    /// The CLI-facing fabric name (`flat`, `fat-tree:K`, `rail:R`).
    pub fn topology_label(&self) -> String {
        self.topo.label()
    }

    /// Compute/memory spec of a device: the CPU spec for the host, the
    /// server row's [`DeviceKind`] on heterogeneous fleets, `spec`
    /// otherwise.
    pub fn device_spec(&self, d: DeviceId) -> &DeviceSpec {
        if d == CPU_DEVICE {
            return &self.cpu_spec;
        }
        if self.server_kind.is_empty() {
            return &self.spec;
        }
        &self.server_kind[self.server_of(d)].spec
    }

    /// Memory capacity of a device (per-kind on heterogeneous fleets).
    pub fn mem_capacity(&self, d: DeviceId) -> u64 {
        self.device_spec(d).mem_bytes
    }

    /// Largest device memory anywhere in the fleet — the optimistic
    /// capacity the search's feasibility pre-filter must use: a plan is
    /// provably infeasible only if its static footprint exceeds even the
    /// biggest device (per-device placement is checked downstream).
    pub fn max_mem_bytes(&self) -> u64 {
        self.server_kind
            .iter()
            .map(|k| k.spec.mem_bytes)
            .max()
            .unwrap_or(self.spec.mem_bytes)
    }

    /// Fastest sustained FLOP rate of any device kind in the fleet
    /// (`peak_flops × max_util`). The lower bound's compute denominator:
    /// no kernel anywhere runs faster, so dividing mean per-device work by
    /// this stays an underestimate on heterogeneous fleets.
    pub fn max_effective_flops(&self) -> f64 {
        self.server_kind
            .iter()
            .map(|k| k.spec.peak_flops * k.spec.max_util)
            .fold(self.spec.peak_flops * self.spec.max_util, f64::max)
    }

    /// Server index of a device. The host CPU counts as its own "server"
    /// (one hop over PCIe from everything).
    pub fn server_of(&self, d: DeviceId) -> usize {
        if d == CPU_DEVICE {
            return usize::MAX;
        }
        assert!(d < self.num_gpus(), "bad device {d}");
        d / self.gpus_per_server
    }

    pub fn same_server(&self, a: DeviceId, b: DeviceId) -> bool {
        self.server_of(a) == self.server_of(b)
    }

    /// (bandwidth, latency) of the path between two devices: bottleneck
    /// bandwidth of the slowest hop on the resolved route, summed switch
    /// latency. Cross-rack / cross-rail paths pay one extra hop of α; on a
    /// flat fabric this is exactly the legacy two-case arithmetic.
    pub fn link(&self, a: DeviceId, b: DeviceId) -> (f64, f64) {
        if a == CPU_DEVICE || b == CPU_DEVICE {
            (self.pcie_bw, 10e-6)
        } else if a == b {
            (f64::INFINITY, 0.0)
        } else if self.same_server(a, b) {
            (self.nvlink_bw, self.nvlink_lat)
        } else if self.topo.cross_tier(a, b) {
            (self.ib_bw, 2.0 * self.ib_lat)
        } else {
            (self.ib_bw, self.ib_lat)
        }
    }

    /// Point-to-point transfer time for `bytes`.
    pub fn p2p_time(&self, from: DeviceId, to: DeviceId, bytes: u64) -> f64 {
        let (bw, lat) = self.link(from, to);
        if bw.is_infinite() {
            0.0
        } else {
            lat + bytes as f64 / bw
        }
    }

    /// Seconds to snapshot (or reload) `bytes` of device `d`'s state
    /// to/from the host over its PCIe lane — the existing host-link cost
    /// tier. The resilience layer ([`crate::fault`]) prices periodic
    /// checkpoints and the restart reload phase with this.
    pub fn checkpoint_time(&self, d: DeviceId, bytes: u64) -> f64 {
        self.p2p_time(d, CPU_DEVICE, bytes)
    }

    /// Bottleneck (bandwidth, latency) within a device group: IB if the
    /// group spans servers, NVLink otherwise. Inter-server collectives are
    /// constrained by whichever fabric hop is most shared by the group —
    /// the per-server NIC on flat fabrics, additionally the per-rack spine
    /// uplink on fat-trees (every cross-rack member in a rack shares its
    /// uplink), the per-rail switch on rail fabrics (where per-device NICs
    /// remove the server bottleneck). Cross-tier groups pay one extra hop
    /// of α.
    pub fn group_link(&self, group: &[DeviceId]) -> (f64, f64) {
        assert!(!group.is_empty());
        if group.contains(&CPU_DEVICE) {
            return (self.pcie_bw, 10e-6);
        }
        let s0 = self.server_of(group[0]);
        if group.iter().all(|&d| self.server_of(d) == s0) {
            return (self.nvlink_bw, self.nvlink_lat);
        }
        // Widest share of any fabric hop on the group's routes.
        let hop_share = |tier_of: &dyn Fn(DeviceId) -> usize| -> usize {
            let mut per_tier = std::collections::HashMap::new();
            for &d in group {
                *per_tier.entry(tier_of(d)).or_insert(0usize) += 1;
            }
            *per_tier.values().max().unwrap()
        };
        match self.topo.kind() {
            TopoKind::Flat => {
                // Members per server share the NIC (legacy arithmetic).
                let share = hop_share(&|d| self.server_of(d)) as f64;
                (self.ib_bw / share, self.ib_lat)
            }
            TopoKind::FatTree { .. } => {
                let nic_share = hop_share(&|d| self.server_of(d));
                let t0 = self.topo.rack_of(self.server_of(group[0]));
                let cross =
                    group.iter().any(|&d| self.topo.rack_of(self.server_of(d)) != t0);
                if cross {
                    // Rack members funnel through their rack's uplink, which
                    // can only be more shared than any single NIC in it.
                    let up_share = hop_share(&|d| self.topo.rack_of(self.server_of(d)));
                    let share = nic_share.max(up_share) as f64;
                    (self.ib_bw / share, 2.0 * self.ib_lat)
                } else {
                    (self.ib_bw / nic_share as f64, self.ib_lat)
                }
            }
            TopoKind::Rail { .. } => {
                // Per-device NICs: members sharing a rail share its switch.
                let share = hop_share(&|d| self.topo.rail_of(d)) as f64;
                let r0 = self.topo.rail_of(group[0]);
                let cross = group.iter().any(|&d| self.topo.rail_of(d) != r0);
                let lat = if cross { 2.0 * self.ib_lat } else { self.ib_lat };
                (self.ib_bw / share, lat)
            }
        }
    }

    /// Physical links a transfer among `group` occupies, deduplicated and
    /// sorted: PCIe lanes when the host participates, the members' NVLink
    /// ports within a server, and — via the fabric [`Topology`] — every
    /// fabric hop on the group's resolved routes when it crosses servers:
    /// the spanned servers' NICs (flat/fat-tree), the spanned racks' spine
    /// uplinks (cross-rack fat-tree), the members' rail switches (rail
    /// fabrics). A single-device "group" crosses nothing. This is the
    /// per-link capacity accounting the DES fair-shares: a transfer holds
    /// *every* link on its route, so two concurrent transfers whose link
    /// sets intersect anywhere — same NIC, same rack uplink, same rail —
    /// split the shared link's bandwidth and each runs at `1/n` of its solo
    /// rate while contended.
    pub fn group_links(&self, group: &[DeviceId]) -> Vec<LinkId> {
        let mut devs: Vec<DeviceId> = group.to_vec();
        devs.sort_unstable();
        devs.dedup();
        let mut out: Vec<LinkId> = if devs.contains(&CPU_DEVICE) {
            devs.iter()
                .filter(|&&d| d != CPU_DEVICE)
                .map(|&d| LinkId::Pcie(d))
                .collect()
        } else if devs.len() <= 1 {
            Vec::new()
        } else {
            let s0 = self.server_of(devs[0]);
            if devs.iter().all(|&d| self.server_of(d) == s0) {
                devs.iter().map(|&d| LinkId::NvLink(d)).collect()
            } else {
                let mut links = Vec::with_capacity(devs.len() * 2);
                self.topo.group_fabric_links(&devs, &mut links);
                links
            }
        };
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Ring-collective time over `group` where each participant holds
    /// `bytes` of payload (the conventional "per-rank buffer size").
    ///
    /// Formulas (n = group size, S = bytes, β = 1/bw):
    /// * all-reduce:      2·(n−1)/n · S·β  + 2(n−1)·α
    /// * all-gather:        (n−1)/n · n·S·β = (n−1)·S·β   (ranks hold shards
    ///   of S each; output is n·S)… we take S as the *shard* size.
    /// * reduce-scatter:  (n−1)·S_shard·β
    /// * all-to-all:      (n−1)/n · S·β
    /// * broadcast:       S·β (pipelined chain)
    /// * RD-scatter/gather: cross-group traffic of S bytes per member over
    ///   the inter-group bottleneck.
    pub fn collective_time(&self, kind: CollKind, group: &[DeviceId], bytes: u64) -> f64 {
        let n = group.len() as f64;
        if group.len() <= 1 {
            return 0.0;
        }
        let (bw, lat) = self.group_link(group);
        let s = bytes as f64;
        let beta = 1.0 / bw;
        match kind {
            CollKind::AllReduce => 2.0 * (n - 1.0) / n * s * beta + 2.0 * (n - 1.0) * lat,
            // `bytes` is the per-rank shard size for both: each rank ships
            // its shard around the ring (n−1) hops.
            CollKind::AllGather | CollKind::ReduceScatter => {
                (n - 1.0) * s * beta + (n - 1.0) * lat
            }
            CollKind::AllToAll => (n - 1.0) / n * s * beta + (n - 1.0) * lat,
            CollKind::Broadcast => s * beta + (n - 1.0) * lat,
            CollKind::RdScatter | CollKind::RdGather => {
                // Every member ships its payload across the group boundary.
                s * beta + lat
            }
        }
    }
}

/// Aggregate model quantities the analytic plan bound needs, extracted once
/// from a forward-only probe graph (before any transformation/autograd).
#[derive(Clone, Copy, Debug)]
pub struct ModelStats {
    /// Total forward FLOPs of the untransformed graph.
    pub fwd_flops: f64,
    /// Forward FLOPs of ops that participate in backward (`!no_grad`) —
    /// autograd will emit `BWD_FLOP_RATIO ×` this much backward work.
    pub grad_fwd_flops: f64,
    /// Total trainable-weight bytes.
    pub weight_bytes: u64,
    /// Total activation bytes of the forward graph (what a plan stashes
    /// for backward unless it recomputes).
    pub act_bytes: u64,
}

impl ModelStats {
    /// Extract stats from a forward-only model graph.
    pub fn of(g: &Graph) -> ModelStats {
        let mut fwd = 0.0;
        let mut grad = 0.0;
        for o in g.live_ops().filter(|o| o.is_forward) {
            fwd += o.flops;
            if !o.no_grad {
                grad += o.flops;
            }
        }
        let act = g
            .ptensors
            .iter()
            .filter(|p| p.kind == TensorKind::Activation)
            .map(|p| p.bytes())
            .sum();
        ModelStats {
            fwd_flops: fwd,
            grad_fwd_flops: grad,
            weight_bytes: g.weight_bytes(),
            act_bytes: act,
        }
    }
}

impl Cluster {
    /// Optimistic analytic lower bound (seconds) on the simulated iteration
    /// time of ANY plan built from `spec` — the dominance-pruning key of
    /// [`crate::search`]. Sound by construction, so pruning on it can never
    /// discard the true optimum:
    ///
    /// * compute: the forward + backward FLOPs must execute somewhere; the
    ///   busiest device carries at least the mean share, and no kernel runs
    ///   faster than the *fastest fleet kind's* `peak_flops × max_util`
    ///   ([`Cluster::max_effective_flops`] — the saturation curve's ceiling,
    ///   kept optimistic on heterogeneous fleets). Recompute, replication,
    ///   optimizer work and kernel-launch overheads only add to the true
    ///   time and are ignored.
    /// * communication: a data-parallel plan must synchronize each replica's
    ///   gradient shard; the simulator's synchronous-collective model blocks
    ///   every group member for the ring all-reduce, costed here at NVLink
    ///   bandwidth (the fastest link in the cluster) with zero latency and a
    ///   further 2× safety margin. Compute and communication both occupy the
    ///   device timeline, so the two bounds add.
    pub fn plan_time_lower_bound(&self, spec: &PlanSpec, stats: &ModelStats) -> f64 {
        let devices = spec.devices().max(1) as f64;
        let work = stats.fwd_flops + BWD_FLOP_RATIO * stats.grad_fwd_flops;
        let compute = work / devices / self.max_effective_flops();
        let dp = spec.dp.max(1);
        let comm = if dp > 1 {
            // Per-device gradient bytes that cross the DP group. Grid plans
            // hold 1/(pp·tp) of the weights per device; ZeRO-family plans
            // reduce-scatter instead of all-reduce (half the ring traffic).
            // Heterogeneous pipelines size the share by the *widest* stage
            // (the smallest per-device gradient buffer any stage holds under
            // the uniform-layer model) with an extra 2× margin on top of
            // the usual one, because FLOP-balanced stages of non-uniform
            // models can hold less than 1/pp of the weights — the sync term
            // must stay below every device's true sync time for dominance
            // pruning to remain sound.
            let w = stats.weight_bytes as f64;
            let (grad_bytes, margin) = match spec.kind {
                PlanKind::Zero3 | PlanKind::Zero3Offload => (w / 2.0, 0.5),
                PlanKind::Hetero => {
                    let wmax = spec
                        .stages
                        .as_ref()
                        .and_then(|st| st.iter().map(|s| s.width()).max())
                        .unwrap_or_else(|| spec.tp.max(1));
                    (w / (spec.pp.max(1) * wmax) as f64, 0.25)
                }
                _ => (w / (spec.pp.max(1) * spec.tp.max(1)) as f64, 0.5),
            };
            let n = dp as f64;
            margin * (2.0 * (n - 1.0) / n * grad_bytes / self.nvlink_bw)
        } else {
            0.0
        };
        compute + comm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_time_monotone_and_saturating() {
        let d = DeviceSpec::default();
        let t1 = d.compute_time(1e9);
        let t2 = d.compute_time(1e12);
        assert!(t2 > t1);
        // Efficiency at 1 TFLOP-kernel should beat efficiency at 1 GFLOP.
        let eff = |f: f64, t: f64| f / t / d.peak_flops;
        assert!(eff(1e12, t2) > eff(1e9, t1) * 2.0);
        // Never exceeds max_util.
        assert!(eff(1e14, d.compute_time(1e14)) <= d.max_util);
    }

    #[test]
    fn topology_classification() {
        let c = Cluster::v100(16); // 2 servers x 8
        assert_eq!(c.n_servers, 2);
        assert!(c.same_server(0, 7));
        assert!(!c.same_server(7, 8));
        assert_eq!(c.server_of(15), 1);
        let (bw_in, _) = c.link(0, 1);
        let (bw_out, _) = c.link(0, 8);
        assert!(bw_in > bw_out * 5.0, "NVLink must dwarf IB");
    }

    #[test]
    fn allreduce_cost_scales_with_group_span() {
        let c = Cluster::v100(16);
        let intra: Vec<usize> = (0..8).collect();
        let inter: Vec<usize> = (0..16).collect();
        let t_intra = c.collective_time(CollKind::AllReduce, &intra, 1 << 30);
        let t_inter = c.collective_time(CollKind::AllReduce, &inter, 1 << 30);
        assert!(
            t_inter > t_intra * 4.0,
            "cross-server all-reduce must be much slower ({t_intra} vs {t_inter})"
        );
    }

    #[test]
    fn nic_sharing_penalizes_wide_groups() {
        let c = Cluster::v100(16);
        let two: Vec<usize> = vec![0, 8]; // one per server
        let sixteen: Vec<usize> = (0..16).collect(); // 8 share each NIC
        let (bw2, _) = c.group_link(&two);
        let (bw16, _) = c.group_link(&sixteen);
        assert!((bw2 / bw16 - 8.0).abs() < 1e-6);
    }

    #[test]
    fn group_links_classify_transport() {
        let c = Cluster::v100(16);
        // Intra-server: one NVLink port per member.
        assert_eq!(c.group_links(&[0, 3]), vec![LinkId::NvLink(0), LinkId::NvLink(3)]);
        // Inter-server: one NIC per spanned server, however many members.
        assert_eq!(c.group_links(&[0, 1, 8]), vec![LinkId::Nic(0), LinkId::Nic(1)]);
        // Host traffic: PCIe lanes of the GPU members.
        assert_eq!(c.group_links(&[4, CPU_DEVICE]), vec![LinkId::Pcie(4)]);
        // Self-transfers cross nothing.
        assert!(c.group_links(&[5]).is_empty());
        // Two disjoint intra-server pairs share no links; two cross-server
        // transfers out of server 0 share its NIC.
        let a = c.group_links(&[0, 8]);
        let b = c.group_links(&[1, 9]);
        assert_eq!(a, b, "both cross the same pair of NICs");
    }

    #[test]
    fn p2p_time_zero_on_same_device() {
        let c = Cluster::v100(8);
        assert_eq!(c.p2p_time(3, 3, 1 << 20), 0.0);
        assert!(c.p2p_time(0, 1, 1 << 20) > 0.0);
    }

    #[test]
    fn singleton_collective_is_free() {
        let c = Cluster::v100(8);
        assert_eq!(c.collective_time(CollKind::AllReduce, &[2], 1 << 20), 0.0);
    }

    #[test]
    fn cpu_link_uses_pcie() {
        let c = Cluster::v100(8);
        let (bw, _) = c.link(0, CPU_DEVICE);
        assert_eq!(bw, c.pcie_bw);
    }

    #[test]
    fn plan_lower_bound_never_exceeds_simulated_time() {
        use crate::materialize::CommMode;
        use crate::plans::registry;
        let c = Cluster::v100(4);
        let stats = ModelStats::of(&crate::models::gpt3(0, 8, 256).graph);
        let specs = [
            ("megatron", PlanSpec { pp: 4, micro: 4, ..PlanSpec::new(PlanKind::Megatron) }),
            ("megatron", PlanSpec { dp: 2, tp: 2, ..PlanSpec::new(PlanKind::Megatron) }),
            ("megatron", PlanSpec { dp: 4, ..PlanSpec::new(PlanKind::Megatron) }),
        ];
        for (name, spec) in specs {
            let out = registry::build(name, &crate::models::gpt3(0, 8, 256), &spec).unwrap();
            let r = crate::sim::run(&out.graph, &out.schedule, &c, CommMode::InterRvd).unwrap();
            let lb = c.plan_time_lower_bound(&spec, &stats);
            assert!(lb > 0.0);
            assert!(lb <= r.makespan, "{}: lb {} > simulated {}", spec.label(), lb, r.makespan);
        }
    }

    #[test]
    fn hetero_dp_bound_adds_sync_term_below_grid_share() {
        use crate::plans::StageSpec;
        let c = Cluster::v100(8);
        let stats = ModelStats::of(&crate::models::gpt3(0, 8, 256).graph);
        let rep = PlanSpec::hetero_dp(2, vec![StageSpec::tp(2), StageSpec::tp(2)], 2);
        let flat = PlanSpec::hetero(vec![StageSpec::tp(4), StageSpec::tp(4)], 2);
        assert_eq!(rep.devices(), flat.devices());
        let br = c.plan_time_lower_bound(&rep, &stats);
        let bf = c.plan_time_lower_bound(&flat, &stats);
        assert!(br > bf, "dp > 1 hetero bound must carry a gradient-sync term: {br} vs {bf}");
        // The hetero sync share carries an extra margin vs the equal-shape
        // megatron grid (uneven stage weights must never make it unsound).
        let mg = PlanSpec { dp: 2, pp: 2, tp: 2, micro: 2, ..PlanSpec::new(PlanKind::Megatron) };
        assert!(br <= c.plan_time_lower_bound(&mg, &stats));
    }

    #[test]
    fn fat_tree_reprices_cross_rack_paths() {
        // 4 servers × 4 GPUs, 2 servers per rack.
        let mut c = Cluster::with_shape(4, 4);
        c.topo = Topology::fat_tree(4, 4, 2).unwrap();
        // Point-to-point: cross-rack pays the extra switch hop of α.
        let (_, lat_in) = c.link(0, 4); // s0 -> s1, same rack
        let (_, lat_x) = c.link(0, 8); // s0 -> s2, cross rack
        assert_eq!(lat_x, 2.0 * lat_in);
        // Collective: a cross-rack group is slower than an equal-size
        // in-rack group (uplink sharing + extra α).
        let in_rack: Vec<usize> = (0..8).collect(); // racks {s0,s1}
        let cross: Vec<usize> = (0..4).chain(8..12).collect(); // s0 + s2
        let t_in = c.collective_time(CollKind::AllReduce, &in_rack, 1 << 26);
        let t_x = c.collective_time(CollKind::AllReduce, &cross, 1 << 26);
        assert!(t_x > t_in, "cross-rack all-reduce must cost more: {t_x} vs {t_in}");
        // Link sets: cross-rack transfers hold both racks' uplinks.
        assert_eq!(
            c.group_links(&[0, 8]),
            vec![LinkId::Nic(0), LinkId::Nic(2), LinkId::Up(0), LinkId::Up(1)]
        );
        // In-rack transfers never touch the spine.
        assert_eq!(c.group_links(&[0, 4]), vec![LinkId::Nic(0), LinkId::Nic(1)]);
    }

    #[test]
    fn rail_fabric_replaces_nics_with_rails() {
        let mut c = Cluster::with_shape(2, 4);
        c.topo = Topology::rail_optimized(2, 4, 2).unwrap();
        // Same-rail inter-server transfer crosses one rail switch.
        assert_eq!(c.group_links(&[0, 4]), vec![LinkId::Rail(0)]);
        // Cross-rail bridges both rails.
        assert_eq!(c.group_links(&[0, 5]), vec![LinkId::Rail(0), LinkId::Rail(1)]);
        // Rail sharing: 2 members on rail 0 halve its bandwidth.
        let (bw_two, _) = c.group_link(&[0, 4]);
        let (bw_four, _) = c.group_link(&[0, 2, 4, 6]); // all on rail 0
        assert!((bw_two / bw_four - 2.0).abs() < 1e-9);
    }

    #[test]
    fn flat_topology_is_bitwise_legacy() {
        // with_shape + flat topo must reproduce v100's link sets and rates
        // exactly (the golden-fixture guarantee).
        let c = Cluster::v100(16);
        assert!(c.topo.is_flat());
        assert_eq!(c.topology_label(), "flat");
        for (a, b) in [(0, 1), (0, 8), (3, CPU_DEVICE), (5, 5)] {
            let (bw, lat) = c.link(a, b);
            let expect = if a == b {
                (f64::INFINITY, 0.0)
            } else if b == CPU_DEVICE {
                (c.pcie_bw, 10e-6)
            } else if a / 8 == b / 8 {
                (c.nvlink_bw, c.nvlink_lat)
            } else {
                (c.ib_bw, c.ib_lat)
            };
            assert_eq!((bw, lat), expect, "link({a},{b})");
        }
    }

    #[test]
    fn hetero_fleet_prices_per_device() {
        let c = crate::topo::build_cluster(16, None, "flat", Some("v100:8,h100:8")).unwrap();
        // Server 0 is V100, server 1 is H100.
        assert!(c.device_spec(12).peak_flops > c.device_spec(4).peak_flops * 5.0);
        assert_eq!(c.mem_capacity(4), 32 * (1 << 30) as u64);
        assert_eq!(c.mem_capacity(12), 80 * (1 << 30) as u64);
        assert_eq!(c.max_mem_bytes(), 80 * (1 << 30) as u64);
        // The bound's compute ceiling follows the fastest kind.
        let hom = Cluster::v100(16);
        assert!(c.max_effective_flops() > hom.max_effective_flops() * 5.0);
        // CPU stays the CPU.
        assert_eq!(c.device_spec(CPU_DEVICE).peak_flops, c.cpu_spec.peak_flops);
    }

    #[test]
    fn hetero_lower_bound_stays_below_fastest_device_time() {
        // On a mixed fleet the bound divides by the fastest kind's rate —
        // it must only ever shrink vs the homogeneous bound (soundness).
        let stats = ModelStats::of(&crate::models::gpt3(0, 8, 256).graph);
        let spec = PlanSpec { dp: 2, tp: 2, ..PlanSpec::new(PlanKind::Megatron) };
        let hom = Cluster::v100(16);
        let het = crate::topo::build_cluster(16, None, "flat", Some("v100:8,h100:8")).unwrap();
        assert!(het.plan_time_lower_bound(&spec, &stats) <= hom.plan_time_lower_bound(&spec, &stats));
    }

    #[test]
    fn prop_collective_costs_positive_and_monotone_in_bytes() {
        crate::util::prop::check("collective-cost", 200, |g| {
            let c = Cluster::v100(*g.rng.choose(&[8usize, 16, 32]));
            let n = g.int(2, c.num_gpus() + 1);
            let group: Vec<usize> = (0..n).collect();
            let kind = *g.rng.choose(&[
                CollKind::AllReduce,
                CollKind::AllGather,
                CollKind::ReduceScatter,
                CollKind::AllToAll,
                CollKind::Broadcast,
            ]);
            let b1 = g.int(1, 1 << 20) as u64;
            let b2 = b1 * 2;
            let t1 = c.collective_time(kind, &group, b1);
            let t2 = c.collective_time(kind, &group, b2);
            if t1 <= 0.0 {
                return Err(format!("{kind:?} non-positive time {t1}"));
            }
            if t2 < t1 {
                return Err(format!("{kind:?} not monotone in bytes"));
            }
            Ok(())
        });
    }
}
