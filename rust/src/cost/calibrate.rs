//! Cost-model calibration from reference-executor measurements.
//!
//! The reference executor ([`crate::exec::reference`]) records, for every
//! task it runs, the measured CPU wall duration next to the analytic
//! prediction the materializer priced the task at (V100-profile compute
//! and NVLink/IB transfer times). This module aggregates those pairs into
//! per-task-kind ratios — the error bar the ROADMAP's "close the
//! sim-vs-real gap" track asks for.
//!
//! Interpretation note: the measured tier is a single-threaded-per-device
//! CPU interpreter and the analytic tier prices datacenter GPUs, so the
//! absolute `ratio` (measured / predicted) is expected to be large; the
//! signal is its *consistency*. `log_sigma` reports the standard deviation
//! of `ln(measured/predicted)` within a kind: a small sigma means the
//! analytic model ranks tasks of that kind faithfully (durations are off
//! by one multiplicative constant), which is exactly what plan *search*
//! needs from a cost model.

use crate::util::json::Value;

/// One executed task's (measured, predicted) duration pair.
#[derive(Clone, Debug)]
pub struct TaskSample {
    /// Task-kind tag: `compute:<op-kind>`, `p2p`, `collective:allreduce`.
    pub kind: String,
    /// The task's trace label (op name / transfer name).
    pub label: String,
    /// Measured wall duration, seconds.
    pub measured: f64,
    /// Analytic `cost::` prediction, seconds.
    pub predicted: f64,
}

/// Aggregated measured-vs-analytic comparison for one task kind.
#[derive(Clone, Debug)]
pub struct KindRow {
    pub kind: String,
    pub n: usize,
    pub measured_total: f64,
    pub predicted_total: f64,
    /// measured_total / predicted_total (the calibration constant).
    pub ratio: f64,
    /// Std-dev of per-task `ln(measured/predicted)` — the model's
    /// within-kind consistency (0 = perfectly proportional).
    pub log_sigma: f64,
}

/// The calibration report `verify-exec` emits.
#[derive(Clone, Debug, Default)]
pub struct CalibrationReport {
    pub rows: Vec<KindRow>,
    pub n_samples: usize,
    pub measured_total: f64,
    pub predicted_total: f64,
    pub overall_ratio: f64,
}

/// Aggregate task samples into per-kind calibration rows.
pub fn calibrate(samples: &[TaskSample]) -> CalibrationReport {
    let mut kinds: Vec<String> = samples.iter().map(|s| s.kind.clone()).collect();
    kinds.sort();
    kinds.dedup();
    let mut rows = Vec::new();
    let (mut mt, mut pt) = (0.0, 0.0);
    for kind in kinds {
        let of_kind: Vec<&TaskSample> = samples.iter().filter(|s| s.kind == kind).collect();
        let measured: f64 = of_kind.iter().map(|s| s.measured).sum();
        let predicted: f64 = of_kind.iter().map(|s| s.predicted).sum();
        mt += measured;
        pt += predicted;
        let logs: Vec<f64> = of_kind
            .iter()
            .filter(|s| s.measured > 0.0 && s.predicted > 0.0)
            .map(|s| (s.measured / s.predicted).ln())
            .collect();
        let log_sigma = if logs.len() > 1 {
            let mean = logs.iter().sum::<f64>() / logs.len() as f64;
            (logs.iter().map(|l| (l - mean).powi(2)).sum::<f64>() / logs.len() as f64).sqrt()
        } else {
            0.0
        };
        rows.push(KindRow {
            kind,
            n: of_kind.len(),
            measured_total: measured,
            predicted_total: predicted,
            ratio: if predicted > 0.0 { measured / predicted } else { f64::INFINITY },
            log_sigma,
        });
    }
    CalibrationReport {
        rows,
        n_samples: samples.len(),
        measured_total: mt,
        predicted_total: pt,
        overall_ratio: if pt > 0.0 { mt / pt } else { f64::INFINITY },
    }
}

impl CalibrationReport {
    /// Human-readable table for the CLI.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<24} {:>6} {:>12} {:>12} {:>10} {:>9}\n",
            "task kind", "n", "measured s", "analytic s", "ratio", "log_sigma"
        ));
        for r in &self.rows {
            out.push_str(&format!(
                "{:<24} {:>6} {:>12.6} {:>12.6} {:>10.2} {:>9.3}\n",
                r.kind, r.n, r.measured_total, r.predicted_total, r.ratio, r.log_sigma
            ));
        }
        out.push_str(&format!(
            "{:<24} {:>6} {:>12.6} {:>12.6} {:>10.2}\n",
            "total", self.n_samples, self.measured_total, self.predicted_total, self.overall_ratio
        ));
        out
    }

    /// JSON shape carried in `BENCH_exec.json`.
    pub fn to_json(&self) -> Value {
        Value::obj([
            ("n_samples", Value::Num(self.n_samples as f64)),
            ("measured_total", Value::Num(self.measured_total)),
            ("predicted_total", Value::Num(self.predicted_total)),
            ("overall_ratio", Value::Num(self.overall_ratio)),
            (
                "kinds",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Value::obj([
                                ("kind", Value::Str(r.kind.clone())),
                                ("n", Value::Num(r.n as f64)),
                                ("measured_total", Value::Num(r.measured_total)),
                                ("predicted_total", Value::Num(r.predicted_total)),
                                ("ratio", Value::Num(r.ratio)),
                                ("log_sigma", Value::Num(r.log_sigma)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(kind: &str, measured: f64, predicted: f64) -> TaskSample {
        TaskSample { kind: kind.into(), label: "t".into(), measured, predicted }
    }

    #[test]
    fn calibrate_groups_by_kind_and_computes_ratios() {
        let rep = calibrate(&[
            s("compute:matmul", 2.0, 1.0),
            s("compute:matmul", 4.0, 2.0),
            s("p2p", 1.0, 4.0),
        ]);
        assert_eq!(rep.rows.len(), 2);
        let mm = rep.rows.iter().find(|r| r.kind == "compute:matmul").unwrap();
        assert_eq!(mm.n, 2);
        assert!((mm.ratio - 2.0).abs() < 1e-12);
        // Both matmul samples have the same measured/predicted ratio.
        assert!(mm.log_sigma < 1e-12);
        let p2p = rep.rows.iter().find(|r| r.kind == "p2p").unwrap();
        assert!((p2p.ratio - 0.25).abs() < 1e-12);
        assert_eq!(rep.n_samples, 3);
        assert!((rep.overall_ratio - 7.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn report_renders_and_serializes() {
        let rep = calibrate(&[s("p2p", 1.0, 2.0)]);
        let txt = rep.render();
        assert!(txt.contains("p2p"));
        let j = rep.to_json();
        assert_eq!(j.get("n_samples").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("kinds").and_then(|v| v.as_arr()).map(|a| a.len()), Some(1));
    }
}
