//! Flag-style CLI parsing (`--key value`, `--flag`, positional args).
//! Shared by the main binary, examples, and every bench harness.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]). `--key value` and
    /// `--key=value` both work; a `--key` followed by another `--...` or
    /// end-of-args is a boolean flag stored as `"true"`.
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    let takes_value = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if takes_value {
                        out.flags.insert(body.to_string(), it.next().unwrap());
                    } else {
                        out.flags.insert(body.to_string(), "true".to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("false") | Some("0") | Some("no") => false,
            _ => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = args(&["--devices", "8", "--plan", "coshard"]);
        assert_eq!(a.usize("devices", 1), 8);
        assert_eq!(a.str("plan", "dp"), "coshard");
    }

    #[test]
    fn parses_equals_form() {
        let a = args(&["--devices=16"]);
        assert_eq!(a.usize("devices", 1), 16);
    }

    #[test]
    fn boolean_flags() {
        let a = args(&["--verbose", "--out", "x.csv"]);
        assert!(a.bool("verbose", false));
        assert!(!a.bool("quiet", false));
        assert_eq!(a.str("out", ""), "x.csv");
    }

    #[test]
    fn positional_args() {
        let a = args(&["run", "--n", "3", "extra"]);
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.usize("n", 0), 3);
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.usize("x", 7), 7);
        assert_eq!(a.f64("y", 2.5), 2.5);
        assert!(a.bool("z", true));
    }
}
