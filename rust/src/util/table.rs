//! Aligned console tables + CSV output for the bench harnesses. Every bench
//! prints the same rows/series the paper's figure or table reports, and
//! mirrors them to a CSV so results can be plotted.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table with a title.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: ToString, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let r: Vec<String> = cells.into_iter().map(|c| c.to_string()).collect();
        assert_eq!(
            r.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(r);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}", c, w = widths[i] + 2);
                let _ = i; // keep clippy quiet about last-pad
            }
            out.push('\n');
        };
        line(&self.header, &mut out);
        let total: usize = widths.iter().map(|w| w + 2).sum::<usize>().max(4);
        let _ = writeln!(out, "{}", "-".repeat(total.min(120)));
        for r in &self.rows {
            line(r, &mut out);
        }
        let _ = ncol;
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write the table as CSV (header + rows). Creates parent dirs.
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", csv_row(&self.header))?;
        for r in &self.rows {
            writeln!(f, "{}", csv_row(r))?;
        }
        Ok(())
    }
}

fn csv_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Wall-clock timing helper for the bench harnesses: runs `f` `warmup+iters`
/// times, returns (mean_secs, min_secs) over the measured iterations.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut total = 0.0;
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = std::time::Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        best = best.min(dt);
    }
    (total / iters.max(1) as f64, best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(["a", "1"]);
        t.row(["longer-name", "2.5"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer-name"));
        // Both data rows start the value column at the same offset.
        let lines: Vec<&str> = s.lines().collect();
        let col = lines[1].find("value").unwrap();
        assert_eq!(lines[3].find('1').unwrap(), col);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(
            csv_row(&["a,b".into(), "c\"d".into(), "e".into()]),
            "\"a,b\",\"c\"\"d\",e"
        );
    }

    #[test]
    fn csv_roundtrip_to_disk() {
        let mut t = Table::new("t", &["k", "v"]);
        t.row(["x", "1"]);
        let p = std::env::temp_dir().join("superscaler_table_test.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "k,v\nx,1\n");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn time_it_positive() {
        let (mean, best) = time_it(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean >= best && best >= 0.0);
    }
}
