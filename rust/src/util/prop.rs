//! Minimal property-testing harness (proptest is not in the offline vendor
//! set). A property is a closure over a [`crate::util::rng::Rng`]-driven
//! `Gen`; `check` runs it many times with distinct seeds and, on failure,
//! reports the failing seed so the case can be replayed deterministically.
//!
//! Used by the coordinator invariants: mask algebra, scheduling validation
//! (cycle detection / topo completion), RVD search, and simulator
//! conservation laws.

use crate::util::rng::Rng;

/// Random-input generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    /// Integer in `[lo, hi)`.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// A "reasonable" dimension size, biased toward small values but
    /// occasionally large — good at shaking out off-by-one splits.
    pub fn dim(&mut self) -> usize {
        match self.rng.below(4) {
            0 => self.int(1, 8),
            1 => self.int(8, 64),
            2 => self.int(64, 512),
            _ => self.int(512, 4096),
        }
    }

    /// A divisor of `n` (uniform over divisors).
    pub fn divisor_of(&mut self, n: usize) -> usize {
        let divs: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
        *self.rng.choose(&divs)
    }

    /// Power of two in `[1, max]`.
    pub fn pow2(&mut self, max: usize) -> usize {
        let maxexp = (usize::BITS - 1 - max.leading_zeros()) as usize;
        1 << self.int(0, maxexp + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_in(lo, hi)
    }

    /// Vector of length in `[0, max_len)` built by `f`.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.int(0, max_len);
        (0..n).map(|_| f(self)).collect()
    }
}

/// Run `prop` for `cases` random cases. Panics with the failing seed on the
/// first property violation (properties signal failure via `Err(msg)`).
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    // Base seed can be overridden for replay: SUPERSCALER_PROP_SEED=<n>.
    let base: u64 = std::env::var("SUPERSCALER_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5c41e7_u64);
    let replay = std::env::var("SUPERSCALER_PROP_SEED").is_ok();
    let n = if replay { 1 } else { cases };
    for case in 0..n {
        let seed = base.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut gen = Gen {
            rng: Rng::new(seed),
            size: case,
        };
        if let Err(msg) = prop(&mut gen) {
            panic!(
                "property '{name}' failed on case {case}: {msg}\n  replay with SUPERSCALER_PROP_SEED={seed}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 100, |g| {
            let a = g.int(0, 1000);
            let b = g.int(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a} + {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn divisor_divides() {
        check("divisor", 200, |g| {
            let n = g.int(1, 400);
            let d = g.divisor_of(n);
            if n % d == 0 {
                Ok(())
            } else {
                Err(format!("{d} does not divide {n}"))
            }
        });
    }

    #[test]
    fn pow2_is_power_of_two() {
        check("pow2", 100, |g| {
            let p = g.pow2(64);
            if p.is_power_of_two() && p <= 64 {
                Ok(())
            } else {
                Err(format!("{p}"))
            }
        });
    }
}
