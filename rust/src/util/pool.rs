//! Tiny scoped thread pool. The real executor ([`crate::exec`]) runs one
//! worker per simulated device; benches use `par_map` to sweep
//! configurations. Built on `std::thread::scope` — no external async
//! runtime is available offline, and a blocking pool is the right shape for
//! a BSP-style training loop anyway.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Run `f(i)` for `i in 0..n` on up to `workers` OS threads, collecting
/// results in order. Panics in a task propagate to the caller.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, workers: usize, f: F) -> Vec<T> {
    assert!(workers > 0);
    let workers = workers.min(n.max(1));
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        out[i] = slot.into_inner().unwrap();
    }
    out.into_iter().map(|o| o.expect("task did not run")).collect()
}

/// A reusable barrier for N participants (std::sync::Barrier exists, but we
/// also need a *sense-reversing* variant that returns a monotonically
/// increasing generation, used by the executor's collective engine to match
/// concurrent collective calls to the right round).
pub struct GenBarrier {
    n: usize,
    state: Mutex<(usize, u64)>, // (arrived, generation)
    cv: Condvar,
}

impl GenBarrier {
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(GenBarrier {
            n,
            state: Mutex::new((0, 0)),
            cv: Condvar::new(),
        })
    }

    /// Block until all `n` participants arrive. Returns the generation index
    /// of the completed round; exactly one caller per round gets
    /// `leader = true`.
    pub fn wait(&self) -> (u64, bool) {
        let mut st = self.state.lock().unwrap();
        let gen = st.1;
        st.0 += 1;
        if st.0 == self.n {
            st.0 = 0;
            st.1 += 1;
            self.cv.notify_all();
            (gen, true)
        } else {
            while st.1 == gen {
                st = self.cv.wait(st).unwrap();
            }
            (gen, false)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_map_results_in_order() {
        let v = par_map(100, 8, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_worker() {
        assert_eq!(par_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn par_map_empty() {
        let v: Vec<usize> = par_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn barrier_rounds_have_one_leader() {
        let b = GenBarrier::new(4);
        let leaders = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let b = b.clone();
                let leaders = leaders.clone();
                s.spawn(move || {
                    for round in 0..50u64 {
                        let (gen, lead) = b.wait();
                        assert_eq!(gen, round);
                        if lead {
                            leaders.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        assert_eq!(leaders.load(Ordering::Relaxed), 50);
    }
}
