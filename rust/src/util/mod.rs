//! Self-contained utilities: the offline vendor set has no tokio / clap /
//! serde / criterion / proptest, so the pieces of those we need are
//! implemented here and exercised across the stack.

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod table;

/// Greatest common divisor (Euclid). `gcd(0, n) == n`.
pub fn gcd(a: u64, b: u64) -> u64 {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Least common multiple; panics on overflow in debug builds.
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    a / gcd(a, b) * b
}

/// Integer ceiling division.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

/// Human-readable byte count (GiB/MiB/KiB).
pub fn fmt_bytes(b: u64) -> String {
    const G: f64 = (1u64 << 30) as f64;
    const M: f64 = (1u64 << 20) as f64;
    const K: f64 = (1u64 << 10) as f64;
    let b = b as f64;
    if b >= G {
        format!("{:.2} GiB", b / G)
    } else if b >= M {
        format!("{:.2} MiB", b / M)
    } else if b >= K {
        format!("{:.2} KiB", b / K)
    } else {
        format!("{b} B")
    }
}

/// Human-readable duration from seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_lcm_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(0, 7), 7);
        assert_eq!(gcd(7, 0), 7);
        assert_eq!(gcd(1, 1), 1);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(0, 5), 0);
    }

    #[test]
    fn ceil_div_rounds_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 8), 1);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.00 MiB");
        assert_eq!(fmt_bytes(5 << 30), "5.00 GiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0021), "2.100 ms");
        assert_eq!(fmt_secs(0.0000021), "2.1 us");
    }
}
