//! Small deterministic PRNG (xoshiro256**). Used by the property-test
//! harness, workload generators, and synthetic data initialization in the
//! real executor. Deterministic across platforms — benches and tests are
//! reproducible bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference implementation).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift reduction.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f64() as f32 * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple, fine for
    /// weight init at this scale).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_roughly_zero() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.normal()).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
