//! Minimal JSON: a `Value` tree, a recursive-descent parser, and a
//! serializer. Used for cluster/model/plan config files and bench CSV/JSON
//! side outputs (serde is not in the offline vendor set).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Builder helper: `Value::obj([("a", 1.0.into())])`.
    pub fn obj<I: IntoIterator<Item = (&'static str, Value)>>(items: I) -> Value {
        Value::Obj(
            items
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}
impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError {
            at: self.i,
            msg: msg.to_string(),
        })
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{s}'"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or(ParseError {
                        at: self.i,
                        msg: "bad escape".into(),
                    })?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("short \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| ParseError {
                                        at: self.i,
                                        msg: "bad \\u".into(),
                                    })?;
                            let n = u32::from_str_radix(hex, 16).map_err(|_| {
                                ParseError {
                                    at: self.i,
                                    msg: "bad \\u".into(),
                                }
                            })?;
                            self.i += 4;
                            out.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                        }
                        _ => return self.err("bad escape char"),
                    }
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(
                            |_| ParseError {
                                at: start,
                                msg: "invalid utf-8".into(),
                            },
                        )?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or(ParseError {
                at: start,
                msg: "bad number".into(),
            })
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(s: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_val(v: &Value, indent: usize, cur: usize, out: &mut String) {
    let pad = |n: usize, out: &mut String| {
        if indent > 0 {
            out.push('\n');
            out.push_str(&" ".repeat(n));
        }
    };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => esc(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(cur + indent, out);
                write_val(item, indent, cur + indent, out);
            }
            if !a.is_empty() {
                pad(cur, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, item)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(cur + indent, out);
                esc(k, out);
                out.push(':');
                if indent > 0 {
                    out.push(' ');
                }
                write_val(item, indent, cur + indent, out);
            }
            if !m.is_empty() {
                pad(cur, out);
            }
            out.push('}');
        }
    }
}

/// Compact serialization.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_val(v, 0, 0, &mut s);
    s
}

/// Pretty serialization with 2-space indent.
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_val(v, 2, 0, &mut s);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\\nthere\"").unwrap(), Value::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":"gpt3","layers":[24,32],"tflops":1.25,"ok":true,"x":null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("nulL").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Value::Num(42.0)), "42");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
    }
}
